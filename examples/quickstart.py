"""Quickstart: AIvailable in ~40 lines.

Build the paper's heterogeneous 6-node testbed, deploy two models through
the SDAI controller (VRAM-aware placement + HAProxy-style frontend), and
talk to everything through ONE unified client endpoint.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax

from repro.cluster import paper_testbed
from repro.configs import ZOO
from repro.core import (Client, ControllerConfig, ModelCatalog,
                        ModelDemand, SDAIController)
from repro.models import build
from repro.serving import SamplingParams

# --- backend nodes pull weights from this store (the Ollama analogue);
#     reduced() models are tiny so the example runs on CPU in seconds
_params = {}


def param_store(cfg):
    if cfg.name not in _params:
        _params[cfg.name] = build(cfg).init(jax.random.PRNGKey(0))
    return _params[cfg.name]


def main():
    fleet = paper_testbed(param_store=param_store)
    catalog = ModelCatalog()
    llama = dataclasses.replace(ZOO["llama3.2-1b"].reduced(),
                                name="llama3.2-1b")
    gemma = dataclasses.replace(ZOO["gemma3-1b"].reduced(),
                                name="gemma3-1b")
    catalog.register(llama)
    catalog.register(gemma)

    ctrl = SDAIController(fleet, catalog, ControllerConfig())
    print("discovered nodes:", ctrl.discover())

    plan = ctrl.deploy([
        ModelDemand(llama, min_replicas=2, n_slots=2, max_len=48),
        ModelDemand(gemma, min_replicas=2, n_slots=2, max_len=48),
    ])
    print(f"deployed {len(plan.assignments)} instances, "
          f"fleet VRAM utilization {ctrl.fleet_utilization():.1%}")

    client = Client(ctrl)
    print("models behind the unified endpoint:", client.models())
    for model in client.models():
        req = client.generate(model, prompt=[1, 2, 3, 4],
                              sampling=SamplingParams(max_tokens=8))
        print(f"  {model:14s} -> {req.output}  (via {req.node}, "
              f"ttft={req.ttft*1e3:.0f}ms)")

    dash = ctrl.dashboard()
    print(f"dashboard: {dash['connected']}/{dash['total']} agents, "
          f"routing={ {m: len(r) for m, r in dash['routing'].items()} }")


if __name__ == "__main__":
    main()

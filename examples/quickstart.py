"""Quickstart: AIvailable in ~60 lines, on Gateway API v1 + wire v1.

Build the paper's heterogeneous 6-node testbed, deploy two models through
the SDAI controller (VRAM-aware placement + HAProxy-style frontend), and
talk to everything through ONE unified gateway: sync `generate`, async
`submit` + token streaming, the typed admin snapshot — then the same
fleet over the network, via the OpenAI-compatible HTTP service and its
stdlib client (the old `repro.core.Client` shim is deprecated).

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax

from repro.api import Gateway
from repro.api.http import GatewayHTTPServer, HTTPClient, HTTPConfig
from repro.cluster import paper_testbed
from repro.configs import ZOO
from repro.core import (ControllerConfig, ModelCatalog, ModelDemand,
                        SDAIController)
from repro.models import build
from repro.serving import SamplingParams

# --- backend nodes pull weights from this store (the Ollama analogue);
#     reduced() models are tiny so the example runs on CPU in seconds
_params = {}


def param_store(cfg):
    if cfg.name not in _params:
        _params[cfg.name] = build(cfg).init(jax.random.PRNGKey(0))
    return _params[cfg.name]


def main():
    fleet = paper_testbed(param_store=param_store)
    catalog = ModelCatalog()
    llama = dataclasses.replace(ZOO["llama3.2-1b"].reduced(),
                                name="llama3.2-1b")
    gemma = dataclasses.replace(ZOO["gemma3-1b"].reduced(),
                                name="gemma3-1b")
    catalog.register(llama)
    catalog.register(gemma)

    ctrl = SDAIController(fleet, catalog, ControllerConfig())
    print("discovered nodes:", ctrl.discover())

    # max_len fits a chat-templated prompt (the llama3 header format
    # alone costs ~120 byte-tokens) plus decode budget
    plan = ctrl.deploy([
        ModelDemand(llama, min_replicas=2, n_slots=2, max_len=192),
        ModelDemand(gemma, min_replicas=2, n_slots=2, max_len=192),
    ])
    print(f"deployed {len(plan.assignments)} instances, "
          f"fleet VRAM utilization {ctrl.fleet_utilization():.1%}")

    gw = Gateway(ctrl)
    print("models behind the unified endpoint:", gw.models())

    # sync: one blocking call -> frozen GenerationResponse
    resp = gw.generate("llama3.2-1b", prompt=[1, 2, 3, 4],
                       sampling=SamplingParams(max_tokens=8))
    print(f"  sync   {resp.model:14s} -> {list(resp.tokens)}  "
          f"(via {resp.node}, ttft={resp.ttft*1e3:.0f}ms, "
          f"finish={resp.finish_reason})")

    # async + streaming: tokens arrive as engine decode steps produce them
    handle = gw.submit("gemma3-1b", prompt=[5, 6, 7],
                       sampling=SamplingParams(max_tokens=8))
    toks = []
    for ev in handle.stream():
        if ev.type.value == "token":
            toks.append(ev.token)           # incremental delta
    print(f"  stream {handle.response.model:14s} -> {toks}  "
          f"(via {handle.response.node})")

    snap = gw.admin.snapshot()
    print(f"admin snapshot: {snap.connected}/{snap.total} agents, "
          f"routing={ {m: len(r) for m, r in snap.routing.items()} }")

    # the same fleet over the wire: OpenAI-compatible HTTP + SSE
    server = GatewayHTTPServer(gw, HTTPConfig(port=0)).start()
    client = HTTPClient(server.url(), tenant="quickstart")
    print(f"HTTP service on {server.url()}: models={client.models()}")
    out = client.chat("llama3.2-1b", ["hello fleet"], max_tokens=8)
    choice = out["choices"][0]
    print(f"  chat   {out['model']:14s} -> {choice['token_ids']}  "
          f"(finish={choice['finish_reason']}, "
          f"via {out['metadata']['node']})")
    deltas = sum(1 for c in client.chat("gemma3-1b", ["stream please"],
                                        max_tokens=8, stream=True)
                 if c["choices"][0].get("delta", {}).get("token")
                 is not None)
    print(f"  stream gemma3-1b      -> {deltas} SSE token deltas")
    server.stop()


if __name__ == "__main__":
    main()

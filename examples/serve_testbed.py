"""End-to-end serving driver (the paper's kind of workload): the 6-node
heterogeneous testbed serves a batched request stream across the full zoo
while nodes fail and recover mid-flight.

Demonstrates every architectural claim at once:
  * unified client interface (one endpoint, many models/nodes),
  * VRAM-aware placement with int8/int4 fallback on legacy nodes,
  * health-checked least-connection load balancing,
  * replica failover + controller-driven reallocation on node death,
  * elastic re-fill when a node recovers.

    PYTHONPATH=src python examples/serve_testbed.py [--requests 60]
"""
import argparse
import dataclasses
import random

import jax

from repro.api import Gateway
from repro.cluster import paper_testbed
from repro.configs import ZOO
from repro.core import (ControllerConfig, ModelCatalog, ModelDemand,
                        SDAIController)
from repro.models import build
from repro.serving import SamplingParams

_params = {}


def param_store(cfg):
    if cfg.name not in _params:
        _params[cfg.name] = build(cfg).init(jax.random.PRNGKey(0))
    return _params[cfg.name]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rng = random.Random(args.seed)

    fleet = paper_testbed(param_store=param_store)
    catalog = ModelCatalog()
    # two live (tiny) models + the big accounted zoo from paper Table 1
    live = {}
    for name in ("llama3.2-1b", "gemma3-1b"):
        cfg = dataclasses.replace(ZOO[name].reduced(), name=name)
        live[name] = cfg
        catalog.register(cfg)
    for name in ("deepseek-r1-7b", "qwen3-8b", "deepseek-r1-1.5b",
                 "nomic-embed-text"):
        catalog.register(ZOO[name])

    ctrl = SDAIController(fleet, catalog, ControllerConfig())
    ctrl.discover()
    plan = ctrl.deploy(
        [ModelDemand(c, min_replicas=2, n_slots=2, max_len=48)
         for c in live.values()] +
        [ModelDemand(ZOO["deepseek-r1-7b"], min_replicas=2),
         ModelDemand(ZOO["qwen3-8b"], min_replicas=1),
         ModelDemand(ZOO["deepseek-r1-1.5b"], min_replicas=2),
         ModelDemand(ZOO["nomic-embed-text"], min_replicas=2)])
    print(f"placed {len(plan.assignments)} instances "
          f"(util {ctrl.fleet_utilization():.1%}); quantized: "
          f"{sum(1 for a in plan.assignments if a.quantize)}")

    gw = Gateway(ctrl)
    models = gw.models()
    ok = fail = 0
    failed_at = recovered_at = None
    victim = None
    for i in range(args.requests):
        # failure injection at 1/3, recovery at 2/3 of the workload
        if i == args.requests // 3:
            victim = rng.choice([n for n in fleet.nodes
                                 if fleet.nodes[n].alive])
            fleet.fail_node(victim)
            ctrl.tick()
            failed_at = i
            print(f"[{i}] !! node {victim} DIED -> controller "
                  f"reallocated; routing now "
                  f"{ {m: len(r) for m, r in ctrl.frontend.routing_table().items()} }")
        if i == 2 * args.requests // 3 and victim:
            fleet.recover_node(victim)
            ctrl.tick()
            recovered_at = i
            print(f"[{i}] node {victim} RECOVERED -> re-filled")
        model = rng.choice(models)
        resp = gw.generate(model, [rng.randrange(64) for _ in range(4)],
                           SamplingParams(max_tokens=4))
        if resp.ok:
            ok += 1
        else:
            fail += 1
    print(f"\navailability: {ok}/{ok+fail} = {ok/(ok+fail):.1%} "
          f"(node died at req {failed_at}, recovered at {recovered_at})")
    print("frontend stats:", ctrl.frontend.stats)
    ev = [e.kind for e in ctrl.bus.events]
    print("controller events:", {k: ev.count(k) for k in sorted(set(ev))})


if __name__ == "__main__":
    main()

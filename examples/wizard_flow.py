"""The SDAI Configuration Wizard flow (paper §5): Select -> Configure ->
Generate, printing the agent cards, model-capacity panel, configuration
overview, and the rendered HAProxy-style frontend config.

    PYTHONPATH=src python examples/wizard_flow.py
"""
import json

from repro.cluster import paper_testbed
from repro.configs import ZOO
from repro.core import (ConfigWizard, ControllerConfig, ModelCatalog,
                        SDAIController, WizardConfig, WizardModelChoice,
                        WizardSelection)


def main():
    fleet = paper_testbed()
    catalog = ModelCatalog()
    for name in ("deepseek-r1-7b", "qwen3-8b", "llama3.2-1b",
                 "gemma3-1b", "nomic-embed-text", "mxbai-embed-large"):
        catalog.register(ZOO[name])
    ctrl = SDAIController(fleet, catalog, ControllerConfig())
    ctrl.discover()
    wiz = ConfigWizard(ctrl)

    print("=" * 64)
    print("STAGE 1 - SELECT AGENTS")
    for card in wiz.list_agents():
        print(f"  [{card['status']:8s}] {card['node_id']:6s} "
              f"{card['class']:12s} {card['toolkit']:7s} "
              f"({card['year']}) free={card['hbm_free_gb']:.1f} GB")

    print("\n  model capacity on node6 (RX 6800 analogue):")
    cap = wiz.model_capacity("deepseek-r1-7b", "node6")
    for q, b in cap["bytes_per_instance"].items():
        print(f"    deepseek-r1-7b {q or 'bf16':5s}: {b/2**30:.2f} GiB")
    print(f"    -> precision={cap['precision'] or 'bf16'}, "
          f"max_instances={cap['max_instances']}")

    print("\n" + "=" * 64)
    print("STAGE 2 - CONFIGURE (models, replicas, ports)")
    wcfg = WizardConfig(
        selection=WizardSelection(agents=[a["node_id"]
                                          for a in wiz.list_agents()]),
        models=[
            WizardModelChoice("deepseek-r1-7b", replicas=2),
            WizardModelChoice("qwen3-8b", replicas=1),
            WizardModelChoice("llama3.2-1b", replicas=3),
            WizardModelChoice("nomic-embed-text", replicas=2,
                              port=11500),
        ])
    gen = wiz.generate(wcfg)

    print("\n" + "=" * 64)
    print("STAGE 3 - GENERATE: configuration overview")
    ov = gen["overview"]
    print(json.dumps({k: v for k, v in ov.items()
                      if k != "frontend_config"}, indent=2))
    print("\n--- generated frontend config " + "-" * 30)
    print(ov["frontend_config"])

    keys = wiz.apply(gen)
    print(f"\napplied: {len(keys)} instances running; fleet util "
          f"{ctrl.fleet_utilization():.1%}")


if __name__ == "__main__":
    main()

"""Training driver: the xLSTM-125M assigned architecture on the synthetic
LM pipeline, with checkpoint/restart fault tolerance.

Full-size run (125M params, a few hundred steps) is sized for a real
accelerator; --tiny runs the reduced config end-to-end on CPU in ~a minute,
exercising the identical code path (scan-over-layers, remat, AdamW,
atomic checkpoints, crash-resume).

    PYTHONPATH=src python examples/train_100m.py --tiny --steps 60
    PYTHONPATH=src python examples/train_100m.py --steps 300   # 125M
"""
import argparse

from repro.configs import ARCHS
from repro.training.data import DataConfig
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_train100m")
    ap.add_argument("--crash-at", type=int, default=0,
                    help="simulate a crash after N steps, then resume")
    args = ap.parse_args()

    cfg = ARCHS["xlstm-125m"].reduced() if args.tiny \
        else ARCHS["xlstm-125m"]
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
    ocfg = AdamWConfig(lr=3e-3 if args.tiny else 6e-4, warmup_steps=10,
                       total_steps=args.steps)
    print(f"arch={cfg.name} params~{cfg.num_params()/1e6:.1f}M "
          f"tokens/step={dc.batch * dc.seq_len}")

    if args.crash_at:
        print(f"-- phase 1: train to step {args.crash_at}, then 'crash'")
        t = Trainer(cfg, dc, TrainConfig(steps=args.crash_at,
                                         ckpt_every=max(args.crash_at // 2,
                                                        1),
                                         ckpt_dir=args.ckpt,
                                         log_every=10), ocfg)
        t.run()
        print("-- phase 2: restart, resume from latest checkpoint")

    t = Trainer(cfg, dc, TrainConfig(steps=args.steps,
                                     ckpt_every=max(args.steps // 4, 1),
                                     ckpt_dir=args.ckpt, log_every=10),
                ocfg)
    result = t.run()
    if result["resumed_from"]:
        print(f"resumed from step {result['resumed_from']}")
    first = result["history"][0]["loss"] if result["history"] else None
    last = result["history"][-1]["loss"] if result["history"] else None
    print(f"loss {first:.4f} -> {last:.4f} over "
          f"{args.steps - result['resumed_from']} steps "
          f"({result['wall_s']:.1f}s)")
    if not result["resumed_from"] and args.steps >= 60:
        assert last < first, "training must reduce loss on structured data"


if __name__ == "__main__":
    main()

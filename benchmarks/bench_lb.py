"""Load-balancing fairness + straggler mitigation through Gateway API v1 —
the Service Frontend claims: leastconn spread (coefficient of variation
across replicas) and traffic kept away from stragglers."""
from __future__ import annotations

import statistics

from repro.api import Gateway
from repro.cluster import BackendNode, Fleet
from repro.configs import ZOO
from repro.core import (ModelCatalog, ReplicaInfo, ReplicaKey,
                        SDAIController)
from repro.serving import SamplingParams

MODEL = "deepseek-r1-7b"


def _stack(n=6):
    fleet = Fleet([BackendNode(f"n{i}", "v5e-1") for i in range(n)])
    cfg = ZOO[MODEL]
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.discover()
    for node in fleet.nodes.values():
        inst = node.deploy(cfg, quantize="int8", real=False)
        ctrl.replicas.add(ReplicaInfo(
            ReplicaKey(node.node_id, inst.instance_id),
            cfg.name, "int8", 4, 2048, inst.bytes))
    return ctrl, Gateway(ctrl)


def run(n_requests: int = 300):
    rows = []
    ctrl, gw = _stack(6)
    for _ in range(n_requests):
        resp = gw.generate(MODEL, [1], SamplingParams(max_tokens=1))
        assert resp.ok, resp.error
    counts = list(ctrl.frontend.stats.per_replica.values())
    cv = statistics.pstdev(counts) / statistics.mean(counts)
    rows.append(("lb_fairness_cv", 0.0, f"{cv:.4f}"))

    # straggler scenario: one replica 100x slower
    ctrl, gw = _stack(6)
    keys = [str(r.key) for r in ctrl.replicas.for_model(MODEL)]
    for _ in range(20):
        ctrl.monitor.observe_latency(keys[0], 1.0)
        for k in keys[1:]:
            ctrl.monitor.observe_latency(k, 0.01)
    for _ in range(n_requests):
        gw.generate(MODEL, [1], SamplingParams(max_tokens=1))
    slow_share = ctrl.frontend.stats.per_replica.get(keys[0], 0) \
        / n_requests
    rows.append(("lb_straggler_traffic_share", 0.0,
                 f"{slow_share:.4f}"))
    return rows

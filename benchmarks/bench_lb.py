"""Load-balancing fairness + straggler mitigation — the Service Frontend
claims: leastconn spread (coefficient of variation across replicas) and
traffic kept away from stragglers."""
from __future__ import annotations

import statistics

from repro.cluster import Fleet, BackendNode
from repro.configs import ZOO
from repro.core.frontend import ServiceFrontend, FrontendConfig
from repro.core.health import HealthMonitor, HealthConfig
from repro.core.registry import ReplicaInfo, ReplicaKey, ReplicaRegistry
from repro.serving.request import Request
from repro.serving.sampler import SamplingParams


def _stack(n=6):
    fleet = Fleet([BackendNode(f"n{i}", "v5e-1") for i in range(n)])
    monitor = HealthMonitor(HealthConfig())
    replicas = ReplicaRegistry()
    cfg = ZOO["deepseek-r1-7b"]
    for node in fleet.nodes.values():
        inst = node.deploy(cfg, quantize="int8", real=False)
        replicas.add(ReplicaInfo(ReplicaKey(node.node_id,
                                            inst.instance_id),
                                 cfg.name, "int8", 4, 2048, inst.bytes))
        monitor.observe_heartbeat(node.node_id)
    return fleet, monitor, replicas, \
        ServiceFrontend(fleet, replicas, monitor, FrontendConfig())


def run(n_requests: int = 300):
    rows = []
    fleet, mon, reps, fe = _stack(6)
    for _ in range(n_requests):
        fe.submit(Request(model="deepseek-r1-7b", prompt=[1],
                          sampling=SamplingParams(max_tokens=1)))
    counts = list(fe.stats.per_replica.values())
    cv = statistics.pstdev(counts) / statistics.mean(counts)
    rows.append(("lb_fairness_cv", 0.0, f"{cv:.4f}"))

    # straggler scenario: one replica 100x slower
    fleet, mon, reps, fe = _stack(6)
    keys = [str(r.key) for r in reps.for_model("deepseek-r1-7b")]
    for _ in range(20):
        mon.observe_latency(keys[0], 1.0)
        for k in keys[1:]:
            mon.observe_latency(k, 0.01)
    for _ in range(n_requests):
        fe.submit(Request(model="deepseek-r1-7b", prompt=[1],
                          sampling=SamplingParams(max_tokens=1)))
    slow_share = fe.stats.per_replica.get(keys[0], 0) / n_requests
    rows.append(("lb_straggler_traffic_share", 0.0,
                 f"{slow_share:.4f}"))
    return rows

"""Benchmark harness — one bench per paper claim/figure (the paper gives no
quantitative tables; §6 names the claims we quantify):

  availability  — HA under failure injection (+ no-HA baseline)
  placement     — VRAM utilization vs naive first-fit, 6/100/1000 nodes
  lb            — frontend fairness + straggler mitigation
  serving       — live engine tokens/s + TTFT (bf16 vs int8-at-rest)
  kernels       — hot-spot kernels: portable-path timing + VMEM budgets
  compression   — gradient wire-byte ratio + convergence parity
  roofline      — per (arch x shape x mesh) dry-run roofline table

Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_availability, bench_placement, bench_lb,
                            bench_serving, bench_kernels,
                            bench_compression, bench_roofline)
    suites = [
        ("availability", bench_availability.run),
        ("placement", bench_placement.run),
        ("lb", bench_lb.run),
        ("serving", bench_serving.run),
        ("kernels", bench_kernels.run),
        ("compression", bench_compression.run),
        ("roofline", bench_roofline.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        try:
            for row in fn():
                n, us, derived = row
                print(f"{n},{us:.2f},{derived}", flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            traceback.print_exc()
            print(f"{name},0.00,SUITE_ERROR:{type(e).__name__}",
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

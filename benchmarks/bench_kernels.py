"""Kernel microbench: portable-path wall time per call at serving-relevant
shapes (CPU measurement of the jnp path the dry-run compiles) + the Pallas
tile VMEM accounting that justifies the chosen BlockSpecs on TPU."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn_lib
from repro.serving.quantization import quantize_array, \
    quantized_matmul_ref


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
        else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)

    # prefill attention (chunked-flash portable path)
    B, H, K, S, hd = 1, 8, 2, 2048, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, hd)), jnp.float32)
    f = jax.jit(lambda q, k, v: attn_lib.chunked_attention(
        q, k, v, chunk=512))
    us = _time(f, q, k, k)
    flops = 4 * B * H * S * S * hd
    rows.append(("kernel_flash_prefill_2k", us,
                 f"gflops_cpu={flops/us/1e3:.2f}"))

    # decode attention against a 16k cache
    S = 16384
    q1 = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B, S, K, hd)), jnp.float32)
    pos = jnp.asarray([S - 1], jnp.int32)
    fd = jax.jit(lambda q, k, v, p: attn_lib.decode_attention(q, k, v, p))
    us = _time(fd, q1, kc, kc, pos)
    byts = 2 * B * S * K * hd * 4
    rows.append(("kernel_flash_decode_16k", us,
                 f"gbps_cpu={byts/us/1e3:.2f}"))

    # int8 dequant matmul
    M, Kd, N = 256, 2048, 2048
    x = jnp.asarray(rng.standard_normal((M, Kd)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((Kd, N)) * 0.1, jnp.float32)
    qd = quantize_array(w, 8)
    fq = jax.jit(lambda x, q, s: quantized_matmul_ref(x, q, s))
    us = _time(fq, x, qd["__q__"], qd["scale"])
    rows.append(("kernel_int8_matmul", us,
                 f"gflops_cpu={2*M*Kd*N/us/1e3:.2f}"))

    # Pallas tile VMEM budgets (the BlockSpec justification, bytes)
    bq = bk = 128
    hd = 128
    flash_vmem = (bq * hd * 2 + 2 * bk * hd * 2 + bq * bk * 4
                  + bq * hd * 4 + 2 * bq * 4)
    rows.append(("kernel_flash_vmem_tile", 0.0,
                 f"bytes={flash_vmem} (<< 16MiB VMEM)"))
    g, bkd = 8, 256
    dec_vmem = (g * hd * 2 + 2 * bkd * hd * 2 + g * bkd * 4
                + g * hd * 4 + 2 * g * 4)
    rows.append(("kernel_decode_vmem_tile", 0.0, f"bytes={dec_vmem}"))
    bm = bn = bkq = 128
    mm_vmem = bm * bkq * 2 + bkq * bn * 1 + bm * bn * 4 + bn * 4
    rows.append(("kernel_int8_vmem_tile", 0.0, f"bytes={mm_vmem}"))
    return rows

"""Placement studies: VRAM utilization AND heterogeneous cost.

Study 1 (utilization): quantifies the paper's 'fully exploit each node's
VRAM' objective: smart (BFD + quant fallback + fill) vs naive first-fit,
at testbed and 100/1000-node scales; plus placement latency.  Each
variant reports its *own* measured latency (naive used to claim
``dt_us=0.0``) and utilization is a structured derived value, not packed
into an info string.

Study 2 (cost): the heterogeneity story — cost-optimal placement
(`place_cost_optimal`, ranking candidate nodes by modeled cost-per-token
from the per-class perf model) vs the class-blind VRAM-only `place()`,
on the paper testbed and the mixed 100-node fleet.  Both solvers place
the same demand set with fill disabled, so equal assignment counts make
the cost-per-token comparison apples-to-apples.  Results land in the
``placement`` section of ``BENCH_serving.json`` and are gated in CI via
``check_regression.py --only placement``.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.cluster import paper_testbed, scale_fleet
from repro.configs import ZOO
from repro.core.perfmodel import PerfModel
from repro.core.placement import (ModelDemand, NodeSpec, as_vram_nodes,
                                  place, place_cost_optimal, place_naive,
                                  plan_cost_per_token, plan_utilization)

DEMANDS = [
    ("deepseek-r1-7b", 2, 6), ("qwen3-8b", 1, 4),
    ("deepseek-r1-8b", 1, 4), ("llama3.2-3b", 2, 8),
    ("llama3.2-1b", 2, 12), ("gemma3-1b", 2, 12),
    ("qwen3-4b", 1, 6), ("nomic-embed-text", 2, 12),
]

# heterogeneous cost study: a mixed workload — high-traffic short-chat
# models plus long-context tails (the long-bucket demands are what a
# class-blind packer mis-places onto slow-BW or overpriced nodes)
COST_DEMANDS = [
    ("llama3.2-1b", 2, 30, 2048, 3.0, (("short", 0.7), ("medium", 0.3))),
    ("qwen3-1.7b", 2, 30, 2048, 2.0,
     (("short", 0.5), ("medium", 0.3), ("long", 0.2))),
    ("llama3.2-3b", 2, 20, 4096, 1.0, (("medium", 0.3), ("long", 0.7))),
    ("deepseek-r1-7b", 1, 10, 4096, 1.0, (("long", 1.0),)),
]


def _nodes_of(fleet):
    return {nid: (n.hbm_budget, n.klass.legacy)
            for nid, n in fleet.nodes.items()}


def _specs_of(fleet):
    return {nid: NodeSpec(n.hbm_budget, n.klass)
            for nid, n in fleet.nodes.items()}


def _merge_report(report: dict, json_path: str = "BENCH_serving.json"):
    """Merge the placement section into the serving bench report —
    creating the file when this study runs standalone (its own CI job),
    augmenting it when run after bench_serving."""
    path = Path(json_path)
    try:
        merged = json.loads(path.read_text())
    except (FileNotFoundError, ValueError):
        merged = {}
    merged["placement"] = report
    path.write_text(json.dumps(merged, indent=2))


def _cost_study():
    """Cost-optimal vs VRAM-only on heterogeneous fleets -> rows +
    structured report (the CI-gated artifact)."""
    perf = PerfModel()
    demands = [ModelDemand(ZOO[m], min_replicas=r, max_replicas=cap,
                           max_len=ml, weight=w, bucket_mix=mix)
               for m, r, cap, ml, w, mix in COST_DEMANDS]
    rows, report = [], {}
    for label, fleet in [("testbed6", paper_testbed()),
                         ("fleet100", scale_fleet(100, seed=1))]:
        specs = _specs_of(fleet)
        vram_nodes = as_vram_nodes(specs)
        # fill=False on both sides: identical demand floors, so both
        # solvers place the same replica count and the comparison is at
        # equal placed demand
        t0 = time.perf_counter()
        p_vram = place(vram_nodes, demands, fill=False)
        dt_vram_us = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        p_cost = place_cost_optimal(specs, demands, perf, fill=False)
        dt_cost_us = (time.perf_counter() - t0) * 1e6
        cpt_vram = plan_cost_per_token(p_vram, specs, demands, perf)
        cpt_cost = plan_cost_per_token(p_cost, specs, demands, perf)
        advantage = 1.0 - cpt_cost / cpt_vram if cpt_vram > 0 else 0.0
        equal = (len(p_vram.assignments) == len(p_cost.assignments)
                 and not p_vram.unplaced and not p_cost.unplaced)
        report[label] = {
            "cost_per_token_vram": cpt_vram,
            "cost_per_token_cost_optimal": cpt_cost,
            "cost_advantage": advantage,
            "placed_vram": len(p_vram.assignments),
            "placed_cost_optimal": len(p_cost.assignments),
            "equal_demand": equal,
            "utilization_vram": plan_utilization(p_vram, vram_nodes),
            "utilization_cost_optimal":
                plan_utilization(p_cost, vram_nodes),
            "dt_vram_us": dt_vram_us,
            "dt_cost_optimal_us": dt_cost_us,
        }
        rows.append((f"placement_cpt_vram_{label}", dt_vram_us,
                     f"{cpt_vram:.4e}"))
        rows.append((f"placement_cpt_cost_{label}", dt_cost_us,
                     f"{cpt_cost:.4e}"))
        rows.append((f"placement_cost_advantage_{label}", 0.0,
                     f"{advantage:.4f}"))
    return rows, report


def run():
    rows = []
    for label, fleet, scale in [
            ("testbed6", paper_testbed(), 1),
            ("fleet100", scale_fleet(100, seed=1), 8),
            ("fleet1000", scale_fleet(1000, seed=2), 60)]:
        nodes = _nodes_of(fleet)
        demands = [ModelDemand(ZOO[m], min_replicas=min(r * scale,
                                                        len(nodes)),
                               max_replicas=cap * scale)
                   for m, r, cap in DEMANDS]
        t0 = time.perf_counter()
        smart = place(nodes, demands)
        dt_smart_us = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        naive = place_naive(nodes, demands)
        dt_naive_us = (time.perf_counter() - t0) * 1e6
        u_s = plan_utilization(smart, nodes)
        u_n = plan_utilization(naive, nodes)
        rows.append((f"placement_util_smart_{label}", dt_smart_us,
                     f"{u_s:.4f}"))
        rows.append((f"placement_util_naive_{label}", dt_naive_us,
                     f"{u_n:.4f}"))
        rows.append((f"placement_unplaced_{label}", 0.0,
                     f"smart={len(smart.unplaced)};"
                     f"naive={len(naive.unplaced)}"))
    cost_rows, report = _cost_study()
    rows.extend(cost_rows)
    _merge_report(report)
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name:36s} {us:12.1f} us/call   {derived}")

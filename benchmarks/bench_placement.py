"""VRAM-utilization of placement — quantifies the paper's 'fully exploit
each node's VRAM' objective: smart (BFD + quant fallback + fill) vs naive
first-fit, at testbed and 100/1000-node scales; plus placement latency."""
from __future__ import annotations

import time

from repro.cluster import paper_testbed, scale_fleet
from repro.configs import ZOO
from repro.core.placement import (ModelDemand, place, place_naive,
                                  plan_utilization)

DEMANDS = [
    ("deepseek-r1-7b", 2, 6), ("qwen3-8b", 1, 4),
    ("deepseek-r1-8b", 1, 4), ("llama3.2-3b", 2, 8),
    ("llama3.2-1b", 2, 12), ("gemma3-1b", 2, 12),
    ("qwen3-4b", 1, 6), ("nomic-embed-text", 2, 12),
]


def _nodes_of(fleet):
    return {nid: (n.hbm_budget, n.klass.legacy)
            for nid, n in fleet.nodes.items()}


def run():
    rows = []
    for label, fleet, scale in [
            ("testbed6", paper_testbed(), 1),
            ("fleet100", scale_fleet(100, seed=1), 8),
            ("fleet1000", scale_fleet(1000, seed=2), 60)]:
        nodes = _nodes_of(fleet)
        demands = [ModelDemand(ZOO[m], min_replicas=min(r * scale,
                                                        len(nodes)),
                               max_replicas=cap * scale)
                   for m, r, cap in DEMANDS]
        t0 = time.perf_counter()
        smart = place(nodes, demands)
        dt_us = (time.perf_counter() - t0) * 1e6
        naive = place_naive(nodes, demands)
        u_s = plan_utilization(smart, nodes)
        u_n = plan_utilization(naive, nodes)
        rows.append((f"placement_util_smart_{label}", dt_us,
                     f"{u_s:.4f}"))
        rows.append((f"placement_util_naive_{label}", 0.0, f"{u_n:.4f}"))
        rows.append((f"placement_unplaced_{label}", 0.0,
                     f"smart={len(smart.unplaced)};"
                     f"naive={len(naive.unplaced)}"))
    return rows

"""Live serving throughput/latency on CPU (tiny model) through Gateway API
v1, plus the device-resident hot-path study: fused K-step decode vs
single-step dispatch (dispatches/token, host syncs/token, tok/s, p50/p95
step time).  Writes ``BENCH_serving.json`` for CI's run-only smoke check.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax

from repro.api import Gateway, GenerationRequest
from repro.cluster import BackendNode, Fleet
from repro.configs import ARCHS
from repro.core import (ModelCatalog, ReplicaInfo, ReplicaKey,
                        SDAIController)
from repro.models import build
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           SamplingParams)

_cache = {}


def _store(cfg):
    if "p" not in _cache:
        _cache["p"] = build(cfg).init(jax.random.PRNGKey(0))
    return _cache["p"]


def _stack(quantize=""):
    """One-node fleet serving one (optionally quantized) live engine,
    fronted by the unified gateway."""
    cfg = ARCHS["olmo-1b"].reduced()
    fleet = Fleet([BackendNode("n0", "v5e-1", param_store=_store)])
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.discover()
    node = fleet.nodes["n0"]
    inst = node.deploy(cfg, quantize=quantize, n_slots=4, max_len=64)
    ctrl.replicas.add(ReplicaInfo(ReplicaKey("n0", inst.instance_id),
                                  cfg.name, quantize, 4, 64, inst.bytes))
    return cfg, inst, Gateway(ctrl)


def _pct(sorted_vals, q):
    if not sorted_vals:
        return 0.0
    i = min(int(q * len(sorted_vals)), len(sorted_vals) - 1)
    return sorted_vals[i]


def _fused_study(n_requests: int = 8, max_tokens: int = 32,
                 ks=(1, 8)) -> dict:
    """Engine-level dispatch-discipline comparison: same workload, same
    params, K=1 (the per-token legacy loop) vs fused K-step blocks.
    Counters are deterministic; timings are informational."""
    cfg = ARCHS["olmo-1b"].reduced()
    params = _store(cfg)
    out = {}
    for k in ks:
        eng = InferenceEngine(cfg, params,
                              EngineConfig(n_slots=4, max_len=64,
                                           decode_block=k))
        # compile outside the clock: 5 warmups cover both admission batch
        # shapes the run will see (a full group of 4 and a tail of 1)
        for i in range(5):
            eng.submit(Request(model=cfg.name, prompt=[1, 2, 3],
                               sampling=SamplingParams(max_tokens=2)))
        eng.run_until_done()
        base = eng.perf_stats()
        reqs = [Request(model=cfg.name, prompt=[1, 2, 3 + (i % 5)],
                        sampling=SamplingParams(max_tokens=max_tokens))
                for i in range(n_requests)]
        for r in reqs:
            eng.submit(r)
        step_s = []
        t0 = time.perf_counter()
        while eng.slot_req or eng.scheduler.depth:
            s0 = time.perf_counter()
            eng.step()
            step_s.append(time.perf_counter() - s0)
        wall = time.perf_counter() - t0
        stats = eng.perf_stats()
        toks = stats["tokens"] - base["tokens"]
        disp = stats["dispatches"] - base["dispatches"]
        syncs = stats["host_syncs"] - base["host_syncs"]
        step_s.sort()
        out[f"k{k}"] = {
            "decode_block": k,
            "tokens": toks,
            "dispatches": disp,
            "host_syncs": syncs,
            "dispatches_per_token": disp / max(toks, 1),
            "host_syncs_per_token": syncs / max(toks, 1),
            "tok_per_s": toks / wall if wall > 0 else 0.0,
            "p50_step_ms": _pct(step_s, 0.50) * 1e3,
            "p95_step_ms": _pct(step_s, 0.95) * 1e3,
            "prefill_traces": stats["prefill_traces"],
        }
    lo, hi = f"k{ks[0]}", f"k{ks[-1]}"
    out["reduction"] = {
        "dispatches_per_token":
            out[lo]["dispatches_per_token"] /
            max(out[hi]["dispatches_per_token"], 1e-12),
        "host_syncs_per_token":
            out[lo]["host_syncs_per_token"] /
            max(out[hi]["host_syncs_per_token"], 1e-12),
    }
    return out


def run(n_requests: int = 12, max_tokens: int = 24,
        json_path: str = "BENCH_serving.json"):
    rows = []
    report = {"gateway": {}}
    for quant in ("", "int8"):
        cfg, inst, gw = _stack(quant)
        # warm-up/compile
        gw.generate(cfg.name, [1, 2, 3],
                    SamplingParams(max_tokens=2))
        reqs = [GenerationRequest(model=cfg.name, prompt=(1, 2, 3, i),
                                  sampling=SamplingParams(
                                      max_tokens=max_tokens))
                for i in range(n_requests)]
        t0 = time.perf_counter()
        resps = gw.generate_batch(reqs)
        dt = time.perf_counter() - t0
        assert all(r.ok for r in resps), [r.error for r in resps if not r.ok]
        toks = sum(len(r.tokens) for r in resps)
        ttfts = [r.ttft for r in resps if r.ttft]
        tag = quant or "bf16"
        rows.append((f"serving_decode_{tag}", dt / toks * 1e6,
                     f"tok_per_s={toks/dt:.1f}"))
        rows.append((f"serving_ttft_{tag}",
                     sum(ttfts) / len(ttfts) * 1e6,
                     f"n={len(ttfts)}"))
        mem = inst.engine.memory_report()
        rows.append((f"serving_mem_{tag}", 0.0,
                     f"params={mem['param_bytes']};"
                     f"cache={mem['cache_bytes']}"))
        report["gateway"][tag] = {
            "tok_per_s": toks / dt,
            "mean_ttft_s": sum(ttfts) / len(ttfts) if ttfts else None,
            "engine": inst.engine.perf_stats(),
        }
        if not quant:
            # streaming path: per-event overhead vs blocking batch
            t0 = time.perf_counter()
            n_events = sum(
                1 for _ in gw.stream(cfg.name, [1, 2, 3],
                                     SamplingParams(
                                         max_tokens=max_tokens)))
            dt = time.perf_counter() - t0
            rows.append(("serving_stream_event", dt / n_events * 1e6,
                         f"events={n_events}"))
    ks = (1, 8)
    fused = _fused_study(ks=ks)
    report["fused"] = fused
    red = fused["reduction"]
    hi = f"k{ks[-1]}"
    rows.append((f"serving_fused_{hi}_tok_per_s", 0.0,
                 f"tok_per_s={fused[hi]['tok_per_s']:.1f};"
                 f"p50_step_ms={fused[hi]['p50_step_ms']:.2f};"
                 f"p95_step_ms={fused[hi]['p95_step_ms']:.2f}"))
    rows.append(("serving_fused_dispatch_reduction", 0.0,
                 f"dispatches_per_token_x{red['dispatches_per_token']:.1f};"
                 f"host_syncs_per_token_x{red['host_syncs_per_token']:.1f}"))
    Path(json_path).write_text(json.dumps(report, indent=2))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")

"""Live serving throughput/latency on CPU (tiny model) through Gateway API
v1: batched decode tokens/s, TTFT from frozen responses, streaming-path
overhead, and the quantized-engine memory ratio."""
from __future__ import annotations

import time

import jax

from repro.api import Gateway, GenerationRequest
from repro.cluster import BackendNode, Fleet
from repro.configs import ARCHS
from repro.core import (ModelCatalog, ReplicaInfo, ReplicaKey,
                        SDAIController)
from repro.models import build
from repro.serving import SamplingParams

_cache = {}


def _store(cfg):
    if "p" not in _cache:
        _cache["p"] = build(cfg).init(jax.random.PRNGKey(0))
    return _cache["p"]


def _stack(quantize=""):
    """One-node fleet serving one (optionally quantized) live engine,
    fronted by the unified gateway."""
    cfg = ARCHS["olmo-1b"].reduced()
    fleet = Fleet([BackendNode("n0", "v5e-1", param_store=_store)])
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.discover()
    node = fleet.nodes["n0"]
    inst = node.deploy(cfg, quantize=quantize, n_slots=4, max_len=64)
    ctrl.replicas.add(ReplicaInfo(ReplicaKey("n0", inst.instance_id),
                                  cfg.name, quantize, 4, 64, inst.bytes))
    return cfg, inst, Gateway(ctrl)


def run(n_requests: int = 12, max_tokens: int = 24):
    rows = []
    for quant in ("", "int8"):
        cfg, inst, gw = _stack(quant)
        # warm-up/compile
        gw.generate(cfg.name, [1, 2, 3],
                    SamplingParams(max_tokens=2))
        reqs = [GenerationRequest(model=cfg.name, prompt=(1, 2, 3, i),
                                  sampling=SamplingParams(
                                      max_tokens=max_tokens))
                for i in range(n_requests)]
        t0 = time.perf_counter()
        resps = gw.generate_batch(reqs)
        dt = time.perf_counter() - t0
        assert all(r.ok for r in resps), [r.error for r in resps if not r.ok]
        toks = sum(len(r.tokens) for r in resps)
        ttfts = [r.ttft for r in resps if r.ttft]
        tag = quant or "bf16"
        rows.append((f"serving_decode_{tag}", dt / toks * 1e6,
                     f"tok_per_s={toks/dt:.1f}"))
        rows.append((f"serving_ttft_{tag}",
                     sum(ttfts) / len(ttfts) * 1e6,
                     f"n={len(ttfts)}"))
        mem = inst.engine.memory_report()
        rows.append((f"serving_mem_{tag}", 0.0,
                     f"params={mem['param_bytes']};"
                     f"cache={mem['cache_bytes']}"))
        if not quant:
            # streaming path: per-event overhead vs blocking batch
            t0 = time.perf_counter()
            n_events = sum(
                1 for _ in gw.stream(cfg.name, [1, 2, 3],
                                     SamplingParams(
                                         max_tokens=max_tokens)))
            dt = time.perf_counter() - t0
            rows.append(("serving_stream_event", dt / n_events * 1e6,
                         f"events={n_events}"))
    return rows

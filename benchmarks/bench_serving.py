"""Live serving throughput/latency on CPU (tiny model) through Gateway API
v1, plus four studies:

* device-resident hot path — fused K-step decode vs single-step dispatch
  (dispatches/token, host syncs/token, tok/s, p50/p95 step time),
* paged KV cache — paged pool with oversubscribed slots vs contiguous
  per-slot strips at the *same KV VRAM budget*: concurrent-slot
  occupancy, kv-page utilization, preemptions, tok/s,
* paged attention — the decode hot path reading the page pool directly
  through the device page table vs gather/scatter logical views: KV
  bytes moved per token, token-identical outputs, equal dispatches,
* speculative decoding — on-device n-gram propose + single-dispatch
  greedy verify vs per-token decode: accepted tokens per verify
  dispatch, dispatch/sync reduction, token-identical outputs,
* continuous runtime — >= 4 concurrent tenants across >= 2 nodes driven
  entirely by background pump threads (zero caller-side pumps), with
  per-tenant token-bucket rejections and load-driven controller scale-up,
* prefix cache — an 80%-shared-prefix workload (one system prompt, many
  private tails) with the hierarchical KV cache on vs off: cache-hit
  rate, prefill dispatch tokens, TTFT, token-identical outputs,
* http wire — requests/s and p95 TTFT through the OpenAI-compatible
  socket service vs the in-process Gateway (informational).

Writes ``BENCH_serving.json``; CI gates ``dispatches_per_token`` /
``host_syncs_per_token`` (lower is better), the paged study's
``kv_page_utilization`` and the prefix study's ``prefix_hit_rate``
(higher is better) against ``benchmarks/baseline_serving.json`` (soft
20% regression budget — wall-clock numbers stay informational).
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path

import jax

from repro.api import (Gateway, GenerationRequest, RuntimeConfig,
                       TenantQuota)
from repro.cluster import BackendNode, Fleet
from repro.configs import ARCHS
from repro.core import (ModelCatalog, ModelDemand, ReplicaInfo, ReplicaKey,
                        SDAIController)
from repro.models import build
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           SamplingParams)

_cache = {}


def _store(cfg):
    if "p" not in _cache:
        _cache["p"] = build(cfg).init(jax.random.PRNGKey(0))
    return _cache["p"]


def _stack(quantize=""):
    """One-node fleet serving one (optionally quantized) live engine,
    fronted by the unified gateway."""
    cfg = ARCHS["olmo-1b"].reduced()
    fleet = Fleet([BackendNode("n0", "v5e-1", param_store=_store)])
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.discover()
    node = fleet.nodes["n0"]
    inst = node.deploy(cfg, quantize=quantize, n_slots=4, max_len=64)
    ctrl.replicas.add(ReplicaInfo(ReplicaKey("n0", inst.instance_id),
                                  cfg.name, quantize, 4, 64, inst.bytes))
    return cfg, inst, Gateway(ctrl)


def _pct(sorted_vals, q):
    if not sorted_vals:
        return 0.0
    i = min(int(q * len(sorted_vals)), len(sorted_vals) - 1)
    return sorted_vals[i]


def _fused_study(n_requests: int = 8, max_tokens: int = 32,
                 ks=(1, 8)) -> dict:
    """Engine-level dispatch-discipline comparison: same workload, same
    params, K=1 (the per-token legacy loop) vs fused K-step blocks.
    Counters are deterministic; timings are informational."""
    cfg = ARCHS["olmo-1b"].reduced()
    params = _store(cfg)
    out = {}
    for k in ks:
        eng = InferenceEngine(cfg, params,
                              EngineConfig(n_slots=4, max_len=64,
                                           decode_block=k))
        # compile outside the clock: 5 warmups cover both admission batch
        # shapes the run will see (a full group of 4 and a tail of 1)
        for i in range(5):
            eng.submit(Request(model=cfg.name, prompt=[1, 2, 3],
                               sampling=SamplingParams(max_tokens=2)))
        eng.run_until_done()
        base = eng.perf_stats()
        reqs = [Request(model=cfg.name, prompt=[1, 2, 3 + (i % 5)],
                        sampling=SamplingParams(max_tokens=max_tokens))
                for i in range(n_requests)]
        for r in reqs:
            eng.submit(r)
        step_s = []
        t0 = time.perf_counter()
        while eng.slot_req or eng.scheduler.depth:
            s0 = time.perf_counter()
            eng.step()
            step_s.append(time.perf_counter() - s0)
        wall = time.perf_counter() - t0
        stats = eng.perf_stats()
        toks = stats["tokens"] - base["tokens"]
        disp = stats["dispatches"] - base["dispatches"]
        syncs = stats["host_syncs"] - base["host_syncs"]
        step_s.sort()
        out[f"k{k}"] = {
            "decode_block": k,
            "tokens": toks,
            "dispatches": disp,
            "host_syncs": syncs,
            "dispatches_per_token": disp / max(toks, 1),
            "host_syncs_per_token": syncs / max(toks, 1),
            "tok_per_s": toks / wall if wall > 0 else 0.0,
            "p50_step_ms": _pct(step_s, 0.50) * 1e3,
            "p95_step_ms": _pct(step_s, 0.95) * 1e3,
            "prefill_traces": stats["prefill_traces"],
        }
    lo, hi = f"k{ks[0]}", f"k{ks[-1]}"
    out["reduction"] = {
        "dispatches_per_token":
            out[lo]["dispatches_per_token"] /
            max(out[hi]["dispatches_per_token"], 1e-12),
        "host_syncs_per_token":
            out[lo]["host_syncs_per_token"] /
            max(out[hi]["host_syncs_per_token"], 1e-12),
    }
    return out


def _paged_study(n_requests: int = 12, max_tokens: int = 24) -> dict:
    """The VRAM story, measured: a paged engine whose 8 slots share the
    *same 32-page KV budget* as a 4-slot contiguous engine admits more
    concurrent requests (higher peak slot occupancy, higher kv-page
    utilization) and drains the same workload in fewer engine steps.
    Counters are deterministic; timings are informational."""
    cfg = ARCHS["olmo-1b"].reduced()
    params = _store(cfg)
    variants = {
        # 4 slots x 64 tokens / 8-token pages = 32 pages, fully reserved
        "contiguous": EngineConfig(n_slots=4, max_len=64, page_size=8,
                                   paged=False),
        # same 32-page budget, slots oversubscribed 2x; admission is
        # page-aware and the engine preempts on exhaustion
        "paged": EngineConfig(n_slots=8, max_len=64, page_size=8,
                              kv_pages=32),
    }
    out = {}
    for name, ecfg in variants.items():
        eng = InferenceEngine(cfg, params, ecfg)
        for _ in range(4):            # compile outside the clock
            eng.submit(Request(model=cfg.name, prompt=[1, 2, 3],
                               sampling=SamplingParams(max_tokens=2)))
        eng.run_until_done()
        base = eng.perf_stats()
        reqs = [Request(model=cfg.name, prompt=[1, 2, 3 + (i % 5)],
                        sampling=SamplingParams(max_tokens=max_tokens))
                for i in range(n_requests)]
        for r in reqs:
            eng.submit(r)
        peak_active, peak_occ, util_sum, steps = 0, 0.0, 0.0, 0
        t0 = time.perf_counter()
        while eng.slot_req or eng.scheduler.depth:
            eng.step()
            steps += 1
            peak_active = max(peak_active, eng.pool.n_active)
            peak_occ = max(peak_occ, eng.pool.page_occupancy())
            util_sum += eng.pool.utilization()
        wall = time.perf_counter() - t0
        stats = eng.perf_stats()
        toks = stats["tokens"] - base["tokens"]
        assert all(len(r.output) == max_tokens for r in reqs), name
        out[name] = {
            "n_slots": ecfg.n_slots,
            "kv_pages": eng.pool.n_pages,
            "peak_active_slots": peak_active,
            "kv_page_utilization": util_sum / max(steps, 1),
            "peak_page_occupancy": peak_occ,
            "steps_to_drain": steps,
            "preemptions": stats["preemptions"],
            "tokens": toks,
            "tok_per_s": toks / wall if wall > 0 else 0.0,
            "dispatches_per_token":
                (stats["dispatches"] - base["dispatches"])
                / max(toks, 1),
        }
    # acceptance: same VRAM, more admitted work
    assert out["paged"]["peak_active_slots"] > \
        out["contiguous"]["peak_active_slots"], out
    out["gain"] = {
        "peak_active_slots":
            out["paged"]["peak_active_slots"]
            / max(out["contiguous"]["peak_active_slots"], 1),
        "kv_page_utilization":
            out["paged"]["kv_page_utilization"]
            / max(out["contiguous"]["kv_page_utilization"], 1e-9),
    }
    return out


def _paged_attn_study(n_requests: int = 8, max_tokens: int = 24) -> dict:
    """Paged-attention study: same paged engine, same workload, with the
    decode hot path either materializing every slot's logical KV view
    (gather + scatter per dispatch) or reading the page pool directly
    through the device page table.  Dispatch/sync discipline must be
    identical and greedy outputs token-identical; the win is logical KV
    bytes moved per token.  Counters are deterministic."""
    cfg = ARCHS["olmo-1b"].reduced()
    params = _store(cfg)
    out, outputs = {}, {}
    for name, on in (("gather", False), ("paged_attn", True)):
        eng = InferenceEngine(cfg, params,
                              EngineConfig(n_slots=4, max_len=64,
                                           decode_block=4, page_size=8,
                                           paged_attention=on))
        for _ in range(4):            # compile outside the clock
            eng.submit(Request(model=cfg.name, prompt=[1, 2, 3],
                               sampling=SamplingParams(max_tokens=2)))
        eng.run_until_done()
        base = eng.perf_stats()
        reqs = [Request(model=cfg.name, prompt=[1, 2, 3 + (i % 5)],
                        sampling=SamplingParams(max_tokens=max_tokens))
                for i in range(n_requests)]
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run_until_done()
        wall = time.perf_counter() - t0
        stats = eng.perf_stats()
        toks = stats["tokens"] - base["tokens"]
        bytes_moved = (stats["logical_bytes_moved"]
                       - base["logical_bytes_moved"])
        outputs[name] = [tuple(r.output) for r in reqs]
        out[name] = {
            "paged_attention": on,
            "tokens": toks,
            "dispatches": stats["dispatches"] - base["dispatches"],
            "host_syncs": stats["host_syncs"] - base["host_syncs"],
            "logical_bytes_moved": bytes_moved,
            "logical_bytes_moved_per_token": bytes_moved / max(toks, 1),
            "tok_per_s": toks / wall if wall > 0 else 0.0,
        }
    # the kernel is a memory optimization, never a numerics or
    # scheduling change
    assert outputs["paged_attn"] == outputs["gather"], \
        "paged attention changed greedy outputs"
    assert out["paged_attn"]["dispatches"] == out["gather"]["dispatches"]
    assert out["paged_attn"]["host_syncs"] == out["gather"]["host_syncs"]
    ratio = (out["gather"]["logical_bytes_moved_per_token"]
             / max(out["paged_attn"]["logical_bytes_moved_per_token"], 1))
    assert ratio >= 2.0, out
    out["gain"] = {"logical_bytes_moved_per_token": ratio}
    return out


def _spec_study(n_requests: int = 6, max_tokens: int = 24) -> dict:
    """Speculative-decoding study: greedy decode with the on-device
    n-gram proposer + single-dispatch verify vs plain per-token decode
    (decode_block=1) on a repetition-heavy workload.  Outputs must be
    token-identical (greedy verify); the win is accepted tokens per
    verify dispatch > 1, i.e. fewer dispatches and host syncs per
    token.  Counters are deterministic."""
    cfg = ARCHS["olmo-1b"].reduced()
    params = _store(cfg)
    out, outputs = {}, {}
    for name, on in (("spec_off", False), ("spec_on", True)):
        eng = InferenceEngine(cfg, params,
                              EngineConfig(n_slots=4, max_len=64,
                                           decode_block=1, page_size=8,
                                           paged_attention=True,
                                           speculative=on))
        for _ in range(4):            # compile outside the clock
            eng.submit(Request(model=cfg.name, prompt=[1, 2, 3],
                               sampling=SamplingParams(max_tokens=2)))
        eng.run_until_done()
        base = eng.perf_stats()
        reqs = [Request(model=cfg.name, prompt=[1, 2, 3 + (i % 5)],
                        sampling=SamplingParams(max_tokens=max_tokens))
                for i in range(n_requests)]
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run_until_done()
        wall = time.perf_counter() - t0
        stats = eng.perf_stats()
        toks = stats["tokens"] - base["tokens"]
        outputs[name] = [tuple(r.output) for r in reqs]
        out[name] = {
            "speculative": on,
            "tokens": toks,
            "dispatches_per_token":
                (stats["dispatches"] - base["dispatches"])
                / max(toks, 1),
            "host_syncs_per_token":
                (stats["host_syncs"] - base["host_syncs"])
                / max(toks, 1),
            "spec_dispatches":
                stats["spec_dispatches"] - base["spec_dispatches"],
            "spec_emitted": stats["spec_emitted"] - base["spec_emitted"],
            "tok_per_s": toks / wall if wall > 0 else 0.0,
        }
        if on:
            d = out[name]["spec_dispatches"]
            out[name]["spec_accepted_per_dispatch"] = \
                out[name]["spec_emitted"] / max(d, 1)
    # greedy verify is provably lossless
    assert outputs["spec_on"] == outputs["spec_off"], \
        "speculative decoding changed greedy outputs"
    assert out["spec_on"]["spec_accepted_per_dispatch"] > 1.0, out
    out["gain"] = {
        "dispatches_per_token":
            out["spec_off"]["dispatches_per_token"]
            / max(out["spec_on"]["dispatches_per_token"], 1e-12),
    }
    return out


def _prefix_study(n_requests: int = 10, max_tokens: int = 12) -> dict:
    """Prefix-cache study: every request carries the same 32-token
    system prefix plus a private 8-token tail (80% shared).  With the
    hierarchical KV cache on, only the first request prefills the full
    prompt — the rest map the cached prefix pages and prefill their
    suffix bucket only, so prefill dispatch tokens and TTFT drop while
    greedy outputs stay token-identical.  Counters are deterministic;
    timings are informational."""
    cfg = ARCHS["olmo-1b"].reduced()
    params = _store(cfg)
    shared = list(range(1, 33))                 # 32 tokens = 4 pages
    prompts = [shared + [40 + i, 50 + i, 60 + i, 70 + i,
                         40 + i, 50 + i, 60 + i, 71 + i]
               for i in range(n_requests)]      # 40 tokens, 80% shared
    out, outputs = {}, {}
    for name, on in (("cache_off", False), ("cache_on", True)):
        eng = InferenceEngine(cfg, params,
                              EngineConfig(n_slots=4, max_len=64,
                                           decode_block=4, page_size=8,
                                           prefix_cache=on))
        # compile outside the clock: full-prompt prefill, and (cache on)
        # the suffix-admission trace; flush so the bench window starts
        # cache-cold and the hit rate reflects the workload, not warmup
        for p in ([99] * 40, [99] * 32 + [98] * 8):
            eng.submit(Request(model=cfg.name, prompt=list(p),
                               sampling=SamplingParams(max_tokens=2)))
            eng.run_until_done()
        if on:
            eng.flush_prefix_cache()
            cache_base = eng.prefix_cache.stats()
        base = eng.perf_stats()
        ttfts, outs = [], []
        t0 = time.perf_counter()
        for p in prompts:
            r = Request(model=cfg.name, prompt=list(p),
                        sampling=SamplingParams(max_tokens=max_tokens))
            eng.submit(r)
            eng.run_until_done()
            ttfts.append(r.ttft)
            outs.append(tuple(r.output))
        wall = time.perf_counter() - t0
        stats = eng.perf_stats()
        outputs[name] = outs
        ttfts.sort()
        toks = stats["tokens"] - base["tokens"]
        out[name] = {
            "requests": n_requests,
            "prefill_dispatch_tokens":
                stats["prefill_dispatch_tokens"]
                - base["prefill_dispatch_tokens"],
            "suffix_prefills":
                stats["suffix_prefills"] - base["suffix_prefills"],
            "mean_ttft_ms": (sum(ttfts) / len(ttfts) * 1e3
                             if ttfts else 0.0),
            "p95_ttft_ms": _pct(ttfts, 0.95) * 1e3,
            "tok_per_s": toks / wall if wall > 0 else 0.0,
        }
        if on:
            cs = eng.prefix_cache.stats()
            lookups = cs["lookups"] - cache_base["lookups"]
            hits = cs["hits"] - cache_base["hits"]
            out[name]["prefix_hit_rate"] = hits / max(lookups, 1)
    # caching is a memory optimization, never a numerics change
    assert outputs["cache_on"] == outputs["cache_off"], \
        "prefix cache changed greedy outputs"
    on, off = out["cache_on"], out["cache_off"]
    assert on["prefix_hit_rate"] >= 0.8, out
    assert on["prefill_dispatch_tokens"] < off["prefill_dispatch_tokens"]
    out["gain"] = {
        "prefill_dispatch_tokens":
            off["prefill_dispatch_tokens"]
            / max(on["prefill_dispatch_tokens"], 1),
        "mean_ttft": off["mean_ttft_ms"] / max(on["mean_ttft_ms"], 1e-9),
    }
    return out


def _runtime_study(n_tenants: int = 4, n_nodes: int = 2,
                   reqs_per_tenant: int = 10, max_tokens: int = 12) -> dict:
    """Multi-tenant continuous serving: background pumps drive >= 2 nodes
    while >= 4 tenants submit concurrently — zero caller-side `_pump()`
    calls — with one rate-capped tenant (structured RATE_LIMITED) and a
    deliberately under-replicated model that the controller scales up
    under sustained queue pressure."""
    cfg = ARCHS["olmo-1b"].reduced()
    params = _store(cfg)
    fleet = Fleet([BackendNode(f"n{i}", "v5e-1",
                               param_store=lambda c: params)
                   for i in range(n_nodes)])
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.cfg.fill_vram = False           # leave free VRAM for scale-up
    ctrl.discover()
    # anti-affinity spreads the two seed replicas across nodes; the
    # flood below still queues ~10 deep per replica, so the autoscaler
    # has headroom (and free VRAM) to grow toward the cap
    plan = ctrl.deploy([ModelDemand(cfg, min_replicas=2, max_replicas=4,
                                    n_slots=2, max_len=48)])
    assert not plan.unplaced
    gw = Gateway(ctrl)
    # tenant 0 gets a hard bucket: 2 requests then (effectively) no refill
    gw.admin.set_tenant_quota("tenant0", TenantQuota(requests_per_s=0.01,
                                                     burst_requests=2))
    rt = gw.start(RuntimeConfig(tick_interval_s=0.02))
    gw.generate(cfg.name, [1, 2, 3], SamplingParams(max_tokens=2),
                timeout_s=120)           # warm the first replica's traces
    results = []
    lock = threading.Lock()

    def worker(t):
        tenant = f"tenant{t}"
        # flood-submit, then collect: ~40 queued requests over 2x2 slots
        # keep backlog-per-replica far above AutoscaleConfig.queue_high
        # for many sustain windows (seconds of decode vs a 60 ms streak),
        # so the scale-up assertion below is timing-robust in CI
        handles = [gw.submit(cfg.name, [1, 2, (i % 5) + 1],
                             SamplingParams(max_tokens=max_tokens),
                             tenant=tenant)
                   for i in range(reqs_per_tenant)]
        for h in handles:
            r = h.result(timeout_s=120)
            with lock:
                results.append((tenant, r))

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_tenants)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    wall = time.perf_counter() - t0
    gw.stop(timeout_s=60)

    ok = [r for _, r in results if r.ok]
    limited = [r for _, r in results
               if not r.ok and r.error.code.value == "rate_limited"]
    # acceptance invariants, enforced here so CI's smoke run catches
    # regressions in the runtime contract itself
    assert gw.stats.caller_pumps == 0, "caller pumped despite runtime"
    assert limited, "capped tenant never saw RATE_LIMITED"
    assert len(ok) >= n_tenants, "fleet stopped serving"
    assert ctrl.scale_ups >= 1, "sustained pressure never scaled up"
    nodes_used = {r.node for r in ok}
    assert len(nodes_used) >= 2, "traffic never spanned multiple nodes"
    return {
        "tenants": n_tenants,
        "nodes": n_nodes,
        "nodes_serving": sorted(nodes_used),
        "requests": len(results),
        "completed": len(ok),
        "rate_limited": len(limited),
        "caller_pumps": gw.stats.caller_pumps,
        "scale_ups": ctrl.scale_ups,
        "replicas_final": len(ctrl.replicas.for_model(cfg.name)),
        "pump_wakeups": rt.stats.pump_wakeups,
        "ticks": rt.stats.ticks,
        "tok_per_s": sum(len(r.tokens) for r in ok) / wall
        if wall > 0 else 0.0,
    }


def _http_study(n_tenants: int = 2, reqs_per_tenant: int = 8,
                max_tokens: int = 8) -> dict:
    """The wire tax, informational: requests/s and TTFT through the
    OpenAI-compatible socket service vs the in-process Gateway for the
    same workload (2 tenants on keep-alive connections, SSE streaming
    so TTFT is measured at the first token frame)."""
    from repro.api.http import GatewayHTTPServer, HTTPClient, HTTPConfig
    cfg = ARCHS["olmo-1b"].reduced()
    params = _store(cfg)
    fleet = Fleet([BackendNode(f"n{i}", "v5e-1",
                               param_store=lambda c: params)
                   for i in range(2)])
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.cfg.fill_vram = False
    ctrl.discover()
    plan = ctrl.deploy([ModelDemand(cfg, min_replicas=2, max_replicas=2,
                                    n_slots=2, max_len=64)])
    assert not plan.unplaced
    gw = Gateway(ctrl)
    srv = GatewayHTTPServer(gw, HTTPConfig(port=0)).start()
    n_total = n_tenants * reqs_per_tenant
    prompts = [(1, 2, (i % 5) + 1) for i in range(n_total)]
    sampling = SamplingParams(max_tokens=max_tokens)
    # warm every replica's traces for the admission shapes both legs
    # will see (prefill batches of 1, 2, and 4) so neither pays compiles
    warm = SamplingParams(max_tokens=2)
    for _ in range(2):
        gw.generate(cfg.name, [1, 2, 3], warm, timeout_s=120)
    for n in (2, 2, 4, 4):
        gw.generate_batch(
            [GenerationRequest(model=cfg.name, prompt=(1, 2, 3),
                               sampling=warm) for _ in range(n)],
            timeout_s=120)
    # in-process reference: identical workload through the Gateway
    t0 = time.perf_counter()
    resps = gw.generate_batch(
        [GenerationRequest(model=cfg.name, prompt=p, sampling=sampling)
         for p in prompts], timeout_s=120)
    dt_inproc = time.perf_counter() - t0
    assert all(r.ok for r in resps)
    inproc_ttfts = sorted(r.ttft for r in resps if r.ttft is not None)
    # over the wire: one keep-alive streaming client per tenant.
    # Workers only collect; every correctness assert runs on the main
    # thread after join (an assert inside a Thread would be swallowed
    # and the study would report fabricated metrics)
    outcomes = []                       # (ttft, n_toks) per request
    lock = threading.Lock()

    def worker(t):
        client = HTTPClient(srv.url(), tenant=f"bench{t}")
        for i in range(reqs_per_tenant):
            s0 = time.perf_counter()
            first = None
            n_toks = 0
            for ch in client.complete(cfg.name,
                                      list(prompts[t * reqs_per_tenant
                                                   + i]),
                                      max_tokens=max_tokens, stream=True,
                                      timeout_s=120):
                if ch["choices"][0].get("token") is not None:
                    n_toks += 1
                    if first is None:
                        first = time.perf_counter() - s0
            with lock:
                outcomes.append((first, n_toks))
        client.close()

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_tenants)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    wall = time.perf_counter() - t0
    srv.stop(timeout_s=60)
    assert len(outcomes) == n_total, "a worker died mid-study"
    assert all(n == max_tokens for _, n in outcomes), \
        "a stream lost tokens over the wire"
    ttfts = sorted(f for f, _ in outcomes)
    return {
        "tenants": n_tenants,
        "requests": n_total,
        "http_req_per_s": n_total / wall if wall > 0 else 0.0,
        "http_p95_ttft_ms": _pct(ttfts, 0.95) * 1e3,
        "http_mean_ttft_ms": (sum(ttfts) / len(ttfts) * 1e3
                              if ttfts else 0.0),
        "inproc_req_per_s": (n_total / dt_inproc
                             if dt_inproc > 0 else 0.0),
        "inproc_p95_ttft_ms": _pct(inproc_ttfts, 0.95) * 1e3,
        "inproc_mean_ttft_ms": (sum(inproc_ttfts) / len(inproc_ttfts)
                                * 1e3 if inproc_ttfts else 0.0),
    }


def run(n_requests: int = 12, max_tokens: int = 24,
        json_path: str = "BENCH_serving.json"):
    rows = []
    report = {"gateway": {}}
    for quant in ("", "int8"):
        cfg, inst, gw = _stack(quant)
        # warm-up/compile
        gw.generate(cfg.name, [1, 2, 3],
                    SamplingParams(max_tokens=2))
        reqs = [GenerationRequest(model=cfg.name, prompt=(1, 2, 3, i),
                                  sampling=SamplingParams(
                                      max_tokens=max_tokens))
                for i in range(n_requests)]
        t0 = time.perf_counter()
        resps = gw.generate_batch(reqs)
        dt = time.perf_counter() - t0
        assert all(r.ok for r in resps), [r.error for r in resps if not r.ok]
        toks = sum(len(r.tokens) for r in resps)
        ttfts = [r.ttft for r in resps if r.ttft]
        tag = quant or "bf16"
        rows.append((f"serving_decode_{tag}", dt / toks * 1e6,
                     f"tok_per_s={toks/dt:.1f}"))
        rows.append((f"serving_ttft_{tag}",
                     sum(ttfts) / len(ttfts) * 1e6,
                     f"n={len(ttfts)}"))
        mem = inst.engine.memory_report()
        rows.append((f"serving_mem_{tag}", 0.0,
                     f"params={mem['param_bytes']};"
                     f"cache={mem['cache_bytes']}"))
        report["gateway"][tag] = {
            "tok_per_s": toks / dt,
            "mean_ttft_s": sum(ttfts) / len(ttfts) if ttfts else None,
            "engine": inst.engine.perf_stats(),
        }
        if not quant:
            # streaming path: per-event overhead vs blocking batch
            t0 = time.perf_counter()
            n_events = sum(
                1 for _ in gw.stream(cfg.name, [1, 2, 3],
                                     SamplingParams(
                                         max_tokens=max_tokens)))
            dt = time.perf_counter() - t0
            rows.append(("serving_stream_event", dt / n_events * 1e6,
                         f"events={n_events}"))
    ks = (1, 8)
    fused = _fused_study(ks=ks)
    report["fused"] = fused
    paged = _paged_study()
    report["paged"] = paged
    rows.append(("serving_paged_occupancy", 0.0,
                 f"peak_active_paged={paged['paged']['peak_active_slots']};"
                 f"peak_active_contig="
                 f"{paged['contiguous']['peak_active_slots']};"
                 f"kv_page_util={paged['paged']['kv_page_utilization']:.3f};"
                 f"preemptions={paged['paged']['preemptions']};"
                 f"tok_per_s={paged['paged']['tok_per_s']:.1f}"))
    pattn = _paged_attn_study()
    report["paged_attn"] = pattn
    rows.append(("serving_paged_attention", 0.0,
                 f"bytes_per_token_gather="
                 f"{pattn['gather']['logical_bytes_moved_per_token']:.0f};"
                 f"bytes_per_token_paged="
                 f"{pattn['paged_attn']['logical_bytes_moved_per_token']:.0f};"
                 f"reduction_x"
                 f"{pattn['gain']['logical_bytes_moved_per_token']:.1f}"))
    spec = _spec_study()
    report["spec"] = spec
    rows.append(("serving_spec_decode", 0.0,
                 f"accepted_per_dispatch="
                 f"{spec['spec_on']['spec_accepted_per_dispatch']:.2f};"
                 f"dispatch_reduction_x"
                 f"{spec['gain']['dispatches_per_token']:.1f}"))
    prefix = _prefix_study()
    report["prefix"] = prefix
    rows.append(("serving_prefix_cache", 0.0,
                 f"hit_rate={prefix['cache_on']['prefix_hit_rate']:.2f};"
                 f"prefill_tokens_on="
                 f"{prefix['cache_on']['prefill_dispatch_tokens']};"
                 f"prefill_tokens_off="
                 f"{prefix['cache_off']['prefill_dispatch_tokens']};"
                 f"mean_ttft_on_ms="
                 f"{prefix['cache_on']['mean_ttft_ms']:.2f};"
                 f"mean_ttft_off_ms="
                 f"{prefix['cache_off']['mean_ttft_ms']:.2f}"))
    runtime = _runtime_study()
    report["runtime"] = runtime
    http = _http_study()
    report["http"] = http
    rows.append(("serving_http_wire", 0.0,
                 f"req_per_s={http['http_req_per_s']:.1f};"
                 f"p95_ttft_ms={http['http_p95_ttft_ms']:.1f};"
                 f"inproc_req_per_s={http['inproc_req_per_s']:.1f};"
                 f"inproc_p95_ttft_ms={http['inproc_p95_ttft_ms']:.1f}"))
    rows.append(("serving_runtime_multitenant", 0.0,
                 f"tenants={runtime['tenants']};"
                 f"completed={runtime['completed']};"
                 f"rate_limited={runtime['rate_limited']};"
                 f"caller_pumps={runtime['caller_pumps']};"
                 f"scale_ups={runtime['scale_ups']};"
                 f"tok_per_s={runtime['tok_per_s']:.1f}"))
    red = fused["reduction"]
    hi = f"k{ks[-1]}"
    rows.append((f"serving_fused_{hi}_tok_per_s", 0.0,
                 f"tok_per_s={fused[hi]['tok_per_s']:.1f};"
                 f"p50_step_ms={fused[hi]['p50_step_ms']:.2f};"
                 f"p95_step_ms={fused[hi]['p95_step_ms']:.2f}"))
    rows.append(("serving_fused_dispatch_reduction", 0.0,
                 f"dispatches_per_token_x{red['dispatches_per_token']:.1f};"
                 f"host_syncs_per_token_x{red['host_syncs_per_token']:.1f}"))
    Path(json_path).write_text(json.dumps(report, indent=2))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")

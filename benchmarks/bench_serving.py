"""Live serving engine throughput/latency on CPU (tiny model): continuous
batching decode tokens/s, TTFT, and the quantized-engine memory ratio."""
from __future__ import annotations

import time

import jax

from repro.configs import ARCHS
from repro.models import build
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           SamplingParams)

_cache = {}


def _engine(quantize=""):
    cfg = ARCHS["olmo-1b"].reduced()
    if "p" not in _cache:
        _cache["p"] = build(cfg).init(jax.random.PRNGKey(0))
    return cfg, InferenceEngine(cfg, _cache["p"],
                                EngineConfig(n_slots=4, max_len=64,
                                             quantize=quantize))


def run(n_requests: int = 12, max_tokens: int = 24):
    rows = []
    for quant in ("", "int8"):
        cfg, eng = _engine(quant)
        reqs = [Request(model=cfg.name, prompt=[1, 2, 3, i],
                        sampling=SamplingParams(max_tokens=max_tokens))
                for i in range(n_requests)]
        for r in reqs:
            eng.submit(r)
        eng.step()                     # warm-up/compile step
        t0 = time.perf_counter()
        eng.run_until_done()
        dt = time.perf_counter() - t0
        toks = sum(len(r.output) for r in reqs)
        ttfts = [r.ttft for r in reqs if r.ttft]
        tag = quant or "bf16"
        rows.append((f"serving_decode_{tag}", dt / toks * 1e6,
                     f"tok_per_s={toks/dt:.1f}"))
        rows.append((f"serving_ttft_{tag}",
                     sum(ttfts) / len(ttfts) * 1e6,
                     f"n={len(ttfts)}"))
        mem = eng.memory_report()
        rows.append((f"serving_mem_{tag}", 0.0,
                     f"params={mem['param_bytes']};"
                     f"cache={mem['cache_bytes']}"))
    return rows

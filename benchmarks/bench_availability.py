"""Availability under failure injection — quantifies the paper's central
HA claim (it gave no numbers; we do).

Two scenarios:

1. **Fleet survival** (paper testbed + zoo): kill k nodes mid-workload,
   measure request success rate, failover overhead (extra retries), the
   controller's reallocation latency, and the no-HA static-table
   baseline the paper's HAProxy replaces.
2. **Survivable streams** (real engines, seeded chaos): N greedy
   streams run through the continuous runtime while a seeded
   `FaultInjector` kill schedule takes out a node mid-decode.  Reports
   `tokens_lost` and `tokens_duplicated` versus the fault-free
   reference (both MUST be 0 — mid-stream migration replays nothing and
   drops nothing) plus recovery latency (crash -> first resumed token)
   and the migration count.  The `availability` section is merged into
   ``BENCH_serving.json`` so `check_regression.py` can gate on it.
"""
from __future__ import annotations

import json
import random
import threading
import time
from pathlib import Path

import jax

from repro.api import Gateway, RuntimeConfig, StreamEventType
from repro.cluster import (BackendNode, FaultInjector, Fleet,
                           paper_testbed)
from repro.configs import ARCHS, ZOO
from repro.core import (ControllerConfig, ModelCatalog, ModelDemand,
                        ReplicaInfo, ReplicaKey, SDAIController)
from repro.models import build
from repro.serving import SamplingParams

_params = {}


def _store(cfg):
    if cfg.name not in _params:
        _params[cfg.name] = build(cfg).init(jax.random.PRNGKey(0))
    return _params[cfg.name]


# ------------------- scenario 1: fleet survival --------------------- #
def _fleet_survival(n_requests: int, kills: int, seed: int):
    rng = random.Random(seed)
    fleet = paper_testbed(param_store=_store)
    catalog = ModelCatalog()
    models = ["deepseek-r1-7b", "qwen3-8b", "deepseek-r1-1.5b",
              "llama3.2-1b", "gemma3-1b", "nomic-embed-text"]
    for m in models:
        catalog.register(ZOO[m])
    ctrl = SDAIController(fleet, catalog, ControllerConfig())
    ctrl.discover()
    ctrl.deploy([ModelDemand(ZOO[m], min_replicas=2) for m in models])

    gw = Gateway(ctrl)
    ok = fail = retries = 0
    realloc_us = []
    kill_at = {n_requests * (i + 1) // (kills + 1) for i in range(kills)}
    for i in range(n_requests):
        if i in kill_at:
            alive = [n for n, node in fleet.nodes.items() if node.alive]
            if len(alive) > 1:
                fleet.fail_node(rng.choice(alive))
                t0 = time.perf_counter()
                ctrl.tick()
                realloc_us.append((time.perf_counter() - t0) * 1e6)
        resp = gw.generate(rng.choice(models),
                           [rng.randrange(64) for _ in range(4)],
                           SamplingParams(max_tokens=4))
        retries += resp.retries
        if resp.ok:
            ok += 1
        else:
            fail += 1
    rows = [
        ("availability_success_rate", 0.0, f"{ok/(ok+fail):.4f}"),
        ("availability_failovers", 0.0, str(retries)),
        ("availability_realloc",
         sum(realloc_us) / max(len(realloc_us), 1),
         f"kills={len(realloc_us)}"),
    ]
    # baseline: NO health-checked frontend — clients pin to a static
    # deploy-time routing table (round-robin, no liveness, no retries),
    # the setup the paper's HAProxy replaces
    fleet2 = paper_testbed(param_store=_store)
    ctrl2 = SDAIController(fleet2, catalog, ControllerConfig())
    ctrl2.discover()
    ctrl2.deploy([ModelDemand(ZOO[m], min_replicas=2) for m in models])
    static_table = {m: [r.key for r in ctrl2.replicas.for_model(m)]
                    for m in models}
    rr = {m: 0 for m in models}
    from repro.serving.request import Request
    rng2 = random.Random(seed)
    ok2 = fail2 = 0
    for i in range(n_requests):
        if i in kill_at:
            alive = [n for n, node in fleet2.nodes.items() if node.alive]
            if len(alive) > 1:
                fleet2.fail_node(rng2.choice(alive))
        m = rng2.choice(models)
        keys = static_table[m]
        key = keys[rr[m] % len(keys)]
        rr[m] += 1
        req = Request(model=m, prompt=[rng2.randrange(64)
                                       for _ in range(4)],
                      sampling=SamplingParams(max_tokens=4))
        node = fleet2.nodes[key.node_id]
        sent = node.submit(key.instance_id, req)
        if sent and not req.error:
            ok2 += 1
        else:
            fail2 += 1
    rows.append(("availability_no_ha_baseline", 0.0,
                 f"{ok2/(ok2+fail2):.4f}"))
    return rows


# ------------------- scenario 2: survivable streams ----------------- #
def _survivable_streams(n_streams: int = 6, max_tokens: int = 24,
                        seed: int = 1234):
    """Seeded kill-a-node-mid-decode chaos soak on real engines."""
    cfg = ARCHS["olmo-1b"].reduced()
    fleet = Fleet([BackendNode(f"n{i}", "v5e-1", param_store=_store)
                   for i in range(3)])
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.discover()
    for node in fleet.nodes.values():
        inst = node.deploy(cfg, n_slots=2, max_len=48)
        ctrl.replicas.add(ReplicaInfo(
            ReplicaKey(node.node_id, inst.instance_id),
            cfg.name, "", 2, 48, inst.bytes))
    gw = Gateway(ctrl)
    prompts = [[1, 2, i + 1] for i in range(n_streams)]

    # fault-free reference (greedy => per-prompt deterministic)
    reference = {}
    for p in prompts:
        r = gw.generate(cfg.name, p, SamplingParams(max_tokens=max_tokens),
                        timeout_s=120)
        assert r.ok, r.error
        reference[tuple(p)] = list(r.tokens)

    inj = FaultInjector.kill_schedule(
        seed=seed, node_ids=list(fleet.nodes), n_kills=1,
        first_step=3).install(fleet, bus=ctrl.bus)
    gw.start(RuntimeConfig(tick_interval_s=0.02))
    streams = {}            # request_id -> [(t, index, token), ...]
    lock = threading.Lock()

    def consume(rid, handle):
        got = []
        for ev in handle.stream(timeout_s=120):
            if ev.type is StreamEventType.TOKEN:
                got.append((time.monotonic(), ev.index, ev.token))
        with lock:
            streams[rid] = got

    try:
        handles = [(p, gw.submit(cfg.name, p,
                                 SamplingParams(max_tokens=max_tokens)))
                   for p in prompts]
        threads = [threading.Thread(target=consume,
                                    args=(h.internal.request_id, h))
                   for _, h in handles]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=180)
    finally:
        gw.stop(timeout_s=60)
        inj.uninstall()

    crash_ts = sorted(e.ts for e in ctrl.bus.of_kind("fault_injected")
                      if e.data.get("fault") == "crash")
    tokens_lost = tokens_dup = 0
    for p, h in handles:
        got = streams.get(h.internal.request_id, [])
        ref = reference[tuple(p)]
        seen = [i for _, i, _ in got]
        tokens_dup += len(seen) - len(set(seen))
        delivered = [tok for _, _, tok in got]
        # lost = reference tokens the stream never delivered in order
        tokens_lost += sum(1 for a, b in zip(ref, delivered) if a != b)
        tokens_lost += max(0, len(ref) - len(delivered))
    # recovery latency: crash -> first token the migrated stream
    # delivered after its resume on the survivor
    recovery_us = []
    for ev in ctrl.bus.of_kind("request_migrated"):
        got = streams.get(ev.data.get("request_id"), [])
        killed_at = max((t for t in crash_ts if t <= ev.ts), default=None)
        after = [t for t, _, _ in got if t > ev.ts]
        if killed_at is not None and after:
            recovery_us.append((min(after) - killed_at) * 1e6)
    recovery_us.sort()
    mean_us = sum(recovery_us) / max(len(recovery_us), 1)
    p95_us = recovery_us[int(0.95 * (len(recovery_us) - 1))] \
        if recovery_us else 0.0
    max_us = recovery_us[-1] if recovery_us else 0.0
    migrations = gw.stats.migrations
    report = {
        "streams": n_streams,
        "max_tokens": max_tokens,
        "seed": seed,
        "tokens_lost": tokens_lost,
        "tokens_duplicated": tokens_dup,
        "migrations": migrations,
        "stream_retries": gw.stats.stream_retries,
        "recovery_mean_us": mean_us,
        "recovery_p95_us": p95_us,
        "recovery_max_us": max_us,
        "faults_fired": len(inj.fired),
    }
    rows = [
        ("chaos_tokens_lost", 0.0, str(tokens_lost)),
        ("chaos_tokens_duplicated", 0.0, str(tokens_dup)),
        ("chaos_migrations", 0.0, str(migrations)),
        ("chaos_recovery", mean_us,
         f"p95={p95_us:.0f}us max={max_us:.0f}us n={len(recovery_us)}"),
    ]
    return rows, report


def _merge_report(report: dict, json_path: str = "BENCH_serving.json"):
    """Merge the availability section into the serving bench report —
    creating the file when the chaos soak runs standalone (its own CI
    job), augmenting it when run after bench_serving."""
    path = Path(json_path)
    try:
        merged = json.loads(path.read_text())
    except (FileNotFoundError, ValueError):
        merged = {}
    merged["availability"] = report
    path.write_text(json.dumps(merged, indent=2))


def run(n_requests: int = 120, kills: int = 2, seed: int = 0):
    rows = _fleet_survival(n_requests, kills, seed)
    chaos_rows, report = _survivable_streams()
    rows.extend(chaos_rows)
    _merge_report(report)
    return rows


if __name__ == "__main__":
    import sys
    if "--chaos-only" in sys.argv:     # CI chaos-soak job: scenario 2
        rows, report = _survivable_streams()
        _merge_report(report)
    else:
        rows = run()
    for name, us, derived in rows:
        print(f"{name:36s} {us:12.1f} us/call   {derived}")

"""Availability under failure injection — quantifies the paper's central
HA claim (it gave no numbers; we do).

Scenario: paper testbed + zoo, kill k nodes mid-workload, measure request
success rate, failover overhead (extra retries), and the controller's
reallocation latency."""
from __future__ import annotations

import dataclasses
import random
import time

import jax

from repro.api import Gateway
from repro.cluster import paper_testbed
from repro.configs import ZOO
from repro.core import (ControllerConfig, ModelCatalog, ModelDemand,
                        SDAIController)
from repro.models import build
from repro.serving import SamplingParams

_params = {}


def _store(cfg):
    if cfg.name not in _params:
        _params[cfg.name] = build(cfg).init(jax.random.PRNGKey(0))
    return _params[cfg.name]


def run(n_requests: int = 120, kills: int = 2, seed: int = 0):
    rng = random.Random(seed)
    fleet = paper_testbed(param_store=_store)
    catalog = ModelCatalog()
    models = ["deepseek-r1-7b", "qwen3-8b", "deepseek-r1-1.5b",
              "llama3.2-1b", "gemma3-1b", "nomic-embed-text"]
    for m in models:
        catalog.register(ZOO[m])
    ctrl = SDAIController(fleet, catalog, ControllerConfig())
    ctrl.discover()
    ctrl.deploy([ModelDemand(ZOO[m], min_replicas=2) for m in models])

    gw = Gateway(ctrl)
    ok = fail = retries = 0
    realloc_us = []
    kill_at = {n_requests * (i + 1) // (kills + 1) for i in range(kills)}
    for i in range(n_requests):
        if i in kill_at:
            alive = [n for n, node in fleet.nodes.items() if node.alive]
            if len(alive) > 1:
                fleet.fail_node(rng.choice(alive))
                t0 = time.perf_counter()
                ctrl.tick()
                realloc_us.append((time.perf_counter() - t0) * 1e6)
        resp = gw.generate(rng.choice(models),
                           [rng.randrange(64) for _ in range(4)],
                           SamplingParams(max_tokens=4))
        retries += resp.retries
        if resp.ok:
            ok += 1
        else:
            fail += 1
    rows = [
        ("availability_success_rate", 0.0, f"{ok/(ok+fail):.4f}"),
        ("availability_failovers", 0.0, str(retries)),
        ("availability_realloc",
         sum(realloc_us) / max(len(realloc_us), 1),
         f"kills={len(realloc_us)}"),
    ]
    # baseline: NO health-checked frontend — clients pin to a static
    # deploy-time routing table (round-robin, no liveness, no retries),
    # the setup the paper's HAProxy replaces
    fleet2 = paper_testbed(param_store=_store)
    ctrl2 = SDAIController(fleet2, catalog, ControllerConfig())
    ctrl2.discover()
    ctrl2.deploy([ModelDemand(ZOO[m], min_replicas=2) for m in models])
    static_table = {m: [r.key for r in ctrl2.replicas.for_model(m)]
                    for m in models}
    rr = {m: 0 for m in models}
    from repro.serving.request import Request
    rng2 = random.Random(seed)
    ok2 = fail2 = 0
    for i in range(n_requests):
        if i in kill_at:
            alive = [n for n, node in fleet2.nodes.items() if node.alive]
            if len(alive) > 1:
                fleet2.fail_node(rng2.choice(alive))
        m = rng2.choice(models)
        keys = static_table[m]
        key = keys[rr[m] % len(keys)]
        rr[m] += 1
        req = Request(model=m, prompt=[rng2.randrange(64)
                                       for _ in range(4)],
                      sampling=SamplingParams(max_tokens=4))
        node = fleet2.nodes[key.node_id]
        sent = node.submit(key.instance_id, req)
        if sent and not req.error:
            ok2 += 1
        else:
            fail2 += 1
    rows.append(("availability_no_ha_baseline", 0.0,
                 f"{ok2/(ok2+fail2):.4f}"))
    return rows

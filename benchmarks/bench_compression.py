"""Gradient-compression benchmark: wire-byte reduction for the DP
all-reduce + quantization overhead + convergence parity (loss delta vs
uncompressed after N steps on the synthetic task)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.training import compression as comp_lib
from repro.training.data import DataConfig
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, Trainer


def run(steps: int = 20):
    rows = []
    cfg = ARCHS["olmo-1b"].reduced()
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, batch=4)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=steps)

    t_plain = Trainer(cfg, dc, TrainConfig(
        steps=steps, ckpt_every=10 ** 9, log_every=steps,
        ckpt_dir="/tmp/bench_comp_a"), ocfg)
    r_plain = t_plain.run(resume=False)
    t_comp = Trainer(cfg, dc, TrainConfig(
        steps=steps, ckpt_every=10 ** 9, log_every=steps,
        ckpt_dir="/tmp/bench_comp_b", compress_grads=True), ocfg)
    r_comp = t_comp.run(resume=False)
    l_p = r_plain["history"][-1]["loss"]
    l_c = r_comp["history"][-1]["loss"]
    rows.append(("compression_loss_delta", 0.0,
                 f"plain={l_p:.4f};int8ef={l_c:.4f}"))

    params = t_plain.init_state()["params"]
    full = comp_lib.wire_bytes(params, compressed=False)
    comp = comp_lib.wire_bytes(params, compressed=True)
    rows.append(("compression_wire_ratio", 0.0,
                 f"{comp/full:.4f} ({full//2**20}MiB->{comp//2**20}MiB)"))

    g = jax.tree.map(lambda p: jnp.ones(p.shape, jnp.float32), params)
    e = comp_lib.init_error(params)
    f = jax.jit(lambda g, e: comp_lib.compress_tree(g, e))
    jax.block_until_ready(f(g, e))
    t0 = time.perf_counter()
    jax.block_until_ready(f(g, e))
    rows.append(("compression_quantize_time",
                 (time.perf_counter() - t0) * 1e6, "per_grad_tree"))
    return rows

"""Soft bench regression gate for CI.

Compares deterministic counters from a fresh ``BENCH_serving.json``
against the checked-in ``benchmarks/baseline_serving.json``: the job
fails when ``dispatches_per_token`` or ``host_syncs_per_token`` (lower is
better) regresses more than the budget (default 20%) for any fused-K
variant, or when the paged study's ``kv_page_utilization`` (higher is
better — the fraction of KV-pool tokens holding live cache entries) or
the prefix study's ``prefix_hit_rate`` (higher is better — cache hits
on the 80%-shared-prefix workload) drops more than the budget below
baseline, or when the paged-attention study's
``logical_bytes_moved_per_token`` (lower is better — KV bytes the
decode hot path moves per emitted token) regresses more than the
budget.  The speculative-decoding study's
``spec_accepted_per_dispatch`` is informational here (workload-shaped);
the bench itself asserts it exceeds 1.0 with token-identical outputs.
Wall-clock metrics (tok/s, step percentiles) are machine-dependent and
stay informational — they are printed but never gate.

The ``availability`` section (written by ``bench_availability``) gates
on absolutes, not baseline ratios: a survivable stream by definition
loses and duplicates **zero** tokens across a mid-decode node kill, so
``tokens_lost`` and ``tokens_duplicated`` must equal 0 and at least one
migration must have happened.  Recovery latency is wall-clock and stays
informational.

The ``placement`` section (written by ``bench_placement``) gates on both
kinds: cost-optimal placement must beat VRAM-only by an absolute margin
(>= 15% lower modeled cost-per-token on the heterogeneous fleet100
study, at equal placed demand), and the advantage must not shrink more
than the budget below the checked-in baseline.  Modeled cost-per-token
is deterministic (no wall-clock), so it gates reliably.

Usage:  python benchmarks/check_regression.py \
            [--only availability|placement] \
            [BENCH_serving.json] [benchmarks/baseline_serving.json]
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

GATED_METRICS = ("dispatches_per_token", "host_syncs_per_token")
BUDGET = 0.20                 # allowed relative regression
COST_ADVANTAGE_FLOOR = 0.15   # cost-optimal must beat VRAM-only by 15%


def _check_availability(current, failures):
    """Absolute gates on the chaos-soak section (when present)."""
    avail = current.get("availability")
    if avail is None:
        return False
    for metric in ("tokens_lost", "tokens_duplicated"):
        c = avail.get(metric)
        status = "FAIL" if c != 0 else "ok"
        print(f"[{status}] availability.{metric}: current={c} (must be 0)")
        if c != 0:
            failures.append(f"availability.{metric} = {c} (must be 0)")
    migrations = avail.get("migrations", 0)
    status = "FAIL" if migrations < 1 else "ok"
    print(f"[{status}] availability.migrations: current={migrations} "
          f"(>= 1 — the soak must actually exercise migration)")
    if migrations < 1:
        failures.append("availability.migrations = 0 "
                        "(chaos soak never exercised migration)")
    print(f"[info] availability: faults_fired={avail.get('faults_fired')} "
          f"recovery_mean_ms={avail.get('recovery_mean_us', 0) / 1e3:.1f} "
          f"p95_ms={avail.get('recovery_p95_us', 0) / 1e3:.1f} "
          f"max_ms={avail.get('recovery_max_us', 0) / 1e3:.1f}")
    return True


def _check_placement(current, baseline, failures):
    """Heterogeneous cost-study gates (when the section is present).

    Absolute: fleet100 cost_advantage >= COST_ADVANTAGE_FLOOR with both
    solvers placing equal demand.  Relative: each study's advantage must
    not drop more than BUDGET below the checked-in baseline."""
    place = current.get("placement")
    if place is None:
        return False
    base_place = (baseline or {}).get("placement", {})
    fleet100 = place.get("fleet100", {})
    adv = fleet100.get("cost_advantage", 0.0)
    equal = fleet100.get("equal_demand", False)
    status = "FAIL" if adv < COST_ADVANTAGE_FLOOR else "ok"
    print(f"[{status}] placement.fleet100.cost_advantage: "
          f"current={adv:.4f} "
          f"(floor={COST_ADVANTAGE_FLOOR:.2f} absolute)")
    if adv < COST_ADVANTAGE_FLOOR:
        failures.append(
            f"placement.fleet100.cost_advantage = {adv:.4f} "
            f"(< {COST_ADVANTAGE_FLOOR:.2f}: cost-optimal no longer "
            f"beats VRAM-only placement)")
    status = "FAIL" if not equal else "ok"
    print(f"[{status}] placement.fleet100.equal_demand: {equal} "
          f"(placed_vram={fleet100.get('placed_vram')} "
          f"placed_cost_optimal={fleet100.get('placed_cost_optimal')})")
    if not equal:
        failures.append(
            "placement.fleet100.equal_demand is false — the solvers "
            "placed different demand, cost comparison is meaningless")
    for label in ("testbed6", "fleet100"):
        b = base_place.get(label, {}).get("cost_advantage")
        c = place.get(label, {}).get("cost_advantage")
        if b is None or c is None:
            continue
        limit = b * (1 - BUDGET)
        status = "FAIL" if c < limit else "ok"
        print(f"[{status}] placement.{label}.cost_advantage vs baseline: "
              f"current={c:.4f} baseline={b:.4f} (floor={limit:.4f})")
        if c < limit:
            failures.append(
                f"placement.{label}.cost_advantage regressed "
                f"{(1 - c / b) * 100:.1f}% (> {BUDGET * 100:.0f}%)")
        cur = place.get(label, {})
        print(f"[info] placement.{label}: "
              f"cpt_vram={cur.get('cost_per_token_vram', 0):.4e} "
              f"cpt_cost={cur.get('cost_per_token_cost_optimal', 0):.4e} "
              f"util_vram={cur.get('utilization_vram', 0):.4f} "
              f"util_cost={cur.get('utilization_cost_optimal', 0):.4f}")
    return True


def main(argv):
    args = list(argv[1:])
    only = None
    if "--only" in args:                 # e.g. --only availability
        i = args.index("--only")
        only = args[i + 1] if i + 1 < len(args) else None
        del args[i:i + 2]
    current_path = Path(args[0] if args else "BENCH_serving.json")
    baseline_path = Path(args[1] if len(args) > 1
                         else "benchmarks/baseline_serving.json")
    current = json.loads(current_path.read_text())

    if only == "availability":           # chaos-soak job: absolute gates
        failures = []
        if not _check_availability(current, failures):
            failures.append(
                f"availability section missing from {current_path}")
        if failures:
            print("\nBench regression gate FAILED:")
            for f in failures:
                print(f"  - {f}")
            return 1
        print("\nBench regression gate passed.")
        return 0

    if only == "placement":              # placement-gate job
        failures = []
        try:
            baseline = json.loads(baseline_path.read_text())
        except (FileNotFoundError, ValueError):
            baseline = {}
        if not _check_placement(current, baseline, failures):
            failures.append(
                f"placement section missing from {current_path}")
        if failures:
            print("\nBench regression gate FAILED:")
            for f in failures:
                print(f"  - {f}")
            return 1
        print("\nBench regression gate passed.")
        return 0

    baseline = json.loads(baseline_path.read_text())

    failures = []
    for variant, base in baseline["fused"].items():
        if variant == "reduction":
            continue
        cur = current.get("fused", {}).get(variant)
        if cur is None:
            failures.append(f"{variant}: missing from {current_path}")
            continue
        for metric in GATED_METRICS:
            b, c = base[metric], cur[metric]
            limit = b * (1 + BUDGET)
            status = "FAIL" if c > limit else "ok"
            print(f"[{status}] fused.{variant}.{metric}: "
                  f"current={c:.6f} baseline={b:.6f} "
                  f"(limit={limit:.6f})")
            if c > limit:
                failures.append(
                    f"fused.{variant}.{metric} regressed "
                    f"{(c / b - 1) * 100:.1f}% (> {BUDGET * 100:.0f}%)")
        # informational only — never gates
        print(f"[info] fused.{variant}.tok_per_s: "
              f"current={cur.get('tok_per_s', 0.0):.1f} "
              f"baseline={base.get('tok_per_s', 0.0):.1f}")

    # paged KV study: utilization gates (higher is better); occupancy
    # and preemptions are printed for the record
    base_paged = baseline.get("paged", {}).get("paged")
    cur_paged = current.get("paged", {}).get("paged")
    if base_paged is not None:
        if cur_paged is None:
            failures.append(f"paged study missing from {current_path}")
        else:
            b = base_paged["kv_page_utilization"]
            c = cur_paged["kv_page_utilization"]
            limit = b * (1 - BUDGET)
            status = "FAIL" if c < limit else "ok"
            print(f"[{status}] paged.kv_page_utilization: "
                  f"current={c:.6f} baseline={b:.6f} "
                  f"(floor={limit:.6f})")
            if c < limit:
                failures.append(
                    f"paged.kv_page_utilization regressed "
                    f"{(1 - c / b) * 100:.1f}% (> {BUDGET * 100:.0f}%)")
            print(f"[info] paged: peak_active="
                  f"{cur_paged.get('peak_active_slots')} "
                  f"(contiguous="
                  f"{current['paged']['contiguous']['peak_active_slots']})"
                  f" preemptions={cur_paged.get('preemptions')} "
                  f"tok_per_s={cur_paged.get('tok_per_s', 0):.1f}")

    # prefix-cache study: hit rate gates (higher is better); dispatch
    # tokens and TTFT are printed for the record
    base_pref = baseline.get("prefix", {}).get("cache_on")
    cur_pref = current.get("prefix", {}).get("cache_on")
    if base_pref is not None:
        if cur_pref is None:
            failures.append(f"prefix study missing from {current_path}")
        else:
            b = base_pref["prefix_hit_rate"]
            c = cur_pref["prefix_hit_rate"]
            limit = b * (1 - BUDGET)
            status = "FAIL" if c < limit else "ok"
            print(f"[{status}] prefix.prefix_hit_rate: "
                  f"current={c:.6f} baseline={b:.6f} "
                  f"(floor={limit:.6f})")
            if c < limit:
                failures.append(
                    f"prefix.prefix_hit_rate regressed "
                    f"{(1 - c / b) * 100:.1f}% (> {BUDGET * 100:.0f}%)")
            off = current.get("prefix", {}).get("cache_off", {})
            print(f"[info] prefix: prefill_tokens_on="
                  f"{cur_pref.get('prefill_dispatch_tokens')} "
                  f"off={off.get('prefill_dispatch_tokens')} "
                  f"mean_ttft_on_ms="
                  f"{cur_pref.get('mean_ttft_ms', 0):.2f} "
                  f"off={off.get('mean_ttft_ms', 0):.2f}")

    # paged-attention study: logical KV bytes moved per token gates
    # (lower is better — the whole point of the page-table-direct
    # kernel); dispatch equality is asserted by the bench itself
    base_pa = baseline.get("paged_attn", {}).get("paged_attn")
    cur_pa = current.get("paged_attn", {}).get("paged_attn")
    if base_pa is not None:
        if cur_pa is None:
            failures.append(f"paged_attn study missing from {current_path}")
        else:
            b = base_pa["logical_bytes_moved_per_token"]
            c = cur_pa["logical_bytes_moved_per_token"]
            limit = b * (1 + BUDGET)
            status = "FAIL" if c > limit else "ok"
            print(f"[{status}] paged_attn.logical_bytes_moved_per_token: "
                  f"current={c:.1f} baseline={b:.1f} "
                  f"(limit={limit:.1f})")
            if c > limit:
                failures.append(
                    f"paged_attn.logical_bytes_moved_per_token regressed "
                    f"{(c / b - 1) * 100:.1f}% (> {BUDGET * 100:.0f}%)")
            gain = current.get("paged_attn", {}).get("gain", {})
            gat = current.get("paged_attn", {}).get("gather", {})
            print(f"[info] paged_attn: reduction_x="
                  f"{gain.get('logical_bytes_moved_per_token', 0):.1f} "
                  f"gather_bytes_per_token="
                  f"{gat.get('logical_bytes_moved_per_token', 0):.0f}")

    # speculative-decoding study: accepted tokens per verify dispatch is
    # informational (workload-shaped) — the bench itself asserts > 1.0
    # and token-identical outputs, so CI still fails on a real break
    cur_spec = current.get("spec", {}).get("spec_on")
    if cur_spec is not None:
        spec_off = current.get("spec", {}).get("spec_off", {})
        print(f"[info] spec: accepted_per_dispatch="
              f"{cur_spec.get('spec_accepted_per_dispatch', 0):.2f} "
              f"dispatches_per_token="
              f"{cur_spec.get('dispatches_per_token', 0):.4f} "
              f"(off={spec_off.get('dispatches_per_token', 0):.4f})")

    rt = current.get("runtime")
    if rt is not None:
        print(f"[info] runtime: tenants={rt.get('tenants')} "
              f"completed={rt.get('completed')} "
              f"rate_limited={rt.get('rate_limited')} "
              f"caller_pumps={rt.get('caller_pumps')} "
              f"scale_ups={rt.get('scale_ups')}")

    http = current.get("http")
    if http is not None:              # informational only — never gates
        print(f"[info] http: req_per_s={http.get('http_req_per_s', 0):.1f} "
              f"p95_ttft_ms={http.get('http_p95_ttft_ms', 0):.1f} "
              f"inproc_req_per_s={http.get('inproc_req_per_s', 0):.1f} "
              f"inproc_p95_ttft_ms="
              f"{http.get('inproc_p95_ttft_ms', 0):.1f}")

    _check_availability(current, failures)   # gates when section present
    _check_placement(current, baseline, failures)

    if failures:
        print("\nBench regression gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nBench regression gate passed.")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))

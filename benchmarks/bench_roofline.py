"""Roofline table from the dry-run artifacts: one row per (arch x shape x
mesh) cell — the per-table benchmark the grading reads.  Requires
results/dryrun/*.json (python -m repro.launch.dryrun --all --mesh both)."""
from __future__ import annotations

import glob
import json
from pathlib import Path


def run(results_dir: str = "results/dryrun"):
    rows = []
    files = sorted(glob.glob(str(Path(results_dir) / "*.json")))
    if not files:
        return [("roofline_table", 0.0, "MISSING: run repro.launch.dryrun")]
    for f in files:
        rec = json.loads(Path(f).read_text())
        tag = f"roofline_{rec['arch']}_{rec['shape']}_{rec['mesh']}"
        if rec["status"] == "skipped":
            rows.append((tag, 0.0, "skipped_subquadratic_rule"))
            continue
        if rec["status"] != "ok":
            rows.append((tag, 0.0, f"ERROR:{rec.get('error','?')[:60]}"))
            continue
        r = rec["roofline"]
        bound = max(r["compute_s"], r["memory_adj_s"],
                    r["collective_adj_s"])
        rows.append((tag, bound * 1e6,
                     f"dom={rec['dominant']};"
                     f"frac={rec['roofline_fraction']:.3f};"
                     f"useful={r['useful_ratio']:.2f}"))
    return rows

"""Step builders: jit-able train / prefill / decode steps with full sharding
specifications, plus `input_specs()` — ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no device allocation).

These are the functions the dry-run lowers and the launchers execute.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.distributed.sharding import (Strategy, make_sharder,
                                        make_tp_col_projector,
                                        make_tp_gather, make_tp_projector,
                                        make_weight_sharder, pick_strategy,
                                        train_compute_strategy,
                                        tree_shardings)
from repro.models import Model, build
from repro.training import optimizer as opt_lib

PyTree = Any

BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "prefix_embeds": ("batch", "seq", "embed"),
    "src_embeds": ("batch", "seq", "embed"),
}


# --------------------------------------------------------------------- #
# Input specs (ShapeDtypeStruct stand-ins; no allocation)

def batch_specs(cfg: ArchConfig, shape: ShapeSpec,
                with_labels: bool = True) -> Dict[str, Any]:
    b, s = shape.batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    out: Dict[str, Any] = {}
    n_text = s - (cfg.n_prefix_tokens if cfg.frontend == "vision" else 0)
    out["tokens"] = sds((b, n_text), jnp.int32)
    if with_labels:
        out["labels"] = sds((b, n_text), jnp.int32)
    if cfg.frontend == "vision":
        out["prefix_embeds"] = sds((b, cfg.n_prefix_tokens, cfg.d_model), dt)
    if cfg.is_encdec:
        src = int(s * cfg.encdec.src_len_ratio)
        out["src_embeds"] = sds((b, src, cfg.d_model), dt)
    return out


def cache_len_for(cfg: ArchConfig, shape: ShapeSpec) -> int:
    """Decode cache length: seq_len + always-resident prefix tokens."""
    return shape.seq_len + cfg.n_meta_tokens + \
        (cfg.n_prefix_tokens if cfg.frontend == "vision" else 0)


def decode_specs(cfg: ArchConfig, shape: ShapeSpec,
                 kv_quant: bool = False) -> Dict[str, Any]:
    model = build(cfg)
    b = shape.batch
    max_len = cache_len_for(cfg, shape)
    src = int(shape.seq_len * cfg.encdec.src_len_ratio) if cfg.is_encdec \
        else 0
    cache = jax.eval_shape(
        lambda: model.init_cache(b, max_len, src_len=src,
                                 kv_quant=kv_quant))
    return {"cache": cache,
            "token": jax.ShapeDtypeStruct((b,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((b,), jnp.int32)}


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """All step inputs for this (arch x shape) cell, as ShapeDtypeStructs."""
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, shape, with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, shape, with_labels=False)}
    return decode_specs(cfg, shape)


# --------------------------------------------------------------------- #
# Sharding trees

def param_shardings(model: Model, mesh: Mesh, strategy: Strategy):
    return tree_shardings(model.param_axes(), model.param_specs(), mesh,
                          strategy)


def batch_shardings(cfg: ArchConfig, specs: Dict, mesh: Mesh,
                    strategy: Strategy):
    return {k: strategy.sharding_for(BATCH_AXES[k], v.shape, mesh)
            for k, v in specs.items()}


def cache_shardings(model: Model, cache_specs, mesh: Mesh,
                    strategy: Strategy, kv_quant: bool = False):
    return tree_shardings(model.cache_axes(kv_quant=kv_quant),
                          cache_specs, mesh, strategy)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# --------------------------------------------------------------------- #
# Step builders

def make_train_step(cfg: ArchConfig, mesh: Optional[Mesh] = None,
                    strategy: Optional[Strategy] = None,
                    opt_cfg: Optional[opt_lib.AdamWConfig] = None):
    """Returns (train_step, init_state_fn).  State = params + adamw + step."""
    model = build(cfg)
    opt_cfg = opt_cfg or opt_lib.AdamWConfig()
    sh = make_sharder(mesh, strategy)
    # explicit per-layer FSDP weight gather (see sharding.py): fsdp_tp
    # gathers only the embed dim; pure-fsdp gathers whole layer weights
    shw = None
    if mesh is not None and strategy is not None:
        comp = train_compute_strategy(mesh) if strategy.name == "fsdp_tp" \
            else Strategy(rules={}, priority=[], name="gather_all")
        shw = make_weight_sharder(mesh, comp)
        # explicit Megatron-SP collectives: row-parallel reduce-scatter
        # out-projections, fused column-parallel gather+einsum, and the
        # standalone seq gather (all with exact psum_scatter transposes)
        sh.tp_project = make_tp_projector(mesh, strategy, comp)
        sh.tp_col_project = make_tp_col_projector(mesh, strategy, comp)
        sh.tp_gather = make_tp_gather(mesh, strategy)

    def init_state(key):
        params = model.init(key)
        return {"params": params, "opt": opt_lib.adamw_init(params),
                "step": jnp.zeros((), jnp.int32)}

    def train_step(state, batch):
        def lossf(p):
            return model.loss(p, batch, sh=sh, shw=shw, remat=True)
        (loss, mets), grads = jax.value_and_grad(
            lossf, has_aux=True)(state["params"])
        new_p, new_opt, om = opt_lib.adamw_update(
            state["params"], grads, state["opt"], state["step"], opt_cfg)
        metrics = {"loss": mets["loss"], "aux": mets["aux"],
                   "grad_norm": om["grad_norm"], "lr": om["lr"]}
        return ({"params": new_p, "opt": new_opt,
                 "step": state["step"] + 1}, metrics)

    return train_step, init_state


def state_shardings(cfg: ArchConfig, mesh: Mesh, strategy: Strategy):
    model = build(cfg)
    ps = param_shardings(model, mesh, strategy)
    return {"params": ps, "opt": {"m": ps, "v": ps},
            "step": replicated(mesh)}


def state_specs(cfg: ArchConfig):
    model = build(cfg)
    p = model.param_specs()
    def f32(s):
        return jax.ShapeDtypeStruct(s.shape, jnp.float32)

    return {"params": p,
            "opt": {"m": jax.tree.map(f32, p), "v": jax.tree.map(f32, p)},
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def make_prefill_step(cfg: ArchConfig, shape: ShapeSpec,
                      mesh: Optional[Mesh] = None,
                      strategy: Optional[Strategy] = None):
    model = build(cfg)
    sh = make_sharder(mesh, strategy)
    max_len = cache_len_for(cfg, shape)

    def prefill_step(params, batch):
        return model.prefill(params, batch["tokens"],
                             prefix_embeds=batch.get("prefix_embeds"),
                             src_embeds=batch.get("src_embeds"),
                             cache_len=max_len, sh=sh)
    return prefill_step


def make_decode_step(cfg: ArchConfig, mesh: Optional[Mesh] = None,
                     strategy: Optional[Strategy] = None):
    model = build(cfg)
    sh = make_sharder(mesh, strategy)

    def decode_step(params, cache, token, pos):
        return model.decode(params, cache, token, pos, sh=sh)
    return decode_step


# --------------------------------------------------------------------- #
# Lowering helpers (used by dryrun + benchmarks)

def lower_cell(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
               strategy_override: str = "", donate_cache: bool = True,
               variant: str = ""):
    """Lower (not compile) the step for one (arch x shape x mesh) cell.

    Returns (lowered, info dict).
    """
    model = build(cfg)
    strategy = pick_strategy(
        "train" if shape.kind == "train" else "serve", mesh,
        cfg.num_params(), override=strategy_override)
    kv_quant = (variant == "int8kv" and shape.kind == "decode"
                and cfg.block != "xlstm")
    specs = input_specs(cfg, shape)
    if kv_quant:
        specs = decode_specs(cfg, shape, kv_quant=True)
    with mesh:
        if shape.kind == "train":
            step, _ = make_train_step(cfg, mesh, strategy)
            st_sh = state_shardings(cfg, mesh, strategy)
            b_sh = batch_shardings(cfg, specs["batch"], mesh, strategy)
            lowered = jax.jit(
                step,
                in_shardings=(st_sh, b_sh),
                out_shardings=(st_sh, replicated(mesh)),
            ).lower(state_specs(cfg), specs["batch"])
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, shape, mesh, strategy)
            p_sh = param_shardings(model, mesh, strategy)
            b_sh = batch_shardings(cfg, specs["batch"], mesh, strategy)
            lowered = jax.jit(
                step, in_shardings=(p_sh, b_sh),
            ).lower(model.param_specs(), specs["batch"])
        else:
            step = make_decode_step(cfg, mesh, strategy)
            p_sh = param_shardings(model, mesh, strategy)
            c_sh = cache_shardings(model, specs["cache"], mesh, strategy,
                                   kv_quant=kv_quant)
            tok_sh = strategy.sharding_for(("batch",),
                                           specs["token"].shape, mesh)
            lowered = jax.jit(
                step,
                in_shardings=(p_sh, c_sh, tok_sh, tok_sh),
                out_shardings=(
                    strategy.sharding_for(
                        ("batch", "vocab"),
                        (shape.batch, cfg.vocab), mesh), c_sh),
                donate_argnums=(1,) if donate_cache else (),
            ).lower(model.param_specs(), specs["cache"], specs["token"],
                    specs["pos"])
    return lowered, {"strategy": strategy.name,
                     "variant": variant}

"""Mesh construction.  Functions only — importing this module never touches
jax device state (spec requirement)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips for the multi-pod run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_node_mesh(n_chips: int):
    """Per-backend-node mesh (TP within one heterogeneous serving node)."""
    return jax.make_mesh((n_chips,), ("model",))


def make_host_mesh():
    """Single-device mesh for CPU smoke tests / tiny serving replicas."""
    return jax.make_mesh((1,), ("model",))

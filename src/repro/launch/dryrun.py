import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production mesh, prove memory fits, and extract the
roofline terms.  The two lines above MUST run before any jax import — jax
locks the device count on first init.

Usage:
    python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             strategy_override: str = "", out_dir: str = "results/dryrun",
             save_hlo: bool = False, variant: str = "") -> dict:
    import jax
    from repro.configs import get_config, SHAPES, runnable
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import lower_cell
    from repro.roofline.analysis import analyze, model_flops_for

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = runnable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "strategy": strategy_override or "auto", "variant": variant}
    if not ok:
        rec.update({"status": "skipped", "reason": reason})
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    try:
        t0 = time.time()
        lowered, info = lower_cell(cfg, shape, mesh,
                                   strategy_override=strategy_override,
                                   variant=variant)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        mem_d = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes", "peak_memory_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_d[k] = int(v)
        hlo = compiled.as_text()
        roof = analyze(compiled, chips,
                       model_flops_global=model_flops_for(cfg, shape),
                       hlo_text=hlo)
        rec.update({
            "status": "ok", "strategy": info["strategy"], "chips": chips,
            "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
            "memory": mem_d,
            "roofline": roof.to_dict(),
            "dominant": roof.dominant,
            "roofline_fraction": roof.roofline_fraction(),
        })
        if save_hlo:
            hp = Path(out_dir) / f"{arch}__{shape_name}__{mesh_kind}.hlo"
            hp.parent.mkdir(parents=True, exist_ok=True)
            hp.write_text(hlo)
    except Exception as e:
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-3000:]})
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--strategy", default="")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--variant", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCHS, SHAPES
    archs = list(ARCHS) if (args.all or not args.arch) \
        else args.arch.split(",")
    shapes = list(SHAPES) if (args.all or not args.shape) \
        else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"{arch}__{shape}__{mesh_kind}"
                if args.strategy:
                    tag += f"__{args.strategy}"
                if args.variant:
                    tag += f"__{args.variant}"
                fp = out_dir / f"{tag}.json"
                if args.skip_existing and fp.exists():
                    prev = json.loads(fp.read_text())
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[skip-existing] {tag}", flush=True)
                        continue
                t0 = time.time()
                rec = run_cell(arch, shape, mesh_kind,
                               strategy_override=args.strategy,
                               out_dir=args.out, save_hlo=args.save_hlo,
                               variant=args.variant)
                fp.write_text(json.dumps(rec, indent=1))
                dt = time.time() - t0
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"[ok]   {tag} ({dt:.0f}s) dominant="
                          f"{rec['dominant']} "
                          f"c/m/coll={r['compute_s']:.3f}/"
                          f"{r['memory_s']:.3f}/{r['collective_s']:.3f}s "
                          f"frac={rec['roofline_fraction']:.2f}",
                          flush=True)
                elif rec["status"] == "skipped":
                    print(f"[skip] {tag}: {rec['reason'][:60]}", flush=True)
                else:
                    n_fail += 1
                    print(f"[FAIL] {tag}: {rec['error']}", flush=True)
    print(f"done; {n_fail} failures", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

"""Synthetic-but-structured LM data pipeline.

Deterministic seeded streams (restart-safe: the iterator state is just
(seed, step)), sequence packing, and per-host sharding.  The token
distribution is a Zipfian mixture with local n-gram structure so models
actually *learn* (loss drops measurably within a few hundred steps —
the train_100m example relies on this).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    # structure knobs
    zipf_a: float = 1.2
    ngram_order: int = 3
    ngram_tables: int = 4096


class SyntheticLM:
    """Markov-ish synthetic corpus: next token depends on a hash of the
    previous `ngram_order` tokens, mixed with Zipf noise."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        # per-context preferred continuations (the learnable signal)
        self._table = base.integers(
            0, cfg.vocab, size=(cfg.ngram_tables,), dtype=np.int64)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._zipf_p = p / p.sum()

    def _ctx_hash(self, ctx: np.ndarray) -> np.ndarray:
        h = np.zeros(ctx.shape[0], dtype=np.int64)
        for j in range(ctx.shape[1]):
            h = h * 1000003 + ctx[:, j]
        return np.abs(h) % self.cfg.ngram_tables

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for a global step (restart-safe)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        b, s = cfg.batch, cfg.seq_len
        toks = np.empty((b, s + 1), dtype=np.int32)
        toks[:, :cfg.ngram_order] = rng.integers(
            0, cfg.vocab, size=(b, cfg.ngram_order))
        follow = rng.random((b, s + 1)) < 0.65     # P(use table)
        noise = rng.choice(cfg.vocab, size=(b, s + 1), p=self._zipf_p)
        for t in range(cfg.ngram_order, s + 1):
            ctx = toks[:, t - cfg.ngram_order:t]
            preferred = self._table[self._ctx_hash(ctx)]
            toks[:, t] = np.where(follow[:, t], preferred, noise[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def host_shard(batch: Dict[str, np.ndarray], host_id: int,
               n_hosts: int) -> Dict[str, np.ndarray]:
    """Per-host slice of the global batch (multi-host input pipeline)."""
    out = {}
    for k, v in batch.items():
        per = v.shape[0] // n_hosts
        out[k] = v[host_id * per:(host_id + 1) * per]
    return out

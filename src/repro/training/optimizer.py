"""Optimizers in pure JAX (no optax): AdamW (fp32 moments, bf16 params) and
Adafactor (sub-linear memory — the legacy-HBM-friendly option, in the spirit
of the paper's "fully use each node's VRAM").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_init(params) -> Dict[str, PyTree]:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def adamw_update(params, grads, opt_state, step, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:   # no decay on norms/bias
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}


# --------------------------------------------------------------------- #
# Adafactor (factored second moments; beyond-paper memory saver)

@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-2
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0


def adafactor_init(params):
    def fac(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"fac": jax.tree.map(fac, params,
                                is_leaf=lambda x: hasattr(x, "shape"))}


def adafactor_update(params, grads, opt_state, step, cfg: AdafactorConfig):
    t = step.astype(jnp.float32) + 1.0
    beta = 1.0 - t ** (-cfg.decay)

    def upd(p, g, st):
        g = g.astype(jnp.float32)
        g2 = g * g + cfg.eps
        if p.ndim >= 2:
            vr = beta * st["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * st["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            r = vr / jnp.maximum(
                jnp.mean(vr, axis=-1, keepdims=True), cfg.eps)
            u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                     + cfg.eps)
            st2 = {"vr": vr, "vc": vc}
        else:
            v = beta * st["v"] + (1 - beta) * g2
            u = g / (jnp.sqrt(v) + cfg.eps)
            st2 = {"v": v}
        rms = jnp.sqrt(jnp.mean(u * u))
        u = u / jnp.maximum(1.0, rms / cfg.clip_threshold)
        return (p.astype(jnp.float32) - cfg.lr * u).astype(p.dtype), st2

    tdef = jax.tree.structure(params)
    # rebuild via tree to keep structures aligned
    paired = jax.tree.map(lambda p, g: (p, g), params, grads)
    out_p, out_s = [], []
    leaves_ps = jax.tree.leaves(paired, is_leaf=lambda x:
                                isinstance(x, tuple) and len(x) == 2
                                and hasattr(x[0], "shape"))
    leaves_st = jax.tree.leaves(
        opt_state["fac"], is_leaf=lambda x: isinstance(x, dict)
        and ("v" in x or "vr" in x))
    for (p, g), st in zip(leaves_ps, leaves_st):
        np_, ns = upd(p, g, st)
        out_p.append(np_)
        out_s.append(ns)
    new_p = jax.tree.unflatten(tdef, out_p)
    st_def = jax.tree.structure(
        opt_state["fac"], is_leaf=lambda x: isinstance(x, dict)
        and ("v" in x or "vr" in x))
    new_fac = jax.tree.unflatten(st_def, out_s)
    return new_p, {"fac": new_fac}, {}

"""Training loop with production fault-tolerance semantics:

* deterministic restart-safe data (batch_at(step)),
* periodic atomic checkpoints (CheckpointManager),
* crash recovery: `Trainer.run` resumes from the latest checkpoint —
  resume-equality is tested (train 2N steps == train N, crash, resume N),
* elastic re-mesh: `remesh_state` re-shards a state pytree onto a new mesh
  (shrunk/grown fleet) — the training analogue of the SDAI controller's
  dynamic reallocation,
* optional int8 error-feedback gradient compression.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import Strategy
from repro.launch.steps import make_train_step, state_shardings
from repro.models import build
from repro.training import compression as comp_lib
from repro.training import optimizer as opt_lib
from repro.training.checkpoint import CheckpointManager
from repro.training.data import DataConfig, SyntheticLM


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    compress_grads: bool = False
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ArchConfig, data_cfg: DataConfig,
                 tcfg: TrainConfig,
                 opt_cfg: Optional[opt_lib.AdamWConfig] = None,
                 mesh=None, strategy: Optional[Strategy] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.model = build(cfg)
        self.data = SyntheticLM(data_cfg)
        self.mgr = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.mesh = mesh
        self.opt_cfg = opt_cfg or opt_lib.AdamWConfig()
        step_fn, init_fn = make_train_step(cfg, mesh, strategy,
                                           self.opt_cfg)
        self._init_fn = init_fn
        if tcfg.compress_grads:
            step_fn = self._wrap_compression(step_fn)
        self._step = jax.jit(step_fn, donate_argnums=(0,))
        self.history: List[Dict] = []

    # ------------------------------------------------------------- #
    def _wrap_compression(self, step_fn):
        model, opt_cfg = self.model, self.opt_cfg

        def compressed_step(state, batch):
            def lossf(p):
                return model.loss(p, batch, remat=True)
            (loss, mets), grads = jax.value_and_grad(
                lossf, has_aux=True)(state["params"])
            _, deq, new_err = comp_lib.compress_tree(grads, state["err"])
            new_p, new_opt, om = opt_lib.adamw_update(
                state["params"], deq, state["opt"], state["step"],
                opt_cfg)
            return ({"params": new_p, "opt": new_opt, "err": new_err,
                     "step": state["step"] + 1},
                    {"loss": mets["loss"], "aux": mets["aux"],
                     "grad_norm": om["grad_norm"], "lr": om["lr"]})
        return compressed_step

    def init_state(self, seed: int = 0):
        state = self._init_fn(jax.random.PRNGKey(seed))
        if self.tcfg.compress_grads:
            state["err"] = comp_lib.init_error(state["params"])
        return state

    # ------------------------------------------------------------- #
    def run(self, resume: bool = True) -> Dict[str, Any]:
        """Train to tcfg.steps, resuming from the latest checkpoint."""
        state = self.init_state(self.tcfg.seed)
        start = 0
        if resume:
            step0, state = self.mgr.restore_latest(state)
            if step0 is not None:
                start = step0
        t0 = time.monotonic()
        for step in range(start, self.tcfg.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in self.data.batch_at(step).items()}
            state, metrics = self._step(state, batch)
            if (step + 1) % self.tcfg.log_every == 0 or \
                    step == self.tcfg.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step + 1
                self.history.append(m)
            if (step + 1) % self.tcfg.ckpt_every == 0:
                self.mgr.save(step + 1, state)
        self.mgr.save(self.tcfg.steps, state)
        return {"state": state, "history": self.history,
                "wall_s": time.monotonic() - t0,
                "resumed_from": start}


# ------------------------------------------------------------------ #
# Elastic re-mesh

def remesh_state(state, cfg: ArchConfig, new_mesh,
                 new_strategy: Strategy):
    """Re-shard a training state onto a different mesh (node loss/join).
    With jax.device_put the runtime moves only the shards each device
    needs — this is the elastic-scaling primitive the launcher uses when
    the controller shrinks or grows the training fleet."""
    shard_tree = state_shardings(cfg, new_mesh, new_strategy)
    if "err" in state and "err" not in shard_tree:
        shard_tree = dict(shard_tree)
        shard_tree["err"] = shard_tree["params"]
    return jax.device_put(state, shard_tree)

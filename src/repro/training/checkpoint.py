"""Checkpointing: msgpack-serialized pytrees with atomic commits and a
keep-last-k manager.  This is both the training fault-tolerance substrate
(checkpoint/restart) and the SDAI controller's model store (the
"Ollama pull" analogue when re-placing models after a node failure).
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _pack_array(a: np.ndarray) -> Dict:
    if a.dtype == jnp.bfloat16:
        return {"dtype": "bfloat16", "shape": list(a.shape),
                "data": a.view(np.uint16).tobytes()}
    return {"dtype": a.dtype.str, "shape": list(a.shape),
            "data": a.tobytes()}


def _unpack_array(d: Dict) -> np.ndarray:
    if d["dtype"] == "bfloat16":
        raw = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return raw.view(jnp.bfloat16)
    return np.frombuffer(d["data"], np.dtype(d["dtype"])) \
        .reshape(d["shape"])


def save(tree: PyTree, path: str | Path):
    """Atomic checkpoint write (tmp file + rename)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = {k: _pack_array(v) for k, v in _flatten(tree).items()}
    blob = msgpack.packb(flat)
    with tempfile.NamedTemporaryFile(dir=path.parent, delete=False) as f:
        f.write(blob)
        tmp = f.name
    os.replace(tmp, path)        # atomic commit


def restore(path: str | Path, like: PyTree) -> PyTree:
    """Restore into the structure of `like` (shape/dtype-checked)."""
    blob = Path(path).read_bytes()
    flat = {k: _unpack_array(v)
            for k, v in msgpack.unpackb(blob).items()}
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves_with_path:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_k)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclasses.dataclass
class CheckpointManager:
    directory: str | Path
    keep: int = 3

    def __post_init__(self):
        self.directory = Path(self.directory)
        self.directory.mkdir(parents=True, exist_ok=True)

    def _path(self, step: int) -> Path:
        return self.directory / f"ckpt_{step:08d}.msgpack"

    def save(self, step: int, tree: PyTree):
        save(tree, self._path(step))
        self._gc()

    def latest_step(self) -> Optional[int]:
        steps = sorted(int(p.stem.split("_")[1])
                       for p in self.directory.glob("ckpt_*.msgpack"))
        return steps[-1] if steps else None

    def restore_latest(self, like: PyTree) -> Tuple[Optional[int], PyTree]:
        step = self.latest_step()
        if step is None:
            return None, like
        return step, restore(self._path(step), like)

    def _gc(self):
        steps = sorted(int(p.stem.split("_")[1])
                       for p in self.directory.glob("ckpt_*.msgpack"))
        for s in steps[:-self.keep]:
            self._path(s).unlink(missing_ok=True)

"""Error-feedback int8 gradient compression (EF-SGD style).

Per-tensor absmax int8 quantization of gradients with an error-feedback
accumulator: e_{t+1} = (g_t + e_t) - dequant(quant(g_t + e_t)).  The
accumulated error is re-injected next step, so the compression bias
vanishes asymptotically (property-tested: ||e|| stays bounded and training
convergence matches uncompressed within tolerance).

Wire accounting: the DP gradient reduction moves int8 payloads + one f32
scale per tensor — a 4x reduction vs f32 (2x vs bf16), which
`benchmarks/bench_compression.py` quantifies against the roofline
collective term.  (XLA's all-reduce cannot sum int8 payloads natively; on
real fleets this maps to a quantized ring all-reduce — dequantize-sum-
requantize per hop, the standard EF-ring construction.)
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def init_error(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(g, e):
    """One tensor: returns (q int8, scale f32 scalar, new_error f32)."""
    x = g.astype(jnp.float32) + e
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    x_hat = q.astype(jnp.float32) * scale
    return q, scale, x - x_hat


def decompress(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads: PyTree, err: PyTree) -> Tuple[PyTree, PyTree,
                                                       PyTree]:
    """Returns ({q, scale} tree, dequantized grads, new error tree)."""
    qs = jax.tree.map(lambda g, e: compress(g, e), grads, err)
    q_tree = jax.tree.map(lambda t: t[0], qs,
                          is_leaf=lambda x: isinstance(x, tuple))
    s_tree = jax.tree.map(lambda t: t[1], qs,
                          is_leaf=lambda x: isinstance(x, tuple))
    e_tree = jax.tree.map(lambda t: t[2], qs,
                          is_leaf=lambda x: isinstance(x, tuple))
    deq = jax.tree.map(decompress, q_tree, s_tree)
    return {"q": q_tree, "scale": s_tree}, deq, e_tree


def wire_bytes(tree: PyTree, compressed: bool) -> int:
    """Bytes a DP ring all-reduce moves per device for this gradient tree."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = leaf.size
        total += n * (1 if compressed else 4) + (4 if compressed else 0)
    return 2 * total          # ring all-reduce: 2x payload per device

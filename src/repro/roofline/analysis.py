"""Three-term roofline from compiled dry-run artifacts.

    compute_term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory_term     = HLO_bytes / (chips * HBM_bw)
    collective_term = collective_bytes / (chips * link_bw)

`cost_analysis()` on the compiled executable is *per-device* (the SPMD
module), so per-chip terms fall out directly.  Collective bytes are not in
cost_analysis — we parse the post-optimization HLO and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (async -start variants counted once).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e-class hardware constants (per spec)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link


def roofline_step_s(flops: float, hbm_bytes: float,
                    peak_flops: float = PEAK_FLOPS,
                    hbm_bw: float = HBM_BW) -> float:
    """Idealized step time under a two-term roofline: compute and memory
    perfectly overlap, so the step takes the *max* of the two terms.

    Parameterized over the capability vector (peak FLOP/s, HBM bytes/s)
    so the per-GPU-class perf model (`repro.core.perfmodel`) can reuse
    the same machinery the dry-run `analyze()` path applies to compiled
    HLO — the defaults keep the historical v5e constants."""
    if peak_flops <= 0 or hbm_bw <= 0:
        return float("inf")
    return max(flops / peak_flops, hbm_bytes / hbm_bw)

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_COLL_RE = re.compile(
    r"= (\([^)]*\)|\S+) (all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


def collective_bytes(hlo_text: str, n_devices: int = 2) -> Dict[str, float]:
    """Per-collective-kind *wire bytes per device* (ring model) from
    post-optimization HLO text.

    HLO collective instructions only carry output types inline, so bytes are
    derived from the output (largest buffer F) and the replica-group size g:
      all-gather / reduce-scatter / all-to-all: F*(g-1)/g
      all-reduce: 2*F*(g-1)/g        collective-permute: F
    (the classic ring-collective cost; -start async variants counted once,
    -done skipped).
    """
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind, is_start = m.group(2), bool(m.group(3))
        lhs = m.group(1)
        shapes = [_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(lhs)]
        shapes = [s for s in shapes if s > 0]
        if not shapes:
            continue
        # async -start returns a (in, out, ...) tuple: the largest element
        # is the full buffer; sync ops list outputs only -> sum (tuple AR).
        f = max(shapes) if is_start else sum(shapes)
        g = _group_size(line, n_devices)
        if kind == "all-reduce":
            wire = 2.0 * f * (g - 1) / g
        elif kind == "reduce-scatter":
            # output is the scattered shard: full buffer = f * g
            full = (f if is_start else f * g)
            wire = full * (g - 1) / g
        elif kind == "collective-permute":
            wire = f
        else:                            # all-gather, all-to-all
            wire = f * (g - 1) / g
        out[kind] = out.get(kind, 0.0) + wire
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: Dict[str, float]
    chips: int
    # HLO traffic inside Pallas-kernel-tagged regions (attention tiles,
    # mLSTM decay matrices): VMEM-resident on the target hardware, HBM
    # traffic only in the portable jnp fallback the dry-run compiles.
    kernel_bytes_per_chip: float = 0.0
    kernel_coll_bytes_per_chip: float = 0.0
    # derived (raw = portable fallback; adj = Pallas-kernel-adjusted)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    memory_adj_s: float = 0.0
    collective_adj_s: float = 0.0
    dominant: str = ""
    model_flops: float = 0.0
    useful_ratio: float = 0.0

    def finish(self, model_flops_global: float = 0.0):
        self.compute_s = self.flops_per_chip / PEAK_FLOPS
        self.memory_s = self.bytes_per_chip / HBM_BW
        self.collective_s = self.coll_bytes_per_chip / LINK_BW
        self.memory_adj_s = max(
            self.bytes_per_chip - self.kernel_bytes_per_chip, 0.0) / HBM_BW
        self.collective_adj_s = max(
            self.coll_bytes_per_chip - self.kernel_coll_bytes_per_chip,
            0.0) / LINK_BW
        terms = {"compute": self.compute_s, "memory": self.memory_adj_s,
                 "collective": self.collective_adj_s}
        self.dominant = max(terms, key=terms.get)
        self.model_flops = model_flops_global
        hlo_global = self.flops_per_chip * self.chips
        self.useful_ratio = (model_flops_global / hlo_global
                             if hlo_global else 0.0)
        return self

    def bound_s(self) -> float:
        """Idealized step time if terms perfectly overlap = max of terms
        (kernel-adjusted memory/collective)."""
        return max(self.compute_s, self.memory_adj_s,
                   self.collective_adj_s)

    def roofline_fraction(self) -> float:
        """compute_term / max-term: 1.0 when compute-bound (the goal)."""
        b = self.bound_s()
        return self.compute_s / b if b else 0.0

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def analyze(compiled, chips: int, model_flops_global: float = 0.0,
            hlo_text: Optional[str] = None) -> Roofline:
    """Loop-aware roofline: uses the HLO text profiler (which multiplies
    while-loop bodies by their trip counts — `cost_analysis()` counts scan
    bodies once and under-counts scanned models by n_layers x)."""
    from repro.roofline.hlo_profile import profile as hlo_profile
    text = hlo_text if hlo_text is not None else compiled.as_text()
    prof = hlo_profile(text, n_devices=chips)
    r = Roofline(
        flops_per_chip=prof.flops, bytes_per_chip=prof.bytes,
        coll_bytes_per_chip=prof.coll_bytes,
        coll_breakdown=dict(prof.coll_breakdown),
        kernel_bytes_per_chip=prof.kernel_bytes,
        kernel_coll_bytes_per_chip=prof.kernel_coll_bytes,
        chips=chips).finish(model_flops_global)
    return r


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N_active*D (D = tokens processed by the step)."""
    n = cfg.active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens()
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens()      # forward only
    return 2.0 * n * shape.batch             # decode: one token per seq

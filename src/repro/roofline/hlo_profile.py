"""HLO text profiler: loop-aware flops / bytes / collective accounting.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — useless
for scan-over-layers models (an 80-layer scan under-counts by 80x).  This
module parses post-optimization HLO text, builds the computation call graph,
extracts loop trip counts from scan conditions, and propagates a
*multiplicity* to every computation:

    entry            x1
    while body/cond  x trip_count (nested loops multiply)
    fusion/call      x caller multiplicity

It then accounts, per computation and scaled by multiplicity:
  * dot FLOPs   — 2 * prod(out_shape) * prod(contracted lhs dims),
  * bytes       — operand + output bytes of scope-level instructions
                  (the HBM-traffic proxy XLA itself uses, post-fusion),
  * collectives — ring-model wire bytes (see analysis.collective_bytes).

All quantities are per-device (the SPMD module is per-device).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "token": 0,
    "u1": 0.125, "s1": 0.125,
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-_]+)\s*\(.*\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*(.*)$")
_SHAPE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPNAME = re.compile(r"%([\w\.\-_]+)")
_CALL_ATTR = re.compile(
    r"(?:calls|to_apply|body|condition)=%?([\w\.\-_]+)")
_WHILE = re.compile(r"\bwhile\(")
_CONST_INT = re.compile(r"=\s*s(?:32|64)\[\]\s*constant\((\d+)\)")
_COLL = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_DOT = re.compile(r"\bdot\(")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_OPKIND = re.compile(
    r"(?:\}|\]|\))\s+([a-z][a-z0-9\-\.]*)\(|^([a-z][a-z0-9\-]*)\(")

# ops that move no HBM bytes themselves (aliases / metadata / control)
_ZERO_BYTE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "custom-call", "rng-get-and-update-state", "infeed", "outfeed",
    "opt-barrier",
}
# ops whose traffic ~= 2x their output (read out-size, write out-size)
_OUT2_OPS = {
    "copy", "convert", "transpose", "reshape", "slice", "dynamic-slice",
    "broadcast", "iota", "reverse", "reduce", "concatenate", "pad",
    "gather", "select", "compare", "add", "subtract", "multiply", "divide",
    "maximum", "minimum", "exponential", "tanh", "negate", "abs", "and",
    "or", "not", "sort", "rsqrt", "sqrt", "log", "clamp",
}


def opkind(rhs: str) -> str:
    m = _OPKIND.search(rhs)
    if m:
        return m.group(1) or m.group(2)
    return "?"


def _shape_elems_bytes(type_str: str) -> Tuple[float, float]:
    """Total (elements, bytes) over every TYPE[dims] in the string."""
    elems = byts = 0.0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def _out_type(rhs: str) -> str:
    """The output type part of an instruction RHS (before the op name)."""
    # rhs looks like: "f32[512,512]{1,0} dot(%a, %b), ..." or
    # "(s32[], f32[...]) while(%tuple), ..."
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rhs[:i + 1]
    m = _SHAPE.search(rhs)
    if m and m.start() < 40:
        # include layout braces; cut at first space after shape
        end = rhs.find(" ", m.start())
        return rhs[:end if end > 0 else len(rhs)]
    return ""


@dataclasses.dataclass
class Instruction:
    name: str
    rhs: str
    out_bytes: float
    out_elems: float


@dataclasses.dataclass
class Computation:
    name: str
    instructions: Dict[str, Instruction]
    lines: List[str]
    is_fusion_like: bool = False       # called via calls=/to_apply=


def parse_module(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(2), {}, [])
                if m.group(1):
                    entry = m.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        mi = _INSTR.match(line)
        if mi:
            name, rhs = mi.group(1), mi.group(2)
            ot = _out_type(rhs)
            elems, byts = _shape_elems_bytes(ot)
            cur.instructions[name] = Instruction(name, rhs, byts, elems)
            cur.lines.append(line)
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Scan conditions compare the induction var against a constant."""
    best = 1
    for line in cond.lines:
        m = _CONST_INT.search(line)
        if m:
            best = max(best, int(m.group(1)))
    return best


def _leading_dim(rhs: str) -> int:
    m = _SHAPE.search(rhs)
    if m and m.group(2):
        return int(m.group(2).split(",")[0])
    return 0


def _scan_scaled(inst_rhs: str, byts: float, trip: int) -> float:
    """Inside a while body with trip count T, tensors whose leading dim is
    T are stacked scan xs/ys: each iteration touches 1/T of them (the
    dynamic-slice/update-slice reads/writes one layer's slice)."""
    if trip > 1 and _leading_dim(inst_rhs) == trip:
        return byts / trip
    return byts


def _resolve_operand_bytes(comp: Computation, rhs: str,
                           trip: int = 1) -> float:
    """Sum bytes of operands named inside the call parens (scan-aware)."""
    p0 = rhs.find("(")
    if p0 < 0:
        return 0.0
    depth, end = 0, len(rhs)
    for i in range(p0, len(rhs)):
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    total = 0.0
    for opname in _OPNAME.findall(rhs[p0:end]):
        inst = comp.instructions.get(opname)
        if inst is not None:
            total += _scan_scaled(inst.rhs, inst.out_bytes, trip)
    return total


def _dot_flops(comp: Computation, inst: Instruction) -> float:
    m = _CONTRACT.search(inst.rhs)
    contract = 1.0
    ops = _OPNAME.findall(inst.rhs[inst.rhs.find("("):])
    lhs = comp.instructions.get(ops[0]) if ops else None
    if m and lhs is not None:
        smatch = _SHAPE.search(lhs.rhs)
        if smatch:
            dims = [int(d) for d in smatch.group(2).split(",") if d]
            for di in (int(x) for x in m.group(1).split(",") if x):
                if di < len(dims):
                    contract *= dims[di]
    return 2.0 * inst.out_elems * contract


def _coll_wire_bytes(line: str, inst: Instruction, comp: Computation,
                     n_devices: int) -> Tuple[str, float]:
    m = _COLL.search(line)
    kind, is_start = m.group(1), bool(m.group(2))
    g = n_devices
    mg = _GROUPS_IOTA.search(line)
    if mg:
        g = max(int(mg.group(2)), 1)
    else:
        mg = _GROUPS_LIST.search(line)
        if mg:
            g = max(len(mg.group(1).split(",")), 1)
    f = inst.out_bytes
    if is_start:
        # (in, out, ...) tuple: full buffer = largest single shape
        shapes = [_shape_elems_bytes(f"{d}[{s}]")[1]
                  for d, s in _SHAPE.findall(_out_type(inst.rhs))]
        f = max(shapes) if shapes else f
    if kind == "all-reduce":
        wire = 2.0 * f * (g - 1) / g
    elif kind == "reduce-scatter":
        full = f if is_start else f * g
        wire = full * (g - 1) / g
    elif kind == "collective-permute":
        wire = f
    else:
        wire = f * (g - 1) / g
    return kind, wire


# jax op_name metadata marking ops that a Pallas kernel keeps in VMEM on
# the target hardware (attention score tiles, mLSTM decay matrices, SSM
# scan intermediates).  Their HLO-level HBM traffic is an artifact of the
# portable jnp fallback; the roofline reports raw and kernel-adjusted terms.
KERNEL_TAGS = ("chunked_attention", "full_attention", "decode_attention",
               "mlstm_parallel", "mlstm_chunkwise", "selective_scan",
               "mlstm_block", "kv_dequant")
_METADATA_OPNAME = re.compile(r'op_name="([^"]*)"')


@dataclasses.dataclass
class HLOProfile:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_breakdown: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    loop_trips: Dict[str, int] = dataclasses.field(default_factory=dict)
    kernel_bytes: float = 0.0          # bytes inside KERNEL_TAGS regions
    kernel_coll_bytes: float = 0.0     # collectives inside those regions

    def add_coll(self, kind: str, b: float):
        self.coll_bytes += b
        self.coll_breakdown[kind] = self.coll_breakdown.get(kind, 0.0) + b


def _kernel_tagged(rhs: str) -> bool:
    m = _METADATA_OPNAME.search(rhs)
    if not m:
        return False
    op = m.group(1)
    return any(t in op for t in KERNEL_TAGS)


def profile(text: str, n_devices: int = 2) -> HLOProfile:
    comps, entry = parse_module(text)
    prof = HLOProfile()
    if entry not in comps:
        return prof

    # which computations are fusion-like (byte traffic counted at caller)?
    fusion_called: set = set()
    for comp in comps.values():
        for line in comp.lines:
            if " fusion(" in line or " call(" in line or \
                    "kind=kLoop" in line or "kind=kInput" in line or \
                    "kind=kOutput" in line:
                for callee in _CALL_ATTR.findall(line):
                    if "while(" not in line:
                        fusion_called.add(callee)

    def visit(name: str, mult: float, trip: int = 1):
        """trip: trip count of the *immediately enclosing* while loop
        (1 at entry) — used to recognize stacked scan xs/ys tensors."""
        comp = comps.get(name)
        if comp is None:
            return
        for inst in comp.instructions.values():
            line_l = inst.rhs
            kind_op = opkind(line_l)
            # --- collectives
            if _COLL.search(line_l) and "-done" not in line_l[:40]:
                ckind, wire = _coll_wire_bytes(line_l, inst, comp,
                                               n_devices)
                prof.add_coll(ckind, wire * mult)
                if _kernel_tagged(line_l):
                    prof.kernel_coll_bytes += wire * mult
            # --- dot flops (fusion-internal dots visited via recursion)
            if kind_op == "dot":
                prof.flops += _dot_flops(comp, inst) * mult
            # --- bytes at scope level, op-kind aware
            if name not in fusion_called and \
                    kind_op not in _ZERO_BYTE_OPS:
                out_b = _scan_scaled(line_l, inst.out_bytes, trip)
                if kind_op in ("dynamic-update-slice", "scatter"):
                    # in-place update: traffic = 2x the update payload —
                    # operands are (buffer > update > indices); the
                    # median operand is the update
                    ops = _OPNAME.findall(
                        line_l[line_l.find("("):])
                    cands = sorted(comp.instructions[o].out_bytes
                                   for o in ops
                                   if o in comp.instructions)
                    b = 2.0 * (cands[len(cands) // 2] if cands
                               else out_b)
                elif kind_op in _OUT2_OPS:
                    b = 2.0 * out_b
                else:       # fusion, dot, scatter, rng, ...
                    b = out_b + _resolve_operand_bytes(comp, line_l,
                                                       trip)
                prof.bytes += b * mult
                if _kernel_tagged(line_l):
                    prof.kernel_bytes += b * mult
            # --- recursion into whiles and calls
            if kind_op == "while":
                mb = re.search(r"body=%?([\w\.\-_]+)", line_l)
                mc = re.search(r"condition=%?([\w\.\-_]+)", line_l)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                trips = _trip_count(comps[cond]) if cond in comps else 1
                prof.loop_trips[body or "?"] = trips
                if body:
                    visit(body, mult * trips, trips)
            else:
                for callee in _CALL_ATTR.findall(line_l):
                    if callee in comps:
                        visit(callee, mult, trip)

    visit(entry, 1.0)
    return prof

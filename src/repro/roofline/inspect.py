import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Hillclimbing profiler: rank collective / dot / byte hot spots in a
cell's compiled HLO by (cost x loop multiplicity), attributed to jax
op_name paths.  This is the dry-run substitute for a wall-clock profile.

    PYTHONPATH=src python -m repro.roofline.inspect --arch mixtral-8x22b \
        --shape train_4k [--mesh single] [--top 15] [--strategy ...]
"""
import argparse  # noqa: E402
import re  # noqa: E402
from collections import defaultdict  # noqa: E402


def inspect(arch: str, shape_name: str, mesh_kind: str = "single",
            strategy: str = "", top: int = 15):
    import jax
    from repro.configs import get_config, SHAPES
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import lower_cell
    from repro.roofline import hlo_profile as hp

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    lowered, info = lower_cell(cfg, shape, mesh,
                               strategy_override=strategy)
    compiled = lowered.compile()
    text = compiled.as_text()
    comps, entry = hp.parse_module(text)
    n_dev = mesh.devices.size

    colls, dots = [], []
    byte_by_op = defaultdict(float)

    fusion_called = set()
    for comp in comps.values():
        for line in comp.lines:
            if " fusion(" in line or " call(" in line or "kind=k" in line:
                for callee in hp._CALL_ATTR.findall(line):
                    if "while(" not in line:
                        fusion_called.add(callee)

    def meta(rhs):
        m = re.search(r'op_name="([^"]*)"', rhs)
        return (m.group(1) if m else "?")

    def visit(name, mult, trip=1):
        comp = comps.get(name)
        if comp is None:
            return
        for inst in comp.instructions.values():
            rhs = inst.rhs
            kind_op = hp.opkind(rhs)
            if hp._COLL.search(rhs) and "-done" not in rhs[:40]:
                ckind, wire = hp._coll_wire_bytes(rhs, inst, comp, n_dev)
                colls.append((wire * mult, ckind, meta(rhs)[-110:]))
            if kind_op == "dot":
                dots.append((hp._dot_flops(comp, inst) * mult,
                             meta(rhs)[-110:]))
            if name not in fusion_called and \
                    kind_op not in hp._ZERO_BYTE_OPS:
                out_b = hp._scan_scaled(rhs, inst.out_bytes, trip)
                if kind_op in hp._OUT2_OPS:
                    b = 2.0 * out_b
                else:
                    b = out_b + hp._resolve_operand_bytes(comp, rhs, trip)
                key = meta(rhs)
                # collapse to the function-level scope
                key = re.sub(r"\[\d+\]", "", key)[-110:]
                byte_by_op[key] += b * mult
            if kind_op == "while":
                mb = re.search(r"body=%?([\w\.\-_]+)", rhs)
                mc = re.search(r"condition=%?([\w\.\-_]+)", rhs)
                trips = hp._trip_count(comps[mc.group(1)]) \
                    if mc and mc.group(1) in comps else 1
                if mb:
                    visit(mb.group(1), mult * trips, trips)
            else:
                for callee in hp._CALL_ATTR.findall(rhs):
                    if callee in comps:
                        visit(callee, mult, trip)

    visit(entry, 1.0)
    print(f"=== {arch} x {shape_name} x {mesh_kind} "
          f"(strategy={info['strategy']}) ===")
    print(f"\n-- top collectives by wire bytes/chip "
          f"(total {sum(c[0] for c in colls)/2**30:.1f} GiB) --")
    for wire, kind, m in sorted(colls, key=lambda x: -x[0])[:top]:
        print(f"  {wire/2**30:9.2f} GiB  {kind:18s} {m}")
    print(f"\n-- top dots by flops/chip "
          f"(total {sum(d[0] for d in dots):.2e}) --")
    for f, m in sorted(dots, key=lambda x: -x[0])[:top]:
        print(f"  {f:9.2e}  {m}")
    print("\n-- top byte scopes --")
    for k, v in sorted(byte_by_op.items(), key=lambda kv: -kv[1])[:top]:
        print(f"  {v/2**30:9.2f} GiB  {k}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--strategy", default="")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()
    inspect(args.arch, args.shape, args.mesh, args.strategy, args.top)


if __name__ == "__main__":
    main()

"""EXPERIMENTS.md table generation from results/dryrun/*.json.

    PYTHONPATH=src python -m repro.roofline.report [results/dryrun]
prints the §Dry-run and §Roofline markdown tables.
"""
from __future__ import annotations

import glob
import json
import sys
from pathlib import Path

ARCH_ORDER = ["internvl2-76b", "phi4-mini-3.8b", "deepseek-7b",
              "starcoder2-3b", "olmo-1b", "granite-moe-3b-a800m",
              "mixtral-8x22b", "seamless-m4t-large-v2", "xlstm-125m",
              "hymba-1.5b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(results_dir: str):
    recs = {}
    for f in glob.glob(str(Path(results_dir) / "*.json")):
        r = json.loads(Path(f).read_text())
        if "arch" in r:
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def gb(x):
    return f"{x/2**30:.2f}"


def dryrun_table(recs) -> str:
    lines = [
        "| arch × shape | mesh | strategy | compile | args/dev | temp/dev"
        " | FLOPs/chip | coll GB/chip (ag/ar/rs/a2a/cp) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            for m in ("single", "multi"):
                r = recs.get((a, s, m))
                if r is None:
                    continue
                if r["status"] == "skipped":
                    if m == "single":
                        lines.append(
                            f"| {a} × {s} | — | — | SKIP | — | — | — | "
                            f"{r['reason'][:48]} |")
                    continue
                if r["status"] != "ok":
                    lines.append(f"| {a} × {s} | {m} | — | **ERROR** | — "
                                 f"| — | — | {r.get('error','')[:40]} |")
                    continue
                rf = r["roofline"]
                mem = r.get("memory", {})
                cb = rf["coll_breakdown"]
                coll = "/".join(
                    f"{cb.get(k, 0)/2**30:.1f}"
                    for k in ("all-gather", "all-reduce",
                              "reduce-scatter", "all-to-all",
                              "collective-permute"))
                lines.append(
                    f"| {a} × {s} | {m} | {r['strategy']} "
                    f"| {r['compile_s']:.0f}s "
                    f"| {gb(mem.get('argument_size_in_bytes', 0))} "
                    f"| {gb(mem.get('temp_size_in_bytes', 0))} "
                    f"| {rf['flops_per_chip']:.2e} | {coll} |")
    return "\n".join(lines)


def roofline_table(recs, mesh: str = "single") -> str:
    lines = [
        "| arch × shape | dominant | compute s | memory s (raw→adj) | "
        "collective s (raw→adj) | bound s | frac | MODEL/HLO |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if r is None:
                continue
            if r["status"] == "skipped":
                lines.append(f"| {a} × {s} | — | — | — | — | — | — | "
                             f"N/A (sub-quadratic rule) |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {a} × {s} | ERROR | | | | | | |")
                continue
            rf = r["roofline"]
            bound = max(rf["compute_s"], rf["memory_adj_s"],
                        rf["collective_adj_s"])
            lines.append(
                f"| {a} × {s} | {r['dominant']} "
                f"| {rf['compute_s']:.4f} "
                f"| {rf['memory_s']:.3f}→{rf['memory_adj_s']:.3f} "
                f"| {rf['collective_s']:.3f}→"
                f"{rf['collective_adj_s']:.3f} "
                f"| {bound:.4f} | {r['roofline_fraction']:.2f} "
                f"| {rf['useful_ratio']:.2f} |")
    return "\n".join(lines)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(d)
    print("## Dry-run table\n")
    print(dryrun_table(recs))
    print("\n## Roofline table (single-pod)\n")
    print(roofline_table(recs, "single"))
    print("\n## Roofline table (multi-pod)\n")
    print(roofline_table(recs, "multi"))


if __name__ == "__main__":
    main()

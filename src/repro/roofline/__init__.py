from repro.roofline.analysis import (Roofline, analyze, collective_bytes,
                                     model_flops_for, PEAK_FLOPS, HBM_BW,
                                     LINK_BW)

__all__ = ["Roofline", "analyze", "collective_bytes", "model_flops_for",
           "PEAK_FLOPS", "HBM_BW", "LINK_BW"]

from repro.roofline.analysis import (HBM_BW, LINK_BW, PEAK_FLOPS, Roofline,
                                     analyze, collective_bytes,
                                     model_flops_for)

__all__ = ["Roofline", "analyze", "collective_bytes", "model_flops_for",
           "PEAK_FLOPS", "HBM_BW", "LINK_BW"]

"""InternVL2-76B — InternViT frontend (stubbed per spec) + InternLM2-76B
backbone.  [arXiv:2404.16821]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256,
    frontend="vision", n_prefix_tokens=256,   # ViT patch embeddings (stub)
    norm="rms", act="swiglu", rope_theta=1_000_000.0,
)

"""StarCoder2-3B — GQA(kv=2), RoPE, sliding-window attention (4096).
[arXiv:2402.19173]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab=49152,
    swa_window=4096,              # makes long_500k runnable (windowed KV)
    norm="rms", act="gelu",
)

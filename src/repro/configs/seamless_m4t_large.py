"""SeamlessM4T-large-v2 text backbone — encoder-decoder; audio frontend
stubbed as precomputed frame embeddings per spec.  [arXiv:2308.11596]"""
from repro.configs.base import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206,
    encdec=EncDecConfig(enc_layers=24, src_len_ratio=1.0),
    frontend="audio",
    norm="rms", act="gelu",
)

"""The paper's own model zoo (Table 1): Ollama-served open models.

These are the models AIvailable actually deploys on its heterogeneous fleet
(llama3.2 1b/3b, gemma3 1b/4b, deepseek-r1 distills, qwen3, qwen2.5vl, and the
embedding models nomic-embed-text / mxbai-embed-large).  We express each as an
ArchConfig so the SDAI controller places them exactly as the paper does; the
serving examples use scaled-down (`reduced()`) variants so they run on CPU.

Param-count sanity: llama32_1b ~= 1.24e9, gemma3_1b ~= 1.0e9 — matching the
published sizes closely enough for VRAM placement math.
"""
from repro.configs.base import ArchConfig

llama32_1b = ArchConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab=128256, tie_embeddings=True,
    norm="rms", act="swiglu", rope_theta=500000.0,
)

llama32_3b = ArchConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab=128256, tie_embeddings=True,
    norm="rms", act="swiglu", rope_theta=500000.0,
)

gemma3_1b = ArchConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262144, tie_embeddings=True,
    swa_window=512, norm="rms", act="gelu",
)

gemma3_4b = ArchConfig(
    name="gemma3-4b", family="vlm",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab=262144, tie_embeddings=True,
    frontend="vision", n_prefix_tokens=256,
    swa_window=1024, norm="rms", act="gelu",
)

deepseek_r1_1_5b = ArchConfig(
    name="deepseek-r1-1.5b", family="dense",   # Qwen2.5-1.5B distill
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936, tie_embeddings=True,
    norm="rms", act="swiglu",
)

deepseek_r1_7b = ArchConfig(
    name="deepseek-r1-7b", family="dense",     # Qwen2.5-7B distill
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab=152064,
    norm="rms", act="swiglu",
)

deepseek_r1_8b = ArchConfig(
    name="deepseek-r1-8b", family="dense",     # Llama-3.1-8B distill
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256,
    norm="rms", act="swiglu", rope_theta=500000.0,
)

qwen3_1_7b = ArchConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=6144, vocab=151936, tie_embeddings=True,
    norm="rms", act="swiglu",
)

qwen3_4b = ArchConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab=151936, tie_embeddings=True,
    norm="rms", act="swiglu",
)

qwen3_8b = ArchConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12288, vocab=151936,
    norm="rms", act="swiglu",
)

qwen25vl_3b = ArchConfig(
    name="qwen2.5vl-3b", family="vlm",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
    d_ff=11008, vocab=151936, tie_embeddings=True,
    frontend="vision", n_prefix_tokens=256,
    norm="rms", act="swiglu",
)

llama32_11b_v = ArchConfig(
    name="llama3.2-11b-v", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256,
    frontend="vision", n_prefix_tokens=256,
    norm="rms", act="swiglu", rope_theta=500000.0,
)

# Embedding models (encoder-only; served for embeddings, no decode)
nomic_embed_text = ArchConfig(
    name="nomic-embed-text", family="embed",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=30528, tie_embeddings=True,
    norm="rms", act="gelu",
)

mxbai_embed_large = ArchConfig(
    name="mxbai-embed-large", family="embed",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=30522, tie_embeddings=True,
    norm="rms", act="gelu",
)

ZOO = {c.name: c for c in [
    llama32_1b, llama32_3b, gemma3_1b, gemma3_4b,
    deepseek_r1_1_5b, deepseek_r1_7b, deepseek_r1_8b,
    qwen3_1_7b, qwen3_4b, qwen3_8b, qwen25vl_3b, llama32_11b_v,
    nomic_embed_text, mxbai_embed_large,
]}

# Paper Table 1: models per node class (node ids 1..6)
PAPER_NODE_MODELS = {
    1: ["deepseek-r1-1.5b", "deepseek-r1-7b", "deepseek-r1-8b",
        "qwen2.5vl-3b", "nomic-embed-text", "gemma3-1b", "gemma3-4b",
        "qwen3-1.7b", "qwen3-4b", "qwen3-8b", "llama3.2-1b", "llama3.2-3b",
        "mxbai-embed-large"],
    2: ["deepseek-r1-1.5b", "deepseek-r1-7b", "deepseek-r1-8b",
        "qwen2.5vl-3b", "nomic-embed-text", "gemma3-1b", "gemma3-4b",
        "qwen3-1.7b", "qwen3-4b", "qwen3-8b", "llama3.2-1b", "llama3.2-3b",
        "mxbai-embed-large"],
    3: ["deepseek-r1-1.5b", "deepseek-r1-7b", "llama3.2-1b", "llama3.2-3b",
        "mxbai-embed-large", "gemma3-1b", "qwen3-1.7b", "qwen3-4b",
        "nomic-embed-text"],
    4: ["deepseek-r1-1.5b", "deepseek-r1-7b", "deepseek-r1-8b",
        "qwen2.5vl-3b", "nomic-embed-text", "gemma3-1b", "gemma3-4b",
        "qwen3-1.7b", "qwen3-4b", "qwen3-8b", "llama3.2-1b", "llama3.2-3b",
        "mxbai-embed-large"],
    5: ["deepseek-r1-1.5b", "deepseek-r1-7b", "llama3.2-1b", "llama3.2-3b",
        "mxbai-embed-large", "gemma3-1b", "qwen3-1.7b", "qwen3-4b",
        "nomic-embed-text"],
    6: ["deepseek-r1-1.5b", "deepseek-r1-7b", "deepseek-r1-8b",
        "llama3.2-1b", "llama3.2-3b", "llama3.2-11b-v", "nomic-embed-text",
        "gemma3-1b", "gemma3-4b", "qwen3-1.7b", "qwen3-4b", "qwen3-8b",
        "qwen2.5vl-3b", "mxbai-embed-large"],
}

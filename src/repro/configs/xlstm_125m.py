"""xLSTM-125M — alternating mLSTM (matrix memory) + sLSTM (scalar memory)
blocks; d_ff=0 (projections live inside the blocks).  [arXiv:2405.04517]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    block="xlstm", tie_embeddings=True,
    norm="rms",
)

"""Config registry: the 10 assigned architectures + the paper's own zoo."""
from repro.configs import (deepseek_7b, granite_moe_3b, hymba_1_5b,
                           internvl2_76b, mixtral_8x22b, olmo_1b, paper_zoo,
                           phi4_mini_3_8b, seamless_m4t_large, starcoder2_3b,
                           xlstm_125m)
from repro.configs.base import (SHAPES, ArchConfig, EncDecConfig, MoEConfig,
                                ShapeSpec, runnable)

ARCHS = {m.CONFIG.name: m.CONFIG for m in [
    internvl2_76b, phi4_mini_3_8b, deepseek_7b, starcoder2_3b, olmo_1b,
    granite_moe_3b, mixtral_8x22b, seamless_m4t_large, xlstm_125m, hymba_1_5b,
]}

ZOO = paper_zoo.ZOO


def get_config(name: str) -> ArchConfig:
    if name in ARCHS:
        return ARCHS[name]
    if name in ZOO:
        return ZOO[name]
    raise KeyError(f"unknown arch {name!r}; known: "
                   f"{sorted(ARCHS) + sorted(ZOO)}")


__all__ = ["ArchConfig", "MoEConfig", "EncDecConfig", "ShapeSpec", "SHAPES",
           "runnable", "ARCHS", "ZOO", "get_config"]

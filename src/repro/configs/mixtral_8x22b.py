"""Mixtral-8x22B — 8 experts top-2, GQA, sliding-window attention.
[arXiv:2401.04088]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=32768,
    moe=MoEConfig(num_experts=8, top_k=2),
    swa_window=4096,              # per assignment: SWA -> long_500k runnable
    norm="rms", act="swiglu",
)

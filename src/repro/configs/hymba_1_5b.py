"""Hymba-1.5B — hybrid-head layers: parallel attention + mamba(SSM) heads,
meta tokens, SWA everywhere except first/middle/last (global) layers.
[arXiv:2411.13676]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001,
    block="hymba", ssm_state=16,
    swa_window=2048, n_meta_tokens=128,
    global_attn_layers=(0, 15, 31),
    norm="rms", act="swiglu",
)

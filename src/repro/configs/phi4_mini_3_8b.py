"""Phi-4-mini 3.8B — dense, RoPE, SwiGLU, GQA.  [arXiv:2412.08905]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab=200064,
    norm="rms", act="swiglu", tie_embeddings=True,
)

"""Architecture & shape configuration system.

Every assigned architecture is expressed as an :class:`ArchConfig`.  The config
carries enough analytic structure (param counts, KV/state bytes) for the SDAI
controller's VRAM-aware placement (the paper's core mechanism) to reason about
memory *without* materializing weights.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

BYTES = {"bf16": 2, "f32": 4, "int8": 1, "int4": 0.5}


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # d_ff of each expert lives in ArchConfig.d_ff


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder split (Seamless backbone).  n_layers is the *decoder*
    depth; the encoder takes enc_layers with the same width."""
    enc_layers: int
    # encoder input = precomputed frame embeddings (modality stub per spec)
    src_len_ratio: float = 1.0  # src_len = seq_len * ratio for shape specs


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    moe: Optional[MoEConfig] = None
    encdec: Optional[EncDecConfig] = None
    swa_window: int = 0              # >0 -> sliding-window attention
    # hybrid/ssm
    block: str = "transformer"       # transformer | xlstm | hymba
    ssm_state: int = 0
    n_meta_tokens: int = 0           # hymba meta tokens
    global_attn_layers: Tuple[int, ...] = ()   # hymba: full-attn layer ids
    # frontend stubs ([vlm]/[audio]): number of prefix embedding positions
    frontend: str = ""               # "" | vision | audio
    n_prefix_tokens: int = 0
    # misc
    norm: str = "rms"                # rms | nonparam_ln
    act: str = "swiglu"              # swiglu | gelu
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    dtype: str = "bf16"

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (sub-quadratic context scaling)."""
        return self.swa_window > 0 or self.block in ("xlstm", "hymba")

    @property
    def is_encdec(self) -> bool:
        return self.encdec is not None

    # ----------------------- analytic memory model -------------------- #
    def attn_params(self) -> int:
        hd = self.head_dim
        return self.d_model * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * self.d_model

    def ffn_params(self) -> int:
        mult = 2 if self.act == "swiglu" else 1
        if self.moe:
            router = self.d_model * self.moe.num_experts
            return router + self.moe.num_experts * (
                mult * self.d_model * self.d_ff + self.d_ff * self.d_model)
        if self.d_ff == 0:
            return 0
        return mult * self.d_model * self.d_ff + self.d_ff * self.d_model

    def layer_params(self) -> int:
        if self.block == "xlstm":
            # mLSTM block (up 2x, qkv on inner, gates, down) + sLSTM block
            inner = 2 * self.d_model
            mlstm = self.d_model * inner * 2 + inner * 3 * inner // 2 \
                + inner * self.d_model
            slstm = 4 * self.d_model * self.d_model \
                + int(2 * (4 / 3) * self.d_model * self.d_model)
            return (mlstm + slstm) // 2 + 2 * self.d_model  # per layer avg
        p = self.attn_params() + self.ffn_params() + 2 * self.d_model
        if self.block == "hymba":
            inner = self.n_heads * self.head_dim
            p += self.d_model * inner * 2 + inner * self.ssm_state * 2 \
                + inner  # ssm branch in/out + B,C proj + dt
        return p

    def num_params(self) -> int:
        """Total parameters (both stacks for enc-dec; embeddings counted)."""
        emb = self.vocab * self.d_model
        head = 0 if self.tie_embeddings else self.vocab * self.d_model
        layers = self.n_layers
        cross = 0
        if self.encdec:
            layers += self.encdec.enc_layers
            cross = self.n_layers * (self.attn_params() + self.d_model)
        return emb + head + layers * self.layer_params() + cross \
            + self.d_model

    def active_params(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.moe:
            return self.num_params()
        mult = 2 if self.act == "swiglu" else 1
        per_expert = mult * self.d_model * self.d_ff + self.d_ff * self.d_model
        inactive = (self.moe.num_experts - self.moe.top_k) * per_expert
        return self.num_params() - self.n_layers * inactive

    def param_bytes(self, dtype: str = "") -> int:
        return int(self.num_params() * BYTES[dtype or self.dtype])

    def kv_bytes_per_token(self, dtype: str = "") -> float:
        """KV-cache (or recurrent state amortization) bytes per cached token
        per sequence — what placement charges for a serving slot."""
        b = BYTES[dtype or self.dtype]
        if self.block == "xlstm":
            return 0.0  # O(1) state, charged via state_bytes()
        per_layer = 2 * self.n_kv_heads * self.head_dim * b
        n_attn_layers = self.n_layers
        return per_layer * n_attn_layers

    def state_bytes(self, batch: int = 1, dtype: str = "") -> int:
        """O(1) recurrent state bytes (ssm / hybrid branches)."""
        b = BYTES[dtype or self.dtype]
        if self.block == "xlstm":
            inner = 2 * self.d_model
            hd = inner // self.n_heads
            per = self.n_heads * (hd * hd + 2 * hd) + 4 * self.d_model
            return int(batch * (self.n_layers // 2 + 1) * 2 * per * b)
        if self.block == "hymba":
            inner = self.n_heads * self.head_dim
            return int(batch * self.n_layers * inner * self.ssm_state * b)
        return 0

    def cache_bytes(self, batch: int, seq_len: int, dtype: str = "") -> int:
        """Total serving-cache bytes for `batch` sequences of `seq_len`."""
        eff = seq_len if self.swa_window == 0 else min(seq_len, self.swa_window)
        total = batch * eff * self.kv_bytes_per_token(dtype)
        if self.encdec:  # cross-attn KV over encoder output
            src = int(seq_len * self.encdec.src_len_ratio)
            total += batch * src * 2 * self.n_kv_heads * self.head_dim \
                * BYTES[dtype or self.dtype] * self.n_layers
        return int(total + self.state_bytes(batch, dtype))

    def flops_per_token(self, seq_len: int = 0) -> float:
        """~6*N_active per trained token (+ attention term when seq given)."""
        f = 6.0 * self.active_params()
        if seq_len:
            f += 12.0 * self.n_layers * self.n_heads * self.head_dim * \
                (min(seq_len, self.swa_window) if self.swa_window else seq_len)
        return f

    # ------------------------------------------------------------------ #
    def reduced(self, **over) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dataclasses.asdict(self)
        # dataclasses.asdict recurses; rebuild nested configs
        if self.moe:
            kw["moe"] = MoEConfig(num_experts=min(self.moe.num_experts, 4),
                                  top_k=min(self.moe.top_k, 2))
        if self.encdec:
            kw["encdec"] = EncDecConfig(enc_layers=2)
        hd = 8
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        kw.update(dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 4) if self.block != "xlstm" else 2,
            d_model=n_heads * hd * 2,
            n_heads=n_heads, n_kv_heads=n_kv, head_dim=hd * 2,
            d_ff=0 if self.d_ff == 0 else 64,
            vocab=256,
            swa_window=min(self.swa_window, 16) if self.swa_window else 0,
            n_prefix_tokens=min(self.n_prefix_tokens, 4),
            n_meta_tokens=min(self.n_meta_tokens, 2),
            global_attn_layers=tuple(
                i for i in self.global_attn_layers if i < 4),
            ssm_state=min(self.ssm_state, 4) if self.ssm_state else 0,
        ))
        kw.update(over)
        return ArchConfig(**kw)


# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    batch: int

    def tokens(self) -> int:
        return self.seq_len * self.batch


SHAPES = {
    "train_4k":    ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k":  ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k":   ShapeSpec("long_500k", "decode", 524288, 1),
}


def runnable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Is this (arch x shape) cell runnable?  Returns (ok, reason)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 500k context is O(L^2) prefill / "
                       "unbounded KV; skipped per spec (see DESIGN.md)")
    return True, ""

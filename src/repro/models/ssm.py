"""State-space & recurrent cores.

- ``selective_scan``  — mamba-style diagonal SSM (Hymba's SSM heads), chunked
  so activation memory is O(chunk) and HLO size is O(1) in sequence length.
- ``mlstm_*``         — xLSTM matrix-memory cell: parallel (quadratic),
  chunkwise (linear memory, for long prefill) and recurrent (decode) forms,
  all with the paper's max-stabilizer; equivalence is property-tested.
- ``slstm_scan``      — xLSTM scalar-memory cell (strictly sequential).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------- #
# Mamba-style diagonal selective SSM (Hymba)

def selective_scan(u, dt, A, B_t, C_t, h0, chunk: int = 256):
    """h_t = exp(dt_t*A) h_{t-1} + dt_t*B_t*u_t ;  y_t = (h_t . C_t) + skip.

    u, dt: (B, S, I);  A: (I, N);  B_t, C_t: (B, S, N);  h0: (B, I, N).
    Returns (y (B,S,I), h_final (B,I,N)).  Skip term is applied by caller.
    """
    with jax.named_scope("selective_scan"):
        return _selective_scan(u, dt, A, B_t, C_t, h0, chunk)


def _selective_scan(u, dt, A, B_t, C_t, h0, chunk):
    b, s, i = u.shape
    n = A.shape[-1]
    if s % chunk:
        chunk = s
    nc = s // chunk

    def chunk_body(h, xs):
        uc, dtc, Bc, Cc = xs               # (B, c, ...)
        dA = jnp.exp(dtc[..., None] * A)                    # (B,c,I,N)
        dBu = (dtc * uc)[..., None] * Bc[:, :, None, :]     # (B,c,I,N)

        def combine(x, y):
            a1, b1 = x
            a2, b2 = y
            return a1 * a2, b1 * a2 + b2

        a_cum, b_cum = jax.lax.associative_scan(
            combine, (dA, dBu), axis=1)
        h_all = b_cum + a_cum * h[:, None]                  # (B,c,I,N)
        y = jnp.einsum("bcin,bcn->bci", h_all, Cc)
        return h_all[:, -1], y

    u_c = u.reshape(b, nc, chunk, i).swapaxes(0, 1)
    dt_c = dt.reshape(b, nc, chunk, i).swapaxes(0, 1)
    B_c = B_t.reshape(b, nc, chunk, n).swapaxes(0, 1)
    C_c = C_t.reshape(b, nc, chunk, n).swapaxes(0, 1)
    h_f, y = jax.lax.scan(chunk_body, h0, (u_c, dt_c, B_c, C_c))
    y = y.swapaxes(0, 1).reshape(b, s, i)
    return y, h_f


def selective_step(u, dt, A, B_t, C_t, h):
    """Single decode step.  u, dt: (B, I); B_t, C_t: (B, N); h: (B, I, N)."""
    dA = jnp.exp(dt[..., None] * A)
    dBu = (dt * u)[..., None] * B_t[:, None, :]
    h_new = dA * h + dBu
    y = jnp.einsum("bin,bn->bi", h_new, C_t)
    return y, h_new


# --------------------------------------------------------------------- #
# mLSTM (xLSTM matrix memory)

class MLSTMState(NamedTuple):
    C: jax.Array      # (B, H, hd, hd)
    n: jax.Array      # (B, H, hd)
    m: jax.Array      # (B, H)


def mlstm_init_state(b, h, hd, dtype=jnp.float32):
    return MLSTMState(C=jnp.zeros((b, h, hd, hd), dtype),
                      n=jnp.zeros((b, h, hd), dtype),
                      m=jnp.full((b, h), -1e30, dtype))


def mlstm_parallel(q, k, v, i_raw, f_raw):
    """Stabilized parallel (quadratic) form.

    q,k,v: (B, S, H, hd);  i_raw, f_raw: (B, S, H).  Returns (B, S, H, hd).
    """
    with jax.named_scope("mlstm_parallel"):
        return _mlstm_parallel(q, k, v, i_raw, f_raw)


def _mlstm_parallel(q, k, v, i_raw, f_raw):
    b, s, h, hd = q.shape
    qf = q.astype(jnp.float32).transpose(0, 2, 1, 3)          # (B,H,S,hd)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3) / (hd ** 0.5)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)
    log_f = jax.nn.log_sigmoid(f_raw.astype(jnp.float32)).transpose(0, 2, 1)
    log_i = i_raw.astype(jnp.float32).transpose(0, 2, 1)      # (B,H,S)
    cum = jnp.cumsum(log_f, axis=-1)                          # inclusive
    # D_log[t, s] = cum[t] - cum[s] + log_i[s]  for s <= t
    dlog = cum[..., :, None] - cum[..., None, :] + log_i[..., None, :]
    causal = jnp.tril(jnp.ones((s, s), bool))
    dlog = jnp.where(causal, dlog, -jnp.inf)
    m = jnp.max(dlog, axis=-1)                                # (B,H,S)
    d = jnp.exp(dlog - m[..., None])
    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * d
    denom = jnp.maximum(jnp.abs(jnp.sum(scores, axis=-1)), jnp.exp(-m))
    out = jnp.einsum("bhqk,bhkd->bhqd", scores, vf) / denom[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def mlstm_recurrent(q, k, v, i_raw, f_raw, state: MLSTMState):
    """Single-step recurrent form.  q,k,v: (B, H, hd); gates: (B, H)."""
    hd = q.shape[-1]
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32) / (hd ** 0.5)
    vf = v.astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))
    log_i = i_raw.astype(jnp.float32)
    m_new = jnp.maximum(log_f + state.m, log_i)
    f_s = jnp.exp(log_f + state.m - m_new)[..., None]
    i_s = jnp.exp(log_i - m_new)[..., None]
    C = f_s[..., None] * state.C + i_s[..., None] * \
        jnp.einsum("bhd,bhk->bhdk", vf, kf)
    n = f_s * state.n + i_s * kf
    num = jnp.einsum("bhdk,bhk->bhd", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)),
                      jnp.exp(-m_new))[..., None]
    out = (num / den).astype(q.dtype)
    return out, MLSTMState(C=C, n=n, m=m_new)


def mlstm_chunkwise(q, k, v, i_raw, f_raw, state: MLSTMState,
                    chunk: int = 256):
    """Chunked linear-memory form: intra-chunk parallel + inter-chunk
    recurrent state, with consistent max-stabilizers.  Matches
    mlstm_parallel when state is the zero/init state (property-tested)."""
    with jax.named_scope("mlstm_chunkwise"):
        return _mlstm_chunkwise(q, k, v, i_raw, f_raw, state, chunk)


def _mlstm_chunkwise(q, k, v, i_raw, f_raw, state, chunk):
    b, s, h, hd = q.shape
    if s % chunk:
        chunk = s
    nc = s // chunk
    qf = q.astype(jnp.float32).reshape(b, nc, chunk, h, hd).transpose(
        1, 0, 3, 2, 4)                                        # (nc,B,H,c,hd)
    kf = (k.astype(jnp.float32) / (hd ** 0.5)).reshape(
        b, nc, chunk, h, hd).transpose(1, 0, 3, 2, 4)
    vf = v.astype(jnp.float32).reshape(b, nc, chunk, h, hd).transpose(
        1, 0, 3, 2, 4)
    log_f = jax.nn.log_sigmoid(f_raw.astype(jnp.float32)).reshape(
        b, nc, chunk, h).transpose(1, 0, 3, 2)                # (nc,B,H,c)
    log_i = i_raw.astype(jnp.float32).reshape(
        b, nc, chunk, h).transpose(1, 0, 3, 2)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(carry, xs):
        C_p, n_p, m_p = carry
        qc, kc, vc, lf, li = xs
        lcum = jnp.cumsum(lf, axis=-1)                        # (B,H,c)
        g = lcum[..., -1]                                     # total decay
        # intra-chunk log decay matrix
        dlog = lcum[..., :, None] - lcum[..., None, :] + li[..., None, :]
        dlog = jnp.where(causal, dlog, -jnp.inf)
        m_intra = jnp.max(dlog, axis=-1)                      # (B,H,c)
        m_inter = m_p[..., None] + lcum                       # (B,H,c)
        m_c = jnp.maximum(m_intra, m_inter)
        d_intra = jnp.exp(dlog - m_c[..., None])
        sc = jnp.einsum("bhqd,bhkd->bhqk", qc, kc) * d_intra
        w_inter = jnp.exp(m_inter - m_c)[..., None]           # (B,H,c,1)
        num = jnp.einsum("bhqk,bhkd->bhqd", sc, vc) \
            + w_inter * jnp.einsum("bhdk,bhqk->bhqd", C_p, qc)
        den_vec = jnp.sum(sc, axis=-1) \
            + w_inter[..., 0] * jnp.einsum("bhk,bhqk->bhq", n_p, qc)
        den = jnp.maximum(jnp.abs(den_vec), jnp.exp(-m_c))
        out = num / den[..., None]
        # state update to end of chunk
        m_new = jnp.maximum(
            m_p + g, jnp.max(g[..., None] - lcum + li, axis=-1))
        decay_s = jnp.exp(g[..., None] - lcum + li - m_new[..., None])
        C_new = jnp.exp(m_p + g - m_new)[..., None, None] * C_p + \
            jnp.einsum("bhk,bhkd,bhke->bhde", decay_s, vc, kc)
        n_new = jnp.exp(m_p + g - m_new)[..., None] * n_p + \
            jnp.einsum("bhk,bhkd->bhd", decay_s, kc)
        return (C_new, n_new, m_new), out

    (C_f, n_f, m_f), outs = jax.lax.scan(
        body, (state.C.astype(jnp.float32), state.n.astype(jnp.float32),
               state.m.astype(jnp.float32)),
        (qf, kf, vf, log_f, log_i))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, s, h, hd)
    return out.astype(q.dtype), MLSTMState(C=C_f, n=n_f, m=m_f)


# --------------------------------------------------------------------- #
# sLSTM (xLSTM scalar memory) — strictly sequential

class SLSTMState(NamedTuple):
    c: jax.Array      # (B, H, hd)
    n: jax.Array      # (B, H, hd)
    m: jax.Array      # (B, H, hd)
    h: jax.Array      # (B, H, hd)


def slstm_init_state(b, h, hd, dtype=jnp.float32):
    z = jnp.zeros((b, h, hd), dtype)
    return SLSTMState(c=z, n=z, m=jnp.full((b, h, hd), -1e30, dtype), h=z)


def slstm_step(xw, r, state: SLSTMState):
    """One timestep.  xw: (B, 4, H, hd) precomputed input projections
    (z, i, f, o); r: (4, H, hd, hd) recurrent block-diagonal weights."""
    hf = state.h
    rec = jnp.einsum("bhk,ghkl->bghl", hf, r)                 # (B,4,H,hd)
    pre = xw.astype(jnp.float32) + rec
    z = jnp.tanh(pre[:, 0])
    log_i = pre[:, 1]
    log_f = jax.nn.log_sigmoid(pre[:, 2])
    o = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(log_f + state.m, log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + state.m - m_new)
    c = f_s * state.c + i_s * z
    n = f_s * state.n + i_s
    h_new = o * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c=c, n=n, m=m_new, h=h_new)


def slstm_scan(xw_seq, r, state: SLSTMState):
    """xw_seq: (B, S, 4, H, hd).  Returns (h_seq (B,S,H,hd), final state)."""
    def body(st, xw):
        st2 = slstm_step(xw, r, st)
        return st2, st2.h

    final, hs = jax.lax.scan(body, state, xw_seq.swapaxes(0, 1))
    return hs.swapaxes(0, 1), final

"""Mixture-of-Experts FFN with sort-based (gather/scatter) dispatch.

TPU-native adaptation: instead of the classic one-hot dispatch einsum —
whose FLOPs (B*S*E*C*D) can exceed the expert FLOPs themselves for
small-expert models like granite-moe — tokens are argsorted by expert id and
scattered into (E, C, D) slot buffers.  Dispatch then costs *memory ops*, not
matmul FLOPs, keeping MODEL_FLOPS/HLO_FLOPs honest.

`moe_ffn_ref` keeps the obvious dense-masked implementation as the oracle
for property tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig


def router_topk(x, router_w, moe: MoEConfig):
    """x: (B,S,D) -> gates (B,S,k) f32, idx (B,S,k) int32, aux-loss scalar."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, moe.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balancing auxiliary loss
    e = moe.num_experts
    density = jnp.mean(jax.nn.one_hot(idx[..., 0], e), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(density * mean_prob)
    return gates, idx, aux


def capacity(seq: int, moe: MoEConfig) -> int:
    c = int(seq * moe.top_k / moe.num_experts * moe.capacity_factor)
    return max(c, moe.top_k)


def _dispatch_one_row(x, idx, gates, e: int, c: int):
    """Per-batch-row sort-based dispatch.

    x: (S, D); idx/gates: (S, k).  Returns (expert_in (E*C, D),
    slot (S*k,), keep (S*k,), flat gates (S*k,)).
    """
    s, k = idx.shape
    flat_e = idx.reshape(s * k)                       # s-major order
    flat_g = gates.reshape(s * k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank within expert group = position - start of group
    group_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank = jnp.arange(s * k) - group_start[sorted_e]
    keep_sorted = rank < c
    slot_sorted = sorted_e * c + jnp.minimum(rank, c - 1)
    # un-sort back to flat order
    inv = jnp.argsort(order, stable=True)
    slot = slot_sorted[inv]
    keep = keep_sorted[inv]
    tok = jnp.arange(s * k) // k
    expert_in = jnp.zeros((e * c, x.shape[-1]), x.dtype)
    contrib = jnp.where(keep[:, None], x[tok], 0)
    expert_in = expert_in.at[jnp.where(keep, slot, e * c)].add(
        contrib, mode="drop")
    return expert_in, slot, keep, flat_g


def moe_ffn(x, router_w, wi, wo, moe: MoEConfig, act: str,
            sh=lambda x, axes: x):
    """x: (B,S,D); wi: (E, 2, D, F) swiglu / (E, D, F) gelu; wo: (E, F, D).

    Returns (y (B,S,D), aux_loss scalar).  sh: sharding-constraint hook —
    REQUIRED under SPMD: XLA loses the batch sharding through the
    argsort/scatter dispatch and would otherwise replicate expert_in,
    running every chip over the *global* batch (a ~n_chips x compute
    blowup, see EXPERIMENTS.md §Perf).
    """
    b, s, d = x.shape
    e, cap = moe.num_experts, capacity(s, moe)
    # dispatch indexes tokens across the whole sequence: with a
    # seq-sharded (SP) residual each chip would scatter partial expert
    # buffers and all-reduce them (7.5 GB/layer!) — gather the token dim
    # once instead (0.8 GB/layer), §Perf iteration 7.
    x = sh(x, ("batch", "seq_attn", "embed"))
    gates, idx, aux = router_topk(x, router_w, moe)

    expert_in, slot, keep, flat_g = jax.vmap(
        lambda xr, ir, gr: _dispatch_one_row(xr, ir, gr, e, cap)
    )(x, idx, gates)
    ein = expert_in.reshape(b, e, cap, d)
    ein = sh(ein, ("batch", "experts", "capacity", "embed"))

    if act == "swiglu":
        gate = jnp.einsum("becd,edf->becf", ein, wi[:, 0])
        up = jnp.einsum("becd,edf->becf", ein, wi[:, 1])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = jnp.einsum("becd,edf->becf", ein, wi)
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    # down-projection contracts the TP-sharded F dim: explicit
    # psum_scatter onto the D dim (XLA would emit a 2x-wire all-reduce);
    # the slot->token gather below runs on D-shards and the residual add
    # reshards via a cheap all-to-all (§Perf iterations 5+8).
    from repro.models.layers import row_project
    eout = row_project(sh, h, wo, "becf,efd->becd",
                       ("batch", "experts", "capacity", "mlp"),
                       ("experts", "mlp", "embed"),
                       ("batch", "experts", "capacity", "embed_rs"),
                       scatter_axis=3)

    eflat = eout.reshape(b, e * cap, d)
    gathered = jnp.take_along_axis(
        eflat, slot[..., None], axis=1)               # (B, S*k, D)
    gathered = jnp.where(keep[..., None], gathered, 0)
    weighted = gathered * flat_g[..., None].astype(x.dtype)
    y = jnp.sum(weighted.reshape(b, s, moe.top_k, d), axis=2)
    return y, aux


def moe_ffn_ref(x, router_w, wi, wo, moe: MoEConfig, act: str):
    """Dense-masked oracle (no capacity drop when cf is large enough):
    every token runs through its top-k experts via masking."""
    gates, idx, aux = router_topk(x, router_w, moe)
    y = jnp.zeros_like(x)
    for e_id in range(moe.num_experts):
        if act == "swiglu":
            g = jnp.einsum("bsd,df->bsf", x, wi[e_id, 0])
            u = jnp.einsum("bsd,df->bsf", x, wi[e_id, 1])
            h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        else:
            h = jnp.einsum("bsd,df->bsf", x, wi[e_id])
            h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
        out = jnp.einsum("bsf,fd->bsd", h, wo[e_id])
        w = jnp.sum(jnp.where(idx == e_id, gates, 0.0),
                    axis=-1)[..., None].astype(x.dtype)
        y += out * w
    return y, aux

"""Attention cores: full (einsum), chunked-flash (jnp, scan over KV blocks),
and single-token decode.  All GQA-aware; masks support causal, sliding-window
and always-visible prefix (meta/patch tokens).

The chunked path is the portable analogue of the Pallas flash kernel in
``repro/kernels/flash_attention`` — same online-softmax math, `lax.scan` over
KV blocks, so lowering stays small and activation memory stays O(chunk).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(q_pos, kv_pos, *, causal: bool, window, prefix: int):
    """Boolean (Q, S) visibility mask from absolute positions.

    `window` may be a python int or a traced scalar (0 => no window).
    Prefix tokens (kv_pos < prefix) are exempt from the *window* constraint
    (hymba meta tokens stay visible beyond the sliding window) but still
    respect causality.
    """
    if not causal:
        return None
    m = kv_pos[None, :] <= q_pos[:, None]
    static_zero = isinstance(window, int) and window == 0
    if not static_zero:
        w = jnp.asarray(window)
        inwin = (kv_pos[None, :] > q_pos[:, None] - w) | (w <= 0)
        if prefix > 0:
            inwin |= (kv_pos < prefix)[None, :]
        m &= inwin
    return m


def _gqa_fold(q, n_kv: int):
    """(B, Q, H, hd) -> (B, Q, K, G, hd)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def full_attention(q, k, v, *, causal=True, window=0, prefix=0,
                   q_offset=0, kv_offset=0):
    """Reference einsum attention.  q: (B,Q,H,hd); k,v: (B,S,K,hd)."""
    with jax.named_scope("full_attention"):
        return _full_attention(q, k, v, causal=causal, window=window,
                               prefix=prefix, q_offset=q_offset,
                               kv_offset=kv_offset)


def _full_attention(q, k, v, *, causal, window, prefix, q_offset,
                    kv_offset):
    b, qlen, h, hd = q.shape
    s = k.shape[1]
    nkv = k.shape[2]
    qf = _gqa_fold(q, nkv).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf) / (hd ** 0.5)
    q_pos = q_offset + jnp.arange(qlen)
    kv_pos = kv_offset + jnp.arange(s)
    m = _mask(q_pos, kv_pos, causal=causal, window=window, prefix=prefix)
    if m is not None:
        scores = jnp.where(m[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(b, qlen, h, hd).astype(q.dtype)


def chunked_attention(q, k, v, *, causal=True, window=0, prefix=0,
                      q_offset=0, kv_offset=0, chunk=1024):
    """Flash-style online-softmax attention, scanning KV in blocks.

    Memory: O(B * Q * chunk) instead of O(B * Q * S); HLO size O(1) in S.
    """
    with jax.named_scope("chunked_attention"):
        return _chunked_attention(q, k, v, causal=causal, window=window,
                                  prefix=prefix, q_offset=q_offset,
                                  kv_offset=kv_offset, chunk=chunk)


def _chunked_attention(q, k, v, *, causal, window, prefix, q_offset,
                       kv_offset, chunk):
    b, qlen, h, hd = q.shape
    s = k.shape[1]
    if s % chunk:
        chunk = s  # fallback; callers pick divisible chunks
    nkv = k.shape[2]
    g = h // nkv
    qf = _gqa_fold(q, nkv).astype(jnp.float32) / (hd ** 0.5)
    n_chunks = s // chunk
    kc = k.reshape(b, n_chunks, chunk, nkv, hd)
    vc = v.reshape(b, n_chunks, chunk, nkv, hd)
    q_pos = q_offset + jnp.arange(qlen)

    def body(carry, xs):
        m_run, l_run, acc = carry
        k_blk, v_blk, blk_idx = xs
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qf,
                            k_blk.astype(jnp.float32))
        kv_pos = kv_offset + blk_idx * chunk + jnp.arange(chunk)
        msk = _mask(q_pos, kv_pos, causal=causal, window=window,
                    prefix=prefix)
        if msk is not None:
            scores = jnp.where(msk[None, None, None], scores, NEG_INF)
        m_blk = jnp.max(scores, axis=-1)
        m_new = jnp.maximum(m_run, m_blk)
        corr = jnp.exp(m_run - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p, v_blk.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, nkv, g, qlen), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nkv, g, qlen), jnp.float32)
    a0 = jnp.zeros((b, nkv, g, qlen, hd), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l_f[..., None], 1e-30)
    # (B, K, G, Q, hd) -> (B, Q, H, hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, qlen, h, hd)
    return out.astype(q.dtype)


def attention(q, k, v, *, causal=True, window=0, prefix=0, q_offset=0,
              kv_offset=0, chunk_threshold=2048, impl: str = "auto"):
    """Dispatch: einsum for short sequences, chunked-flash for long.

    Threshold 2048: above it the O(S^2) score tensor (and its *backward*,
    which XLA reshards with score-sized all-gathers) dominates HBM and ICI
    — chunked-flash keeps tiles O(S*chunk) and is what the Pallas kernel
    implements on TPU (§Perf iteration 4)."""
    s = k.shape[1]
    if impl == "full" or (impl == "auto" and s <= chunk_threshold):
        return full_attention(q, k, v, causal=causal, window=window,
                              prefix=prefix, q_offset=q_offset,
                              kv_offset=kv_offset)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             prefix=prefix, q_offset=q_offset,
                             kv_offset=kv_offset)


def suffix_attention(q, k_cache, v_cache, q_pos):
    """Multi-token decode attention for suffix prefill: `q` (B, Q, H, hd)
    holds Q new tokens per row at *per-row* absolute positions `q_pos`
    (B, Q); caches (B, S, K, hd) are dense from position 0 and already
    contain the new tokens' KV.  Purely causal by absolute position
    (no window/prefix — callers gate eligibility), replicating
    `_full_attention`'s exact op sequence so a cached-prefix suffix pass
    stays numerically aligned with the full-prefill path."""
    with jax.named_scope("suffix_attention"):
        b, qlen, h, hd = q.shape
        s = k_cache.shape[1]
        nkv = k_cache.shape[2]
        qf = _gqa_fold(q, nkv).astype(jnp.float32)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qf,
                            k_cache.astype(jnp.float32)) / (hd ** 0.5)
        m = jnp.arange(s)[None, None, :] <= q_pos[:, :, None]   # (B,Q,S)
        scores = jnp.where(m[:, None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", w,
                         v_cache.astype(jnp.float32))
        return out.reshape(b, qlen, h, hd).astype(q.dtype)


# --------------------------------------------------------------------- #
# Decode (single new token against a cache)

def decode_attention(q, k_cache, v_cache, pos, *, window=0, prefix=0,
                     slot_pos=None):
    """q: (B, 1, H, hd); caches: (B, S, K, hd); pos: (B,) int32 — index of
    the *current* token (cache slots > pos are invalid).

    slot_pos: (B, S) absolute position of each cache slot, for ring-buffer
    (sliding-window) caches; defaults to iota (dense cache).
    """
    with jax.named_scope("decode_attention"):
        return _decode_attention(q, k_cache, v_cache, pos, window=window,
                                 prefix=prefix, slot_pos=slot_pos)


def _decode_attention(q, k_cache, v_cache, pos, *, window, prefix,
                      slot_pos):
    b, _, h, hd = q.shape
    s = k_cache.shape[1]
    nkv = k_cache.shape[2]
    qf = _gqa_fold(q, nkv).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qf,
                        k_cache.astype(jnp.float32)) / (hd ** 0.5)
    if slot_pos is None:
        slot_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    valid = slot_pos <= pos[:, None]
    static_zero = isinstance(window, int) and window == 0
    if not static_zero:
        w = jnp.asarray(window)
        vis = (slot_pos > (pos[:, None] - w)) | (w <= 0)
        if prefix > 0:
            vis |= slot_pos < prefix
        valid &= vis
    scores = jnp.where(valid[:, None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bkgqd", w, v_cache.astype(jnp.float32))
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, 1, h, hd)
    return out.astype(q.dtype)

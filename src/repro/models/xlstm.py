"""xLSTM LM: alternating mLSTM / sLSTM block pairs (arXiv:2405.04517).

Layers come in pairs (mLSTM block, then sLSTM block); pairs are stacked and
scanned.  Training uses the parallel (<=4k) or chunkwise (longer) mLSTM form;
decoding is O(1)-state recurrent.  sLSTM is strictly sequential (lax.scan over
time) — its input projections are hoisted out of the time scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import ssm as ssm_lib


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32


def dims(cfg: ArchConfig):
    d = cfg.d_model
    inner = 2 * d                      # mLSTM up-projection factor 2
    h = cfg.n_heads
    ff = int(8 * d / 3 / 64 + 1) * 64  # sLSTM post-FFN (~4/3 * 2d)
    return d, inner, h, inner // h, d // h, ff


def n_pairs(cfg: ArchConfig) -> int:
    return max(1, cfg.n_layers // 2)


def _pair_init(cfg: ArchConfig, key):
    dt = _dt(cfg)
    d, inner, h, hd_m, hd_s, ff = dims(cfg)
    ks = iter(jax.random.split(key, 16))
    return {
        "mlstm": {
            "ln": jnp.zeros((d,), dt),
            "w_up": L.dense_init(next(ks), d, (d, 2, inner), dt),
            "wq": L.dense_init(next(ks), inner, (inner, h, hd_m), dt),
            "wk": L.dense_init(next(ks), inner, (inner, h, hd_m), dt),
            "wv": L.dense_init(next(ks), inner, (inner, h, hd_m), dt),
            "w_i": L.dense_init(next(ks), inner, (inner, h), jnp.float32),
            "b_i": jnp.zeros((h,), jnp.float32),
            "w_f": L.dense_init(next(ks), inner, (inner, h), jnp.float32),
            "b_f": jnp.full((h,), 3.0, jnp.float32),  # open forget gates
            "gn": jnp.zeros((inner,), dt),
            "w_down": L.dense_init(next(ks), inner, (inner, d), dt),
        },
        "slstm": {
            "ln": jnp.zeros((d,), dt),
            "w_x": L.dense_init(next(ks), d, (d, 4, h, hd_s), jnp.float32),
            "r": L.dense_init(next(ks), hd_s, (4, h, hd_s, hd_s),
                              jnp.float32),
            "b": jnp.zeros((4, h, hd_s), jnp.float32),
            "gn": jnp.zeros((d,), dt),
            "ffn_wi": L.dense_init(next(ks), d, (d, ff), dt),
            "ffn_wo": L.dense_init(next(ks), ff, (ff, d), dt),
        },
    }


def _pair_axes(cfg: ArchConfig):
    return {
        "mlstm": {"ln": ("embed",),
                  "w_up": ("embed", "stack", "inner"),
                  "wq": ("inner", "heads", "head_dim"),
                  "wk": ("inner", "heads", "head_dim"),
                  "wv": ("inner", "heads", "head_dim"),
                  "w_i": ("inner", "heads"), "b_i": ("heads",),
                  "w_f": ("inner", "heads"), "b_f": ("heads",),
                  "gn": ("inner",), "w_down": ("inner", "embed")},
        "slstm": {"ln": ("embed",),
                  "w_x": ("embed", "stack", "heads", "head_dim"),
                  "r": ("stack", "heads", "head_dim", "head_dim2"),
                  "b": ("stack", "heads", "head_dim"),
                  "gn": ("embed",),
                  "ffn_wi": ("embed", "mlp"), "ffn_wo": ("mlp", "embed")},
    }


def init_params(cfg: ArchConfig, key):
    dt = _dt(cfg)
    k_e, k_p = jax.random.split(key)
    pk = jax.random.split(k_p, n_pairs(cfg))
    return {
        "embed": L.trunc_normal(k_e, (cfg.vocab, cfg.d_model), 0.02, dt),
        "pairs": jax.vmap(lambda k: _pair_init(cfg, k))(pk),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }


def param_axes(cfg: ArchConfig):
    stack = jax.tree.map(lambda ax: ("layers",) + ax, _pair_axes(cfg),
                         is_leaf=lambda x: isinstance(x, tuple))
    return {"embed": ("vocab", "embed"), "pairs": stack,
            "final_norm": ("embed",)}


# --------------------------------------------------------------------- #

def _mlstm_block_seq(mp, cfg, h, sh, state=None, chunked=False):
    """Full-sequence mLSTM block.  Returns (h_out, final MLSTMState)."""
    d, inner, nh, hd_m, _, _ = dims(cfg)
    b, s, _ = h.shape
    x = L.rms_norm(h, mp["ln"])
    up = jnp.einsum("bsd,dgi->bsgi", x, mp["w_up"])
    u, z = up[:, :, 0], up[:, :, 1]
    u = sh(u, ("batch", "seq", "inner"))
    q = jnp.einsum("bsi,ihk->bshk", u, mp["wq"])
    k = jnp.einsum("bsi,ihk->bshk", u, mp["wk"])
    v = jnp.einsum("bsi,ihk->bshk", u, mp["wv"])
    i_raw = jnp.einsum("bsi,ih->bsh", u.astype(jnp.float32), mp["w_i"]) \
        + mp["b_i"]
    f_raw = jnp.einsum("bsi,ih->bsh", u.astype(jnp.float32), mp["w_f"]) \
        + mp["b_f"]
    if state is None:
        state = ssm_lib.mlstm_init_state(b, nh, hd_m)
    if chunked or s > 4096:
        core, fin = ssm_lib.mlstm_chunkwise(q, k, v, i_raw, f_raw, state)
    else:
        core = ssm_lib.mlstm_parallel(q, k, v, i_raw, f_raw)
        _, fin = ssm_lib.mlstm_chunkwise(q, k, v, i_raw, f_raw, state,
                                         chunk=min(s, 256)) \
            if False else (None, state)  # final state only needed at prefill
    y = core.reshape(b, s, inner)
    y = L.group_norm(y, nh) * (1.0 + mp["gn"].astype(jnp.float32))
    y = y.astype(h.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype)
    return h + jnp.einsum("bsi,id->bsd", y, mp["w_down"]), fin


def _slstm_block_seq(sp, cfg, h, sh, state=None):
    d, _, nh, _, hd_s, ff = dims(cfg)
    b, s, _ = h.shape
    x = L.rms_norm(h, sp["ln"])
    xw = jnp.einsum("bsd,dghk->bsghk", x.astype(jnp.float32), sp["w_x"]) \
        + sp["b"]
    if state is None:
        state = ssm_lib.slstm_init_state(b, nh, hd_s)
    hs, fin = ssm_lib.slstm_scan(xw, sp["r"], state)
    y = hs.reshape(b, s, d).astype(h.dtype)
    y = L.group_norm(y, nh) * (1.0 + sp["gn"].astype(jnp.float32))
    y = y.astype(h.dtype)
    y = jnp.einsum("bsf,fd->bsd",
                   jax.nn.gelu(jnp.einsum("bsd,df->bsf", y, sp["ffn_wi"])
                               .astype(jnp.float32)).astype(h.dtype),
                   sp["ffn_wo"])
    return h + y, fin


def forward(params, cfg: ArchConfig, tokens, *, sh=lambda x, a: x,
            shw=None, remat=False, collect_cache=False):
    h = jnp.take(params["embed"], tokens, axis=0)
    h = sh(h, ("batch", "seq", "embed"))
    b, s = tokens.shape
    _, inner, nh, hd_m, hd_s, _ = dims(cfg)
    pair_ax = _pair_axes(cfg)

    def pair(h, pp):
        if shw is not None:
            pp = shw(pp, pair_ax)
        m_st = ssm_lib.mlstm_init_state(b, nh, hd_m)
        h, m_fin = _mlstm_block_seq(pp["mlstm"], cfg, h, sh, m_st,
                                    chunked=collect_cache or s > 4096)
        h, s_fin = _slstm_block_seq(pp["slstm"], cfg, h, sh)
        h = sh(h, ("batch", "seq", "embed"))
        return h, (m_fin, s_fin) if collect_cache else None

    body = pair
    if remat:
        body = jax.checkpoint(
            pair, policy=jax.checkpoint_policies.nothing_saveable)
    h, states = jax.lax.scan(body, h, params["pairs"])
    h = L.rms_norm(h, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", h, params["embed"].T)
    logits = sh(logits, ("batch", "seq", "vocab"))
    return logits, states, 0.0


def init_cache(cfg: ArchConfig, batch: int, **_):
    _, inner, nh, hd_m, hd_s, _ = dims(cfg)
    p = n_pairs(cfg)

    def rep(x):
        return jnp.zeros((p,) + x.shape, x.dtype) if x is not None else None
    return {
        "mC": jnp.zeros((p, batch, nh, hd_m, hd_m), jnp.float32),
        "mn": jnp.zeros((p, batch, nh, hd_m), jnp.float32),
        "mm": jnp.full((p, batch, nh), -1e30, jnp.float32),
        "sc": jnp.zeros((p, batch, nh, hd_s), jnp.float32),
        "sn": jnp.zeros((p, batch, nh, hd_s), jnp.float32),
        "sm": jnp.full((p, batch, nh, hd_s), -1e30, jnp.float32),
        "sh": jnp.zeros((p, batch, nh, hd_s), jnp.float32),
    }


def cache_axes(cfg: ArchConfig):
    return {"mC": ("layers", "batch", "heads", "head_dim", "head_dim2"),
            "mn": ("layers", "batch", "heads", "head_dim"),
            "mm": ("layers", "batch", "heads"),
            "sc": ("layers", "batch", "heads", "head_dim"),
            "sn": ("layers", "batch", "heads", "head_dim"),
            "sm": ("layers", "batch", "heads", "head_dim"),
            "sh": ("layers", "batch", "heads", "head_dim")}


def prefill(params, cfg: ArchConfig, tokens, *, sh=lambda x, a: x):
    logits, states, _ = forward(params, cfg, tokens, sh=sh,
                                collect_cache=True)
    m_fin, s_fin = states
    cache = {"mC": m_fin.C, "mn": m_fin.n, "mm": m_fin.m,
             "sc": s_fin.c, "sn": s_fin.n, "sm": s_fin.m, "sh": s_fin.h}
    b = tokens.shape[0]
    pos = jnp.full((b,), tokens.shape[1] - 1, jnp.int32)
    return logits[:, -1], cache, pos


def decode_step(params, cfg: ArchConfig, cache, token, *,
                sh=lambda x, a: x):
    b = token.shape[0]
    d, inner, nh, hd_m, hd_s, ff = dims(cfg)
    h = jnp.take(params["embed"], token, axis=0)       # (B, D)

    def pair(h, xs):
        pp, mC, mn, mm, sc, sn, sm, shh = xs
        mp, sp = pp["mlstm"], pp["slstm"]
        # ---- mLSTM step
        x = L.rms_norm(h, mp["ln"])
        up = jnp.einsum("bd,dgi->bgi", x, mp["w_up"])
        u, z = up[:, 0], up[:, 1]
        q = jnp.einsum("bi,ihk->bhk", u, mp["wq"])
        k = jnp.einsum("bi,ihk->bhk", u, mp["wk"])
        v = jnp.einsum("bi,ihk->bhk", u, mp["wv"])
        i_raw = jnp.einsum("bi,ih->bh", u.astype(jnp.float32), mp["w_i"]) \
            + mp["b_i"]
        f_raw = jnp.einsum("bi,ih->bh", u.astype(jnp.float32), mp["w_f"]) \
            + mp["b_f"]
        st = ssm_lib.MLSTMState(C=mC, n=mn, m=mm)
        out, st2 = ssm_lib.mlstm_recurrent(q, k, v, i_raw, f_raw, st)
        y = out.reshape(b, inner)
        y = L.group_norm(y, nh) * (1.0 + mp["gn"].astype(jnp.float32))
        y = y.astype(h.dtype) * jax.nn.silu(
            z.astype(jnp.float32)).astype(h.dtype)
        h = h + jnp.einsum("bi,id->bd", y, mp["w_down"])
        # ---- sLSTM step
        x = L.rms_norm(h, sp["ln"])
        xw = jnp.einsum("bd,dghk->bghk", x.astype(jnp.float32), sp["w_x"]) \
            + sp["b"]
        sst = ssm_lib.SLSTMState(c=sc, n=sn, m=sm, h=shh)
        sst2 = ssm_lib.slstm_step(xw, sp["r"], sst)
        y = sst2.h.reshape(b, d).astype(h.dtype)
        y = L.group_norm(y, nh) * (1.0 + sp["gn"].astype(jnp.float32))
        y = y.astype(h.dtype)
        y = jnp.einsum("bf,fd->bd",
                       jax.nn.gelu(jnp.einsum("bd,df->bf", y, sp["ffn_wi"])
                                   .astype(jnp.float32)).astype(h.dtype),
                       sp["ffn_wo"])
        h = h + y
        return h, (st2.C, st2.n, st2.m, sst2.c, sst2.n, sst2.m, sst2.h)

    xs = (params["pairs"], cache["mC"], cache["mn"], cache["mm"],
          cache["sc"], cache["sn"], cache["sm"], cache["sh"])
    h, ys = jax.lax.scan(pair, h, xs)
    new_cache = {"mC": ys[0], "mn": ys[1], "mm": ys[2], "sc": ys[3],
                 "sn": ys[4], "sm": ys[5], "sh": ys[6]}
    h = L.rms_norm(h, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", h, params["embed"].T)
    return logits, new_cache

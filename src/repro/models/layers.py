"""Core layers: norms, RoPE, MLPs, embeddings — pure JAX, layout-stable.

Activation layout is always ``(batch, seq, d_model)``; attention heads are
kept as explicit dims ``(batch, seq, heads, head_dim)`` so sharding rules can
target them by logical axis name.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------- #
# Norms

def rms_norm(x, scale=None, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    if scale is not None:
        x = x * (1.0 + scale.astype(jnp.float32)) \
            if scale.ndim == 1 else x * scale
    return x.astype(dt)


def nonparam_ln(x, eps: float = 1e-5):
    """OLMo's non-parametric LayerNorm: no scale, no bias."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def norm(x, scale, kind: str):
    if kind == "nonparam_ln":
        return nonparam_ln(x)
    return rms_norm(x, scale)


def group_norm(x, n_groups: int, eps: float = 1e-6):
    """Per-head group norm (used by xLSTM / Hymba SSM branches).
    x: (..., inner); normalizes each of n_groups groups independently."""
    dt = x.dtype
    *lead, inner = x.shape
    g = x.astype(jnp.float32).reshape(*lead, n_groups, inner // n_groups)
    mu = jnp.mean(g, axis=-1, keepdims=True)
    var = jnp.var(g, axis=-1, keepdims=True)
    g = (g - mu) * jax.lax.rsqrt(var + eps)
    return g.reshape(*lead, inner).astype(dt)


# --------------------------------------------------------------------- #
# RoPE

def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions: (...,) int -> cos/sin (..., head_dim//2) in f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (S, hd//2) or (B, S, hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:           # (S, half) -> broadcast over B, H
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:                        # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1f, x2f = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1f * cos - x2f * sin,
                           x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# Row-parallel projection helper

def row_project(sh, x, w, eq, x_axes, w_axes, out_axes, scatter_axis=1):
    """Row-parallel (Megatron) out-projection: explicit psum_scatter when
    the sharder carries a tp_project hook (distributed.make_tp_projector),
    else plain einsum + output sharding constraint."""
    proj = getattr(sh, "tp_project", None)
    if proj is not None:
        return proj(x, w, eq, x_axes, w_axes, out_axes, scatter_axis)
    return sh(jnp.einsum(eq, x, w), out_axes)


def col_project(sh, x, w, eq, x_axes, w_axes, out_axes, gather_axis=1):
    """Column-parallel (Megatron f) projection: all_gather(x_seq)+einsum
    fused in one shard_map so the backward is a single psum_scatter."""
    proj = getattr(sh, "tp_col_project", None)
    if proj is not None:
        return proj(x, w, eq, x_axes, w_axes, out_axes, gather_axis)
    return sh(jnp.einsum(eq, x, w), out_axes)


def seq_gather(sh, x, axes, axis: int = 1):
    """Megatron-SP f-operator: gather the seq-sharded residual once per
    block (shard_map all_gather => reduce-scatter transpose).  Falls back
    to a sharding constraint when no tp_gather hook is attached."""
    g = getattr(sh, "tp_gather", None)
    if g is not None:
        return g(x, axes, axis)
    fallback = tuple("seq_attn" if a == "seq" else a for a in axes)
    return sh(x, fallback)


# --------------------------------------------------------------------- #
# MLP

def mlp_apply(x, wi, wo, act: str, sh=None):
    """Dense FFN.  wi: (2, D, F) for swiglu, (D, F) for gelu; wo: (F, D)."""
    if act == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, wi[0])
        up = jnp.einsum("bsd,df->bsf", x, wi[1])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = jnp.einsum("bsd,df->bsf", x, wi)
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    if sh is not None:
        return row_project(sh, h, wo, "bsf,fd->bsd",
                           ("batch", "seq_attn", "mlp"),
                           ("mlp", "embed"), ("batch", "seq", "embed"))
    return jnp.einsum("bsf,fd->bsd", h, wo)


# --------------------------------------------------------------------- #
# Init helpers

def trunc_normal(key, shape, scale: float, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key, d_in: int, shape, dtype):
    return trunc_normal(key, shape, (1.0 / d_in) ** 0.5, dtype)

"""Model facade: a uniform interface over all architecture families.

`build(cfg)` returns a `Model` whose methods dispatch to the right family
implementation (transformer / xlstm).  Everything downstream — training loop,
serving engine, dry-run launcher, SDAI backend nodes — talks only to this
interface.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.models import xlstm as xl

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ---------------- params ---------------- #
    def init(self, key) -> PyTree:
        if self.cfg.block == "xlstm":
            return xl.init_params(self.cfg, key)
        return tf.init_params(self.cfg, key)

    def param_axes(self) -> PyTree:
        if self.cfg.block == "xlstm":
            return xl.param_axes(self.cfg)
        return tf.param_axes(self.cfg)

    def param_specs(self) -> PyTree:
        """ShapeDtypeStructs for every param — no allocation (dry-run)."""
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def num_params(self) -> int:
        specs = self.param_specs()
        return sum(int(jnp.prod(jnp.array(x.shape)))
                   for x in jax.tree.leaves(specs))

    # ---------------- training ---------------- #
    def loss(self, params, batch, *, sh=tf._id_sh, shw=None, remat=False):
        if self.cfg.block == "xlstm":
            logits, _, _ = xl.forward(params, self.cfg, batch["tokens"],
                                      sh=sh, shw=shw, remat=remat)
            labels = batch["labels"]
            mask = labels != -100
            lab = jnp.where(mask, labels, 0)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            nll = -jnp.take_along_axis(lp, lab[..., None], axis=-1)[..., 0]
            denom = jnp.maximum(jnp.sum(mask), 1)
            loss = jnp.sum(jnp.where(mask, nll, 0.0)) / denom
            return loss, {"loss": loss, "aux": 0.0,
                          "tokens": denom.astype(jnp.float32)}
        return tf.loss_fn(params, self.cfg, batch, sh=sh, shw=shw,
                          remat=remat)

    def forward(self, params, tokens, **kw):
        if self.cfg.block == "xlstm":
            return xl.forward(params, self.cfg, tokens, **kw)
        return tf.forward(params, self.cfg, tokens, **kw)

    # ---------------- serving ---------------- #
    def init_cache(self, batch: int, max_len: int, src_len: int = 0,
                   dtype=None, kv_quant: bool = False):
        if self.cfg.block == "xlstm":
            return xl.init_cache(self.cfg, batch)
        return tf.init_cache(self.cfg, batch, max_len, src_len=src_len,
                             dtype=dtype, kv_quant=kv_quant)

    def cache_axes(self, kv_quant: bool = False):
        if self.cfg.block == "xlstm":
            return xl.cache_axes(self.cfg)
        return tf.cache_axes(self.cfg, kv_quant=kv_quant)

    def prefill(self, params, tokens, **kw):
        if self.cfg.block == "xlstm":
            kw.pop("prefix_embeds", None)
            kw.pop("src_embeds", None)
            kw.pop("cache_len", None)
            kw.pop("kv_quant", None)
            kw.pop("lengths", None)     # recurrent: exact-length batches
            return xl.prefill(params, self.cfg, tokens, **kw)
        return tf.prefill(params, self.cfg, tokens, **kw)

    def prefill_suffix(self, params, cache, tokens, offsets, lengths, *,
                       sh=tf._id_sh):
        """Extend per-row caches with suffix tokens at per-row offsets
        (the prefix-cache admission path).  Causal decoder-only — the
        engine gates eligibility; see `transformer.prefill_suffix`."""
        return tf.prefill_suffix(params, self.cfg, cache, tokens,
                                 offsets, lengths, sh=sh)

    def decode(self, params, cache, token, pos, *, sh=tf._id_sh):
        if self.cfg.block == "xlstm":
            return xl.decode_step(params, self.cfg, cache, token, sh=sh)
        return tf.decode_step(params, self.cfg, cache, token, pos, sh=sh)

    def decode_paged(self, params, cache, token, pos, page_table,
                     write_table, *, sh=tf._id_sh):
        """Decode one step directly against the paged KV pool via the
        page-table-aware attention kernel — no gathered logical view.
        Not defined for xlstm (no KV to page; the engine gates)."""
        return tf.decode_step_paged(params, self.cfg, cache, token, pos,
                                    page_table, write_table, sh=sh)

    def verify_paged(self, params, cache, tokens, pos, page_table,
                     write_table, *, sh=tf._id_sh):
        """Speculative-decoding batched verify: Q tokens per row in one
        paged forward, causal by absolute position.  Plain causal
        decoders only — the engine gates eligibility."""
        return tf.spec_verify_paged(params, self.cfg, cache, tokens, pos,
                                    page_table, write_table, sh=sh)


def build(cfg: ArchConfig) -> Model:
    return Model(cfg)

"""Decoder-only transformer family: dense GQA, sliding-window, MoE, VLM
(patch-embedding prefix), and Hymba hybrid (parallel attention + SSM heads,
meta tokens).

Layers are *stacked* (leading ``L`` dim) and iterated with ``lax.scan`` so the
lowered HLO is O(1) in depth — essential for compiling 80-layer models on the
no-hardware dry-run path, and the layout FSDP prefetch wants on real TPUs.

Every function takes ``sh(x, logical_axes)`` — a sharding-constraint hook
provided by the distribution layer (identity on single device).
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops as kernel_ops
from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib

PyTree = Any
Sharder = Callable[[jax.Array, tuple], jax.Array]


def _id_sh(x, axes):
    return x


_row_project = L.row_project


def _dt(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32


def ssm_inner(cfg: ArchConfig) -> int:
    return cfg.n_heads * cfg.head_dim


# --------------------------------------------------------------------- #
# Parameter init & logical axes

def _layer_init(cfg: ArchConfig, key, cross: bool = False) -> Dict:
    dt = _dt(cfg)
    d, hd = cfg.d_model, cfg.head_dim
    h, k_, f = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    keys = iter(jax.random.split(key, 24))
    p: Dict[str, Any] = {}
    p["attn"] = {
        "wq": L.dense_init(next(keys), d, (d, h, hd), dt),
        "wk": L.dense_init(next(keys), d, (d, k_, hd), dt),
        "wv": L.dense_init(next(keys), d, (d, k_, hd), dt),
    }
    if cfg.block != "hymba":
        p["attn"]["wo"] = L.dense_init(next(keys), h * hd, (h, hd, d), dt)
    if cfg.norm == "rms":
        p["ln1"] = jnp.zeros((d,), dt)
        p["ln2"] = jnp.zeros((d,), dt)
    if cross:
        p["xattn"] = {
            "wq": L.dense_init(next(keys), d, (d, h, hd), dt),
            "wk": L.dense_init(next(keys), d, (d, k_, hd), dt),
            "wv": L.dense_init(next(keys), d, (d, k_, hd), dt),
            "wo": L.dense_init(next(keys), h * hd, (h, hd, d), dt),
        }
        if cfg.norm == "rms":
            p["lnx"] = jnp.zeros((d,), dt)
    if cfg.moe:
        e = cfg.moe.num_experts
        wi_shape = (e, 2, d, f) if cfg.act == "swiglu" else (e, d, f)
        p["moe"] = {
            "router": L.dense_init(next(keys), d, (d, e), jnp.float32),
            "wi": L.dense_init(next(keys), d, wi_shape, dt),
            "wo": L.dense_init(next(keys), f, (e, f, d), dt),
        }
    elif f > 0:
        wi_shape = (2, d, f) if cfg.act == "swiglu" else (d, f)
        p["mlp"] = {"wi": L.dense_init(next(keys), d, wi_shape, dt),
                    "wo": L.dense_init(next(keys), f, (f, d), dt)}
    if cfg.block == "hymba":
        inner = ssm_inner(cfg)
        n = cfg.ssm_state
        r = max(8, inner // 64)
        p["ssm"] = {
            "w_in": L.dense_init(next(keys), d, (d, 2, inner), dt),
            "w_dt_a": L.dense_init(next(keys), inner, (inner, r), dt),
            "w_dt_b": L.dense_init(next(keys), r, (r, inner), dt),
            "b_dt": jnp.full((inner,), -4.0, jnp.float32),
            "a_log": jnp.log(jnp.broadcast_to(
                jnp.arange(1, n + 1, dtype=jnp.float32), (inner, n))),
            "w_b": L.dense_init(next(keys), inner, (inner, n), dt),
            "w_c": L.dense_init(next(keys), inner, (inner, n), dt),
            "d_skip": jnp.ones((inner,), jnp.float32),
        }
        p["branch_norm_attn"] = jnp.zeros((inner,), dt)
        p["branch_norm_ssm"] = jnp.zeros((inner,), dt)
        p["beta"] = jnp.ones((2,), jnp.float32)
        p["wo_comb"] = L.dense_init(next(keys), inner, (inner, d), dt)
    return p


def _layer_axes(cfg: ArchConfig, cross: bool = False) -> Dict:
    p: Dict[str, Any] = {}
    p["attn"] = {"wq": ("embed", "heads", "head_dim"),
                 "wk": ("embed", "kv_heads", "head_dim"),
                 "wv": ("embed", "kv_heads", "head_dim")}
    if cfg.block != "hymba":
        p["attn"]["wo"] = ("heads", "head_dim", "embed")
    if cfg.norm == "rms":
        p["ln1"] = ("embed",)
        p["ln2"] = ("embed",)
    if cross:
        p["xattn"] = {"wq": ("embed", "heads", "head_dim"),
                      "wk": ("embed", "kv_heads", "head_dim"),
                      "wv": ("embed", "kv_heads", "head_dim"),
                      "wo": ("heads", "head_dim", "embed")}
        if cfg.norm == "rms":
            p["lnx"] = ("embed",)
    if cfg.moe:
        wi = ("experts", "stack", "embed", "mlp") if cfg.act == "swiglu" \
            else ("experts", "embed", "mlp")
        p["moe"] = {"router": ("embed", "experts"), "wi": wi,
                    "wo": ("experts", "mlp", "embed")}
    elif cfg.d_ff > 0:
        wi = ("stack", "embed", "mlp") if cfg.act == "swiglu" \
            else ("embed", "mlp")
        p["mlp"] = {"wi": wi, "wo": ("mlp", "embed")}
    if cfg.block == "hymba":
        p["ssm"] = {"w_in": ("embed", "stack", "inner"),
                    "w_dt_a": ("inner", "rank"),
                    "w_dt_b": ("rank", "inner"),
                    "b_dt": ("inner",), "a_log": ("inner", "state"),
                    "w_b": ("inner", "state"), "w_c": ("inner", "state"),
                    "d_skip": ("inner",)}
        p["branch_norm_attn"] = ("inner",)
        p["branch_norm_ssm"] = ("inner",)
        p["beta"] = ("stack",)
        p["wo_comb"] = ("inner", "embed")
    return p


def init_params(cfg: ArchConfig, key) -> PyTree:
    dt = _dt(cfg)
    keys = iter(jax.random.split(key, 8))
    params: Dict[str, Any] = {
        "embed": L.trunc_normal(next(keys), (cfg.vocab, cfg.d_model),
                                0.02, dt)}
    if cfg.n_meta_tokens:
        params["meta"] = L.trunc_normal(
            next(keys), (cfg.n_meta_tokens, cfg.d_model), 0.02, dt)
    lk = jax.random.split(next(keys), cfg.n_layers)
    params["layers"] = jax.vmap(
        lambda k: _layer_init(cfg, k, cross=cfg.is_encdec))(lk)
    if cfg.is_encdec:
        ek = jax.random.split(next(keys), cfg.encdec.enc_layers)
        params["enc_layers"] = jax.vmap(
            lambda k: _layer_init(cfg, k, cross=False))(ek)
    if cfg.norm == "rms":
        params["final_norm"] = jnp.zeros((cfg.d_model,), dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.trunc_normal(
            next(keys), (cfg.d_model, cfg.vocab), 0.02, dt)
    return params


def param_axes(cfg: ArchConfig) -> PyTree:
    def stack(tree):
        return jax.tree.map(lambda ax: ("layers",) + ax, tree,
                            is_leaf=lambda x: isinstance(x, tuple))
    axes: Dict[str, Any] = {"embed": ("vocab", "embed")}
    if cfg.n_meta_tokens:
        axes["meta"] = ("prefix", "embed")
    axes["layers"] = stack(_layer_axes(cfg, cross=cfg.is_encdec))
    if cfg.is_encdec:
        axes["enc_layers"] = stack(_layer_axes(cfg, cross=False))
    if cfg.norm == "rms":
        axes["final_norm"] = ("embed",)
    if not cfg.tie_embeddings:
        axes["lm_head"] = ("embed", "vocab")
    return axes


# --------------------------------------------------------------------- #
# Blocks

def _attention_block(lp, cfg: ArchConfig, x, sh, *, causal, window, prefix,
                     q_offset=0, rope=True, kv=None, impl="auto"):
    """Full-sequence attention sub-block over a (possibly seq-sharded) x.

    Megatron-SP structure: q is a column-parallel projection (fused
    all_gather + einsum, psum_scatter backward); k/v are projected on the
    LOCAL sequence shard (small) and then seq-gathered — so no cotangent
    ever needs a full (B,S,D) all-reduce (§Perf iterations 2/9/10).
    kv: optional (k, v) override for cross-attention (already projected).
    Returns (out, (k, v)).
    """
    q = L.col_project(sh, x, lp["wq"], "bsd,dhk->bshk",
                      ("batch", "seq", "embed"),
                      ("embed", "heads", "head_dim"),
                      ("batch", "seq_attn", "heads", "head_dim"))
    if kv is None:
        k = jnp.einsum("bsd,dhk->bshk", x, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, lp["wv"])
        k = L.seq_gather(sh, k, ("batch", "seq", "kv_heads", "head_dim"))
        v = L.seq_gather(sh, v, ("batch", "seq", "kv_heads", "head_dim"))
        if rope:
            pos = q_offset + jnp.arange(k.shape[1])
            cos, sin = L.rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)
            q = L.apply_rope(q, cos, sin)
            k = L.apply_rope(k, cos, sin)
    else:
        k, v = kv
        if rope:
            pos = q_offset + jnp.arange(q.shape[1])
            cos, sin = L.rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)
            q = L.apply_rope(q, cos, sin)
    # "seq_attn" (unsharded): attention processes the whole sequence per
    # head-shard; sharding seq here would force XLA to reshard O(S^2)
    # score tensors (§Perf iteration 2)
    q = sh(q, ("batch", "seq_attn", "heads", "head_dim"))
    k = sh(k, ("batch", "seq_attn", "kv_heads", "head_dim"))
    v = sh(v, ("batch", "seq_attn", "kv_heads", "head_dim"))
    out = attn_lib.attention(q, k, v, causal=causal, window=window,
                             prefix=prefix, q_offset=q_offset, impl=impl)
    return out, (k, v)


def _hymba_ssm_seq(sp, cfg: ArchConfig, x, h0=None):
    """Hymba SSM branch over a full sequence.  x: (B,S,D)."""
    inner = ssm_inner(cfg)
    b, s, _ = x.shape
    proj = jnp.einsum("bsd,dgi->bsgi", x, sp["w_in"])
    u, z = proj[:, :, 0], proj[:, :, 1]
    dt = jax.nn.softplus(
        jnp.einsum("bsi,ir,rj->bsj", u.astype(jnp.float32),
                   sp["w_dt_a"].astype(jnp.float32),
                   sp["w_dt_b"].astype(jnp.float32)) + sp["b_dt"])
    a = -jnp.exp(sp["a_log"])
    b_t = jnp.einsum("bsi,in->bsn", u, sp["w_b"]).astype(jnp.float32)
    c_t = jnp.einsum("bsi,in->bsn", u, sp["w_c"]).astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((b, inner, cfg.ssm_state), jnp.float32)
    y, h_f = ssm_lib.selective_scan(u.astype(jnp.float32), dt, a, b_t, c_t,
                                    h0)
    y = y + sp["d_skip"] * u.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y.astype(x.dtype), h_f


def _hymba_ssm_step(sp, cfg: ArchConfig, x, h):
    """Single decode step.  x: (B, D); h: (B, inner, state)."""
    proj = jnp.einsum("bd,dgi->bgi", x, sp["w_in"])
    u, z = proj[:, 0], proj[:, 1]
    dt = jax.nn.softplus(
        jnp.einsum("bi,ir,rj->bj", u.astype(jnp.float32),
                   sp["w_dt_a"].astype(jnp.float32),
                   sp["w_dt_b"].astype(jnp.float32)) + sp["b_dt"])
    a = -jnp.exp(sp["a_log"])
    b_t = jnp.einsum("bi,in->bn", u, sp["w_b"]).astype(jnp.float32)
    c_t = jnp.einsum("bi,in->bn", u, sp["w_c"]).astype(jnp.float32)
    y, h_new = ssm_lib.selective_step(u.astype(jnp.float32), dt, a, b_t,
                                      c_t, h)
    y = y + sp["d_skip"] * u.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y.astype(x.dtype), h_new


def _ffn(lp, cfg: ArchConfig, x, sh):
    """FFN sub-block (dense or MoE) over a possibly seq-sharded x.
    Dense path is fully Megatron: column-parallel up (fused gather),
    row-parallel down (psum_scatter).  Returns (out, aux_loss)."""
    if cfg.moe:
        x = L.seq_gather(sh, x, ("batch", "seq", "embed"))
        y, aux = moe_lib.moe_ffn(x, lp["moe"]["router"], lp["moe"]["wi"],
                                 lp["moe"]["wo"], cfg.moe, cfg.act,
                                 sh=sh)
        return y, aux
    if cfg.d_ff == 0:
        return jnp.zeros_like(x), 0.0
    wi, wo = lp["mlp"]["wi"], lp["mlp"]["wo"]
    if cfg.act == "swiglu":
        h2 = L.col_project(sh, x, wi, "bsd,gdf->bsgf",
                           ("batch", "seq", "embed"),
                           ("stack", "embed", "mlp"),
                           ("batch", "seq_attn", "stack", "mlp"))
        h = jax.nn.silu(h2[:, :, 0].astype(jnp.float32)) \
            .astype(x.dtype) * h2[:, :, 1]
    else:
        h = L.col_project(sh, x, wi, "bsd,df->bsf",
                          ("batch", "seq", "embed"),
                          ("embed", "mlp"),
                          ("batch", "seq_attn", "mlp"))
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    out = L.row_project(sh, h, wo, "bsf,fd->bsd",
                        ("batch", "seq_attn", "mlp"),
                        ("mlp", "embed"), ("batch", "seq", "embed"))
    return out, 0.0


def _decoder_layer(lp, cfg: ArchConfig, h, sh, *, is_global, prefix,
                   enc_kv=None, impl="auto"):
    """One full-sequence decoder layer.  Returns (h, (k, v), aux).
    `is_global` may be a traced bool (hymba per-layer flag)."""
    if isinstance(is_global, bool):
        window = 0 if is_global else cfg.swa_window
    else:
        window = jnp.where(is_global, 0, cfg.swa_window)
    x = L.norm(h, lp.get("ln1"), cfg.norm)
    if cfg.block == "hymba":
        inner = ssm_inner(cfg)
        # hymba's SSM branch scans the full sequence: gather once here
        x = L.seq_gather(sh, x, ("batch", "seq", "embed"))
        a_out, kv_pair = _attention_block(
            lp["attn"], cfg, x, sh, causal=True, window=window,
            prefix=prefix, impl=impl)
        a_out = a_out.reshape(*a_out.shape[:2], inner)
        s_out, _ = _hymba_ssm_seq(lp["ssm"], cfg, x)
        a_n = L.rms_norm(a_out, lp["branch_norm_attn"])
        s_n = L.rms_norm(s_out, lp["branch_norm_ssm"])
        comb = (lp["beta"][0] * a_n.astype(jnp.float32)
                + lp["beta"][1] * s_n.astype(jnp.float32)) * 0.5
        proj = jnp.einsum("bsi,id->bsd", comb.astype(h.dtype),
                          lp["wo_comb"])
        h = h + sh(proj, ("batch", "seq", "embed"))
    else:
        a_out, kv_pair = _attention_block(
            lp["attn"], cfg, x, sh, causal=True, window=window,
            prefix=prefix, impl=impl)
        # row-parallel out-projection: explicit reduce-scatter onto the
        # seq-sharded residual (half the wire of XLA's all-reduce),
        # §Perf iterations 6+8
        proj = _row_project(sh, a_out, lp["attn"]["wo"],
                            "bshk,hkd->bsd",
                            ("batch", "seq_attn", "heads", "head_dim"),
                            ("heads", "head_dim", "embed"),
                            ("batch", "seq", "embed"))
        h = h + proj
    aux = 0.0
    if enc_kv is not None:
        x = L.norm(h, lp.get("lnx"), cfg.norm)
        c_out, _ = _attention_block(lp["xattn"], cfg, x, sh, causal=False,
                                    window=0, prefix=0, rope=False,
                                    kv=enc_kv, impl=impl)
        proj = _row_project(sh, c_out, lp["xattn"]["wo"],
                            "bshk,hkd->bsd",
                            ("batch", "seq_attn", "heads", "head_dim"),
                            ("heads", "head_dim", "embed"),
                            ("batch", "seq", "embed"))
        h = h + proj
    x = L.norm(h, lp.get("ln2"), cfg.norm)
    f_out, aux2 = _ffn(lp, cfg, x, sh)
    h = h + sh(f_out, ("batch", "seq", "embed"))
    h = sh(h, ("batch", "seq", "embed"))
    return h, kv_pair, aux + aux2


# --------------------------------------------------------------------- #
# Full-sequence forward (train / prefill)

def _is_global_flags(cfg: ArchConfig):
    flags = jnp.zeros((cfg.n_layers,), bool)
    if cfg.block == "hymba":
        flags = flags.at[jnp.array(cfg.global_attn_layers)].set(True)
    else:
        flags = jnp.ones((cfg.n_layers,), bool) if cfg.swa_window == 0 \
            else flags
    return flags


def _embed_inputs(params, cfg: ArchConfig, tokens, prefix_embeds, sh):
    """Returns (h (B, S_total, D), prefix_len)."""
    h = jnp.take(params["embed"], tokens, axis=0)
    prefix = 0
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
        prefix = prefix_embeds.shape[1]
    if cfg.n_meta_tokens:
        meta = jnp.broadcast_to(
            params["meta"][None], (h.shape[0],) + params["meta"].shape)
        h = jnp.concatenate([meta.astype(h.dtype), h], axis=1)
        prefix += cfg.n_meta_tokens
    return sh(h, ("batch", "seq", "embed")), prefix


def _run_encoder(params, cfg: ArchConfig, src_embeds, sh, impl="auto"):
    def body(h, lp):
        h2, _, _ = _decoder_layer(lp, cfg, h, sh, is_global=True, prefix=0,
                                  impl=impl)
        return h2, None
    # encoder is bidirectional: reuse layer with causal=False via wrapper
    def enc_layer(h, lp):
        x = L.norm(h, lp.get("ln1"), cfg.norm)
        a_out, _ = _attention_block(lp["attn"], cfg, x, sh, causal=False,
                                    window=0, prefix=0, impl=impl)
        proj = _row_project(sh, a_out, lp["attn"]["wo"],
                            "bshk,hkd->bsd",
                            ("batch", "seq_attn", "heads", "head_dim"),
                            ("heads", "head_dim", "embed"),
                            ("batch", "seq", "embed"))
        h = h + proj
        x = L.norm(h, lp.get("ln2"), cfg.norm)
        f_out, _ = _ffn(lp, cfg, x, sh)
        h = h + sh(f_out, ("batch", "seq", "embed"))
        return sh(h, ("batch", "seq", "embed")), None

    h, _ = jax.lax.scan(enc_layer, src_embeds, params["enc_layers"])
    return L.norm(h, params.get("final_norm"), cfg.norm)


def forward(params, cfg: ArchConfig, tokens, *, prefix_embeds=None,
            src_embeds=None, sh: Sharder = _id_sh, shw=None,
            remat: bool = False, collect_cache: bool = False,
            impl: str = "auto"):
    """Full-sequence forward.  Returns (logits, cache_parts, aux_loss).

    cache_parts is (k_stack, v_stack, enc_out) when collect_cache else None.
    shw(tree, axes_tree): weight compute-sharding hook (explicit FSDP
    gather inside the layer scan).
    """
    if cfg.block == "xlstm":
        from repro.models import xlstm as xl
        return xl.forward(params, cfg, tokens, sh=sh, shw=shw, remat=remat,
                          collect_cache=collect_cache)
    h, prefix = _embed_inputs(params, cfg, tokens, prefix_embeds, sh)
    enc_out = None
    if cfg.is_encdec:
        enc_out = _run_encoder(params, cfg, src_embeds, sh, impl=impl)
    flags = _is_global_flags(cfg) if cfg.block == "hymba" else None
    layer_ax = _layer_axes(cfg, cross=cfg.is_encdec)

    def layer(carry, xs):
        h = carry
        if flags is not None:
            lp, is_glob = xs
        else:
            lp, is_glob = xs, cfg.swa_window == 0
        if shw is not None:
            lp = shw(lp, layer_ax)
        enc_kv = None
        if enc_out is not None:
            ek = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wk"])
            ev = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wv"])
            enc_kv = (ek, ev)
        h2, kv, aux = _decoder_layer(lp, cfg, h, sh, is_global=is_glob,
                                     prefix=prefix, enc_kv=enc_kv, impl=impl)
        ys = (kv if collect_cache else None, aux)
        return h2, ys

    if remat:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)
    xs = (params["layers"], flags) if flags is not None \
        else params["layers"]
    h, (kv_stack, auxs) = jax.lax.scan(layer, h, xs)
    h = L.norm(h, params.get("final_norm"), cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if shw is not None:
        head = shw(head, ("embed", "vocab"))
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    logits = sh(logits, ("batch", "seq", "vocab"))
    aux = jnp.sum(auxs) if cfg.moe else 0.0
    cache_parts = None
    if collect_cache:
        cache_parts = (kv_stack[0], kv_stack[1], enc_out, prefix)
    return logits, cache_parts, aux


# --------------------------------------------------------------------- #
# Loss

def loss_fn(params, cfg: ArchConfig, batch, *, sh: Sharder = _id_sh,
            shw=None, remat: bool = False, aux_weight: float = 0.01):
    """batch: {"tokens", "labels", optional "prefix_embeds"/"src_embeds"}.
    labels == -100 are masked.  Returns (loss, metrics)."""
    logits, _, aux = forward(
        params, cfg, batch["tokens"],
        prefix_embeds=batch.get("prefix_embeds"),
        src_embeds=batch.get("src_embeds"), sh=sh, shw=shw, remat=remat)
    labels = batch["labels"]
    # logits cover prefix+tokens; labels align with the *token* tail
    n_tok = labels.shape[1]
    logits = logits[:, -n_tok:]
    mask = labels != -100
    lab = jnp.where(mask, labels, 0)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, lab[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1)
    loss = jnp.sum(jnp.where(mask, nll, 0.0)) / denom
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux,
                   "tokens": denom.astype(jnp.float32)}


# --------------------------------------------------------------------- #
# Serving: cache init / prefill / decode

def kv_quantize(x):
    """Per-(position, head) absmax int8 KV quantization.
    x: (..., hd) -> (q int8 (..., hd), scale f32 (...))."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0]


def kv_dequant(q, scale):
    """Dequantize int8 KV.  On TPU this runs inside the Pallas decode
    kernel (int8 HBM reads, VMEM dequant) — tagged so the roofline's
    kernel-adjusted terms treat the f32 expansion as VMEM-local."""
    with jax.named_scope("kv_dequant"):
        return q.astype(jnp.float32) * scale[..., None]


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               src_len: int = 0, dtype=None, kv_quant: bool = False):
    """Dense KV cache pytree (zeros).  max_len includes prefix tokens.
    kv_quant: int8 cache + per-(pos, head) f32 scales (halves at-rest KV
    bytes and HBM read traffic per decode step)."""
    if cfg.block == "xlstm":
        from repro.models import xlstm as xl
        return xl.init_cache(cfg, batch)
    dt = dtype or _dt(cfg)
    lshape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    if kv_quant:
        sshape = lshape[:-1]
        cache = {"k": jnp.zeros(lshape, jnp.int8),
                 "v": jnp.zeros(lshape, jnp.int8),
                 "k_scale": jnp.zeros(sshape, jnp.float32),
                 "v_scale": jnp.zeros(sshape, jnp.float32)}
    else:
        cache = {"k": jnp.zeros(lshape, dt), "v": jnp.zeros(lshape, dt)}
    if cfg.block == "hymba":
        cache["ssm_h"] = jnp.zeros(
            (cfg.n_layers, batch, ssm_inner(cfg), cfg.ssm_state),
            jnp.float32)
    if cfg.is_encdec:
        xshape = (cfg.n_layers, batch, src_len, cfg.n_kv_heads, cfg.head_dim)
        cache["ck"] = jnp.zeros(xshape, dt)
        cache["cv"] = jnp.zeros(xshape, dt)
    return cache


def cache_axes(cfg: ArchConfig, kv_quant: bool = False):
    ax = {"k": ("layers", "batch", "seq_kv", "kv_heads", "head_dim"),
          "v": ("layers", "batch", "seq_kv", "kv_heads", "head_dim")}
    if kv_quant:
        ax["k_scale"] = ("layers", "batch", "seq_kv", "kv_heads")
        ax["v_scale"] = ("layers", "batch", "seq_kv", "kv_heads")
    if cfg.block == "xlstm":
        from repro.models import xlstm as xl
        return xl.cache_axes(cfg)
    if cfg.block == "hymba":
        ax["ssm_h"] = ("layers", "batch", "inner", "state")
    if cfg.is_encdec:
        ax["ck"] = ("layers", "batch", "seq_kv", "kv_heads", "head_dim")
        ax["cv"] = ("layers", "batch", "seq_kv", "kv_heads", "head_dim")
    return ax


def prefill(params, cfg: ArchConfig, tokens, *, prefix_embeds=None,
            src_embeds=None, cache_len: int = 0, sh: Sharder = _id_sh,
            impl: str = "auto", kv_quant: bool = False, lengths=None):
    """Run full-sequence forward, build a decode-ready cache.

    Returns (last_logits (B, V), cache, pos (B,)) — pos = index of the last
    valid cache slot.

    lengths: optional (B,) int32 of *valid* (unpadded) token counts per
    row, for bucketed prefill: prompts right-padded to a shared bucket
    length share one trace, and each row's logits/pos are taken at its own
    last real token.  Sound for causal attention families only — padded
    positions sit beyond `pos` and are masked out of every later decode
    read, then overwritten as the slot advances.  Recurrent families
    (xlstm / hymba SSM states) fold pads into their state, so callers must
    batch those at exact lengths instead.
    """
    if cfg.block == "xlstm":
        from repro.models import xlstm as xl
        return xl.prefill(params, cfg, tokens, sh=sh)
    logits, parts, _ = forward(params, cfg, tokens,
                               prefix_embeds=prefix_embeds,
                               src_embeds=src_embeds, sh=sh,
                               collect_cache=True, impl=impl)
    k_stack, v_stack, enc_out, prefix = parts
    b = tokens.shape[0]
    s_tot = k_stack.shape[2]
    cache_len = max(cache_len, s_tot)
    cache = init_cache(cfg, b, cache_len,
                       src_len=(src_embeds.shape[1] if cfg.is_encdec
                                else 0), kv_quant=kv_quant)
    if kv_quant:
        k_stack, ks = kv_quantize(k_stack)
        v_stack, vs = kv_quantize(v_stack)
        cache["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k_scale"], ks, 0, axis=2)
        cache["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v_scale"], vs, 0, axis=2)
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_stack.astype(cache["k"].dtype), 0, axis=2)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_stack.astype(cache["v"].dtype), 0, axis=2)
    if cfg.is_encdec:
        def xkv(lp_enc):
            ck = jnp.einsum("bsd,dhk->bshk", enc_out, lp_enc["wk"])
            cv = jnp.einsum("bsd,dhk->bshk", enc_out, lp_enc["wv"])
            return ck, cv
        ck, cv = jax.vmap(xkv)(
            {"wk": params["layers"]["xattn"]["wk"],
             "wv": params["layers"]["xattn"]["wv"]})
        cache["ck"], cache["cv"] = ck, cv
    if cfg.block == "hymba":
        # re-run SSM branches to harvest final states (prefill-only cost)
        h, _ = _embed_inputs(params, cfg, tokens, prefix_embeds, sh)
        # states are collected during a light scan over layers
        def body(h, lp):
            x = L.norm(h, lp.get("ln1"), cfg.norm)
            _, h_f = _hymba_ssm_seq(lp["ssm"], cfg, x)
            h2, _, _ = _decoder_layer(lp, cfg, h, sh, is_global=False,
                                      prefix=cfg.n_meta_tokens, impl=impl)
            return h2, h_f
        _, states = jax.lax.scan(body, h, params["layers"])
        cache["ssm_h"] = states
    if lengths is not None:
        prefix = s_tot - tokens.shape[1]
        pos = (prefix + lengths - 1).astype(jnp.int32)
        last = jnp.take_along_axis(logits, pos[:, None, None], axis=1)[:, 0]
        return last, cache, pos
    pos = jnp.full((b,), s_tot - 1, jnp.int32)
    return logits[:, -1], cache, pos


def prefill_suffix(params, cfg: ArchConfig, cache, tokens, offsets,
                   lengths, *, sh: Sharder = _id_sh):
    """Extend per-row caches with a *batch of suffix tokens* in one pass —
    the prefix-cache admission path: rows arrive with `offsets` (B,) cache
    positions already valid (the shared cached prefix), `tokens` (B, S)
    right-padded suffix ids, and `lengths` (B,) valid suffix counts
    (>= 1).  The multi-token generalization of `decode_step`: suffix KV
    is written into the cache view at per-row offsets, attention is
    causal by per-row absolute position, and each row's logits come from
    its own last real token.

    Returns (last_logits (B, V), new_cache, pos (B,)) with
    pos = offsets + lengths - 1 (index of the last valid cache slot).

    Causal decoder-only: recurrent families (xlstm / hymba), enc-dec
    cross-attention, sliding windows, always-visible prefix tokens and
    quantized caches all depend on positions/state the suffix pass does
    not reconstruct — callers gate on those (the engine falls back to
    full prefill).
    """
    if cfg.block in ("xlstm", "hymba") or cfg.is_encdec \
            or cfg.swa_window or cfg.n_meta_tokens \
            or cfg.n_prefix_tokens or "k_scale" in cache:
        raise NotImplementedError(
            "prefill_suffix supports plain causal decoders only")
    b, s = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0)              # (B,S,D)
    q_pos = offsets[:, None] + jnp.arange(s)[None, :]          # (B,S)

    def layer(carry, xs):
        h = carry
        lp = xs["lp"]
        kc, vc = xs["k"], xs["v"]
        x = L.norm(h, lp.get("ln1"), cfg.norm)
        q = jnp.einsum("bsd,dhk->bshk", x, lp["attn"]["wq"])
        k_new = jnp.einsum("bsd,dhk->bshk", x, lp["attn"]["wk"])
        v_new = jnp.einsum("bsd,dhk->bshk", x, lp["attn"]["wv"])
        cos, sin = L.rope_cos_sin(q_pos, cfg.head_dim, cfg.rope_theta)
        q = L.apply_rope(q, cos, sin)
        k_new = L.apply_rope(k_new, cos, sin)
        # scatter the suffix block at each row's absolute positions —
        # per-row scatter (not dynamic_update_slice: its clamped starts
        # would shift a row whose offset+S exceeds the view and overwrite
        # real prefix KV).  Out-of-range positions drop; garbage on
        # padded rows lands beyond `pos`, masked out of every later read
        # and overwritten as the slot advances.
        upd = jax.vmap(lambda c, n, p: c.at[p].set(n, mode="drop"))
        kc = upd(kc, k_new.astype(kc.dtype), q_pos)
        vc = upd(vc, v_new.astype(vc.dtype), q_pos)
        a_out = attn_lib.suffix_attention(q, kc, vc, q_pos)
        h = h + jnp.einsum("bshk,hkd->bsd", a_out, lp["attn"]["wo"])
        x = L.norm(h, lp.get("ln2"), cfg.norm)
        f_out, _ = _ffn(lp, cfg, x, sh)
        h = h + f_out
        return h, {"k": kc, "v": vc}

    xs = {"lp": params["layers"], "k": cache["k"], "v": cache["v"]}
    h, ys = jax.lax.scan(layer, h, xs)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = ys["k"], ys["v"]
    h = L.norm(h, params.get("final_norm"), cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    pos = (offsets + lengths - 1).astype(jnp.int32)
    last_idx = jnp.clip(lengths - 1, 0, s - 1)
    last = jnp.take_along_axis(logits, last_idx[:, None, None],
                               axis=1)[:, 0]
    return last, new_cache, pos


def decode_step(params, cfg: ArchConfig, cache, token, pos, *,
                sh: Sharder = _id_sh):
    """One decode step.  token: (B,) int32; pos: (B,) int32 — position of
    the *new* token (cache slots [0, pos) are valid; prefix included).

    Returns (logits (B, V), new_cache).
    """
    if cfg.block == "xlstm":
        from repro.models import xlstm as xl
        return xl.decode_step(params, cfg, cache, token, sh=sh)
    b = token.shape[0]
    h = jnp.take(params["embed"], token, axis=0)[:, None]      # (B,1,D)
    flags = _is_global_flags(cfg) if cfg.block == "hymba" else None
    prefix = cfg.n_meta_tokens + cfg.n_prefix_tokens
    quant = "k_scale" in cache

    def layer(carry, xs):
        h = carry
        lp = xs["lp"]
        kc, vc = xs["k"], xs["v"]
        hs = xs.get("ssm")
        is_glob = xs.get("flag", cfg.swa_window == 0)
        if isinstance(is_glob, bool):
            window = 0 if is_glob else cfg.swa_window
        else:
            window = jnp.where(is_glob, 0, cfg.swa_window)
        x = L.norm(h, lp.get("ln1"), cfg.norm)
        q = jnp.einsum("bsd,dhk->bshk", x, lp["attn"]["wq"])
        k_new = jnp.einsum("bsd,dhk->bshk", x, lp["attn"]["wk"])
        v_new = jnp.einsum("bsd,dhk->bshk", x, lp["attn"]["wv"])
        cos, sin = L.rope_cos_sin(pos[:, None], cfg.head_dim,
                                  cfg.rope_theta)
        q = L.apply_rope(q, cos, sin)
        k_new = L.apply_rope(k_new, cos, sin)
        # write new kv at slot pos (per batch row)
        upd = jax.vmap(
            lambda c, n, p: jax.lax.dynamic_update_slice_in_dim(
                c, n, p, axis=0))
        ys = {}
        if quant:
            kq, ks_new = kv_quantize(k_new)
            vq, vs_new = kv_quantize(v_new)
            kc = upd(kc, kq, pos)
            vc = upd(vc, vq, pos)
            ks = upd(xs["ks"], ks_new, pos)
            vs = upd(xs["vs"], vs_new, pos)
            ys.update(ks=ks, vs=vs)
            k_at = kv_dequant(kc, ks)
            v_at = kv_dequant(vc, vs)
        else:
            kc = upd(kc, k_new, pos)
            vc = upd(vc, v_new, pos)
            k_at, v_at = kc, vc
        ys.update(k=kc, v=vc)
        a_out = attn_lib.decode_attention(q, k_at, v_at, pos,
                                          window=window, prefix=prefix)
        if cfg.block == "hymba":
            inner = ssm_inner(cfg)
            a_out = a_out.reshape(b, 1, inner)
            s_out, hs_new = _hymba_ssm_step(lp["ssm"], cfg, x[:, 0], hs)
            a_n = L.rms_norm(a_out, lp["branch_norm_attn"])
            s_n = L.rms_norm(s_out[:, None], lp["branch_norm_ssm"])
            comb = (lp["beta"][0] * a_n.astype(jnp.float32)
                    + lp["beta"][1] * s_n.astype(jnp.float32)) * 0.5
            h = h + jnp.einsum("bsi,id->bsd", comb.astype(h.dtype),
                               lp["wo_comb"])
            ys["ssm"] = hs_new
        else:
            h = h + jnp.einsum("bshk,hkd->bsd", a_out, lp["attn"]["wo"])
        if cfg.is_encdec:
            x = L.norm(h, lp.get("lnx"), cfg.norm)
            cq = jnp.einsum("bsd,dhk->bshk", x, lp["xattn"]["wq"])
            src_len = xs["ck"].shape[1]
            c_out = attn_lib.decode_attention(
                cq, xs["ck"], xs["cv"],
                jnp.full((b,), src_len - 1, jnp.int32))
            h = h + jnp.einsum("bshk,hkd->bsd", c_out, lp["xattn"]["wo"])
        x = L.norm(h, lp.get("ln2"), cfg.norm)
        f_out, _ = _ffn(lp, cfg, x, sh)
        h = h + f_out
        return h, ys

    xs = {"lp": params["layers"], "k": cache["k"], "v": cache["v"]}
    if quant:
        xs["ks"], xs["vs"] = cache["k_scale"], cache["v_scale"]
    if flags is not None:
        xs["ssm"] = cache["ssm_h"]
        xs["flag"] = flags
    if cfg.is_encdec:
        xs["ck"], xs["cv"] = cache["ck"], cache["cv"]
    h, ys = jax.lax.scan(layer, h, xs)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = ys["k"], ys["v"]
    if quant:
        new_cache["k_scale"], new_cache["v_scale"] = ys["ks"], ys["vs"]
    if cfg.block == "hymba":
        new_cache["ssm_h"] = ys["ssm"]
    h = L.norm(h, params.get("final_norm"), cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)[:, 0]
    return logits, new_cache


def _paged_write(cache_pool, new_kv, write_table, w_pos):
    """Scatter per-row KV into the physical page pool through the write
    table.  cache_pool: (P, ps, K, hd); new_kv: (B, ..., K, hd) matching
    w_pos (B, ...) absolute positions.  Unmapped / cache-shared /
    out-of-range positions resolve to the sentinel and drop on device —
    the paged analogue of `prefill_suffix`'s mode="drop" scatter."""
    n_pages, ps = cache_pool.shape[0], cache_pool.shape[1]
    pps = write_table.shape[1]
    slot_page = w_pos // ps                                  # (B, Q)
    pid = jnp.take_along_axis(write_table,
                              jnp.minimum(slot_page, pps - 1), axis=1)
    pid = jnp.where(slot_page < pps, pid, n_pages)
    return cache_pool.at[pid, w_pos % ps].set(
        new_kv.astype(cache_pool.dtype), mode="drop")


def decode_step_paged(params, cfg: ArchConfig, cache, token, pos,
                      page_table, write_table, *, sh: Sharder = _id_sh):
    """One decode step directly against the paged physical KV pool — no
    gathered logical view.  token/pos: (B,) int32 as in `decode_step`;
    page_table/write_table: (B, pps) int32 with sentinel == n_pages.
    Paged leaves are the flat (L, n_pages, page_size, K, hd) pools;
    constant-size leaves (ssm states, enc-dec cross KV) stay
    slot-resident.  Returns (logits (B, V), new_cache).

    Same family coverage as the paged engine (everything but xlstm);
    quantized KV keeps the gather path — per-page scale layout isn't
    paged yet.
    """
    if cfg.block == "xlstm" or "k_scale" in cache:
        raise NotImplementedError(
            "decode_step_paged: xlstm has no KV to page; quantized KV "
            "uses the gather path")
    b = token.shape[0]
    h = jnp.take(params["embed"], token, axis=0)[:, None]      # (B,1,D)
    flags = _is_global_flags(cfg) if cfg.block == "hymba" else None
    prefix = cfg.n_meta_tokens + cfg.n_prefix_tokens
    nkv = cfg.n_kv_heads

    def layer(carry, xs):
        h = carry
        lp = xs["lp"]
        kc, vc = xs["k"], xs["v"]                # (P, ps, K, hd) pools
        hs = xs.get("ssm")
        is_glob = xs.get("flag", cfg.swa_window == 0)
        if isinstance(is_glob, bool):
            window = 0 if is_glob else cfg.swa_window
        else:
            window = jnp.where(is_glob, 0, cfg.swa_window)
        x = L.norm(h, lp.get("ln1"), cfg.norm)
        q = jnp.einsum("bsd,dhk->bshk", x, lp["attn"]["wq"])
        k_new = jnp.einsum("bsd,dhk->bshk", x, lp["attn"]["wk"])
        v_new = jnp.einsum("bsd,dhk->bshk", x, lp["attn"]["wv"])
        cos, sin = L.rope_cos_sin(pos[:, None], cfg.head_dim,
                                  cfg.rope_theta)
        q = L.apply_rope(q, cos, sin)
        k_new = L.apply_rope(k_new, cos, sin)
        kc = _paged_write(kc, k_new, write_table, pos[:, None])
        vc = _paged_write(vc, v_new, write_table, pos[:, None])
        ys = {"k": kc, "v": vc}
        qf = q[:, 0].reshape(b, nkv, q.shape[2] // nkv, cfg.head_dim)
        a_out = kernel_ops.paged_decode_attention(
            qf, kc, vc, page_table, pos, window=window, prefix=prefix)
        a_out = a_out.reshape(b, 1, q.shape[2], cfg.head_dim)
        if cfg.block == "hymba":
            inner = ssm_inner(cfg)
            a_out = a_out.reshape(b, 1, inner)
            s_out, hs_new = _hymba_ssm_step(lp["ssm"], cfg, x[:, 0], hs)
            a_n = L.rms_norm(a_out, lp["branch_norm_attn"])
            s_n = L.rms_norm(s_out[:, None], lp["branch_norm_ssm"])
            comb = (lp["beta"][0] * a_n.astype(jnp.float32)
                    + lp["beta"][1] * s_n.astype(jnp.float32)) * 0.5
            h = h + jnp.einsum("bsi,id->bsd", comb.astype(h.dtype),
                               lp["wo_comb"])
            ys["ssm"] = hs_new
        else:
            h = h + jnp.einsum("bshk,hkd->bsd", a_out, lp["attn"]["wo"])
        if cfg.is_encdec:
            x = L.norm(h, lp.get("lnx"), cfg.norm)
            cq = jnp.einsum("bsd,dhk->bshk", x, lp["xattn"]["wq"])
            src_len = xs["ck"].shape[1]
            c_out = attn_lib.decode_attention(
                cq, xs["ck"], xs["cv"],
                jnp.full((b,), src_len - 1, jnp.int32))
            h = h + jnp.einsum("bshk,hkd->bsd", c_out, lp["xattn"]["wo"])
        x = L.norm(h, lp.get("ln2"), cfg.norm)
        f_out, _ = _ffn(lp, cfg, x, sh)
        h = h + f_out
        return h, ys

    xs = {"lp": params["layers"], "k": cache["k"], "v": cache["v"]}
    if flags is not None:
        xs["ssm"] = cache["ssm_h"]
        xs["flag"] = flags
    if cfg.is_encdec:
        xs["ck"], xs["cv"] = cache["ck"], cache["cv"]
    h, ys = jax.lax.scan(layer, h, xs)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = ys["k"], ys["v"]
    if cfg.block == "hymba":
        new_cache["ssm_h"] = ys["ssm"]
    h = L.norm(h, params.get("final_norm"), cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)[:, 0]
    return logits, new_cache


def spec_verify_paged(params, cfg: ArchConfig, cache, tokens, pos,
                      page_table, write_table, *, sh: Sharder = _id_sh):
    """Speculative-decoding verify: run Q = 1 + n_draft tokens per row in
    one forward against the paged pool, causal by absolute position —
    the multi-token generalization of `decode_step_paged`, exactly as
    `prefill_suffix` generalizes `decode_step`.  tokens: (B, Q) — the
    last accepted token followed by the draft chain; pos: (B,) absolute
    position of tokens[:, 0].  KV for *every* fed position is written
    through the write table (rejected drafts leave garbage beyond the
    accepted position — masked by causality and overwritten when decode
    resumes there).  Returns (logits (B, Q, V), new_cache).

    Plain causal decoders only (recurrent state can't roll back a
    rejected draft; windows/prefix/cross-KV change visibility) — the
    engine gates speculation on the same predicate as the prefix cache.
    """
    if cfg.block in ("xlstm", "hymba") or cfg.is_encdec \
            or cfg.swa_window or cfg.n_meta_tokens \
            or cfg.n_prefix_tokens or "k_scale" in cache:
        raise NotImplementedError(
            "spec_verify_paged supports plain causal decoders only")
    b, qn = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0)              # (B,Q,D)
    q_pos = pos[:, None] + jnp.arange(qn)[None, :]             # (B,Q)

    def layer(carry, xs):
        h = carry
        lp = xs["lp"]
        kc, vc = xs["k"], xs["v"]
        x = L.norm(h, lp.get("ln1"), cfg.norm)
        q = jnp.einsum("bsd,dhk->bshk", x, lp["attn"]["wq"])
        k_new = jnp.einsum("bsd,dhk->bshk", x, lp["attn"]["wk"])
        v_new = jnp.einsum("bsd,dhk->bshk", x, lp["attn"]["wv"])
        cos, sin = L.rope_cos_sin(q_pos, cfg.head_dim, cfg.rope_theta)
        q = L.apply_rope(q, cos, sin)
        k_new = L.apply_rope(k_new, cos, sin)
        kc = _paged_write(kc, k_new, write_table, q_pos)
        vc = _paged_write(vc, v_new, write_table, q_pos)
        a_out = kernel_ops.paged_suffix_attention(q, kc, vc,
                                                  page_table, q_pos)
        h = h + jnp.einsum("bshk,hkd->bsd", a_out, lp["attn"]["wo"])
        x = L.norm(h, lp.get("ln2"), cfg.norm)
        f_out, _ = _ffn(lp, cfg, x, sh)
        h = h + f_out
        return h, {"k": kc, "v": vc}

    xs = {"lp": params["layers"], "k": cache["k"], "v": cache["v"]}
    h, ys = jax.lax.scan(layer, h, xs)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = ys["k"], ys["v"]
    h = L.norm(h, params.get("final_norm"), cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    return logits, new_cache

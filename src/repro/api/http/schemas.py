"""Wire protocol v1 — JSON schemas, strict validation, error mapping.

One place defines how the frozen Gateway types cross the network:

* `HTTP_STATUS` — THE `ErrorCode -> HTTP status` table.  Every structured
  failure the Gateway can produce becomes a typed JSON error body with a
  documented status; nothing is ever classified by parsing messages.
* `parse_completion_request` / `parse_chat_request` — strict validators
  from untrusted JSON to typed calls (`WireError` carries the status and
  body for anything malformed).
* response/chunk builders — OpenAI-compatible `text_completion` /
  `chat.completion` bodies and their `*.chunk` SSE deltas, extended with
  `token_ids` per choice and a `metadata` routing trace (node, replica,
  retries, ttft) that the paper's dashboard surfaces.
* SSE framing — `sse_event()` renders one `data:` frame; streams always
  terminate with `SSE_DONE` (`data: [DONE]`), including after a
  mid-stream structured error frame.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.api.http.chat import ChatMessage
from repro.api.types import APIError, ErrorCode, GenerationResponse
from repro.serving.sampler import SamplingParams

WIRE_VERSION = "v1"

# ------------------------------------------------------------------ #
# The ErrorCode -> HTTP status table (mirrored in README).  499 is the
# de-facto "client closed request" status (nginx); everything else is
# standard.
HTTP_STATUS: Dict[ErrorCode, int] = {
    ErrorCode.NO_BACKEND: 503,
    ErrorCode.OVERLOADED: 429,
    ErrorCode.ENGINE_FAILED: 500,
    ErrorCode.CANCELLED: 499,
    ErrorCode.TIMEOUT: 504,
    ErrorCode.DRAINING: 503,
    ErrorCode.INVALID_REQUEST: 400,
    ErrorCode.RATE_LIMITED: 429,
}


def status_for(code: ErrorCode) -> int:
    return HTTP_STATUS[code]


def error_body(err: APIError) -> Dict[str, Any]:
    """The typed JSON error envelope (OpenAI-style ``{"error": ...}``)."""
    return {"error": {
        "message": err.message,
        "type": err.code.value,
        "code": HTTP_STATUS[err.code],
        "retryable": err.retryable,
    }}


class WireError(Exception):
    """A request that must be answered with a structured HTTP error."""

    def __init__(self, code: ErrorCode, message: str) -> None:
        super().__init__(f"[{code.value}] {message}")
        self.error = APIError(code, message)

    @property
    def status(self) -> int:
        return HTTP_STATUS[self.error.code]

    def body(self) -> Dict[str, Any]:
        return error_body(self.error)


# ------------------------------------------------------------------ #
def _invalid(msg: str) -> WireError:
    return WireError(ErrorCode.INVALID_REQUEST, msg)


def _field(body: Dict, name: str,
           types: Union[type, Tuple[type, ...]],
           default: Any = None, required: bool = False) -> Any:
    if name not in body or body[name] is None:
        if required:
            raise _invalid(f"missing required field {name!r}")
        return default
    val = body[name]
    # bool is an int subclass; never silently accept it for numbers
    if isinstance(val, bool) and bool not in (
            types if isinstance(types, tuple) else (types,)):
        raise _invalid(f"field {name!r} must be {types}, got bool")
    if not isinstance(val, types):
        raise _invalid(f"field {name!r} has wrong type "
                       f"{type(val).__name__}")
    return val


def _parse_sampling(body: Dict) -> SamplingParams:
    max_tokens = _field(body, "max_tokens", int, default=16)
    if max_tokens < 1:
        raise _invalid("max_tokens must be >= 1")
    temperature = float(_field(body, "temperature", (int, float),
                               default=0.0))
    if temperature < 0.0:
        raise _invalid("temperature must be >= 0")
    top_p = float(_field(body, "top_p", (int, float), default=1.0))
    if not 0.0 < top_p <= 1.0:
        raise _invalid("top_p must be in (0, 1]")
    top_k = _field(body, "top_k", int, default=0)
    if top_k < 0:
        raise _invalid("top_k must be >= 0")
    eos_id = _field(body, "eos_id", int, default=-1)
    return SamplingParams(temperature=temperature, top_k=top_k,
                          top_p=top_p, max_tokens=max_tokens,
                          eos_id=eos_id)


def _parse_common(body: Dict) -> Tuple[str, SamplingParams, bool,
                                       Optional[float]]:
    if not isinstance(body, dict):
        raise _invalid("request body must be a JSON object")
    model = _field(body, "model", str, required=True)
    n = _field(body, "n", int, default=1)
    if n != 1:
        raise _invalid("only n=1 is supported")
    stream = _field(body, "stream", bool, default=False)
    timeout_s = _field(body, "timeout_s", (int, float), default=None)
    if timeout_s is not None and float(timeout_s) <= 0.0:
        raise _invalid("timeout_s must be > 0")
    return (model, _parse_sampling(body), stream,
            None if timeout_s is None else float(timeout_s))


@dataclasses.dataclass(frozen=True)
class CompletionCall:
    """A validated /v1/completions request.  `prompt` is either raw text
    (encoded by the service with the model's vocab) or token ids."""
    model: str
    prompt: Union[str, Tuple[int, ...]]
    sampling: SamplingParams
    stream: bool
    timeout_s: Optional[float]


@dataclasses.dataclass(frozen=True)
class ChatCall:
    """A validated /v1/chat/completions request."""
    model: str
    messages: Tuple[ChatMessage, ...]
    sampling: SamplingParams
    stream: bool
    timeout_s: Optional[float]


def parse_completion_request(body: Dict) -> CompletionCall:
    model, sampling, stream, timeout_s = _parse_common(body)
    prompt = _field(body, "prompt", (str, list), required=True)
    if isinstance(prompt, list):
        if not all(isinstance(t, int) and not isinstance(t, bool)
                   and t >= 0 for t in prompt):
            raise _invalid("prompt token list must contain only "
                           "non-negative integers")
        prompt = tuple(prompt)
    return CompletionCall(model=model, prompt=prompt, sampling=sampling,
                          stream=stream, timeout_s=timeout_s)


def parse_chat_request(body: Dict) -> ChatCall:
    model, sampling, stream, timeout_s = _parse_common(body)
    raw = _field(body, "messages", list, required=True)
    if not raw:
        raise _invalid("messages must contain at least one message")
    messages: List[ChatMessage] = []
    for i, m in enumerate(raw):
        if not isinstance(m, dict):
            raise _invalid(f"messages[{i}] must be an object")
        role = _field(m, "role", str, required=True)
        content = _field(m, "content", str, required=True)
        try:
            messages.append(ChatMessage(role=role, content=content))
        except ValueError as e:
            raise _invalid(f"messages[{i}]: {e}") from None
    return ChatCall(model=model, messages=tuple(messages),
                    sampling=sampling, stream=stream, timeout_s=timeout_s)


# ------------------------------------------------------------------ #
def _usage(prompt_tokens: int, completion_tokens: int) -> Dict[str, int]:
    return {"prompt_tokens": prompt_tokens,
            "completion_tokens": completion_tokens,
            "total_tokens": prompt_tokens + completion_tokens}


def _metadata(resp: GenerationResponse) -> Dict[str, Any]:
    """Routing trace extension — the per-request dashboard row."""
    return {"node": resp.node, "replica": resp.replica,
            "retries": resp.retries, "ttft_s": resp.ttft,
            "latency_s": resp.latency}


def models_body(entries: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
    return {"object": "list", "data": list(entries)}


def model_entry(name: str, *, family: str = "", replicas: int = 0,
                context: int = 0) -> Dict[str, Any]:
    return {"id": name, "object": "model", "owned_by": "repro",
            "family": family, "replicas": replicas,
            "max_context": context}


def completion_body(req_id: int, model: str, *, text: str,
                    resp: GenerationResponse,
                    prompt_tokens: int) -> Dict[str, Any]:
    return {
        "id": f"cmpl-{req_id}",
        "object": "text_completion",
        "created": int(time.time()),
        "model": model,
        "choices": [{
            "index": 0,
            "text": text,
            "token_ids": list(resp.tokens),
            "finish_reason": resp.finish_reason,
        }],
        "usage": _usage(prompt_tokens, len(resp.tokens)),
        "metadata": _metadata(resp),
    }


def chat_body(req_id: int, model: str, *, text: str,
              resp: GenerationResponse,
              prompt_tokens: int) -> Dict[str, Any]:
    return {
        "id": f"chatcmpl-{req_id}",
        "object": "chat.completion",
        "created": int(time.time()),
        "model": model,
        "choices": [{
            "index": 0,
            "message": {"role": "assistant", "content": text},
            "token_ids": list(resp.tokens),
            "finish_reason": resp.finish_reason,
        }],
        "usage": _usage(prompt_tokens, len(resp.tokens)),
        "metadata": _metadata(resp),
    }


# ---- SSE framing -------------------------------------------------- #
SSE_DONE = b"data: [DONE]\n\n"


def sse_event(data: Union[Dict, str]) -> bytes:
    if not isinstance(data, str):
        data = json.dumps(data, separators=(",", ":"))
    return f"data: {data}\n\n".encode("utf-8")


def completion_chunk(req_id: int, model: str, *, text: str = "",
                     token: Optional[int] = None, index: int = 0,
                     finish_reason: Optional[str] = None,
                     usage: Optional[Dict[str, int]] = None
                     ) -> Dict[str, Any]:
    choice: Dict[str, Any] = {"index": 0, "text": text,
                              "finish_reason": finish_reason}
    if token is not None:
        choice["token"] = token
        choice["token_index"] = index
    body = {"id": f"cmpl-{req_id}", "object": "text_completion.chunk",
            "created": int(time.time()), "model": model,
            "choices": [choice]}
    if usage is not None:            # OpenAI parity: final chunk only
        body["usage"] = usage
    return body


def chat_chunk(req_id: int, model: str, *, role: Optional[str] = None,
               text: Optional[str] = None, token: Optional[int] = None,
               index: int = 0, finish_reason: Optional[str] = None,
               usage: Optional[Dict[str, int]] = None
               ) -> Dict[str, Any]:
    delta: Dict[str, Any] = {}
    if role is not None:
        delta["role"] = role
    if text is not None:
        delta["content"] = text
    if token is not None:
        delta["token"] = token
        delta["token_index"] = index
    choice = {"index": 0, "delta": delta, "finish_reason": finish_reason}
    body = {"id": f"chatcmpl-{req_id}",
            "object": "chat.completion.chunk",
            "created": int(time.time()), "model": model,
            "choices": [choice]}
    if usage is not None:            # OpenAI parity: final chunk only
        body["usage"] = usage
    return body


def stream_error_chunk(err: APIError) -> Dict[str, Any]:
    """Terminal SSE frame for a mid-stream structured failure.  Streams
    still end with `[DONE]` after this frame."""
    return error_body(err)

"""Wire protocol v1 — stdlib network client + tiny CLI.

`HTTPClient` speaks the OpenAI-compatible protocol over a plain socket
(`http.client`, keep-alive reused across calls): model listing,
completions, chat completions (both with SSE streaming), remote cancel,
and the admin plane.  Tenant identity rides on every request as
``Authorization: Bearer <tenant>`` and lands in the server-side token
buckets.  Structured HTTP failures raise `HTTPClientError`, which maps
the wire body back onto the `ErrorCode` taxonomy.

CLI::

    python -m repro.api.http.client [--url ...] [--tenant t] models
    python -m repro.api.http.client complete MODEL "some text" --stream
    python -m repro.api.http.client chat MODEL "hi there" --max-tokens 16
    python -m repro.api.http.client health | snapshot

One client instance serializes its calls over one connection — share a
client across threads only with external locking, or give each thread
its own (connections are cheap).
"""
from __future__ import annotations

import http.client
import json
import random
import sys
import time
from typing import Any, Dict, Iterator, List, Optional, Sequence, Union
from urllib.parse import urlparse

from repro.api.http.chat import ChatMessage
from repro.api.types import APIError, ErrorCode


class HTTPClientError(RuntimeError):
    """A non-2xx wire response, mapped back onto the error taxonomy."""

    def __init__(self, status: int, body: Dict[str, Any],
                 retry_after: Optional[float] = None):
        err = body.get("error", {}) if isinstance(body, dict) else {}
        self.status = status
        self.message = err.get("message", f"HTTP {status}")
        self.type = err.get("type", "")
        self.retryable = bool(err.get("retryable", False))
        # the server's Retry-After header (seconds), when it sent one
        self.retry_after = retry_after
        try:
            self.code: Optional[ErrorCode] = ErrorCode(self.type)
        except ValueError:
            self.code = None
        super().__init__(f"HTTP {status} [{self.type}] {self.message}")

    @property
    def error(self) -> Optional[APIError]:
        return (APIError(self.code, self.message)
                if self.code is not None else None)


class _CountingSocket:
    """Transparent socket proxy that counts bytes handed to `sendall` —
    the client's witness for whether any request bytes could have
    reached the server before a send error."""

    def __init__(self, sock):
        self._sock = sock
        self.sent = 0

    def sendall(self, data):
        # count *before* the write: a failed sendall may still have
        # pushed a prefix onto the wire, so any attempted byte counts
        try:
            self.sent += memoryview(data).nbytes
        except TypeError:
            self.sent += len(data)
        return self._sock.sendall(data)

    def __getattr__(self, name):
        return getattr(self._sock, name)


# structured rejections that are safe AND useful to retry: the server
# definitively answered (nothing is in flight), and the condition is
# transient — overload, rate limit, or a routing gap during failover
_RETRYABLE_CODES = (ErrorCode.OVERLOADED, ErrorCode.RATE_LIMITED,
                    ErrorCode.NO_BACKEND)


class HTTPClient:
    def __init__(self, base_url: str = "http://127.0.0.1:8000", *,
                 tenant: str = "", timeout_s: float = 130.0,
                 keepalive_guard_s: float = 4.0, retries: int = 0,
                 backoff_base_s: float = 0.05, backoff_cap_s: float = 2.0,
                 backoff_seed: Optional[int] = None):
        u = urlparse(base_url)
        if u.scheme not in ("http", ""):
            raise ValueError(f"unsupported scheme {u.scheme!r}")
        self.host = u.hostname or "127.0.0.1"
        self.port = u.port or 8000
        self.tenant = tenant
        self.timeout_s = timeout_s
        # a connection idle longer than this is reopened instead of
        # reused — keep it below the server's keepalive_idle_s (5 s
        # default) so generation POSTs never race the server's idle
        # close (a retry there could double-submit)
        self.keepalive_guard_s = keepalive_guard_s
        # automatic backoff-retry budget for *structured* retryable
        # rejections (429/503 with OVERLOADED / RATE_LIMITED /
        # NO_BACKEND).  Default OFF: retrying is a policy decision.
        # Distinct from the transport-level resend in `_request`, which
        # only fires when zero request bytes could have reached the
        # server (the `_CountingSocket` witness) — these retries fire
        # only after the server definitively *answered*, so they can
        # never double-submit a generation
        self.retries = max(0, retries)
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self._rng = random.Random(backoff_seed)
        self._conn: Optional[http.client.HTTPConnection] = None
        self._last_used = 0.0
        # set by streaming calls from the X-Request-Id response header,
        # before the first chunk arrives — feed it to `cancel()` *on a
        # separate HTTPClient* (this one's connection is busy carrying
        # the stream until it is fully consumed)
        self.last_request_id: Optional[int] = None

    # ---- transport ----------------------------------------------- #
    def _connection(self) -> http.client.HTTPConnection:
        if self._conn is not None and (time.monotonic() - self._last_used
                                       > self.keepalive_guard_s):
            self.close()        # the server has likely idled this out
        if self._conn is None:
            self._conn = http.client.HTTPConnection(
                self.host, self.port, timeout=self.timeout_s)
        return self._conn

    def close(self):
        if self._conn is not None:
            self._conn.close()
            self._conn = None

    def __enter__(self) -> "HTTPClient":
        return self

    def __exit__(self, *exc):
        self.close()

    def _request(self, method: str, path: str,
                 body: Optional[Dict] = None) -> http.client.HTTPResponse:
        """One logical request with the optional structured-rejection
        retry budget (exponential backoff, full jitter, honors the
        server's Retry-After)."""
        attempt = 0
        while True:
            try:
                return self._request_once(method, path, body)
            except HTTPClientError as e:
                if attempt >= self.retries \
                        or e.code not in _RETRYABLE_CODES:
                    raise
                delay = min(self.backoff_base_s * (2 ** attempt),
                            self.backoff_cap_s)
                delay *= self._rng.random()          # full jitter
                if e.retry_after is not None:
                    delay = max(delay, min(e.retry_after,
                                           self.backoff_cap_s))
                time.sleep(delay)
                attempt += 1

    def _request_once(self, method: str, path: str,
                      body: Optional[Dict] = None
                      ) -> http.client.HTTPResponse:
        headers = {"Accept": "application/json"}
        if self.tenant:
            headers["Authorization"] = f"Bearer {self.tenant}"
        payload = None
        if body is not None:
            payload = json.dumps(body).encode("utf-8")
            headers["Content-Type"] = "application/json"
        for attempt in (0, 1):
            conn = self._connection()
            sent = 0
            try:
                if conn.sock is None:
                    conn.connect()
                if isinstance(conn.sock, _CountingSocket):
                    conn.sock.sent = 0          # reused keep-alive conn
                else:
                    conn.sock = _CountingSocket(conn.sock)
                try:
                    conn.request(method, path, body=payload,
                                 headers=headers)
                finally:
                    sent = conn.sock.sent if conn.sock is not None else 0
            except (http.client.CannotSendRequest,
                    http.client.ResponseNotReady):
                raise RuntimeError(
                    "this HTTPClient is carrying an unconsumed streaming "
                    "response; finish iterating it, or use a separate "
                    "HTTPClient (e.g. to cancel() a live stream)"
                ) from None
            except OSError:
                # send failed.  Resending on a fresh connection is safe
                # only when the server cannot have acted on the request:
                # the method is idempotent, or *zero* request bytes were
                # handed to the socket (a partial send on a stale
                # keep-alive connection may still have delivered the
                # whole request — blind-retrying a generation POST there
                # could double-submit and double-charge it)
                self.close()
                if attempt or (method != "GET" and sent > 0):
                    raise
                continue
            try:
                resp = conn.getresponse()
                self._last_used = time.monotonic()
                break
            except (http.client.RemoteDisconnected, BrokenPipeError,
                    ConnectionResetError):
                # the request reached the server but the response never
                # came back.  Only idempotent methods are safe to retry
                # — a generation POST may have been admitted and charged
                self.close()
                if method != "GET" or attempt:
                    raise
        if resp.status >= 400:
            raw = resp.read()
            try:
                parsed = json.loads(raw)
            except ValueError:
                parsed = {"error": {"message": raw.decode("utf-8",
                                                          "replace")}}
            after = resp.headers.get("Retry-After")
            try:
                retry_after = float(after) if after is not None else None
            except ValueError:
                retry_after = None
            raise HTTPClientError(resp.status, parsed,
                                  retry_after=retry_after)
        return resp

    def _json(self, method: str, path: str,
              body: Optional[Dict] = None) -> Dict[str, Any]:
        resp = self._request(method, path, body)
        return json.loads(resp.read() or b"{}")

    def _stream(self, path: str,
                body: Dict[str, Any]) -> Iterator[Dict[str, Any]]:
        resp = self._request("POST", path, body)
        rid = resp.headers.get("X-Request-Id")
        self.last_request_id = int(rid) if rid is not None else None
        return self._sse(resp)

    def _sse(self, resp: http.client.HTTPResponse
             ) -> Iterator[Dict[str, Any]]:
        """Parse `data:` frames until `[DONE]`; drains the response so
        the keep-alive connection stays reusable."""
        try:
            while True:
                line = resp.readline()
                if not line:
                    return
                line = line.strip()
                if not line.startswith(b"data:"):
                    continue
                payload = line[len(b"data:"):].strip()
                if payload == b"[DONE]":
                    return
                yield json.loads(payload)
        finally:
            resp.read()

    # ---- service surface ----------------------------------------- #
    def healthz(self) -> Dict[str, Any]:
        return self._json("GET", "/healthz")

    def models(self) -> List[str]:
        return [m["id"] for m in self._json("GET", "/v1/models")["data"]]

    def models_full(self) -> List[Dict[str, Any]]:
        return self._json("GET", "/v1/models")["data"]

    @staticmethod
    def _gen_body(model: str, *, max_tokens: int, temperature: float,
                  top_k: int, top_p: float, stream: bool,
                  timeout_s: Optional[float],
                  extra: Optional[Dict]) -> Dict[str, Any]:
        body: Dict[str, Any] = {
            "model": model, "max_tokens": max_tokens,
            "temperature": temperature, "top_k": top_k, "top_p": top_p,
            "stream": stream}
        if timeout_s is not None:
            body["timeout_s"] = timeout_s
        if extra:
            body.update(extra)
        return body

    def complete(self, model: str,
                 prompt: Union[str, Sequence[int]], *,
                 max_tokens: int = 16, temperature: float = 0.0,
                 top_k: int = 0, top_p: float = 1.0, stream: bool = False,
                 timeout_s: Optional[float] = None,
                 extra: Optional[Dict] = None
                 ) -> Union[Dict[str, Any], Iterator[Dict[str, Any]]]:
        """POST /v1/completions.  Returns the response body, or an
        iterator of chunk dicts when `stream=True`."""
        body = self._gen_body(model, max_tokens=max_tokens,
                              temperature=temperature, top_k=top_k,
                              top_p=top_p, stream=stream,
                              timeout_s=timeout_s, extra=extra)
        body["prompt"] = (prompt if isinstance(prompt, str)
                          else list(prompt))
        if stream:
            return self._stream("/v1/completions", body)
        return self._json("POST", "/v1/completions", body)

    def chat(self, model: str,
             messages: Sequence[Union[ChatMessage, Dict[str, str], str]],
             *, max_tokens: int = 16, temperature: float = 0.0,
             top_k: int = 0, top_p: float = 1.0, stream: bool = False,
             timeout_s: Optional[float] = None,
             extra: Optional[Dict] = None
             ) -> Union[Dict[str, Any], Iterator[Dict[str, Any]]]:
        """POST /v1/chat/completions.  Messages may be `ChatMessage`s,
        ``{"role","content"}`` dicts, or bare strings (treated as user
        turns)."""
        wire = []
        for m in messages:
            if isinstance(m, ChatMessage):
                wire.append({"role": m.role, "content": m.content})
            elif isinstance(m, dict):
                wire.append({"role": m.get("role", "user"),
                             "content": m.get("content", "")})
            else:
                wire.append({"role": "user", "content": str(m)})
        body = self._gen_body(model, max_tokens=max_tokens,
                              temperature=temperature, top_k=top_k,
                              top_p=top_p, stream=stream,
                              timeout_s=timeout_s, extra=extra)
        body["messages"] = wire
        if stream:
            return self._stream("/v1/chat/completions", body)
        return self._json("POST", "/v1/chat/completions", body)

    def cancel(self, request_id: int) -> bool:
        out = self._json("POST", f"/v1/requests/{request_id}/cancel", {})
        return bool(out.get("cancelled"))

    # ---- admin surface ------------------------------------------- #
    def admin_snapshot(self) -> Dict[str, Any]:
        return self._json("GET", "/v1/admin/snapshot")

    def admin_classes(self) -> Dict[str, Any]:
        """Per-GPU-class rollup (cost weights, per-bucket routed traffic
        and modeled cost-per-token) from the fleet snapshot."""
        return self.admin_snapshot().get("classes", {})

    def admin_deploy(self, model: str, *, min_replicas: int = 1,
                     max_replicas: int = 0, n_slots: int = 4,
                     max_len: int = 2048) -> Dict[str, Any]:
        return self._json("POST", "/v1/admin/deploy", {
            "model": model, "min_replicas": min_replicas,
            "max_replicas": max_replicas, "n_slots": n_slots,
            "max_len": max_len})

    def admin_undeploy(self, model: str) -> Dict[str, Any]:
        return self._json("POST", "/v1/admin/undeploy", {"model": model})

    def admin_scale(self, model: str, replicas: int) -> Dict[str, Any]:
        return self._json("POST", "/v1/admin/scale",
                          {"model": model, "replicas": replicas})

    def admin_drain(self, model: str,
                    timeout_s: float = 5.0) -> Dict[str, Any]:
        return self._json("POST", "/v1/admin/drain",
                          {"model": model, "timeout_s": timeout_s})

    def admin_resume(self, model: str) -> Dict[str, Any]:
        return self._json("POST", "/v1/admin/resume", {"model": model})

    def admin_cache_flush(self, model: str = "") -> Dict[str, Any]:
        """Drop unpinned prefix-cache entries fleet-wide (or for one
        model).  Returns `{"flushed": n, "remaining": m}`."""
        body = {"model": model} if model else {"flush": True}
        return self._json("POST", "/v1/admin/cache/flush", body)

    def set_tenant_quota(self, tenant: str, *,
                         requests_per_s: float = 0.0,
                         tokens_per_s: float = 0.0,
                         burst_requests: float = 0.0,
                         burst_tokens: float = 0.0) -> Dict[str, Any]:
        return self._json("POST", "/v1/admin/tenants", {
            "tenant": tenant, "requests_per_s": requests_per_s,
            "tokens_per_s": tokens_per_s,
            "burst_requests": burst_requests,
            "burst_tokens": burst_tokens})

    def remove_tenant_quota(self, tenant: str) -> Dict[str, Any]:
        return self._json("POST", "/v1/admin/tenants",
                          {"tenant": tenant, "remove": True})

    def tenant_quotas(self) -> Dict[str, Dict[str, float]]:
        return self._json("GET", "/v1/admin/tenants")["tenants"]


# ------------------------------------------------------------------ #
def _print_stream(chunks: Iterator[Dict[str, Any]]) -> int:
    for chunk in chunks:
        if "error" in chunk:
            print(f"\n[error] {chunk['error']['type']}: "
                  f"{chunk['error']['message']}", file=sys.stderr)
            return 1
        choice = chunk["choices"][0]
        text = choice.get("text") or choice.get("delta", {}).get(
            "content") or ""
        sys.stdout.write(text)
        sys.stdout.flush()
        if choice.get("finish_reason"):
            print(f"\n[finish] {choice['finish_reason']}")
    return 0


def _main(argv: Optional[List[str]] = None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        prog="python -m repro.api.http.client",
        description="Talk to a repro Gateway HTTP service.")
    p.add_argument("--url", default="http://127.0.0.1:8000")
    p.add_argument("--tenant", default="",
                   help="sent as Authorization: Bearer <tenant>")
    p.add_argument("--retries", type=int, default=0,
                   help="backoff-retry budget for 429/503 rejections")
    sub = p.add_subparsers(dest="cmd", required=True)
    sub.add_parser("health")
    sub.add_parser("models")
    sub.add_parser("snapshot")

    def _gen_args(sp):
        sp.add_argument("--max-tokens", type=int, default=16)
        sp.add_argument("--temperature", type=float, default=0.0)
        sp.add_argument("--top-k", type=int, default=0)
        sp.add_argument("--top-p", type=float, default=1.0)
        sp.add_argument("--timeout", type=float, default=None)
        sp.add_argument("--stream", action="store_true")

    c = sub.add_parser("complete")
    c.add_argument("model")
    c.add_argument("prompt", help="text, or comma-separated token ids "
                                  "with --tokens")
    c.add_argument("--tokens", action="store_true")
    _gen_args(c)

    ch = sub.add_parser("chat")
    ch.add_argument("model")
    ch.add_argument("message", nargs="+", help="user turn(s)")
    ch.add_argument("--system", default="")
    _gen_args(ch)

    cn = sub.add_parser("cancel")
    cn.add_argument("request_id", type=int)

    args = p.parse_args(argv)
    client = HTTPClient(args.url, tenant=args.tenant,
                        retries=args.retries)
    try:
        if args.cmd == "health":
            print(json.dumps(client.healthz(), indent=2))
        elif args.cmd == "models":
            for entry in client.models_full():
                print(f"{entry['id']}  family={entry['family']} "
                      f"replicas={entry['replicas']} "
                      f"ctx={entry['max_context']}")
        elif args.cmd == "snapshot":
            print(json.dumps(client.admin_snapshot(), indent=2))
        elif args.cmd == "cancel":
            print(json.dumps({"cancelled":
                              client.cancel(args.request_id)}))
        elif args.cmd == "complete":
            prompt: Union[str, List[int]] = args.prompt
            if args.tokens:
                prompt = [int(t) for t in args.prompt.split(",")]
            out = client.complete(
                args.model, prompt, max_tokens=args.max_tokens,
                temperature=args.temperature, top_k=args.top_k,
                top_p=args.top_p, stream=args.stream,
                timeout_s=args.timeout)
            if args.stream:
                return _print_stream(out)
            print(json.dumps(out, indent=2))
        elif args.cmd == "chat":
            messages: List[ChatMessage] = []
            if args.system:
                messages.append(ChatMessage("system", args.system))
            messages.extend(ChatMessage("user", m)
                            for m in args.message)
            out = client.chat(
                args.model, messages, max_tokens=args.max_tokens,
                temperature=args.temperature, top_k=args.top_k,
                top_p=args.top_p, stream=args.stream,
                timeout_s=args.timeout)
            if args.stream:
                return _print_stream(out)
            print(json.dumps(out, indent=2))
        return 0
    except HTTPClientError as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    except OSError as e:
        print(f"error: cannot reach {args.url}: {e}", file=sys.stderr)
        return 1
    finally:
        client.close()


if __name__ == "__main__":
    sys.exit(_main())

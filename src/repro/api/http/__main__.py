"""Serve the demo fleet over HTTP:  ``python -m repro.api.http``.

Builds the paper's 6-node heterogeneous testbed, deploys two reduced zoo
models through the SDAI controller, and exposes the Gateway as the
OpenAI-compatible wire service until interrupted.  This is the launch
target CI's http-smoke job (and the README curl examples) run against.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax

from repro.api import Gateway
from repro.api.http.server import GatewayHTTPServer, HTTPConfig
from repro.cluster import paper_testbed
from repro.configs import ZOO
from repro.core import (ControllerConfig, ModelCatalog, ModelDemand,
                        SDAIController)
from repro.models import build

_params = {}


def _param_store(cfg):
    if cfg.name not in _params:
        _params[cfg.name] = build(cfg).init(jax.random.PRNGKey(0))
    return _params[cfg.name]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.api.http")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8000)
    p.add_argument("--models", default="llama3.2-1b,gemma3-1b",
                   help="comma-separated zoo names (reduced variants "
                        "are deployed so the demo runs on CPU)")
    p.add_argument("--replicas", type=int, default=2)
    args = p.parse_args(argv)

    fleet = paper_testbed(param_store=_param_store)
    catalog = ModelCatalog()
    demands = []
    for name in args.models.split(","):
        name = name.strip()
        if name not in ZOO:
            print(f"unknown zoo model {name!r}", file=sys.stderr)
            return 2
        # reduced() shrinks the arch but keeps the name, so chat
        # templates and clients address the paper's model ids
        cfg = dataclasses.replace(ZOO[name].reduced(), name=name)
        catalog.register(cfg)
        # context fits a chat-templated prompt (llama3 headers alone
        # cost ~120 byte-tokens) plus decode budget
        demands.append(ModelDemand(cfg, min_replicas=args.replicas,
                                   n_slots=2, max_len=256))

    ctrl = SDAIController(fleet, catalog, ControllerConfig())
    ctrl.discover()
    plan = ctrl.deploy(demands)
    if plan.unplaced:
        print(f"warning: unplaced {plan.unplaced}", file=sys.stderr)

    server = GatewayHTTPServer(
        Gateway(ctrl), HTTPConfig(host=args.host, port=args.port))
    server.start()
    print(f"serving {ctrl.replicas.models()} on {server.url()}  "
          f"(Ctrl-C to stop)", flush=True)
    try:
        while True:
            time.sleep(1.0)
    except KeyboardInterrupt:
        pass
    finally:
        print("draining...", flush=True)
        server.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())

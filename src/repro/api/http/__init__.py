"""Wire protocol v1 — the OpenAI-compatible HTTP service layer.

    from repro.api.http import GatewayHTTPServer, HTTPClient

    server = GatewayHTTPServer(gateway).start()   # runtime-backed, no pumps
    client = HTTPClient(server.url(), tenant="acme")
    client.models()
    client.chat("llama3.2-1b", ["hello"], stream=True)
    server.stop()                                  # drain, park, join

Launch the demo fleet service:  ``python -m repro.api.http``
Talk to any service:            ``python -m repro.api.http.client``
"""
from repro.api.http.chat import (ChatMessage, ChatTemplate, decode_tokens,
                                 encode_text, prefix_budget,
                                 register_template, render_prompt,
                                 template_for)
from repro.api.http.schemas import (HTTP_STATUS, ChatCall, CompletionCall,
                                    WireError, error_body,
                                    parse_chat_request,
                                    parse_completion_request, sse_event,
                                    status_for)
from repro.api.http.server import GatewayHTTPServer, HTTPConfig


def __getattr__(name):
    # lazy: `python -m repro.api.http.client` imports this package first,
    # and an eager client import here would trip runpy's double-import
    # warning for that module
    if name in ("HTTPClient", "HTTPClientError"):
        from repro.api.http import client
        return getattr(client, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = ["ChatCall", "ChatMessage", "ChatTemplate", "CompletionCall",
           "GatewayHTTPServer", "HTTPClient", "HTTPClientError",
           "HTTPConfig", "HTTP_STATUS", "WireError", "decode_tokens",
           "encode_text", "error_body", "parse_chat_request",
           "parse_completion_request", "prefix_budget",
           "register_template", "render_prompt", "sse_event",
           "status_for", "template_for"]

"""Wire protocol v1 — the stdlib threaded HTTP service over a Gateway.

`GatewayHTTPServer` exposes one `Gateway` (and through it the whole
fleet) as an OpenAI-compatible network service:

* ``GET  /healthz``                — liveness + fleet summary
* ``GET  /v1/models``              — the unified model list
* ``POST /v1/completions``         — prompt (text or token ids) completion
* ``POST /v1/chat/completions``    — chat-templated completion
* ``POST /v1/requests/<id>/cancel``— abort an in-flight request (499)
* ``GET/POST /v1/admin/...``       — snapshot, deploy, undeploy, scale,
                                     drain, resume, tenant quotas

Both generation endpoints accept ``"stream": true`` and answer with SSE
framing (``data:`` JSON chunks, terminal ``data: [DONE]``) driven by the
Gateway's per-token stream callbacks; a mid-stream structured failure
becomes a terminal error frame before ``[DONE]``.  Admission rejections
are returned as plain HTTP errors (the `schemas.HTTP_STATUS` table) even
for stream requests, so every `ErrorCode` is observable from the wire.

Tenancy: ``Authorization: Bearer <tenant>`` maps the caller onto the
PR-3 per-tenant token buckets; no header means the anonymous unlimited
tenant.  `start()` boots the Gateway's continuous serving runtime, so
requests are served entirely by background pump threads (zero caller
pumps); connections are handled by a bounded thread pool with HTTP/1.1
keep-alive, and `stop()` drains in-flight requests before joining.
"""
from __future__ import annotations

import dataclasses
import json
import socket
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from http.server import BaseHTTPRequestHandler, HTTPServer
from typing import Any, Dict, Optional, Tuple

from repro.api.gateway import Gateway, GenerationHandle
from repro.api.http import chat as chat_mod
from repro.api.http import schemas
from repro.api.http.schemas import WireError
from repro.api.runtime import RuntimeConfig
from repro.api.types import (API_VERSION, APIError, ErrorCode,
                             GenerationRequest, StreamEventType)
from repro.core.frontend import TenantQuota
from repro.core.placement import ModelDemand

_MAX_BODY_BYTES = 8 * 1024 * 1024


@dataclasses.dataclass
class HTTPConfig:
    host: str = "127.0.0.1"
    port: int = 0                    # 0 => ephemeral (server.port tells)
    max_workers: int = 8             # connection thread pool size
    keepalive_idle_s: float = 5.0    # idle keep-alive connection timeout
    default_timeout_s: float = 120.0  # per-request generation deadline
    drain_timeout_s: float = 10.0    # stop(): in-flight request budget
    # advisory Retry-After (seconds) attached to every 429/503 response
    # so well-behaved clients back off instead of hammering an
    # overloaded/draining service; <= 0 disables the header
    retry_after_s: float = 1.0


class _PooledHTTPServer(HTTPServer):
    """Accept loop + bounded worker pool.  One pool task per connection;
    HTTP/1.1 keep-alive serves that connection's requests serially while
    other connections proceed on other workers."""

    allow_reuse_address = True
    daemon_threads = True

    def __init__(self, addr, handler, pool: ThreadPoolExecutor,
                 service: "GatewayHTTPServer"):
        super().__init__(addr, handler)
        self._pool = pool
        self.service = service
        self._conns: set = set()
        self._conns_lock = threading.Lock()

    def process_request(self, request, client_address):
        with self._conns_lock:
            self._conns.add(request)
        try:
            self._pool.submit(self._serve, request, client_address)
        except RuntimeError:            # pool already shut down
            self._drop(request)

    def _serve(self, request, client_address):
        try:
            self.finish_request(request, client_address)
        except Exception:               # connection-level noise only
            pass
        finally:
            self._drop(request)

    def _drop(self, request):
        self.shutdown_request(request)
        with self._conns_lock:
            self._conns.discard(request)

    def close_connections(self):
        """Force-close lingering (idle keep-alive) connections."""
        with self._conns_lock:
            conns = list(self._conns)
        for s in conns:
            try:
                s.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"     # keep-alive by default
    server_version = f"repro-gateway/{API_VERSION}"

    @property
    def svc(self) -> "GatewayHTTPServer":
        return self.server.service

    def log_message(self, fmt, *args):  # route nothing to stderr
        pass

    # ---- plumbing ------------------------------------------------ #
    def _tenant(self) -> str:
        auth = self.headers.get("Authorization", "")
        if auth.startswith("Bearer "):
            return auth[len("Bearer "):].strip()
        return ""

    def _read_json(self) -> Dict[str, Any]:
        try:
            length = int(self.headers.get("Content-Length", 0))
        except ValueError:
            self.close_connection = True    # unreadable framing
            raise WireError(ErrorCode.INVALID_REQUEST,
                            "bad Content-Length") from None
        if length <= 0:
            raise WireError(ErrorCode.INVALID_REQUEST,
                            "request body required")
        if length > _MAX_BODY_BYTES:
            self.close_connection = True    # body left unread
            raise WireError(ErrorCode.INVALID_REQUEST,
                            "request body too large")
        raw = self.rfile.read(length)
        try:
            body = json.loads(raw)
        except (ValueError, UnicodeDecodeError):
            raise WireError(ErrorCode.INVALID_REQUEST,
                            "request body is not valid JSON") from None
        if not isinstance(body, dict):
            raise WireError(ErrorCode.INVALID_REQUEST,
                            "request body must be a JSON object")
        return body

    def _drain_body(self):
        """Consume an unread request body so the next keep-alive request
        on this connection parses cleanly (used by bodyless routes).  A
        body we refuse to read (oversized, unparseable length) forces
        connection close instead — never a desynchronized socket."""
        try:
            length = int(self.headers.get("Content-Length", 0))
        except ValueError:
            self.close_connection = True
            return
        if length > _MAX_BODY_BYTES:
            self.close_connection = True
        elif length > 0:
            self.rfile.read(length)

    def _send_json(self, status: int, obj: Dict[str, Any],
                   headers: Optional[Dict[str, str]] = None):
        data = json.dumps(obj).encode("utf-8")
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        if headers:
            for k, v in headers.items():
                self.send_header(k, v)
        self.end_headers()
        self.wfile.write(data)

    def _retry_headers(self, status: int) -> Optional[Dict[str, str]]:
        """Retry-After on 429/503: the rejection is transient (rate
        limit, overload, drain) — tell the client when to come back.
        Header-only; the error body shape stays pinned."""
        after = self.svc.cfg.retry_after_s
        if status in (429, 503) and after > 0:
            return {"Retry-After": str(int(max(1, round(after))))}
        return None

    def _send_error_body(self, err: APIError):
        status = schemas.status_for(err.code)
        self._send_json(status, schemas.error_body(err),
                        headers=self._retry_headers(status))

    # ---- SSE / chunked ------------------------------------------- #
    def _begin_sse(self, rid: int):
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Transfer-Encoding", "chunked")
        # known before the first token: lets a client cancel a stream
        # that has not produced anything yet (POST /v1/requests/<id>/
        # cancel from another connection)
        self.send_header("X-Request-Id", str(rid))
        self.end_headers()

    def _chunk(self, data: bytes):
        self.wfile.write(f"{len(data):x}\r\n".encode("ascii")
                         + data + b"\r\n")
        self.wfile.flush()

    def _end_chunked(self):
        self.wfile.write(b"0\r\n\r\n")
        self.wfile.flush()

    # ---- routing ------------------------------------------------- #
    def do_GET(self):
        self._route("GET")

    def do_POST(self):
        self._route("POST")

    def _route(self, method: str):
        svc = self.svc
        if not svc._enter():
            self.close_connection = True    # also skips body drain
            self._send_json(503, schemas.error_body(APIError(
                ErrorCode.DRAINING, "server is shutting down")),
                headers=self._retry_headers(503))
            return
        try:
            self._dispatch(method, self.path.split("?", 1)[0])
        except WireError as e:
            self._send_json(e.status, e.body(),
                            headers=self._retry_headers(e.status))
        except (BrokenPipeError, ConnectionResetError, socket.timeout):
            self.close_connection = True    # client went away mid-write
        except Exception as e:              # never leak a stack trace
            try:
                self._send_json(500, schemas.error_body(APIError(
                    ErrorCode.ENGINE_FAILED, f"internal error: {e!r}")))
            except OSError:
                self.close_connection = True
        finally:
            svc._leave()

    def _dispatch(self, method: str, path: str):
        if method == "GET":
            if path == "/healthz":
                return self._healthz()
            if path == "/v1/models":
                return self._models()
            if path == "/v1/admin/snapshot":
                return self._send_json(
                    200, self.svc.gateway.admin.snapshot().to_dict())
            if path == "/v1/admin/tenants":
                return self._tenants_get()
        elif method == "POST":
            if path == "/v1/completions":
                return self._completions()
            if path == "/v1/chat/completions":
                return self._chat_completions()
            if (path.startswith("/v1/requests/")
                    and path.endswith("/cancel")):
                return self._cancel(path)
            if path.startswith("/v1/admin/"):
                return self._admin(path[len("/v1/admin/"):])
        if method == "POST":
            self._drain_body()          # unrouted body: keep-alive safe
        known = ("/healthz", "/v1/models", "/v1/completions",
                 "/v1/chat/completions")
        if path in known or path.startswith("/v1/admin/"):
            self._send_json(405, {"error": {
                "message": f"{method} not allowed on {path}",
                "type": "method_not_allowed", "code": 405}})
        else:
            self._send_json(404, {"error": {
                "message": f"no route for {path}",
                "type": "not_found", "code": 404}})

    # ---- endpoints ----------------------------------------------- #
    def _healthz(self):
        gw = self.svc.gateway
        snap_models = gw.models()
        self._send_json(200, {
            "status": "ok",
            "api_version": API_VERSION,
            "runtime_active": gw.runtime_active,
            "models": snap_models,
        })

    def _models(self):
        gw = self.svc.gateway
        entries = []
        for name in gw.models():
            cfg = self.svc.arch_cfg(name)
            ctx = gw._max_prompt_len(name)
            entries.append(schemas.model_entry(
                name,
                family=cfg.family if cfg is not None else "",
                replicas=len(gw.c.frontend.healthy_replicas(name)),
                context=ctx or 0))
        self._send_json(200, schemas.models_body(entries))

    def _completions(self):
        call = schemas.parse_completion_request(self._read_json())
        cfg = self.svc.arch_cfg(call.model)
        prompt = call.prompt
        if isinstance(prompt, str):
            prompt = chat_mod.encode_text(
                prompt, cfg.vocab if cfg is not None else 256)
        self._generate(call.model, prompt, call, kind="completion")

    def _chat_completions(self):
        call = schemas.parse_chat_request(self._read_json())
        cfg = self.svc.arch_cfg(call.model)
        prompt = chat_mod.render_prompt(call.model, call.messages, cfg)
        self._generate(call.model, prompt, call, kind="chat")

    def _generate(self, model: str, prompt: Tuple[int, ...], call,
                  kind: str):
        svc = self.svc
        greq = GenerationRequest(model=model, prompt=tuple(prompt),
                                 sampling=call.sampling,
                                 tenant=self._tenant())
        handle = svc.gateway.submit(greq)
        rid = handle.internal.request_id
        svc._track(rid, handle)
        timeout_s = (call.timeout_s if call.timeout_s is not None
                     else svc.cfg.default_timeout_s)
        try:
            if call.stream:
                # synchronous rejections (validation/admission/routing)
                # surface as plain HTTP errors, not empty streams
                if handle.done and handle.response.error is not None:
                    return self._send_error_body(handle.response.error)
                return self._stream(handle, rid, model, kind, timeout_s,
                                    n_prompt=len(prompt))
            resp = handle.result(timeout_s=timeout_s)
            if resp.error is not None:
                return self._send_error_body(resp.error)
            body_fn = (schemas.chat_body if kind == "chat"
                       else schemas.completion_body)
            self._send_json(200, body_fn(
                rid, model, text=chat_mod.decode_tokens(resp.tokens),
                resp=resp, prompt_tokens=len(prompt)))
        finally:
            svc._untrack(rid)

    def _stream(self, handle: GenerationHandle, rid: int, model: str,
                kind: str, timeout_s: float, n_prompt: int = 0):
        self._begin_sse(rid)
        try:
            if kind == "chat":
                self._chunk(schemas.sse_event(schemas.chat_chunk(
                    rid, model, role="assistant", text="")))
            for ev in handle.stream(timeout_s=timeout_s):
                if ev.type is StreamEventType.TOKEN:
                    text = chat_mod.decode_tokens([ev.token])
                    if kind == "chat":
                        chunk = schemas.chat_chunk(
                            rid, model, text=text, token=ev.token,
                            index=ev.index)
                    else:
                        chunk = schemas.completion_chunk(
                            rid, model, text=text, token=ev.token,
                            index=ev.index)
                elif ev.type is StreamEventType.FINISH:
                    usage = schemas._usage(n_prompt,
                                           len(ev.response.tokens))
                    if kind == "chat":
                        chunk = schemas.chat_chunk(
                            rid, model,
                            finish_reason=ev.response.finish_reason,
                            usage=usage)
                    else:
                        chunk = schemas.completion_chunk(
                            rid, model,
                            finish_reason=ev.response.finish_reason,
                            usage=usage)
                else:       # terminal structured failure mid-stream
                    chunk = schemas.stream_error_chunk(ev.error)
                self._chunk(schemas.sse_event(chunk))
            self._chunk(schemas.SSE_DONE)
            self._end_chunked()
        except (BrokenPipeError, ConnectionResetError, socket.timeout):
            handle.cancel()             # client gone: free the slot
            self.close_connection = True

    def _cancel(self, path: str):
        self._drain_body()              # cancel takes no meaningful body
        frag = path[len("/v1/requests/"):-len("/cancel")]
        try:
            rid = int(frag)
        except ValueError:
            raise WireError(ErrorCode.INVALID_REQUEST,
                            f"bad request id {frag!r}") from None
        handle = self.svc._handle_for(rid)
        if handle is None:
            return self._send_json(404, {"error": {
                "message": f"no in-flight request {rid}",
                "type": "not_found", "code": 404}})
        self._send_json(200, {"id": rid, "cancelled": handle.cancel()})

    # ---- admin --------------------------------------------------- #
    def _admin(self, verb: str):
        gw = self.svc.gateway
        body = self._read_json()
        if verb == "deploy":
            model = schemas._field(body, "model", str, required=True)
            cfg = self.svc.arch_cfg(model)
            if cfg is None:
                raise WireError(ErrorCode.INVALID_REQUEST,
                                f"model {model!r} not in catalog")
            demand = ModelDemand(
                cfg,
                min_replicas=schemas._field(body, "min_replicas", int,
                                            default=1),
                max_replicas=schemas._field(body, "max_replicas", int,
                                            default=0),
                n_slots=schemas._field(body, "n_slots", int, default=4),
                max_len=schemas._field(body, "max_len", int,
                                       default=2048))
            res = gw.admin.deploy_model(demand)
            return self._send_json(200, {
                "model": model, "placed": res.placed,
                "unplaced": list(res.unplaced), "ok": res.ok})
        if verb in ("undeploy", "resume", "drain", "scale"):
            model = schemas._field(body, "model", str, required=True)
            if verb == "undeploy":
                return self._send_json(
                    200, {"model": model,
                          "removed": gw.admin.undeploy_model(model)})
            if verb == "resume":
                gw.admin.resume_model(model)
                return self._send_json(200, {"model": model,
                                             "draining": False})
            if verb == "drain":
                t = float(schemas._field(body, "timeout_s", (int, float),
                                         default=5.0))
                left = gw.admin.drain_model(model, timeout_s=t)
                return self._send_json(200, {"model": model,
                                             "remaining": left,
                                             "drained": left == 0})
            replicas = schemas._field(body, "replicas", int,
                                      required=True)
            res = gw.admin.scale_model(model, replicas)
            return self._send_json(200, {
                "model": model, "placed": res.placed,
                "unplaced": list(res.unplaced), "ok": res.ok})
        if verb == "tenants":
            tenant = schemas._field(body, "tenant", str, required=True)
            if schemas._field(body, "remove", bool, default=False):
                gw.admin.remove_tenant_quota(tenant)
                return self._send_json(200, {"tenant": tenant,
                                             "removed": True})
            quota = TenantQuota(
                requests_per_s=float(schemas._field(
                    body, "requests_per_s", (int, float), default=0.0)),
                tokens_per_s=float(schemas._field(
                    body, "tokens_per_s", (int, float), default=0.0)),
                burst_requests=float(schemas._field(
                    body, "burst_requests", (int, float), default=0.0)),
                burst_tokens=float(schemas._field(
                    body, "burst_tokens", (int, float), default=0.0)))
            gw.admin.set_tenant_quota(tenant, quota)
            return self._send_json(200, {
                "tenant": tenant,
                "requests_per_s": quota.requests_per_s,
                "tokens_per_s": quota.tokens_per_s})
        if verb == "cache/flush":
            model = schemas._field(body, "model", str, default="") or None
            return self._send_json(200, gw.admin.flush_cache(model))
        raise WireError(ErrorCode.INVALID_REQUEST,
                        f"unknown admin verb {verb!r}")

    def _tenants_get(self):
        quotas = self.svc.gateway.admin.tenant_quotas()
        self._send_json(200, {"tenants": {
            t: {"requests_per_s": q.requests_per_s,
                "tokens_per_s": q.tokens_per_s,
                "burst_requests": q.burst_requests,
                "burst_tokens": q.burst_tokens}
            for t, q in sorted(quotas.items())}})


class GatewayHTTPServer:
    """Lifecycle owner: `start()` boots the Gateway runtime + the
    listener; `stop()` drains in-flight requests, parks the fleet, and
    joins every thread.  `port`/`url()` tell where the service landed
    (ephemeral ports supported for tests)."""

    def __init__(self, gateway: Gateway, cfg: Optional[HTTPConfig] = None,
                 runtime_cfg: Optional[RuntimeConfig] = None):
        self.gateway = gateway
        self.cfg = cfg if cfg is not None else HTTPConfig()
        self._runtime_cfg = runtime_cfg
        self._httpd: Optional[_PooledHTTPServer] = None
        self._accept_thread: Optional[threading.Thread] = None
        self._pool: Optional[ThreadPoolExecutor] = None
        self._handles: Dict[int, GenerationHandle] = {}
        self._handles_lock = threading.Lock()
        self._inflight = 0
        self._state_cv = threading.Condition()
        self._closing = False

    # ---- in-flight request accounting (drain on stop) ------------- #
    def _enter(self) -> bool:
        with self._state_cv:
            if self._closing:
                return False
            self._inflight += 1
            return True

    def _leave(self):
        with self._state_cv:
            self._inflight -= 1
            self._state_cv.notify_all()

    def _track(self, rid: int, handle: GenerationHandle):
        with self._handles_lock:
            self._handles[rid] = handle

    def _untrack(self, rid: int):
        with self._handles_lock:
            self._handles.pop(rid, None)

    def _handle_for(self, rid: int) -> Optional[GenerationHandle]:
        with self._handles_lock:
            return self._handles.get(rid)

    def arch_cfg(self, model: str):
        catalog = self.gateway.c.catalog
        return catalog.get(model) if model in catalog else None

    # ---- lifecycle ------------------------------------------------ #
    @property
    def running(self) -> bool:
        return self._httpd is not None

    @property
    def port(self) -> int:
        if self._httpd is None:
            raise RuntimeError("server not started")
        return self._httpd.server_address[1]

    def url(self, path: str = "") -> str:
        return f"http://{self.cfg.host}:{self.port}{path}"

    def start(self) -> "GatewayHTTPServer":
        if self._httpd is not None:
            return self
        self.gateway.start(self._runtime_cfg)    # background pumps drive
        with self._state_cv:           # _enter/_leave race a restart
            self._closing = False
        self._pool = ThreadPoolExecutor(
            max_workers=self.cfg.max_workers,
            thread_name_prefix="http-worker")
        handler = type("GatewayHTTPHandler", (_Handler,),
                       {"timeout": self.cfg.keepalive_idle_s})
        self._httpd = _PooledHTTPServer(
            (self.cfg.host, self.cfg.port), handler, self._pool, self)
        self._accept_thread = threading.Thread(
            target=self._httpd.serve_forever,
            kwargs={"poll_interval": 0.05},
            name="http-accept", daemon=True)
        self._accept_thread.start()
        return self

    def stop(self, drain: bool = True,
             timeout_s: Optional[float] = None) -> bool:
        """Stop the service: refuse new requests, let in-flight ones
        (including open SSE streams) finish within the drain budget,
        force-close what remains, then park the Gateway runtime.
        Returns True when everything drained and joined."""
        if self._httpd is None:
            return True
        budget = (timeout_s if timeout_s is not None
                  else self.cfg.drain_timeout_s)
        deadline = time.monotonic() + budget
        with self._state_cv:
            self._closing = True
        self._httpd.shutdown()                  # stop accepting
        self._accept_thread.join(budget + 1.0)
        drained = True
        if drain:
            with self._state_cv:
                while self._inflight > 0:
                    left = deadline - time.monotonic()
                    if left <= 0:
                        break
                    self._state_cv.wait(min(left, 0.05))
                drained = self._inflight == 0
        if not drain or not drained:
            with self._handles_lock:    # abort whatever is still going
                for h in list(self._handles.values()):
                    h.cancel()
        self._httpd.close_connections()
        self._httpd.server_close()
        self._pool.shutdown(wait=False)
        self._httpd = None
        self._accept_thread = None
        stopped = self.gateway.stop(
            drain=drain, timeout_s=max(deadline - time.monotonic(), 1.0))
        return drained and stopped

"""Chat abstraction for the wire protocol — messages, templates, codec.

The engines speak token ids; OpenAI-compatible clients speak role-tagged
message strings.  This module bridges the two:

* `ChatMessage` — one (role, content) turn; roles follow the OpenAI set.
* `ChatTemplate` — a per-model-family prompt format (llama3 headers,
  gemma turns, ChatML for the qwen/deepseek lineage, a plain fallback)
  rendering a conversation to one deterministic prompt string.  The
  registry resolves a template by model-name prefix, so reduced test
  variants ("llama3.2-1b-reduced") pick up their family automatically.
* byte-level codec — `encode_text`/`decode_tokens` map strings to token
  ids and back.  There is no learned tokenizer in this reproduction, so
  the wire layer uses UTF-8 bytes as ids (folded into the vocab when it
  is smaller than 256); ids beyond the byte range decode to U+FFFD.

Prefix awareness: vision-fronted and meta-token models spend
`n_prefix_tokens`/`n_meta_tokens` cache positions *before* the prompt
(the engine injects those embeddings itself).  Templates therefore never
emit prefix placeholders as tokens — vision models only get a textual
`image_marker` anchor — and `prefix_budget()` exposes the reserved count
so the service layer can validate context against
`max_len - prefix_budget(cfg)`, matching the Gateway's own accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig

ROLES = ("system", "user", "assistant")

_REPLACEMENT = b"\xef\xbf\xbd"          # UTF-8 encoding of U+FFFD


# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ChatMessage:
    """One conversation turn."""
    role: str
    content: str

    def __post_init__(self):
        if self.role not in ROLES:
            raise ValueError(f"role must be one of {ROLES}, "
                             f"got {self.role!r}")
        if not isinstance(self.content, str):
            raise ValueError("content must be a string")


# --------------------------------------------------------------------- #
def encode_text(text: str, vocab: int = 256) -> Tuple[int, ...]:
    """Text -> token ids: UTF-8 bytes, folded into small vocabularies.
    Every catalog model has vocab >= 256, so encoding round-trips; the
    fold only matters for hand-built toy configs."""
    v = max(int(vocab), 1)
    return tuple(b % v for b in text.encode("utf-8"))


def decode_tokens(tokens: Iterable[int]) -> str:
    """Token ids -> text.  Ids in the byte range decode as UTF-8 (lossy
    sequences become U+FFFD); ids beyond it (sampled from a larger
    vocab) decode to U+FFFD placeholders."""
    buf = bytearray()
    for t in tokens:
        t = int(t)
        if 0 <= t < 256:
            buf.append(t)
        else:
            buf.extend(_REPLACEMENT)
    return buf.decode("utf-8", errors="replace")


# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ChatTemplate:
    """One model family's prompt format.  `turn` and `generation_open`
    are format strings over {role} / {content}; `role_names` renames
    wire roles to family-native ones (gemma says "model", not
    "assistant")."""
    name: str
    turn: str
    generation_open: str
    bos: str = ""
    image_marker: str = ""          # textual anchor for vision frontends
    role_names: Tuple[Tuple[str, str], ...] = ()

    def _role(self, role: str) -> str:
        return dict(self.role_names).get(role, role)

    def render_text(self, messages: Sequence[ChatMessage], *,
                    vision: bool = False) -> str:
        """Render a conversation to the family's prompt string, ending
        with the assistant-generation cue."""
        parts: List[str] = [self.bos] if self.bos else []
        if vision and self.image_marker:
            parts.append(self.image_marker)
        for m in messages:
            parts.append(self.turn.format(role=self._role(m.role),
                                          content=m.content))
        parts.append(self.generation_open)
        return "".join(parts)


LLAMA3 = ChatTemplate(
    name="llama3",
    bos="<|begin_of_text|>",
    turn="<|start_header_id|>{role}<|end_header_id|>\n\n{content}"
         "<|eot_id|>",
    generation_open="<|start_header_id|>assistant<|end_header_id|>\n\n",
    image_marker="<|image|>",
)

GEMMA = ChatTemplate(
    name="gemma",
    bos="<bos>",
    turn="<start_of_turn>{role}\n{content}<end_of_turn>\n",
    generation_open="<start_of_turn>model\n",
    image_marker="<start_of_image>",
    role_names=(("assistant", "model"),),
)

CHATML = ChatTemplate(
    name="chatml",
    turn="<|im_start|>{role}\n{content}<|im_end|>\n",
    generation_open="<|im_start|>assistant\n",
    image_marker="<|vision_start|><|image_pad|><|vision_end|>",
)

PLAIN = ChatTemplate(
    name="plain",
    turn="{role}: {content}\n",
    generation_open="assistant:",
    image_marker="[image]\n",
)

# model-name prefix -> template; longest matching prefix wins, so
# reduced()/derived names ("gemma3-1b-reduced") resolve like their base
_REGISTRY: Dict[str, ChatTemplate] = {
    "llama": LLAMA3,
    "gemma": GEMMA,
    "qwen": CHATML,
    "deepseek": CHATML,
    "olmo": CHATML,
    "phi": CHATML,
}


def register_template(prefix: str, template: ChatTemplate):
    """Install (or override) the template for a model-name prefix."""
    _REGISTRY[prefix] = template


def template_for(model: str) -> ChatTemplate:
    best = ""
    for prefix in _REGISTRY:
        if model.startswith(prefix) and len(prefix) > len(best):
            best = prefix
    return _REGISTRY[best] if best else PLAIN


# --------------------------------------------------------------------- #
def prefix_budget(cfg: Optional[ArchConfig]) -> int:
    """Cache positions the engine reserves ahead of the prompt (vision /
    meta prefix embeddings) — they count against the replica context."""
    if cfg is None:
        return 0
    return int(getattr(cfg, "n_prefix_tokens", 0)
               + getattr(cfg, "n_meta_tokens", 0))


def render_prompt(model: str, messages: Sequence[ChatMessage],
                  cfg: Optional[ArchConfig] = None) -> Tuple[int, ...]:
    """Render a conversation to prompt token ids for `model`.  With a
    catalog `cfg` the encoding folds into the model's vocab and vision
    frontends get their image anchor."""
    tmpl = template_for(model)
    vision = cfg is not None and getattr(cfg, "frontend", "") == "vision"
    text = tmpl.render_text(messages, vision=vision)
    return encode_text(text, cfg.vocab if cfg is not None else 256)

"""Gateway API v1 — the system's single public surface.

    from repro.api import Gateway
    gw = Gateway(controller)
    resp = gw.generate("llama3.2-1b", [1, 2, 3])          # sync
    handle = gw.submit("llama3.2-1b", [1, 2, 3])          # async
    for ev in handle.stream(): ...                        # streaming
    snap = gw.admin.snapshot()                            # typed admin
"""
from repro.api.admin import (AdminAPI, DeployResult, FleetSnapshot,
                             InstanceSnapshot, ModelSnapshot, NodeSnapshot)
from repro.api.gateway import (Gateway, GatewayConfig, GatewayStats,
                               GenerationHandle)
from repro.api.types import (API_VERSION, APIError, ErrorCode, GatewayError,
                             GenerationRequest, GenerationResponse,
                             StreamEvent, StreamEventType,
                             response_from_internal)

__all__ = ["API_VERSION", "APIError", "AdminAPI", "DeployResult",
           "ErrorCode", "FleetSnapshot", "Gateway", "GatewayConfig",
           "GatewayError", "GatewayStats", "GenerationHandle",
           "GenerationRequest", "GenerationResponse", "InstanceSnapshot",
           "ModelSnapshot", "NodeSnapshot", "StreamEvent",
           "StreamEventType", "response_from_internal"]

"""Gateway API v1 — the system's single public surface.

    from repro.api import Gateway
    gw = Gateway(controller)
    gw.start()                                            # background pumps
    resp = gw.generate("llama3.2-1b", [1, 2, 3])          # sync
    handle = gw.submit("llama3.2-1b", [1, 2, 3],
                       tenant="acme")                     # async, tenanted
    for ev in handle.stream(): ...                        # streaming
    gw.admin.set_tenant_quota("acme", requests_per_s=5)   # rate limits
    snap = gw.admin.snapshot()                            # typed admin
    gw.stop()                                             # drain + join
"""
from repro.api.admin import (AdminAPI, DeployResult, FleetSnapshot,
                             InstanceSnapshot, ModelSnapshot, NodeSnapshot,
                             TenantSnapshot)
from repro.api.gateway import (Gateway, GatewayConfig, GatewayStats,
                               GenerationHandle)
from repro.api.runtime import RuntimeConfig, RuntimeStats, ServingRuntime
from repro.api.types import (API_VERSION, APIError, ErrorCode, GatewayError,
                             GenerationRequest, GenerationResponse,
                             StreamEvent, StreamEventType,
                             response_from_internal)
from repro.core.frontend import TenantQuota

__all__ = ["API_VERSION", "APIError", "AdminAPI", "DeployResult",
           "ErrorCode", "FleetSnapshot", "Gateway", "GatewayConfig",
           "GatewayError", "GatewayStats", "GenerationHandle",
           "GenerationRequest", "GenerationResponse", "InstanceSnapshot",
           "ModelSnapshot", "NodeSnapshot", "RuntimeConfig",
           "RuntimeStats", "ServingRuntime", "StreamEvent",
           "StreamEventType", "TenantQuota", "TenantSnapshot",
           "response_from_internal"]

"""Gateway API v1 — typed admin surface (the SDAI dashboard, typed).

`AdminAPI` is the control plane the old `SDAIController.dashboard()` dict
grows into: frozen `FleetSnapshot`/`NodeSnapshot`/`InstanceSnapshot`/
`TenantSnapshot` views plus deploy / undeploy / scale / drain verbs and
per-tenant quota configuration.  `dashboard()` remains as a thin shim that
renders `snapshot().to_dict()` in the legacy shape.
"""
from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Tuple

from repro.core.events import FAILURE_EVENT_KINDS
from repro.core.frontend import TenantQuota
from repro.core.placement import ModelDemand

if TYPE_CHECKING:                      # avoid import cycle at runtime
    from repro.api.gateway import Gateway
    from repro.core.controller import SDAIController


@dataclasses.dataclass(frozen=True)
class InstanceSnapshot:
    instance_id: int
    model: str
    quantize: str
    n_slots: int
    max_len: int
    bytes: int
    load: float
    alive: bool
    # paged KV pool occupancy (real engines; accounted replicas report
    # their configured budget with zero occupancy)
    page_size: int = 0
    kv_pages: int = 0
    pages_in_use: int = 0
    page_occupancy: float = 0.0
    page_fragmentation: float = 0.0
    preemptions: int = 0
    # hierarchical KV memory (prefix cache + host swap tier); zeros when
    # the engine runs without the hierarchy enabled
    cache_hit_rate: float = 0.0
    cache_device_pages: int = 0
    cache_evictable_pages: int = 0
    host_pages: int = 0
    host_pages_in_use: int = 0
    swap_outs: int = 0
    swap_ins: int = 0
    # decode hot-path efficiency (paged attention + speculative decode);
    # zeros when the engine runs the gather/scatter path without spec
    paged_attention: bool = False
    speculative: bool = False
    logical_bytes_moved_per_token: float = 0.0
    spec_accepted_per_dispatch: float = 0.0


@dataclasses.dataclass(frozen=True)
class NodeSnapshot:
    node_id: str
    klass: str
    alive: bool
    health: str
    hbm_used: int
    hbm_budget: int
    instances: Tuple[InstanceSnapshot, ...]

    @property
    def utilization(self) -> float:
        return self.hbm_used / self.hbm_budget if self.hbm_budget else 0.0


@dataclasses.dataclass(frozen=True)
class ModelSnapshot:
    name: str
    replicas: int
    healthy_replicas: int


@dataclasses.dataclass(frozen=True)
class TenantSnapshot:
    """One tenant's configured quota + cumulative usage."""
    tenant: str
    requests_per_s: float          # 0 => unlimited
    tokens_per_s: float            # 0 => unlimited
    admitted: int
    rate_limited: int
    tokens_charged: int
    weight: float = 1.0            # DWRR fair-queuing share
    refunds: int = 0               # cancelled-while-queued give-backs


@dataclasses.dataclass(frozen=True)
class ClassSnapshot:
    """Per-node-class rollup: fleet share, cost weight, modeled
    cost-per-token per request-size bucket (averaged over deployed
    models), and observed routed traffic per bucket — the heterogeneity
    dashboard the paper's mixed-GPU story needs."""
    klass: str
    cost_per_hour: float
    legacy: bool
    nodes: int
    alive_nodes: int
    hbm_budget: int
    hbm_used: int
    replicas: int
    routed_by_bucket: Dict[str, int] = \
        dataclasses.field(default_factory=dict)
    cost_per_token: Dict[str, float] = \
        dataclasses.field(default_factory=dict)

    @property
    def utilization(self) -> float:
        return self.hbm_used / self.hbm_budget if self.hbm_budget else 0.0


@dataclasses.dataclass(frozen=True)
class FleetSnapshot:
    connected: int
    total: int
    nodes: Tuple[NodeSnapshot, ...]
    models: Tuple[ModelSnapshot, ...]
    routing: Dict[str, Tuple[str, ...]]
    utilization: float
    last_update: float
    tenants: Tuple[TenantSnapshot, ...] = ()
    # failure-handling activity over the bus's retained window:
    # migrations, watchdog trips, suspects, injected faults (kind -> n)
    failure_events: Dict[str, int] = \
        dataclasses.field(default_factory=dict)
    # per-GPU-class demand/cost rollup (heterogeneity dashboard)
    classes: Tuple[ClassSnapshot, ...] = ()

    def node(self, node_id: str) -> Optional[NodeSnapshot]:
        for n in self.nodes:
            if n.node_id == node_id:
                return n
        return None

    def to_dict(self) -> Dict:
        """Legacy `dashboard()` shape (paper Fig. 3)."""
        return {
            "connected": self.connected,
            "total": self.total,
            "agents": {
                n.node_id: {
                    "class": n.klass,
                    "alive": n.alive,
                    "health": n.health,
                    "hbm_used": n.hbm_used,
                    "hbm_budget": n.hbm_budget,
                    "instances": [{"model": i.model,
                                   "quantize": i.quantize,
                                   "kv_pages": i.kv_pages,
                                   "pages_in_use": i.pages_in_use,
                                   "page_occupancy": i.page_occupancy,
                                   "page_fragmentation":
                                       i.page_fragmentation,
                                   "cache": {
                                       "hit_rate": i.cache_hit_rate,
                                       "device_pages":
                                           i.cache_device_pages,
                                       "evictable_pages":
                                           i.cache_evictable_pages,
                                       "host_pages": i.host_pages,
                                       "host_pages_in_use":
                                           i.host_pages_in_use,
                                       "swap_outs": i.swap_outs,
                                       "swap_ins": i.swap_ins}}
                                  for i in n.instances],
                } for n in self.nodes},
            "models": {m.name: m.replicas for m in self.models},
            "routing": {m: list(r) for m, r in self.routing.items()},
            "tenants": {
                t.tenant: {"requests_per_s": t.requests_per_s,
                           "tokens_per_s": t.tokens_per_s,
                           "weight": t.weight,
                           "admitted": t.admitted,
                           "rate_limited": t.rate_limited,
                           "tokens_charged": t.tokens_charged,
                           "refunds": t.refunds}
                for t in self.tenants},
            "classes": {
                k.klass: {"cost_per_hour": k.cost_per_hour,
                          "legacy": k.legacy,
                          "nodes": k.nodes,
                          "alive_nodes": k.alive_nodes,
                          "hbm_budget": k.hbm_budget,
                          "hbm_used": k.hbm_used,
                          "utilization": k.utilization,
                          "replicas": k.replicas,
                          "routed_by_bucket": dict(k.routed_by_bucket),
                          "cost_per_token": dict(k.cost_per_token)}
                for k in self.classes},
            "failures": dict(self.failure_events),
            "last_update": self.last_update,
        }


@dataclasses.dataclass(frozen=True)
class DeployResult:
    placed: int
    unplaced: Tuple[str, ...]

    @property
    def ok(self) -> bool:
        return not self.unplaced


class AdminAPI:
    """Typed control plane over the SDAI controller.  Standalone
    (`AdminAPI(ctrl)`) for observation; attach a `Gateway` (done
    automatically by `Gateway.__init__`) to enable `drain_model`."""

    def __init__(self, controller: "SDAIController",
                 gateway: Optional["Gateway"] = None) -> None:
        self.c = controller
        self.gateway = gateway

    # ---- observe ------------------------------------------------- #
    def snapshot(self) -> FleetSnapshot:
        c = self.c
        nodes: List[NodeSnapshot] = []
        for nid in c.nodes.ids():
            node = c.fleet.nodes.get(nid)
            alive = c.node_alive(nid)
            instances = []
            if alive:
                for r in c.replicas.on_node(nid):
                    inst = node.instances.get(r.key.instance_id)
                    pages: Dict[str, Any] = {}
                    if inst is not None:
                        if inst.engine is not None:
                            # instance lock: page_stats iterates pool
                            # dicts a pump thread mutates mid-step
                            eng = inst.engine
                            with inst.lock:
                                ps = eng.pool.page_stats()
                                pc, hp = eng.prefix_cache, eng.host_pool
                                cache = dict(
                                    cache_hit_rate=(
                                        pc.hit_rate() if pc else 0.0),
                                    cache_device_pages=(
                                        pc.device_pages if pc else 0),
                                    cache_evictable_pages=(
                                        pc.evictable_device_pages()
                                        if pc else 0),
                                    host_pages=hp.n_pages if hp else 0,
                                    host_pages_in_use=(
                                        hp.in_use if hp else 0),
                                    swap_outs=eng.swap_outs,
                                    swap_ins=eng.swap_ins,
                                    paged_attention=eng._paged_attn,
                                    speculative=eng._spec_ok,
                                    logical_bytes_moved_per_token=(
                                        eng.logical_bytes_moved
                                        / max(eng.total_tokens, 1)),
                                    spec_accepted_per_dispatch=(
                                        eng.spec_emitted
                                        / eng.spec_dispatches
                                        if eng.spec_dispatches else 0.0))
                            frag = ps["page_fragmentation"]
                            pages = dict(
                                page_size=int(ps["page_size"]),
                                kv_pages=int(ps["kv_pages"]),
                                pages_in_use=int(ps["pages_in_use"]),
                                page_occupancy=ps["page_occupancy"],
                                page_fragmentation=frag,
                                preemptions=int(ps["preemptions"]),
                                **cache)
                        else:
                            pages = dict(page_size=inst.page_size,
                                         kv_pages=inst.kv_pages)
                    instances.append(InstanceSnapshot(
                        instance_id=r.key.instance_id,
                        model=r.model_name, quantize=r.quantize,
                        n_slots=r.n_slots, max_len=r.max_len,
                        bytes=r.bytes,
                        load=inst.load if inst is not None else 0.0,
                        alive=inst.alive if inst is not None else False,
                        **pages))
            nodes.append(NodeSnapshot(
                node_id=nid,
                klass=node.klass.name if node else "?",
                alive=alive,
                health=c.monitor.status(nid).value,
                hbm_used=node.hbm_used if node and alive else 0,
                hbm_budget=node.hbm_budget if node else 0,
                instances=tuple(instances)))
        models = tuple(ModelSnapshot(
            name=m, replicas=len(c.replicas.for_model(m)),
            healthy_replicas=len(c.frontend.healthy_replicas(m)))
            for m in c.replicas.models())
        routing = {m: tuple(str(k) for k in c.frontend.healthy_replicas(m))
                   for m in c.replicas.models()}
        tenants: List[TenantSnapshot] = []
        for name, entry in sorted(c.frontend.tenants.snapshot().items()):
            quota, usage = entry["quota"], entry["usage"]
            tenants.append(TenantSnapshot(
                tenant=name,
                requests_per_s=quota.requests_per_s if quota else 0.0,
                tokens_per_s=quota.tokens_per_s if quota else 0.0,
                admitted=usage.admitted,
                rate_limited=usage.rate_limited,
                tokens_charged=usage.tokens_charged,
                weight=quota.weight if quota else 1.0,
                refunds=usage.refunds))
        return FleetSnapshot(
            connected=sum(1 for n in nodes if n.alive),
            total=len(nodes), nodes=tuple(nodes), models=models,
            routing=routing, utilization=c.fleet_utilization(),
            last_update=c.clock(), tenants=tuple(tenants),
            failure_events=c.bus.counts(FAILURE_EVENT_KINDS),
            classes=self._class_rollup(nodes))

    def _class_rollup(self,
                      nodes: List[NodeSnapshot]) -> Tuple[ClassSnapshot,
                                                          ...]:
        """Aggregate node snapshots per NodeClass and annotate each class
        with observed per-bucket routed traffic plus the perf model's
        per-bucket cost-per-token (averaged over models the class could
        serve — the controller's registered demands)."""
        from repro.cluster.hardware import NODE_CLASSES
        from repro.core.perfmodel import BUCKETS
        c = self.c
        by_class: Dict[str, List[NodeSnapshot]] = {}
        for n in nodes:
            by_class.setdefault(n.klass, []).append(n)
        # observed traffic: bucket -> class -> routed count
        traffic = c.frontend.stats.per_bucket_class
        out = []
        for kname in sorted(by_class):
            klass = NODE_CLASSES.get(kname)
            members = by_class[kname]
            cfgs = [d.cfg for d in c.demands.values()]
            cpt: Dict[str, float] = {}
            if klass is not None and cfgs:
                for b in BUCKETS:
                    vals = [c.perf.cost_per_token(klass, cfg, b)
                            for cfg in cfgs]
                    finite = [v for v in vals if v != float("inf")]
                    if finite:
                        cpt[b.name] = sum(finite) / len(finite)
            out.append(ClassSnapshot(
                klass=kname,
                cost_per_hour=klass.cost_per_hour if klass else 0.0,
                legacy=klass.legacy if klass else False,
                nodes=len(members),
                alive_nodes=sum(1 for n in members if n.alive),
                hbm_budget=sum(n.hbm_budget for n in members),
                hbm_used=sum(n.hbm_used for n in members),
                replicas=sum(len(n.instances) for n in members),
                routed_by_bucket={b: kc[kname]
                                  for b, kc in traffic.items()
                                  if kname in kc},
                cost_per_token=cpt))
        return tuple(out)

    # ---- mutate -------------------------------------------------- #
    def flush_cache(self, model: Optional[str] = None) -> Dict[str, int]:
        """Drop every unpinned prefix-cache entry (device and host
        tiers) on every live engine — or only on `model`'s replicas.
        Pinned entries (pages a running slot still reads) survive.
        Returns aggregate `{"flushed": n, "remaining": m}`."""
        c = self.c
        flushed = remaining = 0
        for nid in c.nodes.ids():
            node = c.fleet.nodes.get(nid)
            if node is None or not c.node_alive(nid):
                continue
            for r in c.replicas.on_node(nid):
                if model is not None and r.model_name != model:
                    continue
                inst = node.instances.get(r.key.instance_id)
                if inst is None or inst.engine is None:
                    continue
                with inst.lock:
                    res = inst.engine.flush_prefix_cache()
                flushed += int(res.get("flushed", 0))
                remaining += int(res.get("remaining", 0))
        self.c.bus.emit("cache_flushed", model=model or "*",
                        flushed=flushed, remaining=remaining)
        return {"flushed": flushed, "remaining": remaining}

    def deploy_model(self, demand: ModelDemand) -> DeployResult:
        plan = self.c.deploy([demand])
        return DeployResult(placed=len(plan.assignments),
                            unplaced=tuple(plan.unplaced))

    def undeploy_model(self, model: str) -> int:
        if self.gateway is not None:
            self.gateway._draining.discard(model)
        return self.c.undeploy_model(model)

    def scale_model(self, model: str, min_replicas: int) -> DeployResult:
        """Grow (place additional replicas) or shrink (undeploy surplus)
        the replica count for an already-registered demand."""
        demand = self.c.demands.get(model)
        if demand is None:
            demand = ModelDemand(self.c.catalog.get(model),
                                 min_replicas=min_replicas)
        new_max = demand.max_replicas and max(demand.max_replicas,
                                              min_replicas)
        target = dataclasses.replace(demand, min_replicas=min_replicas,
                                     max_replicas=new_max)
        have = len(self.c.frontend.healthy_replicas(model))
        if min_replicas > have:
            delta = dataclasses.replace(target,
                                        min_replicas=min_replicas - have,
                                        max_replicas=min_replicas - have)
            plan = self.c.deploy([delta])
            # deploy() overwrote the demand with the delta; restore target
            self.c.demands[model] = target
            return DeployResult(placed=len(plan.assignments),
                                unplaced=tuple(plan.unplaced))
        self.c.demands[model] = target
        removed = self.c.remove_replicas(model, keep=min_replicas)
        self.c.bus.emit("model_scaled", model=model,
                        target=min_replicas, removed=removed)
        return DeployResult(placed=0, unplaced=())

    def drain_model(self, model: str, timeout_s: float = 30.0) -> int:
        """Stop admitting new requests for `model` (structured `DRAINING`
        rejections) and wait until in-flight traffic settles — pump
        threads drain it when the runtime is started, otherwise this call
        hand-pumps.  Returns the number of requests still in flight
        (0 == drained).  The model stays drained until `resume_model` or
        `undeploy_model`."""
        if self.gateway is None:
            raise RuntimeError("drain_model needs a Gateway-attached "
                               "AdminAPI (use gateway.admin)")
        gw = self.gateway
        gw._draining.add(model)
        deadline = time.monotonic() + timeout_s
        while gw.inflight(model) > 0 and time.monotonic() < deadline:
            if gw.runtime_active:
                time.sleep(0.005)
            else:
                self.c.fleet.pump()
        self.c.bus.emit("model_drained", model=model,
                        remaining=gw.inflight(model))
        return gw.inflight(model)

    def resume_model(self, model: str) -> None:
        if self.gateway is not None:
            self.gateway._draining.discard(model)

    # ---- multi-tenancy ------------------------------------------- #
    def set_tenant_quota(self, tenant: str,
                         quota: Optional[TenantQuota] = None, *,
                         requests_per_s: float = 0.0,
                         tokens_per_s: float = 0.0,
                         weight: float = 1.0) -> TenantQuota:
        """Install per-tenant rate limits and the fair-queuing weight,
        enforced by the frontend at admission (`ErrorCode.RATE_LIMITED`
        rejections) and inside every engine's DWRR scheduler
        respectively.  Pass a `TenantQuota` or the shorthands; quotas
        show up in `FleetSnapshot.tenants`."""
        if quota is None:
            quota = TenantQuota(requests_per_s=requests_per_s,
                                tokens_per_s=tokens_per_s, weight=weight)
        self.c.frontend.tenants.set_quota(tenant, quota)
        self.c.bus.emit("tenant_quota_set", tenant=tenant,
                        requests_per_s=quota.requests_per_s,
                        tokens_per_s=quota.tokens_per_s,
                        weight=quota.weight)
        return quota

    def remove_tenant_quota(self, tenant: str) -> None:
        """Lift a tenant's rate limits (usage history is kept)."""
        self.c.frontend.tenants.set_quota(tenant, None)
        self.c.bus.emit("tenant_quota_removed", tenant=tenant)

    def tenant_quotas(self) -> Dict[str, TenantQuota]:
        return dict(self.c.frontend.tenants.quotas)

"""Continuous serving runtime — background pumps + controller tick loop.

Before this module the Gateway hand-pumped the fleet from whichever caller
happened to block on `result()`/`stream()`.  `ServingRuntime` makes the
fleet *self-driving*:

* one **pump thread per backend node**, parked on the node's condition
  variable and woken by `submit()`/`cancel()` (plus a short timeout as a
  missed-wakeup backstop); each wakeup steps every live engine on the node
  until its queues drain,
* one **tick thread** that periodically measures per-model pressure
  (scheduler backlog + gateway in-flight over healthy replicas) and feeds
  it into `SDAIController.tick(load=...)` — heartbeat ingestion, failure
  reallocation, and load-driven scale-up all run off this loop,
* **clean drain on stop**: `stop()` (default `drain=True`) lets pumps
  finish in-flight work before joining every thread; `stop(drain=False)`
  parks immediately, leaving queued requests for a later `start()`.

Callers never pump: with the runtime started, `GenerationHandle.result()`
and `.stream()` just block on handle events that the pump threads signal.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import TYPE_CHECKING, Dict, List, Optional

from repro.core.controller import ModelLoad
from repro.core.events import NODE_SUSPECTED, WATCHDOG_FIRED

if TYPE_CHECKING:                      # avoid import cycle at runtime
    from repro.api.gateway import Gateway


@dataclasses.dataclass
class RuntimeConfig:
    tick_interval_s: float = 0.05      # controller load/health cadence
    pump_idle_wait_s: float = 0.02     # cv wait backstop per pump loop
    drain_timeout_s: float = 30.0      # stop(drain=True) upper bound
    # pump watchdog: a single node.pump() call exceeding this wall-clock
    # deadline marks the node SUSPECT in the HealthMonitor, so weighted
    # routing demotes a hung/straggling engine before it stalls queued
    # work.  The mark clears as soon as the step completes.  <= 0 off.
    watchdog_step_timeout_s: float = 10.0


@dataclasses.dataclass
class RuntimeStats:
    ticks: int = 0
    pump_wakeups: int = 0
    tokens_pumped: int = 0
    watchdog_fired: int = 0


class _NodePump(threading.Thread):
    """One node's serving loop: wait for work, step engines, repeat."""

    def __init__(self, runtime: "ServingRuntime", node):
        super().__init__(name=f"pump-{node.node_id}", daemon=True)
        self.rt = runtime
        self.node = node
        # monotonic timestamp of the pump() call in flight (None when
        # idle); the tick loop's watchdog reads it cross-thread
        self.busy_since: Optional[float] = None

    def run(self):
        node, rt = self.node, self.rt
        while True:
            with node.work_cv:
                while not rt._stopping.is_set() and \
                        not node.has_work():
                    node.work_cv.wait(rt.cfg.pump_idle_wait_s)
            if rt._stopping.is_set():
                if not rt._drain or not node.alive:
                    return
                if not node.has_work():
                    return             # drained: exit
                if time.monotonic() > rt._drain_deadline:
                    return             # drain budget exhausted
            if not node.alive:
                # dead nodes idle until recover(); stop() still joins us
                time.sleep(rt.cfg.pump_idle_wait_s)
                continue
            self.busy_since = time.monotonic()
            try:
                emitted = node.pump()
            finally:
                self.busy_since = None
            with rt._stats_lock:       # N pump threads share these
                rt.stats.pump_wakeups += 1
                rt.stats.tokens_pumped += emitted


class _TickLoop(threading.Thread):
    """Controller heartbeat/reallocation/autoscale cadence."""

    def __init__(self, runtime: "ServingRuntime"):
        super().__init__(name="sdai-tick", daemon=True)
        self.rt = runtime

    def run(self):
        rt = self.rt
        while not rt._stopping.wait(rt.cfg.tick_interval_s):
            try:
                rt.tick_once()
            except Exception as e:     # keep the loop alive; surface it
                rt.gateway.c.bus.emit("tick_error", error=repr(e))


class ServingRuntime:
    """Drives a `Gateway`'s fleet from background threads.  Construct via
    `Gateway.start()` (which owns the lifecycle) or directly for finer
    control."""

    def __init__(self, gateway: "Gateway",
                 cfg: Optional[RuntimeConfig] = None):
        self.gateway = gateway
        self.cfg = cfg if cfg is not None else RuntimeConfig()
        self.stats = RuntimeStats()
        self._stats_lock = threading.Lock()
        self._pumps: Dict[str, _NodePump] = {}
        self._ticker: Optional[_TickLoop] = None
        self._stopping = threading.Event()
        self._drain = True
        self._drain_deadline = 0.0
        self._running = False
        self._suspected: set = set()   # nodes the watchdog has demoted

    # ------------------------------------------------------------- #
    @property
    def running(self) -> bool:
        return self._running

    def start(self) -> "ServingRuntime":
        if self._running:
            return self
        self._stopping.clear()
        self._pumps = {}
        for node in self.gateway.c.fleet.nodes.values():
            pump = _NodePump(self, node)
            self._pumps[node.node_id] = pump
        self._ticker = _TickLoop(self)
        self._running = True           # set before threads observe state
        for pump in self._pumps.values():
            pump.start()
        self._ticker.start()
        return self

    def stop(self, drain: bool = True,
             timeout_s: Optional[float] = None) -> bool:
        """Stop all background threads.  With `drain=True` (default) pump
        threads first finish every queued/in-flight request (bounded by
        `timeout_s`/`drain_timeout_s`).  Returns True when every thread
        joined."""
        if not self._running:
            return True
        budget = timeout_s if timeout_s is not None \
            else self.cfg.drain_timeout_s
        self._drain = drain
        self._drain_deadline = time.monotonic() + budget
        self._stopping.set()
        self.wake_all()
        joined = True
        deadline = time.monotonic() + budget
        # join the ticker FIRST: it is the only thread that spawns new
        # pumps (elastic joins / autoscale), so once it is down the pump
        # map is stable and the join list below cannot miss a thread
        if self._ticker is not None:
            self._ticker.join(max(0.0, deadline - time.monotonic()) + 1.0)
            joined = joined and not self._ticker.is_alive()
        for t in list(self._pumps.values()):
            t.join(max(0.0, deadline - time.monotonic()) + 1.0)
            joined = joined and not t.is_alive()
        self._running = False
        self._drain = True
        return joined

    def wake_all(self):
        for node in self.gateway.c.fleet.nodes.values():
            node.notify_work()

    def threads(self) -> List[threading.Thread]:
        """Every runtime thread (tests assert they join on stop)."""
        out: List[threading.Thread] = list(self._pumps.values())
        if self._ticker is not None:
            out.append(self._ticker)
        return out

    # ------------------------------------------------------------- #
    def load_report(self) -> Dict[str, ModelLoad]:
        """Per-model pressure: scheduler backlog across live replicas +
        gateway in-flight, over healthy replica count."""
        gw = self.gateway
        c = gw.c
        out: Dict[str, ModelLoad] = {}
        for model in c.replicas.models():
            depth, head_wait, page_pressure = 0, 0.0, 0.0
            for info in c.replicas.for_model(model):
                node = c.fleet.nodes.get(info.key.node_id)
                if node is None or not node.alive:
                    continue
                inst = node.instances.get(info.key.instance_id)
                if inst is not None and inst.engine is not None:
                    sched = inst.engine.scheduler
                    depth += sched.depth
                    head_wait = max(head_wait, sched.head_wait_s())
                    # KV-page occupancy net of evictable prefix-cache
                    # pages: a nearly-exhausted pool means admitted work
                    # is about to preempt — VRAM pressure queue depth
                    # alone cannot see — but pages the cache will hand
                    # back on demand are not pressure, so a cache-warm
                    # idle engine does not trigger scale-up
                    page_pressure = max(
                        page_pressure,
                        inst.engine.page_pressure())
            out[model] = ModelLoad(
                queue_depth=depth,
                inflight=gw.inflight(model),
                replicas=len(c.frontend.healthy_replicas(model)),
                max_head_wait_s=head_wait,
                page_pressure=page_pressure)
        return out

    def _watchdog(self):
        """Demote nodes whose pump step blew its wall-clock deadline: a
        hung engine (driver stall, pathological compile, chaos-injected
        hang) would otherwise block its pump thread forever while the
        node keeps heartbeating HEALTHY.  The SUSPECT mark adds the
        frontend's `suspect_penalty` to every replica on the node, so
        new work routes around it; the mark clears when the step
        finally completes."""
        deadline = self.cfg.watchdog_step_timeout_s
        if deadline <= 0:
            return
        mon = self.gateway.c.monitor
        bus = self.gateway.c.bus
        now = time.monotonic()
        for node_id, pump in list(self._pumps.items()):
            since = pump.busy_since
            stalled = since is not None and (now - since) > deadline
            if stalled and node_id not in self._suspected:
                self._suspected.add(node_id)
                mon.mark_suspect(node_id)
                with self._stats_lock:
                    self.stats.watchdog_fired += 1
                bus.emit(WATCHDOG_FIRED, node=node_id,
                         stalled_s=now - since)
                bus.emit(NODE_SUSPECTED, node=node_id, reason="watchdog")
            elif not stalled and node_id in self._suspected:
                self._suspected.discard(node_id)
                mon.clear_suspect(node_id)

    def tick_once(self):
        """One controller iteration with fresh load feedback.  New nodes
        (elastic joins / autoscale targets) get pump threads here."""
        with self._stats_lock:         # pumps bump their counters too
            self.stats.ticks += 1
        self._watchdog()
        self.gateway.c.tick(load=self.load_report())
        if not self._stopping.is_set():
            for node in list(self.gateway.c.fleet.nodes.values()):
                if node.node_id not in self._pumps:
                    pump = _NodePump(self, node)
                    self._pumps[node.node_id] = pump
                    pump.start()

"""Gateway API v1 — versioned, frozen request/response types.

These are the system's *public* wire types, decoupled from the internal
mutable `repro.serving.request.Request`.  Everything here is immutable so
responses can be cached, logged, and shared across threads safely; the
`Gateway` is the only component that translates between the two worlds.

Error taxonomy (`ErrorCode`) mirrors the internal code strings set at each
failure site (frontend, scheduler, engine, node), so classification never
depends on parsing human-readable messages.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple

from repro.serving.request import Request
from repro.serving.sampler import SamplingParams

API_VERSION = "v1"


class ErrorCode(enum.Enum):
    """Structured failure classes — the HTTP-status analogue."""
    NO_BACKEND = "no_backend"          # 503: no healthy replica serves model
    OVERLOADED = "overloaded"          # 429: admission/queue limit hit
    ENGINE_FAILED = "engine_failed"    # 500: backend crashed mid-request
    CANCELLED = "cancelled"            # 499: caller aborted the request
    TIMEOUT = "timeout"                # 504: wall-clock deadline exceeded
    DRAINING = "draining"              # 503: model is being drained
    INVALID_REQUEST = "invalid_request"  # 400: malformed request
    RATE_LIMITED = "rate_limited"      # 429: tenant token bucket empty

    @property
    def retryable(self) -> bool:
        return self in (ErrorCode.NO_BACKEND, ErrorCode.OVERLOADED,
                        ErrorCode.TIMEOUT, ErrorCode.DRAINING,
                        ErrorCode.RATE_LIMITED)


@dataclasses.dataclass(frozen=True)
class APIError:
    code: ErrorCode
    message: str

    @property
    def retryable(self) -> bool:
        return self.code.retryable


class GatewayError(RuntimeError):
    """Raised by strict API entry points; carries the structured error."""

    def __init__(self, error: APIError) -> None:
        super().__init__(f"[{error.code.value}] {error.message}")
        self.error = error


@dataclasses.dataclass(frozen=True)
class GenerationRequest:
    """One immutable generation call against the unified endpoint.
    `tenant` identifies the caller for per-tenant rate limiting and
    accounting; "" is the unlimited anonymous tenant."""
    model: str
    prompt: Tuple[int, ...]
    sampling: SamplingParams = SamplingParams()   # frozen -> safe default
    tenant: str = ""

    def __post_init__(self) -> None:
        object.__setattr__(self, "prompt", tuple(self.prompt))


@dataclasses.dataclass(frozen=True)
class GenerationResponse:
    request_id: int
    model: str
    tokens: Tuple[int, ...]
    finish_reason: str                  # "stop" | "length" | "error" |
    error: Optional[APIError] = None    # "cancelled"
    ttft: Optional[float] = None        # seconds to first token
    latency: Optional[float] = None     # seconds to completion
    node: str = ""                      # routing trace
    replica: str = ""
    retries: int = 0

    @property
    def ok(self) -> bool:
        return self.error is None


class StreamEventType(enum.Enum):
    TOKEN = "token"      # one incremental output token
    FINISH = "finish"    # terminal: successful completion
    ERROR = "error"      # terminal: structured failure / cancellation


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """One delta on a `GenerationHandle.stream()` iterator.  TOKEN events
    carry (token, index); FINISH and ERROR carry the final response (and,
    for ERROR, the structured `APIError`)."""
    type: StreamEventType
    token: Optional[int] = None
    index: int = -1
    response: Optional[GenerationResponse] = None
    error: Optional[APIError] = None

    @property
    def terminal(self) -> bool:
        return self.type is not StreamEventType.TOKEN


# --------------------------------------------------------------------- #
def error_from_internal(req: Request) -> Optional[APIError]:
    """Map an internal request's failure onto the public taxonomy."""
    if not req.error and not req.cancelled:
        return None
    try:
        code = ErrorCode(req.error_code) if req.error_code \
            else ErrorCode.ENGINE_FAILED
    except ValueError:
        code = ErrorCode.ENGINE_FAILED
    if req.cancelled:
        code = ErrorCode.CANCELLED
    return APIError(code, req.error or code.value)


def response_from_internal(req: Request) -> GenerationResponse:
    """Freeze an internal request's terminal state into a response."""
    err = error_from_internal(req)
    if req.cancelled:
        reason = "cancelled"
    elif err is not None:
        reason = "error"
    elif (req.sampling.eos_id >= 0 and req.output
          and req.output[-1] == req.sampling.eos_id):
        reason = "stop"
    else:
        reason = "length"
    return GenerationResponse(
        request_id=req.request_id, model=req.model,
        tokens=tuple(req.output), finish_reason=reason, error=err,
        ttft=req.ttft, latency=req.latency, node=req.node,
        replica=req.replica, retries=req.retries)

"""Gateway API v1 — the unified serving facade.

One `Gateway` fronts the whole fleet (the paper's "single logical unit"):

* `generate()`        — blocking call, returns a frozen `GenerationResponse`
* `submit()`          — returns a `GenerationHandle` (async future) with
                        `.result()`, `.cancel()` and `.stream()` (a true
                        incremental token iterator driven by per-token
                        engine callbacks, surviving failover retries)
* `generate_batch()`  — submit many, pump the fleet once for all of them
* admission control   — per-model in-flight and backend queue-depth caps
                        return structured 429-style `OVERLOADED` rejections
                        instead of silently queuing
* `.admin`            — the typed control plane (`repro.api.admin.AdminAPI`)

The simulated fleet is hand-pumped: handles advance engines lazily via
`Gateway._pump()` whenever a caller blocks on `result()`/`stream()`.  Each
pump advances engines by one fused dispatch, so tokens surface in
K-token quanta (`EngineConfig.decode_block`); streams still deliver every
token as its own `StreamEvent`, and `cancel()` takes effect at the next
dispatch boundary (the already-dispatched block is the last one emitted).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Iterator, List, Optional, Sequence, Union

from repro.api.admin import AdminAPI
from repro.api.types import (APIError, ErrorCode, GenerationRequest,
                             GenerationResponse, StreamEvent,
                             StreamEventType, response_from_internal)
from repro.core.controller import SDAIController
from repro.serving.request import (CODE_CANCELLED, CODE_ENGINE_FAILED,
                                   CODE_TIMEOUT, Request)
from repro.serving.sampler import SamplingParams


@dataclasses.dataclass
class GatewayConfig:
    # admission control (None => unlimited, the seed behaviour)
    max_inflight_per_model: Optional[int] = None
    max_queue_depth_per_model: Optional[int] = None
    # liveness: pump budget before a blocking wait times out
    max_pump_steps: int = 10_000
    # transparent re-route of a streaming request whose backend died
    # before emitting any token (after first token the failure surfaces
    # as a structured ERROR event instead — we never re-emit tokens)
    max_stream_retries: int = 2


@dataclasses.dataclass
class GatewayStats:
    submitted: int = 0
    completed: int = 0
    rejected_overloaded: int = 0
    rejected_draining: int = 0
    cancelled: int = 0
    stream_retries: int = 0
    timeouts: int = 0


class GenerationHandle:
    """Future for one in-flight generation.  Created by `Gateway.submit`;
    never constructed directly."""

    def __init__(self, gateway: "Gateway", request: GenerationRequest):
        self._gw = gateway
        self.request = request
        self.internal: Optional[Request] = None   # current routing attempt
        self._events: Deque[StreamEvent] = deque()
        self._emitted = 0          # tokens delivered to this handle
        self._retries_left = gateway.cfg.max_stream_retries
        self._admitted = False
        self._done = False
        self._response: Optional[GenerationResponse] = None

    # ------------------------------------------------------------- #
    @property
    def done(self) -> bool:
        return self._done

    @property
    def response(self) -> Optional[GenerationResponse]:
        return self._response

    # ---- wiring: callbacks installed on the internal request ------ #
    def _on_token(self, req: Request, tok: int):
        if req is not self.internal or self._done:
            return
        self._events.append(StreamEvent(StreamEventType.TOKEN, token=tok,
                                        index=self._emitted))
        self._emitted += 1

    def _on_finish(self, req: Request):
        if req is not self.internal or self._done:
            return
        if (req.error_code == CODE_ENGINE_FAILED and not req.cancelled
                and self._emitted == 0 and self._retries_left > 0):
            # backend died before the stream produced anything: re-route
            # transparently on a fresh internal request
            self._retries_left -= 1
            self._gw.stats.stream_retries += 1
            retry = self._gw._make_internal(self.request, self)
            retry.retries = req.retries + 1
            self.internal = retry
            if self._gw.c.frontend.submit(retry):
                return          # re-routed; stream continues seamlessly
            if not retry._finish_fired and retry.finished_at is None:
                # defensive: frontend always finishes on failure
                retry.finish(error=req.error, code=req.error_code)
            return              # retry's own on_finish finalized us
        self._finalize(req)

    def _finalize(self, req: Request):
        self._done = True
        self._response = resp = response_from_internal(req)
        if self._admitted:
            self._gw._release(self.request.model)
            self._admitted = False
            self._gw.stats.completed += 1   # settled admitted requests
                                            # only, not rejections
        if resp.error is not None:
            self._events.append(StreamEvent(StreamEventType.ERROR,
                                            response=resp,
                                            error=resp.error))
        else:
            self._events.append(StreamEvent(StreamEventType.FINISH,
                                            response=resp))

    def _reject(self, error: APIError):
        """Admission rejection: finish immediately, never routed."""
        req = self.internal
        req.finish(error=error.message, code=error.code.value)

    # ------------------------------------------------------------- #
    def stream(self) -> Iterator[StreamEvent]:
        """Yield `StreamEvent`s incrementally, pumping the fleet between
        deltas.  Always ends with exactly one terminal FINISH/ERROR."""
        pumps = 0
        while True:
            while self._events:
                ev = self._events.popleft()
                yield ev
                if ev.terminal:
                    return
            if self._done:
                return
            if pumps >= self._gw.cfg.max_pump_steps:
                self._timeout()
                continue
            self._gw._pump()
            pumps += 1

    def result(self) -> GenerationResponse:
        """Block (pump the fleet) until this request completes."""
        pumps = 0
        while not self._done:
            if pumps >= self._gw.cfg.max_pump_steps:
                self._timeout()
                break
            self._gw._pump()
            pumps += 1
        return self._response

    def cancel(self) -> bool:
        """Abort the request, freeing its engine slot.  Returns False if
        already finished."""
        if self._done:
            return False
        req = self.internal
        if req.node and req.replica:
            node = self._gw.c.fleet.nodes.get(req.node)
            if node is not None:
                node.cancel(int(req.replica), req.request_id)
        req.cancelled = True
        self._gw.stats.cancelled += 1
        if req.finished_at is None:
            req.finish(error="cancelled by client", code=CODE_CANCELLED)
        else:                       # finished while suppressed? finalize
            self._finalize(req)
        return True

    def _timeout(self):
        req = self.internal
        self._gw.stats.timeouts += 1
        if req.node and req.replica:
            node = self._gw.c.fleet.nodes.get(req.node)
            if node is not None:
                node.cancel(int(req.replica), req.request_id)
        if req.finished_at is None:
            req.finish(error="pump budget exhausted", code=CODE_TIMEOUT)
        elif not self._done:
            self._finalize(req)


class Gateway:
    """The single public entry point over `SDAIController` + frontend."""

    def __init__(self, controller: SDAIController,
                 cfg: Optional[GatewayConfig] = None):
        self.c = controller
        self.cfg = cfg if cfg is not None else GatewayConfig()
        self.stats = GatewayStats()
        self.admin = AdminAPI(controller, gateway=self)
        self._inflight: Dict[str, int] = {}
        self._draining: set = set()

    # ------------------------------------------------------------- #
    def models(self) -> List[str]:
        """Every model currently served behind the unified endpoint."""
        return self.c.replicas.models()

    def inflight(self, model: str) -> int:
        return self._inflight.get(model, 0)

    # ------------------------------------------------------------- #
    def _pump(self):
        self.c.fleet.pump()

    def _release(self, model: str):
        n = self._inflight.get(model, 0)
        if n > 0:
            self._inflight[model] = n - 1

    def _queue_depth(self, model: str) -> int:
        """Aggregate scheduler backlog across the model's live replicas."""
        depth = 0
        for info in self.c.replicas.for_model(model):
            node = self.c.fleet.nodes.get(info.key.node_id)
            if node is None or not node.alive:
                continue
            inst = node.instances.get(info.key.instance_id)
            if inst is not None and inst.engine is not None:
                depth += inst.engine.scheduler.depth
        return depth

    def _max_prompt_len(self, model: str) -> Optional[int]:
        """Largest prompt any live replica of `model` can hold — replica
        context minus the model's prefix (meta/vision) tokens, which
        occupy cache slots ahead of the prompt.  None when nothing serves
        the model (NO_BACKEND handles that case)."""
        lens = [info.max_len for info in self.c.replicas.for_model(model)]
        if not lens:
            return None
        prefix = 0
        if model in self.c.catalog:
            cfg = self.c.catalog.get(model)
            prefix = (getattr(cfg, "n_meta_tokens", 0)
                      + getattr(cfg, "n_prefix_tokens", 0))
        return max(lens) - prefix

    def _validation_error(self,
                          greq: GenerationRequest) -> Optional[APIError]:
        if not greq.prompt:
            return APIError(ErrorCode.INVALID_REQUEST,
                            "prompt must contain at least one token")
        if greq.sampling.max_tokens < 1:
            return APIError(ErrorCode.INVALID_REQUEST,
                            "sampling.max_tokens must be >= 1")
        ctx = self._max_prompt_len(greq.model)
        if ctx is not None and len(greq.prompt) > ctx:
            # a prompt no replica can ever hold is malformed input (400),
            # not a transient capacity problem (429): reject at submit
            # time, before it ever reaches a backend queue
            return APIError(
                ErrorCode.INVALID_REQUEST,
                f"prompt length {len(greq.prompt)} exceeds the maximum "
                f"context {ctx} of model {greq.model!r}")
        return None

    def _admission_error(self, model: str) -> Optional[APIError]:
        if model in self._draining:
            return APIError(ErrorCode.DRAINING,
                            f"model {model!r} is draining")
        lim = self.cfg.max_inflight_per_model
        if lim is not None and self._inflight.get(model, 0) >= lim:
            return APIError(
                ErrorCode.OVERLOADED,
                f"model {model!r} at max in-flight ({lim})")
        qlim = self.cfg.max_queue_depth_per_model
        if qlim is not None and self._queue_depth(model) >= qlim:
            return APIError(
                ErrorCode.OVERLOADED,
                f"model {model!r} backend queue depth >= {qlim}")
        return None

    def _make_internal(self, greq: GenerationRequest,
                       handle: GenerationHandle) -> Request:
        return Request(model=greq.model, prompt=list(greq.prompt),
                       sampling=greq.sampling,
                       on_token=handle._on_token,
                       on_finish=handle._on_finish)

    # ------------------------------------------------------------- #
    def submit(self, model: Union[str, GenerationRequest],
               prompt: Optional[Sequence[int]] = None,
               sampling: Optional[SamplingParams] = None
               ) -> GenerationHandle:
        """Route one request; returns immediately with an async handle.
        Admission-control rejections come back as an already-finished
        handle whose response carries `ErrorCode.OVERLOADED`/`DRAINING`."""
        if isinstance(model, GenerationRequest):
            greq = model
        else:
            greq = GenerationRequest(model=model, prompt=tuple(prompt),
                                     sampling=sampling or SamplingParams())
        handle = GenerationHandle(self, greq)
        handle.internal = self._make_internal(greq, handle)
        self.stats.submitted += 1
        err = self._validation_error(greq)
        if err is not None:
            handle._reject(err)
            return handle
        err = self._admission_error(greq.model)
        if err is not None:
            if err.code is ErrorCode.DRAINING:
                self.stats.rejected_draining += 1
            else:
                self.stats.rejected_overloaded += 1
            handle._reject(err)
            return handle
        handle._admitted = True
        self._inflight[greq.model] = self._inflight.get(greq.model, 0) + 1
        self.c.frontend.submit(handle.internal)
        return handle

    def generate(self, model: Union[str, GenerationRequest],
                 prompt: Optional[Sequence[int]] = None,
                 sampling: Optional[SamplingParams] = None
                 ) -> GenerationResponse:
        """Blocking generate: submit and drive the fleet to completion."""
        return self.submit(model, prompt, sampling).result()

    def generate_batch(self, requests: Sequence[GenerationRequest]
                       ) -> List[GenerationResponse]:
        """Submit a batch, then pump the whole fleet until every request
        settles — replicas decode concurrently (continuous batching
        across the fleet, not sequential per-request pumping)."""
        handles = [self.submit(r) for r in requests]
        pumps = 0
        while any(not h.done for h in handles):
            if pumps >= self.cfg.max_pump_steps:
                for h in handles:
                    if not h.done:
                        h._timeout()
                break
            self._pump()
            pumps += 1
        return [h.response for h in handles]

    def stream(self, model: Union[str, GenerationRequest],
               prompt: Optional[Sequence[int]] = None,
               sampling: Optional[SamplingParams] = None
               ) -> Iterator[StreamEvent]:
        """Convenience: submit + stream in one call."""
        return self.submit(model, prompt, sampling).stream()

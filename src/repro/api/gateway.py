"""Gateway API v1 — the unified serving facade.

One `Gateway` fronts the whole fleet (the paper's "single logical unit"):

* `generate()`        — blocking call, returns a frozen `GenerationResponse`
* `submit()`          — returns a `GenerationHandle` (async future) with
                        `.result()`, `.cancel()` and `.stream()` (a true
                        incremental token iterator driven by per-token
                        engine callbacks, surviving failover retries)
* `generate_batch()`  — submit many, block until all settle
* admission control   — per-model in-flight and backend queue-depth caps
                        return structured 429-style `OVERLOADED` rejections;
                        per-tenant token buckets return `RATE_LIMITED`
* `.admin`            — the typed control plane (`repro.api.admin.AdminAPI`)
* `start()`/`stop()`  — the continuous serving runtime: background pump
                        threads drive every node and a tick loop feeds
                        load into the SDAI controller, so `submit()` is
                        fire-and-forget and blocking calls wait on events

Without `start()` the fleet is hand-pumped exactly as before: handles
advance engines lazily via `Gateway._pump()` whenever a caller blocks.
Either way blocking calls honor a *wall-clock* deadline
(`GatewayConfig.default_timeout_s`, overridable per call) and surface
`ErrorCode.TIMEOUT` — never a spurious pump-count failure.  Tokens surface
in K-token quanta (`EngineConfig.decode_block`); `cancel()` takes effect at
the next dispatch boundary.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Deque, Dict, Iterator, List, Optional, Sequence, Union

from repro.api.admin import AdminAPI
from repro.api.runtime import RuntimeConfig, ServingRuntime
from repro.api.types import (APIError, ErrorCode, GenerationRequest,
                             GenerationResponse, StreamEvent,
                             StreamEventType, response_from_internal)
from repro.core.controller import SDAIController
from repro.core.events import REQUEST_MIGRATED
from repro.serving.request import (CODE_CANCELLED, CODE_ENGINE_FAILED,
                                   CODE_TIMEOUT, Request, RequestState)
from repro.serving.sampler import SamplingParams


@dataclasses.dataclass
class GatewayConfig:
    # admission control (None => unlimited, the seed behaviour)
    max_inflight_per_model: Optional[int] = None
    max_queue_depth_per_model: Optional[int] = None
    # liveness: wall-clock budget for blocking waits (result / stream /
    # generate_batch); per-call `timeout_s` overrides
    default_timeout_s: float = 60.0
    # transparent recovery budget for a request whose backend died:
    # before the first token the request is re-routed fresh; after it,
    # the emitted-token journal migrates to a surviving replica and the
    # stream resumes where it left off — tokens are never re-emitted.
    # Only when no healthy replica remains (or the budget is spent) does
    # the failure surface as a structured ERROR event.
    max_stream_retries: int = 2


@dataclasses.dataclass
class GatewayStats:
    submitted: int = 0
    completed: int = 0
    rejected_overloaded: int = 0
    rejected_draining: int = 0
    rejected_rate_limited: int = 0
    cancelled: int = 0
    stream_retries: int = 0    # pre-token re-routes (fresh request)
    migrations: int = 0        # mid-stream journal migrations
    timeouts: int = 0
    caller_pumps: int = 0      # hand-pump fallback iterations; stays 0
                               # while the runtime drives the fleet


class GenerationHandle:
    """Future for one in-flight generation.  Created by `Gateway.submit`;
    never constructed directly.  Thread-safe: pump threads append events
    and signal `_cv`; the owning caller blocks on it."""

    def __init__(self, gateway: "Gateway", request: GenerationRequest):
        self._gw = gateway
        self.request = request
        self.internal: Optional[Request] = None   # current routing attempt
        self._events: Deque[StreamEvent] = deque()
        self._cv = threading.Condition()
        self._emitted = 0          # tokens delivered to this handle
        self._retries_left = gateway.cfg.max_stream_retries
        self._admitted = False
        self._done = False
        self._response: Optional[GenerationResponse] = None

    # ------------------------------------------------------------- #
    @property
    def done(self) -> bool:
        return self._done

    @property
    def response(self) -> Optional[GenerationResponse]:
        return self._response

    # ---- wiring: callbacks installed on the internal request ------ #
    def _on_token(self, req: Request, tok: int):
        if req is not self.internal or self._done:
            return
        with self._cv:
            self._events.append(StreamEvent(StreamEventType.TOKEN,
                                            token=tok,
                                            index=self._emitted))
            self._emitted += 1
            self._cv.notify_all()

    def _on_finish(self, req: Request):
        if req is not self.internal or self._done:
            return
        with self._cv:                 # _on_token writes under _cv
            emitted = self._emitted
        if (req.error_code == CODE_ENGINE_FAILED and not req.cancelled
                and emitted > 0
                and len(req.output) >= req.sampling.max_tokens):
            # the journal is already complete: the backend died between
            # its last token and the finish bookkeeping — every token
            # was delivered, so this is a success, not a failure
            req.error, req.error_code = "", ""
            req.state = RequestState.FINISHED
            self._finalize(req)
            return
        if (req.error_code == CODE_ENGINE_FAILED and not req.cancelled
                and self._retries_left > 0):
            if emitted == 0:
                # backend died before the stream produced anything:
                # re-route transparently on a fresh internal request
                self._retries_left -= 1
                with self._gw._stats_lock:
                    self._gw.stats.stream_retries += 1
                retry = self._gw._make_internal(self.request, self)
                retry.retries = req.retries + 1
                self.internal = retry
                if self._gw.c.frontend.submit(retry):
                    return      # re-routed; stream continues seamlessly
                if not retry._finish_fired and retry.finished_at is None:
                    # defensive: frontend always finishes on failure
                    retry.finish(error=req.error, code=req.error_code)
                return          # retry's own on_finish finalized us
            if self._gw.c.frontend.healthy_replicas(req.model):
                # mid-stream migration: the emitted-token journal on the
                # SAME internal request is authoritative.  The surviving
                # engine re-admits it as prompt + output (through the
                # prefix cache, suffix-only prefill on a shared prefix)
                # with the remaining budget, and emits only *new* tokens
                # — the handle's stream resumes with no duplicated,
                # lost, or reordered tokens.  `reset_for_retry` floors
                # `wfq_charged` at the served tokens so the new
                # replica's WFQ clock bills only the remainder, and the
                # tenant token bucket (charged once at admission) is
                # never touched again.
                self._retries_left -= 1
                with self._gw._stats_lock:
                    self._gw.stats.migrations += 1
                src, err, code = req.node, req.error, req.error_code
                n_resumed = len(req.output)
                req.reset_for_retry()
                if self._gw.c.frontend.submit(req):
                    self._gw.c.bus.emit(
                        REQUEST_MIGRATED, request_id=req.request_id,
                        tenant=req.tenant, model=req.model,
                        from_node=src, to_node=req.node,
                        tokens_resumed=n_resumed)
                    return      # resumed; stream continues seamlessly
                if not req._finish_fired and req.finished_at is None:
                    # defensive: frontend always finishes on failure
                    req.finish(error=err, code=code)
                return          # the failure finish re-entered _on_finish
                                # and finalized us
        self._finalize(req)

    def _finalize(self, req: Request):
        with self._cv:
            if self._done:
                return
            self._response = resp = response_from_internal(req)
            if self._admitted:
                self._gw._release(self.request.model)
                self._admitted = False
                with self._gw._stats_lock:      # settled admitted
                    self._gw.stats.completed += 1   # requests only,
                                                    # not rejections
            if resp.error is not None:
                self._events.append(StreamEvent(StreamEventType.ERROR,
                                                response=resp,
                                                error=resp.error))
            else:
                self._events.append(StreamEvent(StreamEventType.FINISH,
                                                response=resp))
            # `_done` goes last: result()/stream() read it without the
            # lock, so everything they may touch afterwards (_response,
            # the terminal event) must already be in place
            self._done = True
            self._cv.notify_all()

    def _reject(self, error: APIError):
        """Admission rejection: finish immediately, never routed."""
        req = self.internal
        req.finish(error=error.message, code=error.code.value)

    # ------------------------------------------------------------- #
    def _deadline(self, timeout_s: Optional[float]) -> float:
        t = timeout_s if timeout_s is not None \
            else self._gw.cfg.default_timeout_s
        return time.monotonic() + t

    def _wait_for_progress(self, deadline: float):
        """Block until an event may be available.  Runtime mode: wait on
        the handle condition (pump threads signal it).  Hand-pump mode:
        advance the fleet one iteration."""
        if self._gw.runtime_active:
            with self._cv:
                if self._events or self._done:
                    return
                self._cv.wait(min(0.05,
                                  max(1e-4, deadline - time.monotonic())))
        else:
            self._gw._pump()

    def stream(self, timeout_s: Optional[float] = None
               ) -> Iterator[StreamEvent]:
        """Yield `StreamEvent`s incrementally; blocks between deltas (on
        pump-thread signals with the runtime started, hand-pumping
        otherwise).  Always ends with exactly one terminal FINISH/ERROR.
        The wall-clock deadline spans the whole stream; on expiry the
        request finishes with `ErrorCode.TIMEOUT`."""
        deadline = self._deadline(timeout_s)
        while True:
            while True:
                with self._cv:
                    if not self._events:
                        break
                    ev = self._events.popleft()
                yield ev
                if ev.terminal:
                    return
            if self._done:
                return
            if time.monotonic() >= deadline:
                self._timeout()
                continue
            self._wait_for_progress(deadline)

    def result(self, timeout_s: Optional[float] = None
               ) -> GenerationResponse:
        """Block until this request completes (or the wall-clock deadline
        expires -> `ErrorCode.TIMEOUT`)."""
        deadline = self._deadline(timeout_s)
        while not self._done:
            if time.monotonic() >= deadline:
                self._timeout()
                break
            self._wait_for_progress(deadline)
        return self._response

    def _cancel_backend(self, req: Request):
        """Abort `req` on its backend.  When it was still *queued* (not
        occupying a slot), refund the tenant token-bucket charge for the
        tokens it will now never generate — the bucket was debited the
        full `max_tokens` at submit.  Tokens already generated (a
        preempted-then-requeued request carries its output) stay
        charged: that engine work was consumed and delivered."""
        if not (req.node and req.replica):
            return
        node = self._gw.c.fleet.nodes.get(req.node)
        if node is None:
            return
        verdict = node.cancel(int(req.replica), req.request_id)
        if verdict == "queued":
            unserved = req.sampling.max_tokens - len(req.output)
            if unserved > 0:
                self._gw.c.frontend.tenants.refund(req.tenant, unserved)

    def cancel(self) -> bool:
        """Abort the request, freeing its engine slot and pages.  Returns
        False if already finished.  Cancelling a request that was still
        queued refunds the unconsumed part of its tenant token-bucket
        charge."""
        if self._done:
            return False
        req = self.internal
        self._cancel_backend(req)
        req.cancelled = True
        with self._gw._stats_lock:
            self._gw.stats.cancelled += 1
        if req.finished_at is None:
            req.finish(error="cancelled by client", code=CODE_CANCELLED)
        else:                       # finished while suppressed? finalize
            self._finalize(req)
        return True

    def _timeout(self):
        req = self.internal
        if self._done:
            return
        with self._gw._stats_lock:
            self._gw.stats.timeouts += 1
        # same refund semantics as cancel(): a request that timed out
        # while still queued never consumed the capacity it was charged
        self._cancel_backend(req)
        if req.finished_at is None:
            req.finish(error="wall-clock deadline exceeded",
                       code=CODE_TIMEOUT)
        elif not self._done:
            self._finalize(req)


class Gateway:
    """The single public entry point over `SDAIController` + frontend."""

    def __init__(self, controller: SDAIController,
                 cfg: Optional[GatewayConfig] = None):
        self.c = controller
        self.cfg = cfg if cfg is not None else GatewayConfig()
        self.stats = GatewayStats()
        self.admin = AdminAPI(controller, gateway=self)
        self.runtime: Optional[ServingRuntime] = None
        self._inflight: Dict[str, int] = {}
        self._inflight_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self._draining: set = set()

    # ---- continuous runtime lifecycle ----------------------------- #
    @property
    def runtime_active(self) -> bool:
        return self.runtime is not None and self.runtime.running

    def start(self, cfg: Optional[RuntimeConfig] = None) -> ServingRuntime:
        """Start the continuous serving runtime: one pump thread per
        node plus the controller tick loop.  Idempotent."""
        if self.runtime_active:
            return self.runtime
        self.runtime = ServingRuntime(self, cfg)
        return self.runtime.start()

    def stop(self, drain: bool = True,
             timeout_s: Optional[float] = None) -> bool:
        """Stop the runtime, draining in-flight work by default.
        Returns True when every runtime thread joined."""
        if self.runtime is None:
            return True
        return self.runtime.stop(drain=drain, timeout_s=timeout_s)

    # ------------------------------------------------------------- #
    def models(self) -> List[str]:
        """Every model currently served behind the unified endpoint."""
        return self.c.replicas.models()

    def inflight(self, model: str) -> int:
        with self._inflight_lock:
            return self._inflight.get(model, 0)

    # ------------------------------------------------------------- #
    def _pump(self):
        """Hand-pump fallback (runtime not started): advance the whole
        fleet one iteration from the calling thread."""
        with self._stats_lock:
            self.stats.caller_pumps += 1
        self.c.fleet.pump()

    def _release(self, model: str):
        with self._inflight_lock:
            n = self._inflight.get(model, 0)
            if n > 0:
                self._inflight[model] = n - 1

    def _queue_depth(self, model: str) -> int:
        """Aggregate scheduler backlog across the model's live replicas."""
        depth = 0
        for info in self.c.replicas.for_model(model):
            node = self.c.fleet.nodes.get(info.key.node_id)
            if node is None or not node.alive:
                continue
            inst = node.instances.get(info.key.instance_id)
            if inst is not None and inst.engine is not None:
                depth += inst.engine.scheduler.depth
        return depth

    def _max_prompt_len(self, model: str) -> Optional[int]:
        """Largest prompt any live replica of `model` can hold — replica
        context minus the model's prefix (meta/vision) tokens, which
        occupy cache slots ahead of the prompt.  None when nothing serves
        the model (NO_BACKEND handles that case)."""
        lens = [info.max_len for info in self.c.replicas.for_model(model)]
        if not lens:
            return None
        prefix = 0
        if model in self.c.catalog:
            cfg = self.c.catalog.get(model)
            prefix = (getattr(cfg, "n_meta_tokens", 0)
                      + getattr(cfg, "n_prefix_tokens", 0))
        return max(lens) - prefix

    def _validation_error(self,
                          greq: GenerationRequest) -> Optional[APIError]:
        if not greq.prompt:
            return APIError(ErrorCode.INVALID_REQUEST,
                            "prompt must contain at least one token")
        if greq.sampling.max_tokens < 1:
            return APIError(ErrorCode.INVALID_REQUEST,
                            "sampling.max_tokens must be >= 1")
        ctx = self._max_prompt_len(greq.model)
        if ctx is not None and len(greq.prompt) > ctx:
            # a prompt no replica can ever hold is malformed input (400),
            # not a transient capacity problem (429): reject at submit
            # time, before it ever reaches a backend queue
            return APIError(
                ErrorCode.INVALID_REQUEST,
                f"prompt length {len(greq.prompt)} exceeds the maximum "
                f"context {ctx} of model {greq.model!r}")
        return None

    def _try_admit(self, greq: GenerationRequest) -> Optional[APIError]:
        """Atomically run every admission gate and, on success, claim the
        in-flight slot.  Capacity checks come first so a fleet-rejected
        request never drains the tenant's token bucket; the bucket charge
        is last because it is the one check with a side effect."""
        model = greq.model
        with self._inflight_lock:
            if model in self._draining:
                return APIError(ErrorCode.DRAINING,
                                f"model {model!r} is draining")
            lim = self.cfg.max_inflight_per_model
            if lim is not None and self._inflight.get(model, 0) >= lim:
                return APIError(
                    ErrorCode.OVERLOADED,
                    f"model {model!r} at max in-flight ({lim})")
            qlim = self.cfg.max_queue_depth_per_model
            if qlim is not None and self._queue_depth(model) >= qlim:
                return APIError(
                    ErrorCode.OVERLOADED,
                    f"model {model!r} backend queue depth >= {qlim}")
            # per-tenant token buckets (frontend-owned, AdminAPI-config)
            reason = self.c.frontend.tenants.admit(
                greq.tenant, greq.sampling.max_tokens)
            if reason is not None:
                return APIError(ErrorCode.RATE_LIMITED, reason)
            self._inflight[model] = self._inflight.get(model, 0) + 1
            return None

    def _make_internal(self, greq: GenerationRequest,
                       handle: GenerationHandle) -> Request:
        return Request(model=greq.model, prompt=list(greq.prompt),
                       sampling=greq.sampling, tenant=greq.tenant,
                       on_token=handle._on_token,
                       on_finish=handle._on_finish)

    # ------------------------------------------------------------- #
    def submit(self, model: Union[str, GenerationRequest],
               prompt: Optional[Sequence[int]] = None,
               sampling: Optional[SamplingParams] = None,
               tenant: str = "") -> GenerationHandle:
        """Route one request; returns immediately with an async handle.
        Admission-control rejections come back as an already-finished
        handle whose response carries `ErrorCode.OVERLOADED`/`DRAINING`/
        `RATE_LIMITED`."""
        if isinstance(model, GenerationRequest):
            greq = model
        else:
            greq = GenerationRequest(model=model, prompt=tuple(prompt),
                                     sampling=sampling or SamplingParams(),
                                     tenant=tenant)
        handle = GenerationHandle(self, greq)
        handle.internal = self._make_internal(greq, handle)
        with self._stats_lock:
            self.stats.submitted += 1
        err = self._validation_error(greq)
        if err is not None:
            handle._reject(err)
            return handle
        err = self._try_admit(greq)    # claims the in-flight slot on None
        if err is not None:
            with self._stats_lock:
                if err.code is ErrorCode.DRAINING:
                    self.stats.rejected_draining += 1
                elif err.code is ErrorCode.RATE_LIMITED:
                    self.stats.rejected_rate_limited += 1
                else:
                    self.stats.rejected_overloaded += 1
            handle._reject(err)
            return handle
        handle._admitted = True
        self.c.frontend.submit(handle.internal)
        return handle

    def generate(self, model: Union[str, GenerationRequest],
                 prompt: Optional[Sequence[int]] = None,
                 sampling: Optional[SamplingParams] = None,
                 tenant: str = "",
                 timeout_s: Optional[float] = None) -> GenerationResponse:
        """Blocking generate: submit and wait for completion (pump
        threads drive the fleet when the runtime is started; otherwise
        this call hand-pumps)."""
        return self.submit(model, prompt, sampling,
                           tenant=tenant).result(timeout_s)

    def generate_batch(self, requests: Sequence[GenerationRequest],
                       timeout_s: Optional[float] = None
                       ) -> List[GenerationResponse]:
        """Submit a batch, then block until every request settles —
        replicas decode concurrently (continuous batching across the
        fleet).  One wall-clock deadline covers the whole batch."""
        handles = [self.submit(r) for r in requests]
        deadline = time.monotonic() + (
            timeout_s if timeout_s is not None
            else self.cfg.default_timeout_s)
        for h in handles:
            while not h.done:
                if time.monotonic() >= deadline:
                    for lh in handles:
                        if not lh.done:
                            lh._timeout()
                    break
                h._wait_for_progress(deadline)
        return [h.response for h in handles]

    def stream(self, model: Union[str, GenerationRequest],
               prompt: Optional[Sequence[int]] = None,
               sampling: Optional[SamplingParams] = None,
               tenant: str = "",
               timeout_s: Optional[float] = None) -> Iterator[StreamEvent]:
        """Convenience: submit + stream in one call."""
        return self.submit(model, prompt, sampling,
                           tenant=tenant).stream(timeout_s)

"""Health monitoring: heartbeats, liveness deadlines, straggler detection.

HAProxy-style checks adapted to the controller loop: a node missing
`suspect_after` seconds of heartbeats is SUSPECT (demoted in routing);
missing `dead_after` it is DEAD (instances re-placed).  Per-replica EWMA
latency feeds straggler demotion in the frontend's weighted routing.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Callable, Dict, Optional


class NodeHealth(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"


@dataclasses.dataclass
class HealthConfig:
    suspect_after: float = 2.0
    dead_after: float = 5.0
    straggler_factor: float = 3.0     # x median latency => straggler
    straggler_floor: float = 0.010    # ignore sub-10ms jitter


class HealthMonitor:
    """Two-level liveness: *marks* (authoritative, set by the controller
    when it confirms a death or recovery — what routing consults) and
    *heartbeat ages* (how the controller's tick loop detects silent
    failures in the first place)."""

    def __init__(self, cfg: Optional[HealthConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg if cfg is not None else HealthConfig()
        self.clock = clock
        self.last_seen: Dict[str, float] = {}
        self.latency_ewma: Dict[str, float] = {}
        self.dead_marks: set = set()
        # watchdog/operator demotions: the node still heartbeats, but a
        # pump step blew its deadline — weighted routing penalizes it
        # until the stall clears
        self.suspect_marks: set = set()

    def observe_heartbeat(self, node_id: str,
                          ts: Optional[float] = None):
        self.last_seen[node_id] = self.clock() if ts is None else ts

    def observe_latency(self, replica_key: str, seconds: float):
        prev = self.latency_ewma.get(replica_key)
        self.latency_ewma[replica_key] = seconds if prev is None \
            else 0.8 * prev + 0.2 * seconds

    def mark_dead(self, node_id: str):
        self.dead_marks.add(node_id)

    def clear_mark(self, node_id: str):
        self.dead_marks.discard(node_id)

    def mark_suspect(self, node_id: str):
        self.suspect_marks.add(node_id)

    def clear_suspect(self, node_id: str):
        self.suspect_marks.discard(node_id)

    def status(self, node_id: str) -> NodeHealth:
        """Routing-facing status: marks are authoritative; ages demote."""
        if node_id in self.dead_marks:
            return NodeHealth.DEAD
        seen = self.last_seen.get(node_id)
        if seen is None:
            return NodeHealth.DEAD
        if node_id in self.suspect_marks:
            return NodeHealth.SUSPECT
        if self.clock() - seen > self.cfg.suspect_after:
            return NodeHealth.SUSPECT
        return NodeHealth.HEALTHY

    def heartbeat_expired(self, node_id: str) -> bool:
        """Tick-loop detection: has this node missed its deadline?"""
        seen = self.last_seen.get(node_id)
        return seen is None or (self.clock() - seen > self.cfg.dead_after)

    def forget(self, node_id: str):
        self.last_seen.pop(node_id, None)
        self.dead_marks.discard(node_id)
        self.suspect_marks.discard(node_id)

    def is_straggler(self, replica_key: str) -> bool:
        lat = self.latency_ewma.get(replica_key)
        if lat is None or len(self.latency_ewma) < 3:
            return False      # need a quorum to call anyone slow
        vals = sorted(self.latency_ewma.values())
        median = vals[(len(vals) - 1) // 2]
        return lat > self.cfg.straggler_floor and median > 0 and \
            lat > self.cfg.straggler_factor * median

"""Client Interface — the OpenWebUI analogue: one logical endpoint for every
deployed model; the user never sees nodes, replicas, or routing."""
from __future__ import annotations

from typing import List, Optional

from repro.core.controller import SDAIController
from repro.serving.request import Request
from repro.serving.sampler import SamplingParams


class Client:
    def __init__(self, controller: SDAIController):
        self.c = controller

    def models(self) -> List[str]:
        """Every model currently served (across all nodes)."""
        return self.c.replicas.models()

    def submit(self, model: str, prompt: List[int],
               sampling: Optional[SamplingParams] = None) -> Request:
        req = Request(model=model, prompt=prompt,
                      sampling=sampling or SamplingParams())
        self.c.frontend.submit(req)
        return req

    def generate(self, model: str, prompt: List[int],
                 sampling: Optional[SamplingParams] = None,
                 max_pump_steps: int = 10_000) -> Request:
        """Submit and drive the fleet until the request completes."""
        req = self.submit(model, prompt, sampling)
        steps = 0
        while req.finished_at is None and steps < max_pump_steps:
            self.c.fleet.pump()
            steps += 1
        return req

"""Client Interface — back-compat shim over the Gateway API v1.

Historically the OpenWebUI analogue: one logical endpoint for every
deployed model.  New code should use `repro.api.Gateway` directly — it
adds streaming, async handles, admission control, and frozen response
types.  `Client` survives as a thin adapter that routes through a
`Gateway` but keeps returning the internal mutable `Request` objects the
seed API exposed.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.controller import SDAIController
from repro.serving.request import Request
from repro.serving.sampler import SamplingParams


class Client:
    def __init__(self, controller: SDAIController):
        # imported lazily: repro.api builds on repro.core, and this shim
        # is the one place the dependency points back up
        from repro.api.gateway import Gateway, GatewayConfig
        self.c = controller
        # stream retries swap the handle's internal Request; this shim
        # hands the internal Request to callers, so hidden re-routing
        # would leave them polling a stale object — keep seed semantics
        self.gateway = Gateway(controller,
                               GatewayConfig(max_stream_retries=0))

    def models(self) -> List[str]:
        """Every model currently served (across all nodes)."""
        return self.gateway.models()

    def submit(self, model: str, prompt: List[int],
               sampling: Optional[SamplingParams] = None) -> Request:
        handle = self.gateway.submit(model, prompt, sampling)
        return handle.internal

    def generate(self, model: str, prompt: List[int],
                 sampling: Optional[SamplingParams] = None,
                 max_pump_steps: int = 10_000) -> Request:
        """Submit and drive the fleet until the request completes."""
        handle = self.gateway.submit(model, prompt, sampling)
        steps = 0
        while not handle.done and steps < max_pump_steps:
            self.c.fleet.pump()
            steps += 1
        return handle.internal

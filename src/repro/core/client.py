"""Client Interface — DEPRECATED back-compat shim over Gateway API v1.

Historically the OpenWebUI analogue: one logical endpoint for every
deployed model.  In-process callers should use `repro.api.Gateway`
(streaming, async handles, admission control, frozen response types);
network callers should use `repro.api.http.HTTPClient` against a
`GatewayHTTPServer`.  `Client` survives one more cycle as a thin adapter
that routes through a `Gateway` but keeps returning the internal mutable
`Request` objects the seed API exposed; constructing one emits a
`DeprecationWarning`.
"""
from __future__ import annotations

import warnings
from typing import List, Optional

from repro.core.controller import SDAIController
from repro.serving.request import Request
from repro.serving.sampler import SamplingParams


class Client:
    def __init__(self, controller: SDAIController):
        warnings.warn(
            "repro.core.Client is deprecated: use repro.api.Gateway "
            "in-process or repro.api.http.HTTPClient over the wire",
            DeprecationWarning, stacklevel=2)
        # imported lazily: repro.api builds on repro.core, and this shim
        # is the one place the dependency points back up
        from repro.api.gateway import Gateway, GatewayConfig
        self.c = controller
        # stream retries swap the handle's internal Request; this shim
        # hands the internal Request to callers, so hidden re-routing
        # would leave them polling a stale object — keep seed semantics
        self.gateway = Gateway(controller,
                               GatewayConfig(max_stream_retries=0))

    def models(self) -> List[str]:
        """Every model currently served (across all nodes)."""
        return self.gateway.models()

    def submit(self, model: str, prompt: List[int],
               sampling: Optional[SamplingParams] = None) -> Request:
        handle = self.gateway.submit(model, prompt, sampling)
        return handle.internal

    def generate(self, model: str, prompt: List[int],
                 sampling: Optional[SamplingParams] = None,
                 max_pump_steps: int = 10_000) -> Request:
        """Submit and drive the fleet until the request completes."""
        handle = self.gateway.submit(model, prompt, sampling)
        steps = 0
        while not handle.done and steps < max_pump_steps:
            self.c.fleet.pump()
            steps += 1
        return handle.internal

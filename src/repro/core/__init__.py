"""The paper's primary contribution: the Software-Defined AI (SDAI) control
plane — controller, VRAM-aware placement, HAProxy-style frontend, health
monitoring, configuration wizard, unified client."""
from repro.core.controller import (SDAIController, ControllerConfig,
                                   AutoscaleConfig, ModelLoad)
from repro.core.placement import (ModelDemand, Assignment, PlacementPlan,
                                  place, place_naive, reallocation_plan,
                                  plan_utilization)
from repro.core.frontend import (ServiceFrontend, FrontendConfig,
                                 TenantLimiter, TenantQuota, TenantUsage)
from repro.core.health import HealthMonitor, HealthConfig, NodeHealth
from repro.core.registry import (ModelCatalog, NodeRegistry,
                                 ReplicaRegistry, ReplicaKey, ReplicaInfo)
from repro.core.wizard import (ConfigWizard, WizardConfig, WizardSelection,
                               WizardModelChoice)
from repro.core.client import Client
from repro.core.events import EventBus, Event

__all__ = ["SDAIController", "ControllerConfig", "AutoscaleConfig",
           "ModelLoad", "ModelDemand",
           "Assignment", "PlacementPlan", "place", "place_naive",
           "reallocation_plan", "plan_utilization", "ServiceFrontend",
           "FrontendConfig", "TenantLimiter", "TenantQuota", "TenantUsage",
           "HealthMonitor", "HealthConfig", "NodeHealth",
           "ModelCatalog", "NodeRegistry", "ReplicaRegistry", "ReplicaKey",
           "ReplicaInfo", "ConfigWizard", "WizardConfig", "WizardSelection",
           "WizardModelChoice", "Client", "EventBus", "Event"]

"""The paper's primary contribution: the Software-Defined AI (SDAI) control
plane — controller, VRAM-aware placement, HAProxy-style frontend, health
monitoring, configuration wizard, unified client."""
from repro.core.client import Client
from repro.core.controller import (AutoscaleConfig, ControllerConfig,
                                   ModelLoad, SDAIController)
from repro.core.events import Event, EventBus
from repro.core.frontend import (FrontendConfig, ServiceFrontend,
                                 TenantLimiter, TenantQuota, TenantUsage)
from repro.core.health import HealthConfig, HealthMonitor, NodeHealth
from repro.core.placement import (Assignment, ModelDemand, PlacementPlan,
                                  place, place_naive, plan_utilization,
                                  reallocation_plan)
from repro.core.registry import (ModelCatalog, NodeRegistry, ReplicaInfo,
                                 ReplicaKey, ReplicaRegistry)
from repro.core.wizard import (ConfigWizard, WizardConfig, WizardModelChoice,
                               WizardSelection)

__all__ = ["SDAIController", "ControllerConfig", "AutoscaleConfig",
           "ModelLoad", "ModelDemand",
           "Assignment", "PlacementPlan", "place", "place_naive",
           "reallocation_plan", "plan_utilization", "ServiceFrontend",
           "FrontendConfig", "TenantLimiter", "TenantQuota", "TenantUsage",
           "HealthMonitor", "HealthConfig", "NodeHealth",
           "ModelCatalog", "NodeRegistry", "ReplicaRegistry", "ReplicaKey",
           "ReplicaInfo", "ConfigWizard", "WizardConfig", "WizardSelection",
           "WizardModelChoice", "Client", "EventBus", "Event"]

"""VRAM-aware model placement — the SDAI controller's core algorithm.

The paper's objective (§1, §3): *fully exploit the VRAM capacity of each
node*, across a heterogeneous fleet, while spreading replicas for high
availability.  We implement it as best-fit-decreasing bin packing with:

  * replica anti-affinity (replicas of a model prefer distinct nodes —
    the paper's resilience-by-rerouting story needs them apart),
  * per-node precision selection (bf16 where it fits; int8/int4 fallback on
    small/legacy nodes — the Ollama-GGUF-quant analogue),
  * a fill phase that packs *extra* replicas into leftover VRAM until no
    instance fits (maximizing utilization and throughput),
  * reallocation planning for node failures / joins (dynamic reallocation,
    §3 "dynamically reallocating workloads as necessary").

`place_naive` is the paper-comparison baseline: first-fit, no sorting, no
quantization fallback, no anti-affinity.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig
from repro.cluster.node import instance_bytes

PRECISIONS = ["", "int8", "int4"]          # descending fidelity


@dataclasses.dataclass(frozen=True)
class ModelDemand:
    cfg: ArchConfig
    min_replicas: int = 1
    max_replicas: int = 0                   # 0 => min_replicas + 2
    n_slots: int = 4
    max_len: int = 2048
    allow_quant: bool = True
    weight: float = 1.0                     # expected traffic share
    # paged KV pool sizing: placement charges the *page budget*, not the
    # worst-case n_slots x max_len strips.  kv_page_frac < 1 oversubscribes
    # slots against pages (engines preempt on exhaustion) — the VRAM win.
    page_size: int = 16
    kv_page_frac: float = 1.0

    @property
    def replica_cap(self) -> int:
        return self.max_replicas or (self.min_replicas + 2)

    @property
    def kv_pages(self) -> int:
        """Per-replica page budget: `kv_page_frac` of the contiguous-
        equivalent pool, floored at one full sequence."""
        per_slot = -(-self.max_len // self.page_size)
        full = self.n_slots * per_slot
        return max(int(full * self.kv_page_frac), per_slot)

    def bytes_at(self, quantize: str) -> int:
        return instance_bytes(self.cfg, quantize, self.n_slots,
                              self.max_len, self.page_size, self.kv_pages)


@dataclasses.dataclass(frozen=True)
class Assignment:
    node_id: str
    model_name: str
    quantize: str
    n_slots: int
    max_len: int
    bytes: int
    page_size: int = 16
    kv_pages: int = 0          # 0 => contiguous-equivalent budget


@dataclasses.dataclass
class PlacementPlan:
    assignments: List[Assignment] = dataclasses.field(default_factory=list)
    unplaced: List[str] = dataclasses.field(default_factory=list)

    def by_node(self) -> Dict[str, List[Assignment]]:
        out: Dict[str, List[Assignment]] = {}
        for a in self.assignments:
            out.setdefault(a.node_id, []).append(a)
        return out

    def replicas(self, model_name: str) -> List[Assignment]:
        return [a for a in self.assignments if a.model_name == model_name]


@dataclasses.dataclass
class _Bin:
    node_id: str
    free: int
    legacy: bool
    hosted: Dict[str, int] = dataclasses.field(default_factory=dict)


def _mk_bins(nodes: Dict[str, Tuple[int, bool]]) -> List[_Bin]:
    return [_Bin(nid, free, legacy) for nid, (free, legacy)
            in nodes.items()]


def _best_node(bins: List[_Bin], demand: ModelDemand) -> \
        Optional[Tuple[_Bin, str]]:
    """Pick (node, precision): prefer anti-affinity, then highest
    precision, then tightest fit (best-fit => maximal utilization)."""
    precisions = PRECISIONS if demand.allow_quant else [""]
    best = None
    best_key = None
    for b in bins:
        for p_idx, prec in enumerate(precisions):
            need = demand.bytes_at(prec)
            if need > b.free:
                continue
            affinity = b.hosted.get(demand.cfg.name, 0)
            leftover = b.free - need
            key = (affinity, p_idx, leftover)
            if best_key is None or key < best_key:
                best, best_key = (b, prec), key
            break          # higher precision fits on this node; stop
    return best


def place(nodes: Dict[str, Tuple[int, bool]],
          demands: Sequence[ModelDemand],
          fill: bool = True) -> PlacementPlan:
    """nodes: node_id -> (free_bytes, is_legacy)."""
    bins = _mk_bins(nodes)
    plan = PlacementPlan()

    def commit(b: _Bin, d: ModelDemand, prec: str):
        need = d.bytes_at(prec)
        b.free -= need
        b.hosted[d.cfg.name] = b.hosted.get(d.cfg.name, 0) + 1
        plan.assignments.append(Assignment(
            b.node_id, d.cfg.name, prec, d.n_slots, d.max_len, need,
            page_size=d.page_size, kv_pages=d.kv_pages))

    # phase 1: min replicas, biggest models first (FFD)
    order = sorted(demands, key=lambda d: -d.bytes_at(""))
    for d in order:
        for _ in range(d.min_replicas):
            pick = _best_node(bins, d)
            if pick is None:
                plan.unplaced.append(d.cfg.name)
                continue
            commit(*[pick[0], d, pick[1]])

    # phase 2: fill leftover VRAM with extra replicas (bounded by each
    # demand's replica_cap), most under-provisioned-per-traffic first
    if fill and demands:
        counts = {d.cfg.name: len(plan.replicas(d.cfg.name))
                  for d in demands}
        exhausted: set = set()
        progress = True
        while progress:
            live = [d for d in demands
                    if d.cfg.name not in plan.unplaced
                    and d.cfg.name not in exhausted
                    and counts[d.cfg.name] < d.replica_cap]
            if not live:
                break
            progress = False
            live.sort(key=lambda d: counts[d.cfg.name] / d.weight)
            for d in live:
                pick = _best_node(bins, d)
                if pick is not None:
                    commit(pick[0], d, pick[1])
                    counts[d.cfg.name] += 1
                    progress = True
                    break
                exhausted.add(d.cfg.name)   # nothing fits anywhere
    return plan


def place_naive(nodes: Dict[str, Tuple[int, bool]],
                demands: Sequence[ModelDemand]) -> PlacementPlan:
    """Baseline: first-fit in arrival order, bf16 only, no fill phase."""
    bins = _mk_bins(nodes)
    plan = PlacementPlan()
    for d in demands:
        for _ in range(d.min_replicas):
            placed = False
            for b in bins:
                need = d.bytes_at("")
                if need <= b.free:
                    b.free -= need
                    plan.assignments.append(Assignment(
                        b.node_id, d.cfg.name, "", d.n_slots, d.max_len,
                        need))
                    placed = True
                    break
            if not placed:
                plan.unplaced.append(d.cfg.name)
    return plan


def reallocation_plan(nodes: Dict[str, Tuple[int, bool]],
                      lost: Sequence[ModelDemand]) -> PlacementPlan:
    """Re-place instances lost to a failure on the surviving fleet
    (min_replicas of each lost demand; no fill — keep headroom for the
    next failure)."""
    return place(nodes, lost, fill=False)


def plan_utilization(plan: PlacementPlan,
                     nodes: Dict[str, Tuple[int, bool]]) -> float:
    """Fraction of fleet VRAM used by the plan (the paper's efficiency
    objective)."""
    used = sum(a.bytes for a in plan.assignments)
    total = sum(free for free, _ in nodes.values())
    return used / total if total else 0.0

"""VRAM-aware model placement — the SDAI controller's core algorithm.

The paper's objective (§1, §3): *fully exploit the VRAM capacity of each
node*, across a heterogeneous fleet, while spreading replicas for high
availability.  We implement it as best-fit-decreasing bin packing with:

  * replica anti-affinity (replicas of a model prefer distinct nodes —
    the paper's resilience-by-rerouting story needs them apart),
  * per-node precision selection (bf16 where it fits; int8/int4 fallback on
    small/legacy nodes — the Ollama-GGUF-quant analogue),
  * a fill phase that packs *extra* replicas into leftover VRAM until no
    instance fits (maximizing utilization and throughput),
  * reallocation planning for node failures / joins (dynamic reallocation,
    §3 "dynamically reallocating workloads as necessary").

`place_naive` is the paper-comparison baseline: first-fit, no sorting, no
quantization fallback, no anti-affinity.

`place_cost_optimal` is the heterogeneity-aware solver: same bin-packing
skeleton, but candidate nodes are ranked by modeled cost-per-token from
`core.perfmodel` (class cost weight / class tokens/s, prorated by the
VRAM share the instance occupies), with an SLO phase that adds replicas
until each demand's `target_tokens_per_s` is met — the Mélange/AIBrix
shape: a throughput matrix times a cost vector, solved greedily.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.cluster.hardware import RUNTIME_RESERVE_FRACTION, NodeClass
from repro.cluster.node import instance_bytes
from repro.configs.base import ArchConfig
from repro.core.perfmodel import PerfModel

PRECISIONS = ["", "int8", "int4"]          # descending fidelity


@dataclasses.dataclass(frozen=True)
class ModelDemand:
    cfg: ArchConfig
    min_replicas: int = 1
    max_replicas: int = 0                   # 0 => min_replicas + 2
    n_slots: int = 4
    max_len: int = 2048
    allow_quant: bool = True
    weight: float = 1.0                     # expected traffic share
    # paged KV pool sizing: placement charges the *page budget*, not the
    # worst-case n_slots x max_len strips.  kv_page_frac < 1 oversubscribes
    # slots against pages (engines preempt on exhaustion) — the VRAM win.
    page_size: int = 16
    kv_page_frac: float = 1.0
    # heterogeneity-aware extensions (consumed by `place_cost_optimal`):
    # aggregate output-tokens/s the replica set must sustain (0 = no SLO,
    # min_replicas only), and the expected request-size bucket mix
    # (frozen-safe tuple of (bucket_name, fraction); () = DEFAULT_MIX).
    target_tokens_per_s: float = 0.0
    bucket_mix: Tuple[Tuple[str, float], ...] = ()

    @property
    def replica_cap(self) -> int:
        return self.max_replicas or (self.min_replicas + 2)

    @property
    def kv_pages(self) -> int:
        """Per-replica page budget: `kv_page_frac` of the contiguous-
        equivalent pool, floored at one full sequence."""
        per_slot = -(-self.max_len // self.page_size)
        full = self.n_slots * per_slot
        return max(int(full * self.kv_page_frac), per_slot)

    def bytes_at(self, quantize: str) -> int:
        return instance_bytes(self.cfg, quantize, self.n_slots,
                              self.max_len, self.page_size, self.kv_pages)


@dataclasses.dataclass(frozen=True)
class Assignment:
    node_id: str
    model_name: str
    quantize: str
    n_slots: int
    max_len: int
    bytes: int
    page_size: int = 16
    kv_pages: int = 0          # 0 => contiguous-equivalent budget


@dataclasses.dataclass
class PlacementPlan:
    assignments: List[Assignment] = dataclasses.field(default_factory=list)
    unplaced: List[str] = dataclasses.field(default_factory=list)

    def by_node(self) -> Dict[str, List[Assignment]]:
        out: Dict[str, List[Assignment]] = {}
        for a in self.assignments:
            out.setdefault(a.node_id, []).append(a)
        return out

    def replicas(self, model_name: str) -> List[Assignment]:
        return [a for a in self.assignments if a.model_name == model_name]


@dataclasses.dataclass
class _Bin:
    node_id: str
    free: int
    legacy: bool
    hosted: Dict[str, int] = dataclasses.field(default_factory=dict)


def _mk_bins(nodes: Dict[str, Tuple[int, bool]]) -> List[_Bin]:
    return [_Bin(nid, free, legacy) for nid, (free, legacy)
            in nodes.items()]


def _best_node(bins: List[_Bin], demand: ModelDemand) -> \
        Optional[Tuple[_Bin, str]]:
    """Pick (node, precision): prefer anti-affinity, then highest
    precision, then tightest fit (best-fit => maximal utilization)."""
    precisions = PRECISIONS if demand.allow_quant else [""]
    best = None
    best_key = None
    for b in bins:
        for p_idx, prec in enumerate(precisions):
            need = demand.bytes_at(prec)
            if need > b.free:
                continue
            affinity = b.hosted.get(demand.cfg.name, 0)
            leftover = b.free - need
            key = (affinity, p_idx, leftover)
            if best_key is None or key < best_key:
                best, best_key = (b, prec), key
            break          # higher precision fits on this node; stop
    return best


def place(nodes: Dict[str, Tuple[int, bool]],
          demands: Sequence[ModelDemand],
          fill: bool = True) -> PlacementPlan:
    """nodes: node_id -> (free_bytes, is_legacy)."""
    bins = _mk_bins(nodes)
    plan = PlacementPlan()

    def commit(b: _Bin, d: ModelDemand, prec: str):
        need = d.bytes_at(prec)
        b.free -= need
        b.hosted[d.cfg.name] = b.hosted.get(d.cfg.name, 0) + 1
        plan.assignments.append(Assignment(
            b.node_id, d.cfg.name, prec, d.n_slots, d.max_len, need,
            page_size=d.page_size, kv_pages=d.kv_pages))

    # phase 1: min replicas, biggest models first (FFD)
    order = sorted(demands, key=lambda d: -d.bytes_at(""))
    for d in order:
        for _ in range(d.min_replicas):
            pick = _best_node(bins, d)
            if pick is None:
                plan.unplaced.append(d.cfg.name)
                continue
            commit(*[pick[0], d, pick[1]])

    # phase 2: fill leftover VRAM with extra replicas (bounded by each
    # demand's replica_cap), most under-provisioned-per-traffic first
    if fill and demands:
        counts = {d.cfg.name: len(plan.replicas(d.cfg.name))
                  for d in demands}
        exhausted: set = set()
        progress = True
        while progress:
            live = [d for d in demands
                    if d.cfg.name not in plan.unplaced
                    and d.cfg.name not in exhausted
                    and counts[d.cfg.name] < d.replica_cap]
            if not live:
                break
            progress = False
            live.sort(key=lambda d: counts[d.cfg.name] / d.weight)
            for d in live:
                pick = _best_node(bins, d)
                if pick is not None:
                    commit(pick[0], d, pick[1])
                    counts[d.cfg.name] += 1
                    progress = True
                    break
                exhausted.add(d.cfg.name)   # nothing fits anywhere
    return plan


def place_naive(nodes: Dict[str, Tuple[int, bool]],
                demands: Sequence[ModelDemand]) -> PlacementPlan:
    """Baseline: first-fit in arrival order, bf16 only, no fill phase."""
    bins = _mk_bins(nodes)
    plan = PlacementPlan()
    for d in demands:
        for _ in range(d.min_replicas):
            placed = False
            for b in bins:
                need = d.bytes_at("")
                if need <= b.free:
                    b.free -= need
                    plan.assignments.append(Assignment(
                        b.node_id, d.cfg.name, "", d.n_slots, d.max_len,
                        need))
                    placed = True
                    break
            if not placed:
                plan.unplaced.append(d.cfg.name)
    return plan


def reallocation_plan(nodes: Dict[str, Tuple[int, bool]],
                      lost: Sequence[ModelDemand]) -> PlacementPlan:
    """Re-place instances lost to a failure on the surviving fleet
    (min_replicas of each lost demand; no fill — keep headroom for the
    next failure)."""
    return place(nodes, lost, fill=False)


def plan_utilization(plan: PlacementPlan,
                     nodes: Dict[str, Tuple[int, bool]]) -> float:
    """Fraction of fleet VRAM used by the plan (the paper's efficiency
    objective)."""
    used = sum(a.bytes for a in plan.assignments)
    total = sum(free for free, _ in nodes.values())
    return used / total if total else 0.0


# ------------------------------------------------------------------ #
# Heterogeneity-aware, cost-optimal placement
# ------------------------------------------------------------------ #
@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """Capability-aware view of a node for the cost-optimal solver —
    free VRAM plus the full `NodeClass` vector (the `(bytes, legacy)`
    tuple `place()` consumes is this with the class erased)."""
    free: int
    klass: NodeClass

    @property
    def legacy(self) -> bool:
        return self.klass.legacy


def as_vram_nodes(nodes: Dict[str, NodeSpec]) -> Dict[str, Tuple[int, bool]]:
    """Erase capability vectors -> the class-blind shape `place()` eats."""
    return {nid: (s.free, s.legacy) for nid, s in nodes.items()}


def _hbm_fraction(need: int, klass: NodeClass) -> float:
    """VRAM share of the node an instance occupies — prorates node cost
    across co-hosted instances (nodes are shared; charging every tenant
    the full node would make packed nodes look expensive)."""
    budget = klass.hbm_total * (1.0 - RUNTIME_RESERVE_FRACTION)
    return min(need / budget, 1.0) if budget > 0 else 1.0


def _assign_cost_rate(a: Assignment, klass: NodeClass) -> float:
    """Prorated cost units/s this assignment consumes on its node."""
    return klass.cost_rate * _hbm_fraction(a.bytes, klass)


@dataclasses.dataclass
class _CostBin:
    node_id: str
    free: int
    klass: NodeClass
    hosted: Dict[str, int] = dataclasses.field(default_factory=dict)


def _best_node_cost(bins: List["_CostBin"], demand: ModelDemand,
                    perf: PerfModel) -> Optional[Tuple["_CostBin", str]]:
    """Pick (node, precision) minimizing modeled cost-per-token; within
    equal cost (nodes of the same class) the `place()` tie-break applies
    unchanged: anti-affinity, then highest precision, then tightest fit.
    Precision per node stays quality-first — quantization remains a
    *fit* fallback, never a cost dodge."""
    precisions = PRECISIONS if demand.allow_quant else [""]
    mix = dict(demand.bucket_mix) or None
    best = None
    best_key = None
    for b in bins:
        for p_idx, prec in enumerate(precisions):
            need = demand.bytes_at(prec)
            if need > b.free:
                continue
            cpt = perf.mix_cost_per_token(
                b.klass, demand.cfg, mix, prec,
                hbm_fraction=_hbm_fraction(need, b.klass))
            affinity = b.hosted.get(demand.cfg.name, 0)
            key = (cpt, affinity, p_idx, b.free - need)
            if best_key is None or key < best_key:
                best, best_key = (b, prec), key
            break          # higher precision fits on this node; stop
    return best


def place_cost_optimal(nodes: Dict[str, NodeSpec],
                       demands: Sequence[ModelDemand],
                       perf: Optional[PerfModel] = None,
                       fill: bool = True) -> PlacementPlan:
    """Cost-optimal replica mix: greedy over modeled cost-per-token.

    Three phases — (1) min_replicas of each demand on the cheapest
    feasible class, (2) SLO top-up: demands declaring
    `target_tokens_per_s` gain replicas (bounded by `replica_cap`) until
    the summed per-replica modeled throughput covers the target, (3) the
    usual fill phase, cheapest candidate first.  VRAM budgets are never
    exceeded (same bin accounting as `place()`); a demand whose SLO
    cannot be met keeps its replicas and the shortfall shows up in
    `plan_throughput`."""
    perf = perf or PerfModel()
    bins = [_CostBin(nid, s.free, s.klass) for nid, s in nodes.items()]
    plan = PlacementPlan()
    tput: Dict[str, float] = {d.cfg.name: 0.0 for d in demands}

    def commit(b: _CostBin, d: ModelDemand, prec: str):
        need = d.bytes_at(prec)
        b.free -= need
        b.hosted[d.cfg.name] = b.hosted.get(d.cfg.name, 0) + 1
        plan.assignments.append(Assignment(
            b.node_id, d.cfg.name, prec, d.n_slots, d.max_len, need,
            page_size=d.page_size, kv_pages=d.kv_pages))
        tput[d.cfg.name] += perf.mix_tokens_per_s(
            b.klass, d.cfg, dict(d.bucket_mix) or None, prec)

    # phase 1: min replicas, biggest models first (FFD), cheapest node
    order = sorted(demands, key=lambda d: -d.bytes_at(""))
    for d in order:
        for _ in range(d.min_replicas):
            pick = _best_node_cost(bins, d, perf)
            if pick is None:
                plan.unplaced.append(d.cfg.name)
                continue
            commit(pick[0], d, pick[1])

    # phase 2: SLO top-up — grow the most under-served demand first
    counts = {d.cfg.name: len(plan.replicas(d.cfg.name)) for d in demands}
    while True:
        lagging = [d for d in order
                   if d.target_tokens_per_s > 0
                   and d.cfg.name not in plan.unplaced
                   and tput[d.cfg.name] < d.target_tokens_per_s
                   and counts[d.cfg.name] < d.replica_cap]
        if not lagging:
            break
        lagging.sort(
            key=lambda d: tput[d.cfg.name] / d.target_tokens_per_s)
        placed_any = False
        for d in lagging:
            pick = _best_node_cost(bins, d, perf)
            if pick is not None:
                commit(pick[0], d, pick[1])
                counts[d.cfg.name] += 1
                placed_any = True
                break
        if not placed_any:
            break          # fleet exhausted; shortfall stands

    # phase 3: fill leftover VRAM, cheapest candidates first
    if fill and demands:
        exhausted: set = set()
        progress = True
        while progress:
            live = [d for d in demands
                    if d.cfg.name not in plan.unplaced
                    and d.cfg.name not in exhausted
                    and counts[d.cfg.name] < d.replica_cap]
            if not live:
                break
            progress = False
            live.sort(key=lambda d: counts[d.cfg.name] / d.weight)
            for d in live:
                pick = _best_node_cost(bins, d, perf)
                if pick is not None:
                    commit(pick[0], d, pick[1])
                    counts[d.cfg.name] += 1
                    progress = True
                    break
                exhausted.add(d.cfg.name)
    return plan


def plan_throughput(plan: PlacementPlan, nodes: Dict[str, NodeSpec],
                    demands: Sequence[ModelDemand],
                    perf: Optional[PerfModel] = None) -> Dict[str, float]:
    """Modeled aggregate output-tokens/s per model under each demand's
    bucket mix — works on any plan (cost-optimal or VRAM-only)."""
    perf = perf or PerfModel()
    by_name = {d.cfg.name: d for d in demands}
    out: Dict[str, float] = {d.cfg.name: 0.0 for d in demands}
    for a in plan.assignments:
        d = by_name.get(a.model_name)
        if d is None or a.node_id not in nodes:
            continue
        out[a.model_name] += perf.mix_tokens_per_s(
            nodes[a.node_id].klass, d.cfg,
            dict(d.bucket_mix) or None, a.quantize)
    return out


def plan_cost_per_token(plan: PlacementPlan, nodes: Dict[str, NodeSpec],
                        demands: Sequence[ModelDemand],
                        perf: Optional[PerfModel] = None) -> float:
    """Fleet-level modeled cost units per output token for a plan: total
    prorated node-cost rate / total modeled throughput.  The bench's
    headline heterogeneous metric (cost-optimal vs VRAM-only)."""
    perf = perf or PerfModel()
    by_name = {d.cfg.name: d for d in demands}
    cost_rate = 0.0
    for a in plan.assignments:
        if a.node_id in nodes and a.model_name in by_name:
            cost_rate += _assign_cost_rate(a, nodes[a.node_id].klass)
    tps = sum(plan_throughput(plan, nodes, demands, perf).values())
    return cost_rate / tps if tps > 0 else float("inf")

"""SDAI Controller — the orchestration core (paper §3, §5).

Lifecycle:  discover() -> deploy(demands) -> tick() loop.

* discover: register every backend node's capability payload (GPU type,
  VRAM, preloaded models — the dashboard's agent cards).
* deploy: run VRAM-aware placement, start instances on nodes, provision
  frontend routes (the generated per-model HAProxy config).
* tick: ingest heartbeats, detect dead nodes, *dynamically reallocate* lost
  instances onto surviving VRAM, handle elastic joins, demote stragglers.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

from repro.cluster.fleet import Fleet
from repro.core.events import EventBus
from repro.core.frontend import FrontendConfig, ServiceFrontend
from repro.core.health import HealthConfig, HealthMonitor, NodeHealth
from repro.core.perfmodel import PerfModel
from repro.core.placement import (ModelDemand, NodeSpec, PlacementPlan,
                                  place, place_cost_optimal,
                                  plan_utilization, reallocation_plan)
from repro.core.registry import (ModelCatalog, NodeRegistry, ReplicaInfo,
                                 ReplicaKey, ReplicaRegistry)


@dataclasses.dataclass
class AutoscaleConfig:
    """Load-feedback elasticity policy (paper: reallocation under
    workload fluctuations), in both directions.

    Scale-up: a model is "hot" when its backlog-per-replica exceeds
    `queue_high`, OR its oldest queued request has waited longer than
    `head_wait_high_s` (a shallow-but-stale queue is still starvation),
    OR some replica's KV-page pool is nearly exhausted (`page_high`
    occupancy — admitted work is about to preempt, so VRAM pressure is
    real even when the queue looks shallow); `sustain_ticks` consecutive
    hot ticks place one more replica into free VRAM, then
    `cooldown_ticks` of hysteresis before the next growth step.

    Scale-down: a model is "idle" when it has zero backlog AND zero
    in-flight requests while holding more replicas than its demand's
    `min_replicas`; `idle_sustain_ticks` consecutive idle ticks retire
    one *surplus, work-free* replica (VRAM returns to the pool for other
    models), then `down_cooldown_ticks` of hysteresis.  Replicas with
    queued or decoding work are never retired, and the floor is always
    the demand's `min_replicas`."""
    enabled: bool = True
    queue_high: float = 2.0        # queued requests per healthy replica
    head_wait_high_s: float = 2.0  # oldest-queued-request age threshold
    page_high: float = 0.92        # KV-page occupancy pressure threshold
    sustain_ticks: int = 3
    cooldown_ticks: int = 10
    scale_down: bool = True
    idle_sustain_ticks: int = 20   # idle ticks before retiring a replica
    down_cooldown_ticks: int = 20


@dataclasses.dataclass
class ModelLoad:
    """One model's instantaneous pressure signal, fed into `tick()` by
    the serving runtime (or a test harness)."""
    queue_depth: int = 0           # scheduler backlog across replicas
    inflight: int = 0              # gateway-admitted, not yet settled
    replicas: int = 0              # healthy replicas serving the model
    max_head_wait_s: float = 0.0   # oldest queued request, any replica
    page_pressure: float = 0.0     # max KV-page occupancy, any replica


@dataclasses.dataclass
class ControllerConfig:
    real_param_threshold: int = 5_000_000   # params; above => accounted mode
    health: HealthConfig = dataclasses.field(default_factory=HealthConfig)
    frontend: FrontendConfig = dataclasses.field(
        default_factory=FrontendConfig)
    fill_vram: bool = True
    autoscale: AutoscaleConfig = dataclasses.field(
        default_factory=AutoscaleConfig)
    # "vram": classic class-blind bin packing (`place`); "cost":
    # heterogeneity-aware cost-optimal solver (`place_cost_optimal`) —
    # initial deploy and rebalance rank candidate nodes by modeled
    # cost-per-token.  Scale-up/scale-down are always class-aware.
    placement_policy: str = "vram"


class SDAIController:
    def __init__(self, fleet: Fleet, catalog: ModelCatalog,
                 cfg: Optional[ControllerConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.fleet = fleet
        self.catalog = catalog
        self.cfg = cfg if cfg is not None else ControllerConfig()
        self.clock = clock
        self.nodes = NodeRegistry()
        self.replicas = ReplicaRegistry()
        self.monitor = HealthMonitor(self.cfg.health, clock=clock)
        self.bus = EventBus()
        self.perf = PerfModel()
        self.frontend = ServiceFrontend(fleet, self.replicas, self.monitor,
                                        self.cfg.frontend, perf=self.perf,
                                        catalog=catalog)
        self.demands: Dict[str, ModelDemand] = {}
        self._dead_nodes: set = set()
        # load-feedback autoscale state: model -> consecutive hot/idle
        # ticks and remaining per-direction cooldown ticks
        self._pressure_streak: Dict[str, int] = {}
        self._scale_cooldown: Dict[str, int] = {}
        self._idle_streak: Dict[str, int] = {}
        self._down_cooldown: Dict[str, int] = {}
        self.scale_ups = 0
        self.scale_downs = 0

    # ---------------------------------------------------------------- #
    # Discovery phase (paper: "Upon startup, it discovers and establishes
    # communication with all backend nodes")
    def discover(self) -> List[str]:
        found = []
        for node in self.fleet.nodes.values():
            if not node.alive:
                continue
            payload = node.discovery_payload()
            self.nodes.register(payload)
            self.monitor.observe_heartbeat(node.node_id)
            self.bus.emit("node_discovered", **payload)
            found.append(node.node_id)
        return found

    # ---------------------------------------------------------------- #
    def _free_capacity(self) -> Dict[str, tuple]:
        """node_id -> (free_bytes, legacy) over healthy nodes."""
        out = {}
        for nid in self.nodes.ids():
            node = self.fleet.nodes.get(nid)
            if node is None or not node.alive or nid in self._dead_nodes:
                continue
            if self.monitor.status(nid) == NodeHealth.DEAD:
                continue
            out[nid] = (node.hbm_free, node.klass.legacy)
        return out

    def _free_capacity_specs(self) -> Dict[str, NodeSpec]:
        """Capability-aware view of `_free_capacity` for the cost-optimal
        solver (free VRAM + the full NodeClass vector)."""
        out = {}
        for nid in self._free_capacity():
            node = self.fleet.nodes[nid]
            out[nid] = NodeSpec(node.hbm_free, node.klass)
        return out

    def _execute(self, plan: PlacementPlan) -> List[ReplicaKey]:
        keys = []
        for a in plan.assignments:
            node = self.fleet.nodes[a.node_id]
            cfg = self.catalog.get(a.model_name)
            real = cfg.num_params() <= self.cfg.real_param_threshold
            try:
                inst = node.deploy(cfg, quantize=a.quantize,
                                   n_slots=a.n_slots, max_len=a.max_len,
                                   real=real, page_size=a.page_size,
                                   kv_pages=a.kv_pages)
            except MemoryError as e:      # placement invariant violated
                self.bus.emit("deploy_failed", node=a.node_id,
                              model=a.model_name, error=str(e))
                continue
            if inst.engine is not None:
                # tenant fair-queuing weights flow from the frontend's
                # quota registry straight into the engine's DWRR
                # scheduler — live lookups, no broadcast needed
                inst.engine.scheduler.weight_of = \
                    self.frontend.tenants.weight
            key = ReplicaKey(a.node_id, inst.instance_id)
            self.replicas.add(ReplicaInfo(key, a.model_name, a.quantize,
                                          a.n_slots, a.max_len, a.bytes))
            self.bus.emit("instance_deployed", node=a.node_id,
                          model=a.model_name, quantize=a.quantize,
                          bytes=a.bytes, real=real)
            keys.append(key)
        return keys

    def deploy(self, demands: Sequence[ModelDemand]) -> PlacementPlan:
        for d in demands:
            if d.cfg.name not in self.catalog:
                self.catalog.register(d.cfg)
            self.demands[d.cfg.name] = d
        cap = self._free_capacity()
        if self.cfg.placement_policy == "cost":
            plan = place_cost_optimal(self._free_capacity_specs(), demands,
                                      self.perf, fill=self.cfg.fill_vram)
        else:
            plan = place(cap, demands, fill=self.cfg.fill_vram)
        self._execute(plan)
        self.bus.emit("deployment_complete",
                      assignments=len(plan.assignments),
                      unplaced=plan.unplaced,
                      utilization=plan_utilization(plan, cap))
        return plan

    # ---------------------------------------------------------------- #
    # Monitoring / dynamic reallocation loop
    def tick(self, load: Optional[Dict[str, ModelLoad]] = None):
        """One control-loop iteration.  `load` (optional) carries the
        per-model pressure signal — queue depth and in-flight count — the
        serving runtime measures each tick; sustained pressure triggers
        scale-up into free VRAM (`AutoscaleConfig`)."""
        # 1. heartbeats
        for node in list(self.fleet.nodes.values()):
            hb = node.heartbeat()
            if hb is not None:
                self.monitor.observe_heartbeat(node.node_id, hb["ts"])
        # 2. failure detection -> reallocation
        for nid in self.nodes.ids():
            node = self.fleet.nodes.get(nid)
            down = self.monitor.heartbeat_expired(nid) or node is None \
                or not node.alive
            if down and nid not in self._dead_nodes:
                self._handle_node_death(nid)
        # 3. elastic join: nodes present in fleet but not registered
        for nid, node in list(self.fleet.nodes.items()):
            if node.alive and nid not in self.nodes.payloads:
                self.nodes.register(node.discovery_payload())
                self.monitor.observe_heartbeat(nid)
                self.bus.emit("node_joined", node=nid)
                self._rebalance_into(nid)
            if node.alive and nid in self._dead_nodes:
                # recovered node: re-register empty
                self._dead_nodes.discard(nid)
                self.monitor.clear_mark(nid)
                self.monitor.observe_heartbeat(nid)
                self.nodes.register(node.discovery_payload())
                self.bus.emit("node_recovered", node=nid)
                self._rebalance_into(nid)
        # 4. load feedback -> scale-up under sustained pressure
        if load:
            self._observe_load(load)

    # ---------------------------------------------------------------- #
    def _observe_load(self, load: Dict[str, ModelLoad]):
        acfg = self.cfg.autoscale
        if not acfg.enabled:
            return
        for model, ml in load.items():
            replicas = max(ml.replicas, 1)
            hot = (ml.queue_depth / replicas >= acfg.queue_high
                   or ml.max_head_wait_s >= acfg.head_wait_high_s
                   or ml.page_pressure >= acfg.page_high)
            idle = ml.queue_depth == 0 and ml.inflight == 0
            # ---- scale-up under sustained pressure ------------------ #
            cd = self._scale_cooldown.get(model, 0)
            if cd > 0:
                self._scale_cooldown[model] = cd - 1
            else:
                streak = self._pressure_streak.get(model, 0) + 1 \
                    if hot else 0
                self._pressure_streak[model] = streak
                if streak >= acfg.sustain_ticks:
                    self._pressure_streak[model] = 0
                    if self.scale_up(model):
                        self._scale_cooldown[model] = acfg.cooldown_ticks
            # ---- scale-down after a sustained idle streak ----------- #
            if not acfg.scale_down:
                continue
            dcd = self._down_cooldown.get(model, 0)
            if dcd > 0:
                self._down_cooldown[model] = dcd - 1
                continue
            istreak = self._idle_streak.get(model, 0) + 1 if idle else 0
            self._idle_streak[model] = istreak
            if istreak >= acfg.idle_sustain_ticks:
                self._idle_streak[model] = 0
                if self.scale_down(model):
                    self._down_cooldown[model] = acfg.down_cooldown_ticks

    def scale_up(self, model: str) -> bool:
        """Place one additional replica of `model` into free VRAM (bounded
        by the demand's replica cap).  Class-aware: the delta replica goes
        to the node whose class serves the model's bucket mix at the
        lowest modeled cost-per-token — on a homogeneous fleet this
        degenerates to `place()`'s anti-affinity/tightest-fit choice.
        Returns True when a replica was actually deployed."""
        if model not in self.catalog:
            return False
        demand = self.demands.get(model)
        if demand is None:
            demand = ModelDemand(self.catalog.get(model), min_replicas=1)
        have = len(self.replicas.for_model(model))
        if have >= demand.replica_cap:
            return False
        delta = dataclasses.replace(demand, min_replicas=1, max_replicas=1)
        plan = place_cost_optimal(self._free_capacity_specs(), [delta],
                                  self.perf, fill=False)
        keys = self._execute(plan)
        if not keys:
            return False           # no node has room: pressure persists
        self.scale_ups += 1
        self.bus.emit("autoscaled_up", model=model,
                      replicas=have + len(keys),
                      placed=[str(k) for k in keys])
        return True

    def _instance_busy(self, inst) -> bool:
        if inst is None:
            return False
        if inst.engine is not None:
            return bool(inst.engine.slot_req
                        or inst.engine.scheduler.depth)
        return inst.sim_active > 0

    def scale_down(self, model: str) -> bool:
        """Retire one surplus replica of `model` back toward the
        demand's `min_replicas` floor, freeing its VRAM.  Only a replica
        with no queued or in-flight work is eligible; the most expensive
        node class retires first, most recently placed breaking ties — on a homogeneous fleet this unwinds
        autoscale growth exactly as before.  When every surplus replica
        is busy nothing is retired.  Returns True when a replica was
        actually removed."""
        demand = self.demands.get(model)
        floor = max(demand.min_replicas, 1) if demand is not None else 1
        infos = self.replicas.for_model(model)
        if len(infos) <= floor:
            return False

        def retire_cost(pair):
            idx, info = pair
            node = self.fleet.nodes.get(info.key.node_id)
            rate = node.klass.cost_rate if node is not None else 0.0
            return (-rate, -idx)

        ordered = [info for _, info
                   in sorted(enumerate(infos), key=retire_cost)]
        for info in ordered:
            node = self.fleet.nodes.get(info.key.node_id)
            if node is None or not node.alive:
                continue
            with node.lock:
                inst = node.instances.get(info.key.instance_id)
                if inst is None:
                    continue
                with inst.lock:   # don't retire an engine mid-step
                    if self._instance_busy(inst):
                        continue
                    # node.submit is deliberately lock-free, so a request
                    # can still slip into the scheduler between the busy
                    # check and undeploy: fail the engine first, so any
                    # such request finishes with ENGINE_FAILED and the
                    # gateway's pre-token retry re-routes it — never
                    # silently stranded
                    if inst.engine is not None:
                        inst.engine.fail()
                # undeploy re-takes node.lock (held here, reentrant) —
                # outside inst.lock so the instance -> node direction
                # never appears in the acquisition order.  Safe: the
                # engine is already failed, so a pump thread grabbing
                # inst.lock now sees a dead engine and does nothing.
                node.undeploy(info.key.instance_id)
            self.replicas.remove(info.key)
            self.scale_downs += 1
            self.bus.emit("autoscaled_down", model=model,
                          replicas=len(self.replicas.for_model(model)),
                          retired=str(info.key))
            return True
        return False

    def _handle_node_death(self, nid: str):
        self._dead_nodes.add(nid)
        self.monitor.mark_dead(nid)
        # fence a zombie: a node whose heartbeats went silent but whose
        # process is still up must not keep serving while routing has
        # written it off (split-brain).  fail() finishes every in-flight
        # request with ENGINE_FAILED, which drives the gateway's
        # pre-token re-route / mid-stream migration onto survivors.
        node = self.fleet.nodes.get(nid)
        if node is not None and node.alive:
            node.fail()
        lost = self.replicas.on_node(nid)
        for info in lost:
            self.replicas.remove(info.key)
        self.bus.emit("node_dead", node=nid,
                      lost=[r.model_name for r in lost])
        # recompute what must be re-placed to restore min replicas
        lost_demands = []
        for info in lost:
            d = self.demands.get(info.model_name)
            if d is None:
                continue
            alive = len(self.frontend.healthy_replicas(info.model_name))
            if alive < d.min_replicas:
                lost_demands.append(dataclasses.replace(
                    d, min_replicas=d.min_replicas - alive))
        if lost_demands:
            plan = reallocation_plan(self._free_capacity(), lost_demands)
            self._execute(plan)
            self.bus.emit("reallocated", node=nid,
                          moved=len(plan.assignments),
                          unplaced=plan.unplaced)

    def _rebalance_into(self, nid: str):
        """Fill a joined/recovered node with replicas of hot models."""
        if not self.demands or not self.cfg.fill_vram:
            return
        node = self.fleet.nodes[nid]
        fill = [dataclasses.replace(d, min_replicas=0)
                for d in self.demands.values()]
        if self.cfg.placement_policy == "cost":
            plan = place_cost_optimal(
                {nid: NodeSpec(node.hbm_free, node.klass)}, fill,
                self.perf, fill=True)
        else:
            plan = place({nid: (node.hbm_free, node.klass.legacy)}, fill,
                         fill=True)
        self._execute(plan)

    def remove_replicas(self, model: str, keep: int = 0) -> int:
        """Retire all but the first `keep` replicas of `model`.  In-flight
        and queued requests on a retired engine are finished with a
        structured error (streaming handles re-route or surface it) —
        never silently stranded."""
        removed = 0
        for info in self.replicas.for_model(model)[keep:]:
            node = self.fleet.nodes.get(info.key.node_id)
            if node is not None:
                with node.lock:
                    inst = node.instances.get(info.key.instance_id)
                    if inst is not None and inst.engine is not None:
                        with inst.lock:   # not mid-step on the executor
                            inst.engine.fail()
                    node.undeploy(info.key.instance_id)
            self.replicas.remove(info.key)
            removed += 1
        return removed

    def undeploy_model(self, model: str) -> int:
        """Remove every replica of `model` from the fleet and drop its
        demand (so reallocation stops restoring it)."""
        removed = self.remove_replicas(model, keep=0)
        self.demands.pop(model, None)
        self.bus.emit("model_undeployed", model=model, removed=removed)
        return removed

    def node_alive(self, nid: str) -> bool:
        node = self.fleet.nodes.get(nid)
        return node is not None and node.alive \
            and nid not in self._dead_nodes

    # ---------------------------------------------------------------- #
    def dashboard(self) -> Dict:
        """The SDAI Interface overview (paper Fig. 3).

        Back-compat: the typed view is `repro.api.AdminAPI.snapshot()`;
        this returns the same data as the legacy dict shape."""
        from repro.api.admin import AdminAPI
        return AdminAPI(self).snapshot().to_dict()

    def fleet_utilization(self) -> float:
        used = tot = 0
        for nid in self.nodes.ids():
            node = self.fleet.nodes.get(nid)
            if node is None or not node.alive:
                continue
            used += node.hbm_used
            tot += node.hbm_budget
        return used / tot if tot else 0.0

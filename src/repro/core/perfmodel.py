"""Per-GPU-class performance & cost model — heterogeneity made visible.

The source paper's premise is a fleet of mixed legacy GPUs, yet until this
module every placement/routing decision reduced a node to "free VRAM +
legacy bit".  `PerfModel` closes that gap: an analytical tokens/s
estimator per ``(NodeClass, model, phase)`` over request-size buckets,
derived from each class's capability vector (FLOP/s, chips, HBM
bandwidth) through the same two-term roofline the dry-run analyzer uses
(`repro.roofline.analysis.roofline_step_s`), plus a calibration hook that
overrides analytical estimates with measured ``bench_serving`` rows.

Three consumers:

* `core.placement.place_cost_optimal` — choose the replica mix that
  minimizes modeled cost-per-token subject to VRAM and SLO-throughput
  constraints (the Mélange shape: a measured/modeled tput matrix times a
  per-class cost weight; Adaptive Orchestration and AIBrix in PAPERS.md
  make the same argument at cloud scale),
* `core.frontend.ServiceFrontend` — size-bucket routing: short chats
  prefer cheap legacy classes, long-context requests prefer fast
  big-VRAM classes, folded into the weighted-least-connection score,
* `core.controller.SDAIController` — scale-up picks *which class* to
  grow (cheapest that satisfies demand); scale-down retires the most
  expensive surplus replica first.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Mapping, Optional, Tuple

from repro.cluster.hardware import NodeClass
from repro.configs.base import BYTES, ArchConfig
from repro.roofline.analysis import roofline_step_s

Phase = str                              # "prefill" | "decode"


# ------------------------------------------------------------------ #
# Request-size buckets
# ------------------------------------------------------------------ #
@dataclasses.dataclass(frozen=True)
class SizeBucket:
    """One (prompt-length, output-length) bucket of the request-size
    policy.  ``rep_*`` are the representative lengths estimates are
    evaluated at; ``latency_weight`` sets how much routing weighs
    modeled request latency vs cost-per-token for this bucket — short
    chats chase cheap tokens (legacy cards are fine), long-context
    requests chase fast capable nodes (they hold slots and KV pages for
    a long time, so slot-seconds dominate)."""
    name: str
    max_prompt: int                      # inclusive upper bound
    max_output: int                      # inclusive upper bound
    rep_prompt: int
    rep_output: int
    latency_weight: float

    @property
    def rep_context(self) -> int:
        return self.rep_prompt + self.rep_output


BUCKETS: Tuple[SizeBucket, ...] = (
    SizeBucket("short", 128, 128, 64, 32, 0.0),
    SizeBucket("medium", 512, 512, 256, 128, 0.5),
    SizeBucket("long", 1 << 30, 1 << 30, 2048, 256, 1.0),
)

_BY_NAME: Dict[str, SizeBucket] = {b.name: b for b in BUCKETS}

# Default traffic mix placement assumes when a demand does not declare
# one: mostly short chat, a tail of long-context work.
DEFAULT_MIX: Tuple[Tuple[str, float], ...] = (
    ("short", 0.6), ("medium", 0.3), ("long", 0.1))


def bucket_for(prompt_len: int, max_tokens: int) -> SizeBucket:
    """The first bucket that can hold (prompt_len, max_tokens)."""
    for b in BUCKETS:
        if prompt_len <= b.max_prompt and max_tokens <= b.max_output:
            return b
    return BUCKETS[-1]


def bucket_named(name: str) -> SizeBucket:
    return _BY_NAME[name]


def normalize_mix(mix: Optional[Mapping[str, float] |
                  Iterable[Tuple[str, float]]]) -> Dict[str, float]:
    """-> bucket-name -> fraction, summing to 1 (DEFAULT_MIX when
    empty/None)."""
    pairs = dict(mix or ()) or dict(DEFAULT_MIX)
    total = sum(pairs.values())
    if total <= 0:
        pairs, total = dict(DEFAULT_MIX), 1.0
    return {k: v / total for k, v in pairs.items() if v > 0}


# ------------------------------------------------------------------ #
@dataclasses.dataclass(frozen=True)
class PerfEstimate:
    """One (class, model, phase, bucket) throughput estimate."""
    tokens_per_s: float
    source: str                          # "analytical" | "measured"


class PerfModel:
    """Analytical tokens/s per (NodeClass, model, phase, bucket), with
    measured-row overrides.

    The analytical path is a per-step roofline over the class capability
    vector: decode streams the resident weights plus each active slot's
    KV window every step (memory term) and spends ~2*N_active FLOPs per
    token plus the attention term (compute term); prefill amortizes the
    weight stream over the whole prompt.  ``batch_slots`` is the assumed
    continuous-batching occupancy (engines default to 4 slots).

    `record()` / `calibrate_from_bench()` install measured rows that take
    precedence over the analytical estimate — the bench machinery is the
    profiler, this table is the model."""

    def __init__(self, batch_slots: int = 4):
        self.batch_slots = max(int(batch_slots), 1)
        # (class, model, phase, bucket) -> measured tokens/s
        self._measured: Dict[Tuple[str, str, str, str], float] = {}

    # ---- calibration --------------------------------------------- #
    def record(self, klass: str, model: str, phase: Phase, bucket: str,
               tokens_per_s: float):
        """Install one measured throughput row (overrides analytical)."""
        if tokens_per_s > 0:
            self._measured[(klass, model, phase, bucket)] = \
                float(tokens_per_s)

    def calibrate_from_bench(self, report: Mapping, klass: str,
                             model: str) -> int:
        """Ingest a ``BENCH_serving.json``-shaped report measured on
        `klass` serving `model`: every fused-variant ``tok_per_s`` row
        becomes a measured decode estimate for every bucket (the fused
        study decodes at engine batch occupancy, which is what the
        analytical decode path models).  Returns rows installed."""
        n = 0
        for variant in (report.get("fused") or {}).values():
            if not isinstance(variant, Mapping):
                continue
            tps = float(variant.get("tok_per_s", 0.0))
            if tps <= 0:
                continue
            for b in BUCKETS:
                self.record(klass, model, "decode", b.name, tps)
                n += 1
        return n

    def measured(self, klass: str, model: str, phase: Phase,
                 bucket: str) -> Optional[float]:
        return self._measured.get((klass, model, phase, bucket))

    def calibration_count(self) -> int:
        """Measured rows installed — consumers key caches on this so
        fresh calibration data invalidates stale scores."""
        return len(self._measured)

    # ---- analytical roofline ------------------------------------- #
    def _weight_bytes(self, cfg: ArchConfig, quantize: str) -> float:
        dt = {"": cfg.dtype, "int8": "int8", "int4": "int4"}[quantize]
        return cfg.num_params() * BYTES[dt]

    def _flops_per_token(self, cfg: ArchConfig, context: int) -> float:
        """Forward FLOPs per generated/processed token: 2*N_active plus
        the attention score/value matmuls over the visible window."""
        window = context if cfg.swa_window == 0 \
            else min(context, cfg.swa_window)
        attn = 4.0 * cfg.n_layers * cfg.n_heads * cfg.head_dim * window
        return 2.0 * cfg.active_params() + attn

    def _analytic(self, klass: NodeClass, cfg: ArchConfig, phase: Phase,
                  bucket: SizeBucket, quantize: str) -> float:
        w = self._weight_bytes(cfg, quantize)
        kv_tok = cfg.kv_bytes_per_token()
        if phase == "prefill":
            toks = max(bucket.rep_prompt, 1)
            flops = toks * self._flops_per_token(cfg, bucket.rep_prompt)
            hbm = w + toks * kv_tok          # stream weights once + write KV
            t = roofline_step_s(flops, hbm, klass.flops_total,
                                klass.hbm_bw_total)
            return toks / t if t > 0 else 0.0
        # decode: one token per active slot per step; the step re-reads
        # the weights once and every slot's live KV window
        batch = self.batch_slots
        ctx = bucket.rep_prompt + bucket.rep_output // 2
        window = ctx if cfg.swa_window == 0 else min(ctx, cfg.swa_window)
        flops = batch * self._flops_per_token(cfg, ctx)
        hbm = w + batch * window * kv_tok
        t = roofline_step_s(flops, hbm, klass.flops_total,
                            klass.hbm_bw_total)
        return batch / t if t > 0 else 0.0

    # ---- public estimates ---------------------------------------- #
    def estimate(self, klass: NodeClass, cfg: ArchConfig, phase: Phase,
                 bucket: SizeBucket, quantize: str = "") -> PerfEstimate:
        m = self.measured(klass.name, cfg.name, phase, bucket.name)
        if m is not None:
            return PerfEstimate(m, "measured")
        return PerfEstimate(
            self._analytic(klass, cfg, phase, bucket, quantize),
            "analytical")

    def tokens_per_s(self, klass: NodeClass, cfg: ArchConfig,
                     phase: Phase, bucket: SizeBucket,
                     quantize: str = "") -> float:
        return self.estimate(klass, cfg, phase, bucket,
                             quantize).tokens_per_s

    def request_latency_s(self, klass: NodeClass, cfg: ArchConfig,
                          bucket: SizeBucket, quantize: str = "") -> float:
        """Modeled wall-clock for one request of this bucket's shape:
        prefill the prompt, then decode the output at the per-sequence
        token rate (engine decode tokens/s is batch-aggregate)."""
        pre = self.tokens_per_s(klass, cfg, "prefill", bucket, quantize)
        dec = self.tokens_per_s(klass, cfg, "decode", bucket, quantize)
        if pre <= 0 or dec <= 0:
            return float("inf")
        per_seq = dec / self.batch_slots
        return bucket.rep_prompt / pre + bucket.rep_output / per_seq

    def bucket_tokens_per_s(self, klass: NodeClass, cfg: ArchConfig,
                            bucket: SizeBucket,
                            quantize: str = "") -> float:
        """Engine-level *output* tokens/s serving only this bucket:
        batch_slots concurrent requests, each paying prefill + decode."""
        lat = self.request_latency_s(klass, cfg, bucket, quantize)
        if lat <= 0 or lat == float("inf"):
            return 0.0
        return self.batch_slots * bucket.rep_output / lat

    def mix_tokens_per_s(self, klass: NodeClass, cfg: ArchConfig,
                         mix: Optional[Mapping[str, float]] = None,
                         quantize: str = "") -> float:
        """Time-weighted (harmonic) throughput over a bucket mix — the
        per-replica service rate placement sums against SLO targets."""
        denom = 0.0
        for name, frac in normalize_mix(mix).items():
            tps = self.bucket_tokens_per_s(klass, cfg, bucket_named(name),
                                           quantize)
            if tps <= 0:
                return 0.0
            denom += frac / tps
        return 1.0 / denom if denom > 0 else 0.0

    # ---- cost ------------------------------------------------------ #
    def cost_per_token(self, klass: NodeClass, cfg: ArchConfig,
                       bucket: SizeBucket, quantize: str = "",
                       hbm_fraction: float = 1.0) -> float:
        """Modeled cost units per generated token on this class for this
        bucket.  ``hbm_fraction`` prorates the node's cost by the VRAM
        share the instance occupies (instances share nodes; the paper's
        objective is to fully exploit each node's VRAM)."""
        tps = self.bucket_tokens_per_s(klass, cfg, bucket, quantize)
        if tps <= 0:
            return float("inf")
        return klass.cost_rate * max(min(hbm_fraction, 1.0), 0.0) / tps

    def mix_cost_per_token(self, klass: NodeClass, cfg: ArchConfig,
                           mix: Optional[Mapping[str, float]] = None,
                           quantize: str = "",
                           hbm_fraction: float = 1.0) -> float:
        tps = self.mix_tokens_per_s(klass, cfg, mix, quantize)
        if tps <= 0:
            return float("inf")
        return klass.cost_rate * max(min(hbm_fraction, 1.0), 0.0) / tps

    # ---- routing scores -------------------------------------------- #
    def routing_scores(self, classes: Iterable[NodeClass],
                       cfg: ArchConfig,
                       bucket: SizeBucket) -> Dict[str, float]:
        """Per-class routing score for one (model, bucket): a blend of
        normalized cost-per-token and normalized request latency, the
        bucket's ``latency_weight`` sliding between them.  The best class
        scores 1.0; the frontend turns (score - 1) into virtual
        connections.  Short buckets (weight 0) chase cheap tokens ->
        legacy classes win; long buckets (weight 1) chase modeled
        latency -> big-VRAM high-bandwidth classes win."""
        classes = list(classes)
        cost = {k.name: self.cost_per_token(k, cfg, bucket)
                for k in classes}
        lat = {k.name: self.request_latency_s(k, cfg, bucket)
               for k in classes}
        c_min = min(cost.values(), default=0.0)
        l_min = min(lat.values(), default=0.0)
        out: Dict[str, float] = {}
        lw = bucket.latency_weight
        for k in classes:
            c = cost[k.name] / c_min if c_min > 0 else 1.0
            lt = lat[k.name] / l_min if l_min > 0 else 1.0
            out[k.name] = (1.0 - lw) * c + lw * lt
        return out

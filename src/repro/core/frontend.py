"""Service Frontend — the HAProxy analogue.

Health-checked, weighted-least-connection routing over model replicas, with
retries and transparent failover.  Every backend node also gets a
`NodeProxy` view (the paper runs HAProxy *on each node* so multiple replicas
of one model can live on one node or across nodes); the frontend composes
them into one logical endpoint per model — the unified client interface.

Multi-tenancy lives here too: per-tenant token buckets (`TenantQuota`)
rate-limit requests/s and generated-tokens/s at admission, so one tenant's
burst degrades into structured `RATE_LIMITED` rejections instead of eating
the whole fleet's slots.  Buckets are thread-safe — with the
`ServingRuntime` started, callers admit from arbitrary threads.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.cluster.fleet import Fleet
from repro.configs import ZOO
from repro.configs.base import ArchConfig
from repro.core.health import HealthMonitor, NodeHealth
from repro.core.perfmodel import PerfModel, SizeBucket, bucket_for
from repro.core.registry import ReplicaKey, ReplicaRegistry
from repro.serving.request import (CODE_ENGINE_FAILED, CODE_NO_BACKEND,
                                   Request)


@dataclasses.dataclass
class FrontendConfig:
    max_retries: int = 3
    straggler_penalty: float = 10.0     # virtual connections added to
    suspect_penalty: float = 10.0       # stragglers / suspect nodes
    # size-bucket routing: virtual connections added per unit of
    # class-mismatch (perf-model routing score - 1).  0 disables the
    # heterogeneity-aware term and recovers pure least-connections.
    bucket_affinity: float = 4.0


@dataclasses.dataclass
class FrontendStats:
    routed: int = 0
    failed: int = 0
    retried: int = 0
    rejected_no_backend: int = 0
    per_replica: Dict[str, int] = dataclasses.field(default_factory=dict)
    # bucket name -> count, and bucket name -> node-class name -> count
    routed_by_bucket: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    per_bucket_class: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=dict)


# ------------------------------------------------------------------ #
@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Per-tenant rate limits.  0 disables that dimension.  Bursts
    default to one second's worth of rate (min 1), so a quota of
    5 req/s admits 5 back-to-back then refills continuously.

    `weight` is the tenant's fair-queuing share inside every engine's
    DWRR scheduler: under contention a weight-3 tenant is served ~3x the
    tokens of a weight-1 tenant.  It does not gate admission."""
    requests_per_s: float = 0.0
    tokens_per_s: float = 0.0
    burst_requests: float = 0.0
    burst_tokens: float = 0.0
    weight: float = 1.0

    def request_burst(self) -> float:
        return self.burst_requests or max(self.requests_per_s, 1.0)

    def token_burst(self) -> float:
        return self.burst_tokens or max(self.tokens_per_s, 1.0)


@dataclasses.dataclass
class TenantUsage:
    admitted: int = 0
    rate_limited: int = 0
    tokens_charged: int = 0
    refunds: int = 0           # cancelled-before-admission give-backs


class _TokenBucket:
    """Classic leaky/token bucket: `rate` units/s refill up to `burst`."""

    def __init__(self, rate: float, burst: float,
                 clock: Callable[[], float]):
        self.rate = rate
        self.burst = burst
        self.clock = clock
        self.level = burst
        self._last = clock()

    def _refill(self):
        now = self.clock()
        self.level = min(self.burst,
                         self.level + (now - self._last) * self.rate)
        self._last = now

    def try_take(self, n: float) -> bool:
        self._refill()
        if self.level >= n:
            self.level -= n
            return True
        return False


class TenantLimiter:
    """Thread-safe registry of per-tenant request/token buckets."""

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.quotas: Dict[str, TenantQuota] = {}
        self.usage: Dict[str, TenantUsage] = {}
        self._req_buckets: Dict[str, _TokenBucket] = {}
        self._tok_buckets: Dict[str, _TokenBucket] = {}
        self._lock = threading.Lock()

    def set_quota(self, tenant: str, quota: Optional[TenantQuota]):
        """Install (or, with None, remove) a tenant's rate limits.
        Resets the tenant's buckets to a full burst."""
        with self._lock:
            self._req_buckets.pop(tenant, None)
            self._tok_buckets.pop(tenant, None)
            if quota is None:
                self.quotas.pop(tenant, None)
                return
            self.quotas[tenant] = quota
            if quota.requests_per_s > 0:
                self._req_buckets[tenant] = _TokenBucket(
                    quota.requests_per_s, quota.request_burst(), self.clock)
            if quota.tokens_per_s > 0:
                self._tok_buckets[tenant] = _TokenBucket(
                    quota.tokens_per_s, quota.token_burst(), self.clock)

    def admit(self, tenant: str, projected_tokens: int) -> Optional[str]:
        """Charge one request + its projected token budget against the
        tenant's buckets.  Returns None when admitted, else a human
        reason (the caller maps it to `RATE_LIMITED`).  Tenants without
        an installed quota (including the anonymous "") are unlimited
        and untracked — usage state stays bounded by the number of
        configured quotas, not by caller-supplied tenant strings."""
        with self._lock:
            if tenant not in self.quotas:
                return None
            usage = self.usage.setdefault(tenant, TenantUsage())
            rb = self._req_buckets.get(tenant)
            tb = self._tok_buckets.get(tenant)
            if rb is not None and not rb.try_take(1.0):
                usage.rate_limited += 1
                return (f"tenant {tenant!r} over request rate "
                        f"({self.quotas[tenant].requests_per_s:g} req/s)")
            if tb is not None and \
                    not tb.try_take(float(projected_tokens)):
                if rb is not None:      # roll back the request charge
                    rb.level = min(rb.burst, rb.level + 1.0)
                usage.rate_limited += 1
                return (f"tenant {tenant!r} over token rate "
                        f"({self.quotas[tenant].tokens_per_s:g} tok/s)")
            usage.admitted += 1
            usage.tokens_charged += projected_tokens
            return None

    def refund(self, tenant: str, projected_tokens: int):
        """Give back one request + its projected token charge — the
        request was cancelled while still queued, so it never consumed
        engine capacity.  Buckets refill up to their burst; usage books
        the refund so dashboards stay honest."""
        with self._lock:
            if tenant not in self.quotas:
                return
            usage = self.usage.setdefault(tenant, TenantUsage())
            rb = self._req_buckets.get(tenant)
            tb = self._tok_buckets.get(tenant)
            if rb is not None:
                rb.level = min(rb.burst, rb.level + 1.0)
            if tb is not None:
                tb.level = min(tb.burst,
                               tb.level + float(projected_tokens))
            usage.tokens_charged -= projected_tokens
            usage.refunds += 1

    def weight(self, tenant: str) -> float:
        """The tenant's DWRR fair-queuing weight (1.0 when no quota is
        installed).  Thread-safe: engine schedulers call this on the hot
        admission path."""
        with self._lock:
            q = self.quotas.get(tenant)
            return q.weight if q is not None else 1.0

    def snapshot(self) -> Dict[str, Dict]:
        """tenant -> {quota, usage} for the admin surface."""
        with self._lock:
            out = {}
            for tenant in set(self.quotas) | set(self.usage):
                q = self.quotas.get(tenant)
                u = self.usage.get(tenant, TenantUsage())
                out[tenant] = {"quota": q, "usage": dataclasses.replace(u)}
            return out


class ServiceFrontend:
    def __init__(self, fleet: Fleet, replicas: ReplicaRegistry,
                 monitor: HealthMonitor,
                 cfg: Optional[FrontendConfig] = None,
                 perf: Optional[PerfModel] = None,
                 catalog: Optional[Dict[str, ArchConfig]] = None):
        self.fleet = fleet
        self.replicas = replicas
        self.monitor = monitor
        self.cfg = cfg if cfg is not None else FrontendConfig()
        self.perf = perf if perf is not None else PerfModel()
        self.catalog = catalog if catalog is not None else ZOO
        self.stats = FrontendStats()
        self.tenants = TenantLimiter()
        self._last_pick: Dict[str, int] = {}
        self._pick_seq = 0
        # (model, bucket, live-class set, calibration epoch) -> scores
        self._score_cache: Dict[tuple, Dict[str, float]] = {}

    # ------------------------------------------------------------- #
    def _replica_load(self, key: ReplicaKey) -> Optional[float]:
        node = self.fleet.nodes.get(key.node_id)
        if node is None or not node.alive:
            return None
        if self.monitor.status(key.node_id) == NodeHealth.DEAD:
            return None
        inst = node.instances.get(key.instance_id)
        if inst is None or not inst.alive:
            return None
        load = float(inst.load)
        # capability weighting: stronger nodes look "less loaded"
        load /= max(node.klass.flops_total / 1e14, 1e-3)
        if self.monitor.is_straggler(str(key)):
            load += self.cfg.straggler_penalty
        if self.monitor.status(key.node_id) == NodeHealth.SUSPECT:
            load += self.cfg.suspect_penalty
        return load

    def healthy_replicas(self, model: str) -> List[ReplicaKey]:
        out = []
        for info in self.replicas.for_model(model):
            if self._replica_load(info.key) is not None:
                out.append(info.key)
        return out

    def _class_scores(self, model: str,
                      bucket: SizeBucket) -> Dict[str, float]:
        """Per-node-class routing scores (1.0 = best class) for one
        (model, bucket), over the classes that currently host healthy
        replicas of the model.  Cached; the cache key carries the live
        class set and the perf model's calibration epoch so topology
        changes and new measured rows invalidate naturally."""
        if model not in self.catalog:
            return {}
        cfg = self.catalog.get(model)
        klasses = {}
        for info in self.replicas.for_model(model):
            node = self.fleet.nodes.get(info.key.node_id)
            if node is not None and node.alive:
                klasses[node.klass.name] = node.klass
        if len(klasses) < 2:
            return {}          # homogeneous: nothing to discriminate
        key = (model, bucket.name, tuple(sorted(klasses)),
               self.perf.calibration_count())
        if key not in self._score_cache:
            self._score_cache[key] = self.perf.routing_scores(
                klasses.values(), cfg, bucket)
        return self._score_cache[key]

    def pick(self, model: str, exclude: Optional[set] = None,
             bucket: Optional[SizeBucket] = None) -> Optional[ReplicaKey]:
        """Weighted least-connections with round-robin tie-breaking (so
        instantly-completing requests still spread across replicas).

        With a `bucket`, the request-size policy folds in: replicas on a
        class the perf model scores poorly for this bucket carry extra
        virtual connections (`bucket_affinity` per unit of mismatch), so
        short chats drift to cheap legacy classes and long-context
        requests to fast big-VRAM classes — but a hammered "right" class
        still sheds load onto the "wrong" one (it is a preference, not a
        partition)."""
        scores = self._class_scores(model, bucket) \
            if bucket is not None else {}
        best, best_key = None, None
        for info in self.replicas.for_model(model):
            if exclude and info.key in exclude:
                continue
            load = self._replica_load(info.key)
            if load is None:
                continue
            if scores:
                node = self.fleet.nodes.get(info.key.node_id)
                if node is not None:
                    mismatch = scores.get(node.klass.name, 1.0) - 1.0
                    load += self.cfg.bucket_affinity * mismatch
            last = self._last_pick.get(str(info.key), -1)
            sort_key = (load, last)
            if best_key is None or sort_key < best_key:
                best, best_key = info.key, sort_key
        if best is not None:
            self._pick_seq += 1
            self._last_pick[str(best)] = self._pick_seq
        return best

    # ------------------------------------------------------------- #
    def submit(self, req: Request) -> bool:
        """Route with health-checked failover: on backend failure the
        request transparently retries on the next-best replica.

        Finish callbacks are suppressed while the retry loop runs so a
        streaming handle never sees a transient attempt failure as the
        request's final outcome; the settled outcome (success, routed, or
        terminal failure) fires exactly once on exit."""
        tried: set = set()
        last_code = CODE_ENGINE_FAILED
        bucket = bucket_for(len(req.prompt), req.sampling.max_tokens)
        req._suppress_finish = True
        try:
            for attempt in range(self.cfg.max_retries + 1):
                key = self.pick(req.model, exclude=tried, bucket=bucket)
                if key is None:
                    self.stats.rejected_no_backend += 1
                    req.finish(error="no healthy backend",
                               code=CODE_NO_BACKEND)
                    return False
                tried.add(key)
                node = self.fleet.nodes[key.node_id]
                t0 = time.monotonic()
                ok = node.submit(key.instance_id, req)
                if ok:
                    self.stats.routed += 1
                    rk = str(key)
                    self.stats.per_replica[rk] = \
                        self.stats.per_replica.get(rk, 0) + 1
                    self.stats.routed_by_bucket[bucket.name] = \
                        self.stats.routed_by_bucket.get(bucket.name, 0) + 1
                    by_class = self.stats.per_bucket_class.setdefault(
                        bucket.name, {})
                    kn = node.klass.name
                    by_class[kn] = by_class.get(kn, 0) + 1
                    self.monitor.observe_latency(rk, time.monotonic() - t0)
                    return True
                # backend refused / died mid-submit: reset & fail over
                self.stats.retried += 1
                if req.error_code:
                    last_code = req.error_code
                req.reset_for_retry()
            self.stats.failed += 1
            # keep the last attempt's class: all-queues-full must surface
            # as OVERLOADED (retryable 429), not an engine failure
            req.finish(error="all replicas failed", code=last_code)
            return False
        finally:
            req._suppress_finish = False
            if req.finished_at is not None:
                req._fire_finish()

    # ------------------------------------------------------------- #
    def routing_table(self) -> Dict[str, List[str]]:
        """model -> healthy replica keys (the generated HAProxy config)."""
        return {m: [str(k) for k in self.healthy_replicas(m)]
                for m in self.replicas.models()}

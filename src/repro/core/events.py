"""Controller event bus: every orchestration action is an auditable event
(what the SDAI dashboard renders)."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Sequence

# Failure/recovery event kinds.  Emitters elsewhere pass ad-hoc strings
# for routine orchestration actions; the fault-tolerance kinds are named
# here because the admin snapshot (`FleetSnapshot.failure_events`) and
# the chaos harness both count them by exact name.
REQUEST_MIGRATED = "request_migrated"   # mid-stream resume on a new replica
NODE_SUSPECTED = "node_suspected"       # demoted in weighted routing
WATCHDOG_FIRED = "watchdog_fired"       # a pump step blew its deadline
FAULT_INJECTED = "fault_injected"       # chaos harness applied a fault

FAILURE_EVENT_KINDS = (REQUEST_MIGRATED, NODE_SUSPECTED, WATCHDOG_FIRED,
                       FAULT_INJECTED)


@dataclasses.dataclass
class Event:
    kind: str
    data: Dict[str, Any]
    ts: float = dataclasses.field(default_factory=time.monotonic)


class EventBus:
    def __init__(self, keep: int = 10_000):
        self.events: List[Event] = []
        self.keep = keep
        self.subscribers: List[Callable[[Event], None]] = []

    def emit(self, kind: str, **data):
        ev = Event(kind, data)
        self.events.append(ev)
        if len(self.events) > self.keep:
            self.events = self.events[-self.keep:]
        for sub in self.subscribers:
            sub(ev)
        return ev

    def subscribe(self, fn: Callable[[Event], None]):
        self.subscribers.append(fn)

    def of_kind(self, kind: str) -> List[Event]:
        return [e for e in self.events if e.kind == kind]

    def counts(self, kinds: Sequence[str]) -> Dict[str, int]:
        """Occurrence count per kind over the retained window (the admin
        snapshot's failure-event summary)."""
        out = {k: 0 for k in kinds}
        for e in self.events:
            if e.kind in out:
                out[e.kind] += 1
        return out

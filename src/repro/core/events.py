"""Controller event bus: every orchestration action is an auditable event
(what the SDAI dashboard renders)."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List


@dataclasses.dataclass
class Event:
    kind: str
    data: Dict[str, Any]
    ts: float = dataclasses.field(default_factory=time.monotonic)


class EventBus:
    def __init__(self, keep: int = 10_000):
        self.events: List[Event] = []
        self.keep = keep
        self.subscribers: List[Callable[[Event], None]] = []

    def emit(self, kind: str, **data):
        ev = Event(kind, data)
        self.events.append(ev)
        if len(self.events) > self.keep:
            self.events = self.events[-self.keep:]
        for sub in self.subscribers:
            sub(ev)
        return ev

    def subscribe(self, fn: Callable[[Event], None]):
        self.subscribers.append(fn)

    def of_kind(self, kind: str) -> List[Event]:
        return [e for e in self.events if e.kind == kind]

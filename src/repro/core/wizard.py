"""Configuration Wizard — Select -> Configure -> Generate (paper §5.1-5.3).

Stage 1 (Select): choose agents + enable GPU instances per agent.
Stage 2 (Configure): per-model network ports, replica counts, LB policy.
Stage 3 (Generate): the consolidated Configuration Overview — system stats,
model distribution, agent distribution — plus the rendered frontend config
(our HAProxy-config analogue) the controller pushes to nodes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.controller import SDAIController
from repro.core.placement import ModelDemand, PlacementPlan, place


@dataclasses.dataclass
class WizardSelection:
    agents: List[str]
    # agent -> enabled (True) / disabled; missing => enabled
    gpu_enabled: Dict[str, bool] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class WizardModelChoice:
    model_name: str
    replicas: int = 1
    n_slots: int = 4
    max_len: int = 2048
    allow_quant: bool = True
    port: Optional[int] = None      # auto-assigned when None


@dataclasses.dataclass
class WizardConfig:
    selection: WizardSelection
    models: List[WizardModelChoice]
    stats_port: int = 8404
    base_port: int = 11434          # ollama-style default


class ConfigWizard:
    def __init__(self, controller: SDAIController):
        self.c = controller

    # Stage 1 ------------------------------------------------------ #
    def list_agents(self) -> List[Dict]:
        """Agent cards: status, last-seen, vendor/class, VRAM."""
        out = []
        for nid in self.c.nodes.ids():
            node = self.c.fleet.nodes.get(nid)
            if node is None:
                continue
            out.append({
                "node_id": nid,
                "class": node.klass.name,
                "toolkit": node.klass.toolkit,
                "year": node.klass.year,
                "hbm_total_gb": node.klass.hbm_total / 2**30,
                "hbm_free_gb": node.hbm_free / 2**30,
                "status": self.c.monitor.status(nid).value,
                "last_seen": self.c.monitor.last_seen.get(nid),
            })
        return out

    # Stage 1b: model capacity panel ------------------------------- #
    def model_capacity(self, model_name: str, node_id: str,
                       n_slots: int = 4, max_len: int = 2048) -> Dict:
        """VRAM per instance / free VRAM / max instances (paper Fig. 6)."""
        from repro.cluster.node import instance_bytes
        cfg = self.c.catalog.get(model_name)
        node = self.c.fleet.nodes[node_id]
        per = {q: instance_bytes(cfg, q, n_slots, max_len)
               for q in ["", "int8", "int4"]}
        fit_prec = next((q for q in ["", "int8", "int4"]
                         if per[q] <= node.hbm_free), None)
        return {
            "model": model_name,
            "bytes_per_instance": per,
            "node_free": node.hbm_free,
            "max_instances": (node.hbm_free // per[fit_prec]
                              if fit_prec is not None else 0),
            "precision": fit_prec,
        }

    # Stage 2+3 ----------------------------------------------------- #
    def generate(self, wcfg: WizardConfig) -> Dict:
        """Dry-run placement over the selected agents and render the
        Configuration Overview + frontend config.  Nothing is deployed
        until `apply()`."""
        enabled = [a for a in wcfg.selection.agents
                   if wcfg.selection.gpu_enabled.get(a, True)]
        cap = {nid: v for nid, v in self.c._free_capacity().items()
               if nid in enabled}
        demands = [ModelDemand(self.c.catalog.get(mc.model_name),
                               min_replicas=mc.replicas,
                               n_slots=mc.n_slots, max_len=mc.max_len,
                               allow_quant=mc.allow_quant)
                   for mc in wcfg.models]
        plan = place(cap, demands, fill=self.c.cfg.fill_vram)
        # port assignment (paper Fig. 7)
        ports = {}
        next_port = wcfg.base_port
        for mc in wcfg.models:
            if mc.port is not None:
                ports[mc.model_name] = mc.port
            else:
                ports[mc.model_name] = next_port
                next_port += 1
        by_model: Dict[str, int] = {}
        by_agent: Dict[str, int] = {}
        for a in plan.assignments:
            by_model[a.model_name] = by_model.get(a.model_name, 0) + 1
            by_agent[a.node_id] = by_agent.get(a.node_id, 0) + 1
        overview = {
            "system_stats": {
                "agents": len(enabled),
                "instances": len(plan.assignments),
                "distinct_models": len(by_model),
                "stats_port": wcfg.stats_port,
            },
            "model_distribution": by_model,
            "agent_distribution": by_agent,
            "ports": ports,
            "unplaced": plan.unplaced,
            "frontend_config": self.render_frontend_config(plan, ports,
                                                           wcfg.stats_port),
        }
        return {"plan": plan, "overview": overview}

    def render_frontend_config(self, plan: PlacementPlan,
                               ports: Dict[str, int],
                               stats_port: int) -> str:
        """HAProxy-style config text (one frontend+backend per model)."""
        lines = ["global", "  maxconn 4096", "defaults",
                 "  timeout connect 5s", "  timeout server 300s",
                 "listen stats", f"  bind *:{stats_port}",
                 "  stats enable"]
        for model, port in sorted(ports.items()):
            lines += [f"frontend ft_{model}", f"  bind *:{port}",
                      f"  default_backend bk_{model}",
                      f"backend bk_{model}", "  balance leastconn"]
            for i, a in enumerate(plan.replicas(model)):
                lines.append(
                    f"  server {model}_{i} {a.node_id}:auto check "
                    f"weight 100{' # ' + a.quantize if a.quantize else ''}")
        return "\n".join(lines)

    def apply(self, generated: Dict) -> List:
        """Execute the generated plan (Stage 3 'finalize')."""
        plan: PlacementPlan = generated["plan"]
        keys = self.c._execute(plan)
        self.c.bus.emit("wizard_applied",
                        instances=len(plan.assignments))
        return keys

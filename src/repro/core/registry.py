"""Registries: the controller's authoritative view of nodes, models, and
deployed replicas (what the SDAI dashboard's agent cards render)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import ArchConfig


@dataclasses.dataclass
class ReplicaKey:
    node_id: str
    instance_id: int

    def __hash__(self):
        return hash((self.node_id, self.instance_id))

    def __eq__(self, other):
        return (self.node_id, self.instance_id) == \
            (other.node_id, other.instance_id)

    def __str__(self):
        return f"{self.node_id}/{self.instance_id}"


@dataclasses.dataclass
class ReplicaInfo:
    key: ReplicaKey
    model_name: str
    quantize: str
    n_slots: int
    max_len: int
    bytes: int


class ModelCatalog:
    """The deployable model zoo (paper Table 1)."""

    def __init__(self):
        self._models: Dict[str, ArchConfig] = {}

    def register(self, cfg: ArchConfig):
        self._models[cfg.name] = cfg

    def get(self, name: str) -> ArchConfig:
        return self._models[name]

    def __contains__(self, name: str) -> bool:
        return name in self._models

    def names(self) -> List[str]:
        return sorted(self._models)


class NodeRegistry:
    def __init__(self):
        self.payloads: Dict[str, Dict] = {}

    def register(self, payload: Dict):
        self.payloads[payload["node_id"]] = payload

    def deregister(self, node_id: str):
        self.payloads.pop(node_id, None)

    def capacities(self) -> Dict[str, int]:
        return {nid: p["hbm_budget"] for nid, p in self.payloads.items()}

    def ids(self) -> List[str]:
        return sorted(self.payloads)


class ReplicaRegistry:
    def __init__(self):
        self.replicas: Dict[ReplicaKey, ReplicaInfo] = {}

    def add(self, info: ReplicaInfo):
        self.replicas[info.key] = info

    def remove(self, key: ReplicaKey):
        self.replicas.pop(key, None)

    def for_model(self, model_name: str) -> List[ReplicaInfo]:
        return [r for r in self.replicas.values()
                if r.model_name == model_name]

    def on_node(self, node_id: str) -> List[ReplicaInfo]:
        return [r for r in self.replicas.values()
                if r.key.node_id == node_id]

    def models(self) -> List[str]:
        return sorted({r.model_name for r in self.replicas.values()})

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import attention as attn_lib


def flash_attention_ref(q, k, v, *, causal=True, window=0, prefix=0):
    """q: (B, H, Sq, hd); k, v: (B, K, Skv, hd) -> (B, H, Sq, hd)."""
    qt = q.transpose(0, 2, 1, 3)          # (B, Sq, H, hd)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = attn_lib.full_attention(qt, kt, vt, causal=causal, window=window,
                                  prefix=prefix)
    return out.transpose(0, 2, 1, 3)


def decode_attention_ref(q, k_cache, v_cache, pos, *, window=0, prefix=0):
    """q: (B, K, G, hd); caches: (B, K, S, hd); pos: (B,)."""
    b, nkv, g, hd = q.shape
    qt = q.reshape(b, 1, nkv * g, hd) if False else \
        q.transpose(0, 2, 1, 3).reshape(b, 1, nkv * g, hd)
    # models/attention expects (B, 1, H, hd) with H grouped kv-major:
    # fold (K, G) -> H in kv-major order to match _gqa_fold
    qt = q.reshape(b, nkv * g, hd)[:, None]
    kt = k_cache.transpose(0, 2, 1, 3)    # (B, S, K, hd)
    vt = v_cache.transpose(0, 2, 1, 3)
    out = attn_lib.decode_attention(qt, kt, vt, pos, window=window,
                                    prefix=prefix)
    return out[:, 0].reshape(b, nkv, g, hd)


def int8_matmul_ref(x, w_q, scale):
    """x: (M, K); w_q: (K, N) int8; scale: (1, N)."""
    w = w_q.astype(jnp.float32) * scale.astype(jnp.float32)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)

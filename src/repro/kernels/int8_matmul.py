"""Pallas TPU dequantizing int8 matmul: x @ (w_q * scale).

Weights stay int8 in HBM (the legacy-VRAM story of the paper: models packed
into 6-8 GB nodes); dequantization happens in VMEM after the integer tile is
loaded, feeding the MXU in bf16/f32.  Per-output-channel scales.

Grid (M/bm, N/bn, K/bk), K sequential, f32 accumulator in VMEM scratch;
scale applied once at the final K block — so the inner loop is a plain
int8-load + f32 FMA, no per-block rescaling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# the installed toolchain may predate the CompilerParams rename
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                           getattr(pltpu, "TPUCompilerParams", None))


def _int8_mm_kernel(x_ref, w_ref, s_ref, o_ref, acc, *, block_k: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[...].astype(jnp.float32)                     # (bm, bk)
    w = w_ref[...].astype(jnp.float32)                     # (bk, bn)
    acc[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[...] = (acc[...] * s_ref[...].astype(jnp.float32)) \
            .astype(o_ref.dtype)


def int8_matmul(x, w_q, scale, *, block_m: int = 128, block_n: int = 128,
                block_k: int = 128, out_dtype=None,
                interpret: bool = False):
    """x: (M, K) float; w_q: (K, N) int8; scale: (1, N) f32.
    Returns (M, N) in out_dtype (defaults to x.dtype)."""
    m, k = x.shape
    _, n = w_q.shape
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    out_dtype = out_dtype or x.dtype
    kernel = functools.partial(_int8_mm_kernel, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, k // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((block_k, block_n), lambda i, j, ki: (ki, j)),
            pl.BlockSpec((1, block_n), lambda i, j, ki: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_q, scale)

"""Jit'd public wrappers for the Pallas kernels, including the distributed
flash-decode combine (sequence-sharded KV + LSE merge via shard_map) — the
TPU-native answer to serving long contexts across chips.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.int8_matmul import int8_matmul as _int8_mm
from repro.kernels.paged_attention import (
    paged_decode_attention as _paged_decode,
    paged_decode_attention_ref as _paged_decode_ref,
    paged_suffix_attention_ref as _paged_suffix_ref)

# interpret=True everywhere on CPU (the TPU target compiles the same calls
# with interpret=False)
_INTERPRET = jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "prefix",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=0, prefix=0,
                    block_q=128, block_k=128):
    return _flash(q, k, v, causal=causal, window=window, prefix=prefix,
                  block_q=block_q, block_k=block_k, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("window", "prefix", "block_k"))
def decode_attention(q, k_cache, v_cache, pos, *, window=0, prefix=0,
                     block_k=256):
    return _decode(q, k_cache, v_cache, pos, window=window, prefix=prefix,
                   block_k=block_k, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "block_k"))
def int8_matmul(x, w_q, scale, *, block_m=128, block_n=128, block_k=128):
    return _int8_mm(x, w_q, scale, block_m=block_m, block_n=block_n,
                    block_k=block_k, interpret=_INTERPRET)


def paged_decode_attention(q, k_pool, v_pool, page_table, pos, *,
                           window=0, prefix=0):
    """Page-table-direct decode attention.  Routes to the Pallas kernel
    on accelerator backends; the jittable fori_loop reference runs the
    identical schedule on CPU and whenever `window` is traced (hymba's
    per-layer global/local mix)."""
    if _INTERPRET or not isinstance(window, int):
        return _paged_decode_ref(q, k_pool, v_pool, page_table, pos,
                                 window=window, prefix=prefix)
    return _paged_decode(q, k_pool, v_pool, page_table, pos,
                         window=window, prefix=prefix)


def paged_suffix_attention(q, k_pool, v_pool, page_table, q_pos):
    """Multi-query paged attention for speculative verify (plain causal);
    pure-jnp reference on every backend — the verify dispatch is tiny
    (Q = spec_draft + 1 rows)."""
    return _paged_suffix_ref(q, k_pool, v_pool, page_table, q_pos)


# --------------------------------------------------------------------- #
# Distributed flash-decode: KV sequence-sharded over `axis`, partial
# (num, denom, max) merged with tiny all-reduces — the collective-optimal
# decode for GQA models whose kv_heads don't divide the TP axis.

def _lse_partials(q, k_shard, v_shard, pos, kv_offset, *, window, prefix):
    """Single-shard partial attention with explicit (m, l, num) outputs,
    computed in pure jnp (the Pallas kernel's per-shard analogue)."""
    b, nkv, g, hd = q.shape
    s = k_shard.shape[2]
    qf = q.astype(jnp.float32) * (hd ** -0.5)
    kf = k_shard.astype(jnp.float32)
    scores = jnp.einsum("bkgd,bksd->bkgs", qf, kf)
    slot = kv_offset + jnp.arange(s)
    valid = slot[None, :] <= pos[:, None]
    if window > 0:
        vis = slot[None, :] > (pos[:, None] - window)
        if prefix > 0:
            vis |= (slot < prefix)[None, :]
        valid &= vis
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    m = jnp.max(scores, axis=-1)                             # (B,K,G)
    p = jnp.exp(scores - m[..., None])
    l = jnp.sum(p, axis=-1)
    num = jnp.einsum("bkgs,bksd->bkgd", p,
                     v_shard.astype(jnp.float32))
    return m, l, num


def decode_attention_sharded(mesh: Mesh, axis: str):
    """Returns fn(q, k_cache, v_cache, pos) with k/v sequence-sharded over
    `axis`; each shard computes flash-decode partials locally, then a pair
    of small all-reduces (max + weighted sums) merges them — wire cost
    O(B*H*hd) instead of O(B*H*S)."""
    from jax.experimental.shard_map import shard_map

    def fn(q, k_cache, v_cache, pos):
        b, nkv, g, hd = q.shape
        s = k_cache.shape[2]
        n_shards = mesh.shape[axis]
        shard_len = s // n_shards

        def shard_fn(q_, k_, v_, pos_):
            idx = jax.lax.axis_index(axis)
            m, l, num = _lse_partials(q_, k_, v_, pos_,
                                      idx * shard_len, window=0, prefix=0)
            m_g = jax.lax.pmax(m, axis)
            corr = jnp.exp(m - m_g)
            l_g = jax.lax.psum(l * corr, axis)
            num_g = jax.lax.psum(num * corr[..., None], axis)
            return (num_g / jnp.maximum(l_g[..., None], 1e-30)) \
                .astype(q_.dtype)

        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(), P(None, None, axis, None),
                      P(None, None, axis, None), P()),
            out_specs=P(),
        )(q, k_cache, v_cache, pos)

    return fn

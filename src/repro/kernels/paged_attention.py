"""Pallas TPU paged decode attention: queries attend to KV pages through
the slot page table — no gathered logical view ever materializes.

The physical cache is the engine's flat page pool, (n_pages, page_size,
kv_heads, head_dim) per layer; each slot owns one row of the
``(n_slots, pages_per_slot)`` page table whose unused entries hold the
OOB sentinel ``n_pages``.  The kernel grid is (slots, kv_heads,
page_blocks) with the page axis sequential, carrying partial-softmax
state (m, l, acc) in VMEM scratch exactly like ``decode_attention`` —
but the K/V BlockSpec index maps read the *page table* (scalar-prefetch,
SMEM-resident) to pick which physical page streams in next, vLLM
PagedAttention-style.  Sentinel entries are clamped for the DMA and
masked to -inf in-kernel, so partially-filled tables cost masked lanes,
never wrong output.

``paged_decode_attention_ref`` is the jittable ``lax.fori_loop``
reference the tier-1 CPU suite (and the engine on CPU backends) runs:
same page-at-a-time online-softmax schedule, pure jnp, and the only
implementation that supports *traced* windows (hymba's per-layer
global/local mix).  ``paged_suffix_attention_ref`` is the multi-query
variant the speculative-verify dispatch uses: Q draft positions per
slot, causal by absolute position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# the installed toolchain may predate the CompilerParams rename
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                           getattr(pltpu, "TPUCompilerParams", None))


# ------------------------------------------------------------------ #
# jittable references (the CPU/tier-1 code path)
# ------------------------------------------------------------------ #
def paged_decode_attention_ref(q, k_pool, v_pool, page_table, pos, *,
                               window=0, prefix: int = 0):
    """One new token per slot attends through its page table.

    q: (B, K, G, hd) grouped queries; k_pool/v_pool: (P, ps, K, hd)
    physical pages; page_table: (B, pps) int32, sentinel == P for
    unmapped entries; pos: (B,) int32 current token index.  `window`
    may be a traced (B,)/scalar array (0 => full causal).  Returns
    (B, K, G, hd).
    """
    b, nkv, g, hd = q.shape
    n_pages, ps, _, _ = k_pool.shape
    pps = page_table.shape[1]
    sm_scale = hd ** -0.5
    qf = q.astype(jnp.float32) * sm_scale
    static_full = isinstance(window, int) and window == 0
    win = None if static_full else jnp.broadcast_to(
        jnp.asarray(window, jnp.int32), (b,))

    def body(j, carry):
        m, l, acc = carry
        ids = page_table[:, j]                              # (B,)
        kp = jnp.take(k_pool, ids, axis=0, mode="fill",
                      fill_value=0).astype(jnp.float32)     # (B,ps,K,hd)
        vp = jnp.take(v_pool, ids, axis=0, mode="fill",
                      fill_value=0).astype(jnp.float32)
        s = jnp.einsum("bkgd,bskd->bkgs", qf, kp)           # (B,K,G,ps)
        kv_pos = j * ps + jnp.arange(ps, dtype=jnp.int32)   # (ps,)
        mask = (kv_pos[None, :] <= pos[:, None]) \
            & (ids < n_pages)[:, None]                      # (B, ps)
        if win is not None:
            inwin = kv_pos[None, :] > (pos - win)[:, None]
            inwin = jnp.where((win > 0)[:, None], inwin, True)
            if prefix > 0:
                inwin |= kv_pos[None, :] < prefix
            mask &= inwin
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        m_blk = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_blk)
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        pv = jnp.einsum("bkgs,bskd->bkgd", p, vp)
        return m_new, l_new, acc * corr + pv

    m0 = jnp.full((b, nkv, g, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nkv, g, 1), jnp.float32)
    a0 = jnp.zeros((b, nkv, g, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, pps, body, (m0, l0, a0))
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)


def paged_suffix_attention_ref(q, k_pool, v_pool, page_table, q_pos):
    """Multi-query paged attention for speculative verify: Q tokens per
    slot at absolute positions ``q_pos`` (B, Q), causal by position.

    q: (B, Q, H, hd); k_pool/v_pool: (P, ps, K, hd); page_table:
    (B, pps) with sentinel == P.  Returns (B, Q, H, hd).  Plain causal
    only (no window/prefix) — the engine gates speculation accordingly.
    """
    b, qn, h, hd = q.shape
    n_pages, ps, nkv, _ = k_pool.shape
    pps = page_table.shape[1]
    grp = h // nkv
    sm_scale = hd ** -0.5
    qf = (q.astype(jnp.float32) * sm_scale).reshape(b, qn, nkv, grp, hd)

    def body(j, carry):
        m, l, acc = carry
        ids = page_table[:, j]
        kp = jnp.take(k_pool, ids, axis=0, mode="fill",
                      fill_value=0).astype(jnp.float32)     # (B,ps,K,hd)
        vp = jnp.take(v_pool, ids, axis=0, mode="fill",
                      fill_value=0).astype(jnp.float32)
        s = jnp.einsum("bqkgd,bskd->bqkgs", qf, kp)
        kv_pos = j * ps + jnp.arange(ps, dtype=jnp.int32)
        mask = (kv_pos[None, None, :] <= q_pos[:, :, None]) \
            & (ids < n_pages)[:, None, None]                # (B, Q, ps)
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        m_blk = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_blk)
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        pv = jnp.einsum("bqkgs,bskd->bqkgd", p, vp)
        return m_new, l_new, acc * corr + pv

    m0 = jnp.full((b, qn, nkv, grp, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, qn, nkv, grp, 1), jnp.float32)
    a0 = jnp.zeros((b, qn, nkv, grp, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, pps, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(b, qn, h, hd).astype(q.dtype)


# ------------------------------------------------------------------ #
# Pallas kernel (one physical page per sequential grid step)
# ------------------------------------------------------------------ #
def _paged_decode_kernel(table_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, sm_scale: float,
                         page_size: int, n_pages: int, window: int,
                         prefix: int):
    bi = pl.program_id(0)
    ij = pl.program_id(2)
    nj = pl.num_programs(2)
    pos = pos_ref[bi]
    page = table_ref[bi, ij]

    @pl.when(ij == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip sentinel pages and pages entirely past the valid region
    blk_start = ij * page_size
    run = jnp.logical_and(page < n_pages, blk_start <= pos)
    if window > 0:
        in_reach = (blk_start + page_size - 1) > (pos - window)
        if prefix > 0:
            in_reach = jnp.logical_or(in_reach, blk_start < prefix)
        run = jnp.logical_and(run, in_reach)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale      # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)              # (ps, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # (G, ps)
        kv_pos = blk_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = kv_pos <= pos
        if window > 0:
            inwin = kv_pos > pos - window
            if prefix > 0:
                inwin = jnp.logical_or(inwin, kv_pos < prefix)
            mask = jnp.logical_and(mask, inwin)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_blk = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_blk)
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1,
                                                 keepdims=True)
        v = v_ref[0, :, 0].astype(jnp.float32)              # (ps, hd)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)             # (G, hd)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new

    @pl.when(ij == nj - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention(q, k_pool, v_pool, page_table, pos, *,
                           window: int = 0, prefix: int = 0,
                           interpret: bool = False):
    """Pallas paged decode attention.  q: (B, K, G, hd); k_pool/v_pool:
    (P, ps, K, hd); page_table: (B, pps) int32 with sentinel == P; pos:
    (B,) int32.  Returns (B, K, G, hd).  `window`/`prefix` must be
    static here — callers with traced windows use the ref."""
    b, nkv, g, hd = q.shape
    n_pages, ps, _, _ = k_pool.shape
    pps = page_table.shape[1]
    grid = (b, nkv, pps)
    kernel = functools.partial(
        _paged_decode_kernel, sm_scale=hd ** -0.5, page_size=ps,
        n_pages=n_pages, window=window, prefix=prefix)

    # sentinel entries still drive the DMA index map: clamp them to a
    # real page (the kernel masks the whole block, so the data is dead)
    def kv_map(bi, hi, ij, table_ref, _pos):
        return (jnp.minimum(table_ref[bi, ij], n_pages - 1), 0, hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda bi, hi, ij, _t, _p: (bi, hi, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
            pl.BlockSpec((1, ps, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bi, hi, ij, _t, _p: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nkv, g, hd), q.dtype),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table, pos, q, k_pool, v_pool)

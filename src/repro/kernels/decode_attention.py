"""Pallas TPU flash-decode: one new token attends to a long KV cache.

The cache's sequence dim is tiled into blocks; grid
(batch, kv_heads, kv_blocks) with kv_blocks sequential, carrying the
partial-softmax state (m, l, acc) for the G=H/K query heads of this kv head
in VMEM scratch.  Per-sequence valid length arrives via scalar prefetch
(`pos`, (B,) int32) — the SMEM-resident scalar drives block masking, so
ragged batches (continuous batching!) don't waste MXU work on dead blocks:
blocks entirely past pos[b] are skipped.

This kernel is the distributed flash-decode building block: when the cache
is sequence-sharded across chips, each chip runs it over its shard and the
(m, l, acc) partials are combined with a tiny LSE all-reduce
(`ops.decode_attention_sharded`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# the installed toolchain may predate the CompilerParams rename
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                           getattr(pltpu, "TPUCompilerParams", None))


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   sm_scale: float, block_k: int, window: int,
                   prefix: int):
    bi = pl.program_id(0)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)
    pos = pos_ref[bi]

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip blocks entirely beyond the valid cache region (ragged batch)
    blk_start = ik * block_k
    run = blk_start <= pos
    if window > 0:
        in_reach = (blk_start + block_k - 1) > (pos - window)
        if prefix > 0:
            in_reach = jnp.logical_or(in_reach, blk_start < prefix)
        run = jnp.logical_and(run, in_reach)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale      # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # (G, bk)
        kv_pos = blk_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = kv_pos <= pos
        if window > 0:
            inwin = kv_pos > pos - window
            if prefix > 0:
                inwin = jnp.logical_or(inwin, kv_pos < prefix)
            mask = jnp.logical_and(mask, inwin)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_blk = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_blk)
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1,
                                                 keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)             # (G, hd)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     prefix: int = 0, block_k: int = 256,
                     interpret: bool = False, return_lse: bool = False):
    """q: (B, K, G, hd) — new-token queries grouped by kv head;
    k_cache/v_cache: (B, K, S, hd); pos: (B,) int32 (current token index).
    Returns (B, K, G, hd) [+ (m, l) partials when return_lse]."""
    b, nkv, g, hd = q.shape
    s = k_cache.shape[2]
    block_k = min(block_k, s)
    assert s % block_k == 0
    grid = (b, nkv, s // block_k)
    kernel = functools.partial(
        _decode_kernel, sm_scale=hd ** -0.5, block_k=block_k,
        window=window, prefix=prefix)

    out_shapes = [jax.ShapeDtypeStruct((b, nkv, g, hd), q.dtype)]
    # NOTE: with scalar prefetch, index maps receive the scalar ref as an
    # extra trailing argument.
    out_specs = [pl.BlockSpec((1, 1, g, hd),
                              lambda bi, hi, ki, _p: (bi, hi, 0, 0))]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda bi, hi, ki, _p: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, hi, ki, _p: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, hi, ki, _p: (bi, hi, ki, 0)),
        ],
        out_specs=out_specs[0],
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes[0],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos, q, k_cache, v_cache)
    return out

"""Pallas TPU flash attention (prefill): GQA, causal, sliding-window,
always-visible prefix.

TPU-native tiling: grid (batch, q_heads, q_blocks, kv_blocks) with the
kv_blocks dim sequential ("arbitrary"); online-softmax state (m, l, acc)
lives in VMEM scratch across kv iterations.  Block shapes default to
(128, 128) — MXU-aligned (multiples of 128 on both matmul dims) and small
enough that q/k/v tiles + scratch fit VMEM:
    bq*hd + 2*bk*hd (bf16) + bq*bk + bq*hd + 2*bq (f32) ~ 0.25 MB << 16 MB.

Validated against ``ref.flash_attention_ref`` with interpret=True on CPU
(shape/dtype sweeps in tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# the installed toolchain may predate the CompilerParams rename
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                           getattr(pltpu, "TPUCompilerParams", None))


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, block_q: int, block_k: int,
                  causal: bool, window: int, prefix: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kv_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # skip fully-masked blocks (beyond the causal frontier / window)
    run = True
    if causal:
        run = (ik * block_k) <= (iq * block_q + block_q - 1)
    if causal and window > 0:
        # block entirely left of the window AND not prefix-visible
        left_edge = iq * block_q - window
        in_reach = (ik * block_k + block_k - 1) > left_edge
        has_prefix = (ik * block_k) < prefix
        run = jnp.logical_and(run, jnp.logical_or(in_reach, has_prefix)) \
            if prefix > 0 else jnp.logical_and(run, in_reach)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale      # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # (bq, bk)
        if causal:
            mask = kv_pos <= q_pos
            if window > 0:
                inwin = kv_pos > q_pos - window
                if prefix > 0:
                    inwin = jnp.logical_or(inwin, kv_pos < prefix)
                mask = jnp.logical_and(mask, inwin)
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_blk = jnp.max(s, axis=-1, keepdims=True)          # (bq, 1)
        m_new = jnp.maximum(m_prev, m_blk)
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                              # (bq, bk)
        l_new = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)             # (bq, hd)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    prefix: int = 0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, H, Sq, hd); k, v: (B, K, Skv, hd) with H % K == 0.
    Returns (B, H, Sq, hd)."""
    b, h, sq, hd = q.shape
    _, nkv, skv, _ = k.shape
    g = h // nkv
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0
    grid = (b, h, sq // block_q, skv // block_k)
    sm_scale = hd ** -0.5

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, block_q=block_q,
        block_k=block_k, causal=causal, window=window, prefix=prefix)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, hi, qi, ki, g_=g: (bi, hi // g_, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, hi, qi, ki, g_=g: (bi, hi // g_, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)

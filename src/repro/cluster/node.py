"""Backend node agent — one heterogeneous Service-Backend node.

A node hosts multiple model *instances* (engines) packed into its HBM by the
SDAI controller.  Small models run REAL jitted engines; large configs run in
`accounted` mode (exact byte accounting + analytic latency from the node's
capability vector) so thousand-node fleets stay simulable on one host.  Each
node also runs its own replica proxy (`NodeProxy` in core/frontend.py),
mirroring the paper's per-node HAProxy.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Optional

from repro.cluster.hardware import (NODE_CLASSES, RUNTIME_RESERVE_FRACTION,
                                    NodeClass)
from repro.configs.base import ArchConfig
from repro.serving.engine import EngineConfig, EngineFailure, InferenceEngine
from repro.serving.request import CODE_ENGINE_FAILED, Request

_inst_ids = itertools.count()


def kv_pool_bytes(cfg: ArchConfig, n_slots: int, max_len: int,
                  page_size: int = 0, kv_pages: int = 0) -> int:
    """KV/state bytes one instance's cache pool occupies.  With a page
    budget (`page_size` x `kv_pages`), the sequence-scaling KV term is
    charged per *page*, not per worst-case `n_slots x max_len` strip —
    the whole point of the paged pool; constant-size per-slot state
    (recurrent/ssm, encoder cross-attention) still scales with slots."""
    dense = cfg.cache_bytes(n_slots, max_len)
    if not (page_size and kv_pages) or cfg.block == "xlstm":
        return int(dense)
    eff = max_len if cfg.swa_window == 0 else min(max_len, cfg.swa_window)
    dense_kv = n_slots * eff * cfg.kv_bytes_per_token()
    paged_kv = kv_pages * page_size * cfg.kv_bytes_per_token()
    return int(dense - dense_kv + paged_kv)


@functools.lru_cache(maxsize=4096)
def instance_bytes(cfg: ArchConfig, quantize: str, n_slots: int,
                   max_len: int, page_size: int = 0,
                   kv_pages: int = 0) -> int:
    """Exact HBM bytes one instance occupies: weights at rest + KV pool
    (page-budget-sized when `page_size`/`kv_pages` are given).  This is
    the quantity placement charges — the paper's 'model capacity' panel
    (VRAM required per instance).  Cached: placement calls this per
    (bin x commit) across thousand-node fleets."""
    wdt = {"": cfg.dtype, "int8": "int8", "int4": "int4"}[quantize]
    w = cfg.param_bytes(wdt)
    kv = kv_pool_bytes(cfg, n_slots, max_len, page_size, kv_pages)
    return int(w + kv)


@dataclasses.dataclass
class Instance:
    instance_id: int
    model_name: str
    cfg: ArchConfig
    quantize: str
    n_slots: int
    max_len: int
    bytes: int
    engine: Optional[InferenceEngine] = None     # None => accounted mode
    page_size: int = 0
    kv_pages: int = 0
    # accounted-mode synthetic state
    sim_active: int = 0
    # per-instance step lock: the sharded pump executor steps instances
    # concurrently, so engine mutation (step/cancel/fail/retire) is
    # serialized here instead of on the whole-node lock
    lock: threading.RLock = dataclasses.field(
        default_factory=threading.RLock, repr=False, compare=False)

    @property
    def alive(self) -> bool:
        return self.engine.alive if self.engine else True

    @property
    def load(self) -> float:
        return self.engine.load if self.engine else float(self.sim_active)


class BackendNode:
    def __init__(self, node_id: str, klass: str,
                 param_store=None, seed: int = 0):
        self.node_id = node_id
        self.klass: NodeClass = NODE_CLASSES[klass]
        self.instances: Dict[int, Instance] = {}
        self.param_store = param_store          # model name -> params fn
        self._alive = True
        self._seed = seed
        self.last_heartbeat = time.monotonic()
        # chaos harness hook (repro.cluster.faults.FaultInjector); None
        # in production.  Consulted at the pump/submit/heartbeat
        # boundaries so faults land at exact, reproducible step counts.
        self.faults = None
        # `lock` guards node structure (the instances map, alive flag);
        # engine mutation is serialized per-instance on `Instance.lock`
        # (always acquired *after* the node lock, never before — no
        # ordering cycle).  `work_cv` is a *separate* light lock so
        # submitters can wake this node's pump thread without contending
        # on a running step — and, crucially, so a pump thread that
        # re-routes a dying request to another node mid-step never waits
        # on that node's big lock.
        self.lock = threading.RLock()
        self.work_cv = threading.Condition(threading.Lock())
        # sharded executor: created lazily the first time this node pumps
        # more than one live engine, so multi-instance nodes overlap
        # their fused-decode dispatches instead of stepping serially
        self._executor: Optional[ThreadPoolExecutor] = None
        self._executor_size = 0

    # ------------------------------------------------------------- #
    @property
    def hbm_budget(self) -> int:
        return int(self.klass.hbm_total * (1 - RUNTIME_RESERVE_FRACTION))

    @property
    def hbm_used(self) -> int:
        return sum(i.bytes for i in self.instances.values())

    @property
    def hbm_free(self) -> int:
        return self.hbm_budget - self.hbm_used

    @property
    def alive(self) -> bool:
        return self._alive

    def utilization(self) -> float:
        return self.hbm_used / float(self.hbm_budget)

    # ------------------------------------------------------------- #
    def discovery_payload(self) -> Dict:
        """What the node reports during the controller's discovery phase."""
        return {
            "node_id": self.node_id,
            "class": self.klass.name,
            "chips": self.klass.chips,
            "hbm_total": self.klass.hbm_total,
            "hbm_budget": self.hbm_budget,
            "flops_total": self.klass.flops_total,
            "toolkit": self.klass.toolkit,
            "year": self.klass.year,
            "legacy": self.klass.legacy,
            "preloaded": [i.model_name for i in self.instances.values()],
        }

    def heartbeat(self) -> Optional[Dict]:
        if not self._alive:
            return None
        if self.faults is not None \
                and self.faults.heartbeat_muted(self.node_id):
            # silent heartbeat loss: the process is up and serving, but
            # the control plane hears nothing — the zombie case the
            # controller must fence before re-routing
            return None
        self.last_heartbeat = time.monotonic()
        return {
            "node_id": self.node_id,
            "hbm_used": self.hbm_used,
            "instances": {
                i.instance_id: {"model": i.model_name, "alive": i.alive,
                                "load": i.load}
                for i in self.instances.values()},
            "ts": self.last_heartbeat,
        }

    # ------------------------------------------------------------- #
    def deploy(self, cfg: ArchConfig, *, quantize: str = "",
               n_slots: int = 4, max_len: int = 128,
               real: bool = True, decode_block: int = 4,
               page_size: int = 16, kv_pages: int = 0,
               paged: bool = True, prefix_cache: bool = False,
               prefix_cache_pages: int = 0, host_kv_pages: int = 0,
               prefix_share_tenants: bool = False,
               paged_attention: bool = False,
               speculative: bool = False,
               spec_draft: int = 4) -> Instance:
        """Launch one model instance (the controller's startup-script
        analogue).  `kv_pages` sizes the paged KV pool (0 => the
        contiguous-equivalent budget); HBM is charged by page budget, not
        worst-case strips.  Raises MemoryError when it would not fit —
        placement should never let that happen (property-tested)."""
        pages_per_slot = -(-max_len // page_size)
        eff_pages = kv_pages if (paged and cfg.block != "xlstm") \
            and kv_pages else n_slots * pages_per_slot
        need = instance_bytes(cfg, quantize, n_slots, max_len,
                              page_size, eff_pages)
        if need > self.hbm_free:
            raise MemoryError(
                f"{self.node_id}: {cfg.name} needs {need/2**30:.2f} GiB, "
                f"free {self.hbm_free/2**30:.2f} GiB")
        engine = None
        if real:
            params = self.param_store(cfg) if self.param_store else None
            if params is None:
                real = False
            else:
                engine = InferenceEngine(
                    cfg, params,
                    EngineConfig(n_slots=n_slots, max_len=max_len,
                                 quantize=quantize, seed=self._seed,
                                 decode_block=decode_block,
                                 page_size=page_size, kv_pages=kv_pages,
                                 paged=paged, prefix_cache=prefix_cache,
                                 prefix_cache_pages=prefix_cache_pages,
                                 host_kv_pages=host_kv_pages,
                                 prefix_share_tenants=prefix_share_tenants,
                                 paged_attention=paged_attention,
                                 speculative=speculative,
                                 spec_draft=spec_draft))
        inst = Instance(next(_inst_ids), cfg.name, cfg, quantize, n_slots,
                        max_len, need, engine, page_size=page_size,
                        kv_pages=eff_pages)
        with self.lock:
            self.instances[inst.instance_id] = inst
        return inst

    def undeploy(self, instance_id: int):
        with self.lock:
            self.instances.pop(instance_id, None)

    # ------------------------------------------------------------- #
    def submit(self, instance_id: int, req: Request) -> bool:
        """Enqueue a request on one of this node's engines.  Deliberately
        lock-free on `self.lock`: real-engine submits only touch the
        engine's internally-locked scheduler queue, so a pump thread
        re-routing a request here mid-step can never deadlock across
        nodes.  Wakes this node's pump thread on success."""
        if not self._alive:
            req.finish(error=f"node {self.node_id} down",
                       code=CODE_ENGINE_FAILED)
            return False
        if self.faults is not None \
                and self.faults.submit_blocked(self.node_id):
            # transient submit flap (dropped RPC): refuse without dying,
            # the frontend's retry loop fails over to the next replica
            req.finish(error=f"node {self.node_id} dropped the submit",
                       code=CODE_ENGINE_FAILED)
            return False
        inst = self.instances.get(instance_id)
        if inst is None:
            req.finish(error="instance gone", code=CODE_ENGINE_FAILED)
            return False
        req.node = self.node_id
        req.replica = str(instance_id)
        if inst.engine:
            ok = inst.engine.submit(req)
            if ok:
                self.notify_work()
            return ok
        # accounted mode: synthetic tokens through the same emit/finish
        # streaming path as real engines, honoring sampling.max_tokens
        inst.sim_active += 1
        for t in range(max(req.sampling.max_tokens, 0)):
            tok = (req.request_id + t) % max(inst.cfg.vocab, 1)
            req.emit(tok)
            if req.sampling.eos_id >= 0 and tok == req.sampling.eos_id:
                break
        req.finish()
        inst.sim_active -= 1
        return True

    def cancel(self, instance_id: int, request_id: int):
        """Abort a request on one of this node's engines (frees its slot
        and pages).  Takes the instance lock: cancellation rewrites
        per-slot device state and must not interleave with that engine's
        fused-decode step.  Returns the engine's verdict — "queued"
        (never admitted; the gateway refunds the tenant's token-bucket
        charge), "active", or False."""
        inst = self.instances.get(instance_id)
        if inst is None or inst.engine is None:
            return False
        with inst.lock:
            return inst.engine.cancel(request_id)

    # ------------------------------------------------------------- #
    def has_work(self) -> bool:
        """Any engine with active slots or queued requests."""
        if not self._alive:
            return False
        return any(inst.engine is not None and inst.engine.alive
                   and (inst.engine.slot_req or inst.engine.scheduler.depth)
                   for inst in list(self.instances.values()))

    def notify_work(self):
        """Wake this node's pump thread (no-op without a runtime)."""
        with self.work_cv:
            self.work_cv.notify_all()

    def _step_instance(self, inst: Instance, max_steps: int) -> int:
        """Advance one engine under its own lock (the sharded executor's
        unit of work)."""
        emitted = 0
        with inst.lock:
            eng = inst.engine
            if eng is None or not eng.alive:
                return 0
            for _ in range(max_steps):
                if eng.slot_req or eng.scheduler.depth:
                    try:
                        emitted += eng.step()
                    except EngineFailure:
                        break            # failed under us mid-loop
        return emitted

    def _get_executor(self, n: int) -> ThreadPoolExecutor:
        # under the node lock: recover() tears the pool down concurrently
        want = min(max(n, 1), 4)
        with self.lock:
            if self._executor is not None and self._executor_size < want:
                # the node grew (elastic scale-up): re-size so new
                # instances actually overlap.  Safe: pump() waits on
                # every future, so the old pool is idle here.
                self._executor.shutdown(wait=False)
                self._executor = None
            if self._executor is None:
                self._executor = ThreadPoolExecutor(
                    max_workers=want,
                    thread_name_prefix=f"step-{self.node_id}")
                self._executor_size = want
            return self._executor

    def pump(self, max_steps: int = 1) -> int:
        """Advance all engines (the node's serving loop).  Multi-instance
        nodes step their engines concurrently through a small per-node
        thread pool (one fused dispatch per instance overlaps on device);
        single-instance nodes step inline, paying no executor overhead.
        Returns decode tokens emitted, so pump loops can tell progress
        from idling."""
        if not self._alive:
            return 0
        if self.faults is not None:
            # the chaos clock ticks at pump boundaries: due faults fire
            # here (crash, hang, slow, window transitions) so every
            # injected failure lands at an exact, reproducible step
            self.faults.on_step(self)
            if not self._alive:        # the due fault crashed this node
                return 0
        with self.lock:
            insts = [i for i in self.instances.values()
                     if i.engine is not None and i.engine.alive]
        if not insts:
            return 0
        if len(insts) == 1:
            return self._step_instance(insts[0], max_steps)
        ex = self._get_executor(len(insts))
        futs = [ex.submit(self._step_instance, i, max_steps)
                for i in insts]
        return sum(f.result() for f in futs)

    # ------------------------------------------------------------- #
    def fail(self):
        """Node-level outage (power/network loss)."""
        with self.lock:
            self._alive = False
            insts = list(self.instances.values())
        for inst in insts:
            if inst.engine:
                with inst.lock:    # not mid-step on the sharded executor
                    inst.engine.fail()
        self.notify_work()         # unblock the pump thread promptly

    def recover(self):
        """Node returns empty — models must be re-placed by the
        controller (the Ollama re-pull analogue)."""
        with self.lock:
            self._alive = True
            self.instances.clear()
            if self._executor is not None:
                self._executor.shutdown(wait=False)
                self._executor = None
                self._executor_size = 0
        self.last_heartbeat = time.monotonic()

"""Deterministic chaos harness — seeded fault injection at exact steps.

The paper's availability claim ("resilience against failures or workload
fluctuations") is only credible if failure handling is *exercised
continuously and reproducibly*, not assumed.  `FaultInjector` is a
single chaos clock wired into every `BackendNode`: the clock advances by
one at each `node.pump()` boundary (any node), and every scheduled
`FaultSpec` fires when the clock reaches its `at_step` — so a given
(seed, schedule) always produces the same failure sequence, and a chaos
soak that passes locally reproduces bit-for-bit in CI.

Fault kinds (all consulted at pump/submit/heartbeat boundaries — the
same boundaries real outages hit, never mid-dispatch):

* ``crash``          — node-level outage (`node.fail()`): every in-flight
                       request finishes ENGINE_FAILED and the gateway
                       migrates mid-stream victims to survivors.
* ``mute_heartbeat`` — silent heartbeat loss: the node keeps serving but
                       the control plane hears nothing (the zombie the
                       controller must fence before re-routing).
* ``hang`` / ``slow``— the node's pump stalls `stall_s` per step for the
                       window: `hang` (long stall) trips the runtime
                       watchdog; `slow` (short stall) makes a straggler.
* ``flap``           — submits to the node are refused for the window;
                       the frontend's retry loop fails over.
* ``swap_fail``      — the node's host swap tier refuses new puts for
                       the window; preemption falls back to recompute.

Windowed kinds (`mute_heartbeat`/`hang`/`slow`/`flap`/`swap_fail`) stay
active for `duration_steps` after firing (0 => until `uninstall()`).
Every firing is recorded in `fired` and emitted on the event bus as a
``fault_injected`` event when a bus is installed.
"""
from __future__ import annotations

import dataclasses
import random
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

FAULT_KINDS = ("crash", "mute_heartbeat", "hang", "slow", "flap",
               "swap_fail")
_WINDOWED = ("mute_heartbeat", "hang", "slow", "flap", "swap_fail")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: `kind` hits `node` when the global chaos
    clock reaches `at_step`."""
    kind: str
    node: str
    at_step: int
    duration_steps: int = 0      # windowed kinds: 0 => until uninstall
    stall_s: float = 0.0         # hang/slow: injected sleep per pump

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {FAULT_KINDS})")


class FaultInjector:
    """Seeded, step-deterministic fault scheduler.

    Thread-safety: `on_step` is called from every node's pump thread and
    `submit_blocked` re-enters from migration resubmits on the same
    thread, so internal state sits behind an RLock; fault *application*
    (node.fail, sleeps, flag flips) happens outside it, so a crash
    cascade never holds the injector lock while it fans out."""

    def __init__(self, specs: Iterable[FaultSpec],
                 bus=None):
        self.specs: List[FaultSpec] = sorted(specs,
                                             key=lambda s: s.at_step)
        self.bus = bus
        self.fleet = None
        self.step = 0
        self.fired: List[Tuple[int, FaultSpec]] = []
        self._pending: List[FaultSpec] = list(self.specs)
        # node -> window-end step (None => until uninstall)
        self._mute: Dict[str, Optional[int]] = {}
        self._flap: Dict[str, Optional[int]] = {}
        self._swap: Dict[str, Optional[int]] = {}
        self._stall: Dict[str, Tuple[Optional[int], float]] = {}
        self._lock = threading.RLock()

    # ---------------------------------------------------------------- #
    def install(self, fleet, bus=None) -> "FaultInjector":
        """Wire this injector into every node of `fleet` (including the
        heartbeat/submit hooks) and start the chaos clock."""
        self.fleet = fleet
        if bus is not None:
            self.bus = bus
        for node in fleet.nodes.values():
            node.faults = self
        return self

    def uninstall(self):
        if self.fleet is not None:
            for node in self.fleet.nodes.values():
                if node.faults is self:
                    node.faults = None
        with self._lock:
            self._mute.clear()
            self._flap.clear()
            self._stall.clear()
            self._swap.clear()
        self._sync_swap_flags()

    # ---------------------------------------------------------------- #
    def _active(self, windows: Dict[str, Optional[int]],
                node_id: str) -> bool:
        end = windows.get(node_id, 0)
        if node_id not in windows:
            return False
        return end is None or self.step < end

    def heartbeat_muted(self, node_id: str) -> bool:
        with self._lock:
            return self._active(self._mute, node_id)

    def submit_blocked(self, node_id: str) -> bool:
        with self._lock:
            return self._active(self._flap, node_id)

    def swap_blocked(self, node_id: str) -> bool:
        with self._lock:
            return self._active(self._swap, node_id)

    # ---------------------------------------------------------------- #
    def _sync_swap_flags(self):
        """Mirror active swap_fail windows onto the target nodes' host
        pools (the engine-side hook is a plain flag so the swap path
        stays lock-free)."""
        if self.fleet is None:
            return
        targets = {s.node for s in self.specs if s.kind == "swap_fail"}
        for nid in targets:
            node = self.fleet.nodes.get(nid)
            if node is None:
                continue
            blocked = self.swap_blocked(nid)
            for inst in list(node.instances.values()):
                eng = inst.engine
                if eng is not None and eng.host_pool is not None:
                    eng.host_pool.fail_puts = blocked

    def on_step(self, node) -> None:
        """Advance the chaos clock by one pump boundary and apply every
        fault that just came due.  Crashes and sleeps run outside the
        injector lock."""
        with self._lock:
            self.step += 1
            now = self.step
            due = [s for s in self._pending if s.at_step <= now]
            if due:
                self._pending = [s for s in self._pending
                                 if s.at_step > now]
                for s in due:
                    self.fired.append((now, s))
                    end = (now + s.duration_steps
                           if s.duration_steps > 0 else None)
                    if s.kind == "mute_heartbeat":
                        self._mute[s.node] = end
                    elif s.kind == "flap":
                        self._flap[s.node] = end
                    elif s.kind == "swap_fail":
                        self._swap[s.node] = end
                    elif s.kind in ("hang", "slow"):
                        self._stall[s.node] = (end, s.stall_s)
            stall = self._stall.get(node.node_id)
            stall_s = 0.0
            if stall is not None:
                end, secs = stall
                if end is None or now < end:
                    stall_s = secs
                else:
                    del self._stall[node.node_id]
            swap_windows = bool(self._swap)
        # ---- apply outside the lock ---------------------------------- #
        if due:
            if self.bus is not None:
                for s in due:
                    self.bus.emit("fault_injected", fault=s.kind,
                                  node=s.node, at_step=now,
                                  duration_steps=s.duration_steps)
            self._sync_swap_flags()
            for s in due:
                if s.kind == "crash" and self.fleet is not None:
                    victim = self.fleet.nodes.get(s.node)
                    if victim is not None and victim.alive:
                        victim.fail()
        elif swap_windows:
            self._sync_swap_flags()    # windows also *expire* on steps
        if stall_s > 0:
            import time
            time.sleep(stall_s)

    # ---------------------------------------------------------------- #
    @classmethod
    def kill_schedule(cls, seed: int, node_ids: Sequence[str],
                      n_kills: int = 1, first_step: int = 8,
                      spacing: int = 16, bus=None) -> "FaultInjector":
        """Seeded kill schedule: `n_kills` distinct victims drawn with
        `random.Random(seed)`, crashed at `first_step`, `first_step +
        spacing`, ... — the reproducible soak CI runs."""
        rng = random.Random(seed)
        victims = rng.sample(list(node_ids),
                             min(n_kills, len(node_ids)))
        return cls([FaultSpec("crash", v, first_step + i * spacing)
                    for i, v in enumerate(victims)], bus=bus)

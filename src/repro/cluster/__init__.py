from repro.cluster.faults import FaultInjector, FaultSpec
from repro.cluster.fleet import Fleet, paper_testbed, scale_fleet
from repro.cluster.hardware import NODE_CLASSES, PAPER_TESTBED, NodeClass
from repro.cluster.node import BackendNode, Instance, instance_bytes

__all__ = ["NodeClass", "NODE_CLASSES", "PAPER_TESTBED", "BackendNode",
           "Instance", "instance_bytes", "Fleet", "paper_testbed",
           "scale_fleet", "FaultInjector", "FaultSpec"]

"""Fleet assembly & failure injection.

`paper_testbed()` reproduces the paper's 6-node heterogeneous deployment;
`scale_fleet()` builds thousand-node fleets from a class mix for the
large-scale placement/availability benchmarks.
"""
from __future__ import annotations

import random
from typing import Callable, Dict, List, Optional

from repro.cluster.hardware import PAPER_TESTBED
from repro.cluster.node import BackendNode


class Fleet:
    def __init__(self, nodes: Optional[List[BackendNode]] = None):
        self.nodes: Dict[str, BackendNode] = {
            n.node_id: n for n in (nodes or [])}

    def add(self, node: BackendNode):
        self.nodes[node.node_id] = node

    def remove(self, node_id: str):
        self.nodes.pop(node_id, None)

    def alive_nodes(self) -> List[BackendNode]:
        return [n for n in self.nodes.values() if n.alive]

    def pump(self, max_steps: int = 1):
        for n in self.alive_nodes():
            n.pump(max_steps)

    # failure injection ------------------------------------------- #
    def fail_node(self, node_id: str):
        self.nodes[node_id].fail()

    def fail_random(self, rng: random.Random, k: int = 1) -> List[str]:
        alive = [n.node_id for n in self.alive_nodes()]
        victims = rng.sample(alive, min(k, len(alive)))
        for v in victims:
            self.fail_node(v)
        return victims

    def recover_node(self, node_id: str):
        self.nodes[node_id].recover()

    def total_hbm(self) -> int:
        return sum(n.hbm_budget for n in self.alive_nodes())

    def used_hbm(self) -> int:
        return sum(n.hbm_used for n in self.alive_nodes())


def paper_testbed(param_store: Optional[Callable] = None) -> Fleet:
    """The paper's Table-2 testbed, GPU-for-TPU adapted."""
    return Fleet([BackendNode(nid, klass, param_store=param_store, seed=i)
                  for i, (nid, klass) in enumerate(PAPER_TESTBED)])


def scale_fleet(n_nodes: int, mix: Optional[Dict[str, float]] = None,
                param_store: Optional[Callable] = None,
                seed: int = 0) -> Fleet:
    """Large fleet with a heterogeneous class mix (default: paper-like
    40% v5lite, 25% legacy, 25% v5e-1, 10% v5e-4)."""
    mix = mix or {"v5lite-1": 0.4, "v2-legacy": 0.25, "v5e-1": 0.25,
                  "v5e-4": 0.10}
    rng = random.Random(seed)
    classes = list(mix)
    weights = [mix[c] for c in classes]
    fleet = Fleet()
    for i in range(n_nodes):
        klass = rng.choices(classes, weights)[0]
        fleet.add(BackendNode(f"node{i:05d}", klass,
                              param_store=param_store, seed=i))
    return fleet

"""Heterogeneous hardware classes — the TPU adaptation of the paper's mixed
NVIDIA/AMD fleet (Table 2).

The controller never touches vendor APIs; it consumes *capability vectors*
(HBM bytes, peak FLOP/s, chips, interconnect generation) — exactly the
abstraction that makes the paper's software-defined approach work.  The
paper's GPUs map to TPU slice classes of comparable memory/age:

    RX 6600 8GB (ROCm, 2021)      -> v5lite-1  (1 chip,  8 GB)
    RTX 3070 8GB (CUDA, 2020)     -> v5lite-1  (1 chip,  8 GB)
    GTX 1660S 6GB (CUDA, 2019)    -> v2-legacy (1 chip,  6 GB usable)
    2x GTX 1660S (CUDA, 2019)     -> v2-legacy-2 (2 chips, 6 GB each)
    RX 6800 16GB (ROCm, 2020)     -> v5e-1     (1 chip, 16 GB)
plus datacenter classes (v5e-4/8, v5p) for the 1000-node scaling story.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

GB = 1024 ** 3


@dataclasses.dataclass(frozen=True)
class NodeClass:
    name: str
    chips: int
    hbm_per_chip: int            # bytes
    flops_per_chip: float        # bf16 FLOP/s
    ici_bw: float                # bytes/s per link (intra-node)
    year: int
    toolkit: str                 # paper keeps CUDA/ROCm visible in the UI
    legacy: bool = False
    # per-GPU-class performance/cost vector: memory bandwidth bounds the
    # decode roofline (weights + KV stream every step); `cost_per_hour`
    # is the class's relative cost weight — legacy cards are nearly free
    # (sunk hardware, the paper's whole premise), datacenter slices are
    # priced like cloud on-demand.  The perf model and the cost-optimal
    # placement solver consume both.
    hbm_bw: float = 819e9        # bytes/s per chip
    cost_per_hour: float = 1.0   # relative cost units per node-hour

    @property
    def hbm_total(self) -> int:
        return self.chips * self.hbm_per_chip

    @property
    def flops_total(self) -> float:
        return self.chips * self.flops_per_chip

    @property
    def hbm_bw_total(self) -> float:
        return self.chips * self.hbm_bw

    @property
    def cost_rate(self) -> float:
        """Cost units per second for the whole node."""
        return self.cost_per_hour / 3600.0


NODE_CLASSES: Dict[str, NodeClass] = {c.name: c for c in [
    # legacy / constrained classes (the paper's regime)
    NodeClass("v2-legacy", 1, 6 * GB, 23e12, 70e9, 2019, "XLA-v2",
              legacy=True, hbm_bw=300e9, cost_per_hour=0.10),
    NodeClass("v2-legacy-2", 2, 6 * GB, 23e12, 70e9, 2019, "XLA-v2",
              legacy=True, hbm_bw=300e9, cost_per_hour=0.18),
    NodeClass("v5lite-1", 1, 8 * GB, 98e12, 180e9, 2021, "XLA-v5",
              hbm_bw=400e9, cost_per_hour=0.35),
    NodeClass("v5e-1", 1, 16 * GB, 197e12, 200e9, 2020, "XLA-v5",
              hbm_bw=819e9, cost_per_hour=0.60),
    # datacenter classes for scale-out
    NodeClass("v5e-4", 4, 16 * GB, 197e12, 200e9, 2023, "XLA-v5",
              hbm_bw=819e9, cost_per_hour=2.40),
    NodeClass("v5e-8", 8, 16 * GB, 197e12, 200e9, 2023, "XLA-v5",
              hbm_bw=819e9, cost_per_hour=4.80),
    NodeClass("v5p-8", 8, 95 * GB, 459e12, 600e9, 2023, "XLA-v5p",
              hbm_bw=2765e9, cost_per_hour=13.00),
]}

# The paper's 6-node testbed (Table 2), adapted chip-for-GPU.
PAPER_TESTBED: List[tuple] = [
    ("node1", "v5lite-1"),    # AMD RX 6600 8GB (ROCm)
    ("node2", "v5lite-1"),    # NVIDIA RTX 3070 8GB (CUDA)
    ("node3", "v2-legacy"),   # NVIDIA GTX 1660 Super 6GB
    ("node4", "v5lite-1"),    # AMD RX 6600 8GB (ROCm)
    ("node5", "v2-legacy-2"), # 2x NVIDIA GTX 1660 Super 6GB
    ("node6", "v5e-1"),       # AMD RX 6800 16GB (ROCm)
]

# Serving memory model: fraction of HBM reserved for runtime/activations
RUNTIME_RESERVE_FRACTION = 0.08

"""Continuous-batching inference engine — the Ollama analogue each backend
node runs, one per deployed model instance.

Fully GPU/TPU-accelerated path (no CPU fallback, per the paper): the hot
loop is *device-resident*.  Each `step()` issues at most two jitted
dispatches:

* **bucketed prefill** — queued prompts are padded to power-of-two length
  buckets and admitted as one batch per bucket; the jitted call runs the
  forward, scatters every row's cache into its pages (or slot strip),
  samples the first token per row, and updates the persistent per-slot
  state arrays — all on device.  Distinct prompt lengths inside one bucket
  share a single trace (`prefill_traces` counts compiles to prove it).
* **fused K-step decode** — a jitted `lax.scan` runs `decode_block`
  decode+sample steps per dispatch, carrying `(cache, last_tok, pos, key)`
  on device, applying per-slot temperature/top-k/top-p and an on-device
  done mask (EOS or token budget) so finished slots stop advancing
  mid-scan.  Exactly one blocking `device_get` brings back the
  `(K, n_slots)` token block plus emit/done flags.

KV memory is **paged** (`EngineConfig.paged`, default on): the physical
cache is a flat pool of `page_size`-token pages and each slot owns a page
table (`serving.kv_cache.PagedKVPool`).  The fused decode gathers every
slot's logical view through the page table once per dispatch and scatters
it back once — zero extra host syncs — while bucketed prefill lands rows
directly in their pages.  Slots may be *oversubscribed* against the page
budget (`kv_pages` below the contiguous-equivalent `n_slots x
pages_per_slot`): admission is page-aware via the two-level DWRR
scheduler, page tables grow at decode-block boundaries, and on page
exhaustion the engine preempts the lowest-deficit tenant's slot — the
victim re-enters the front of its tenant queue and later *resumes* from
its full context (prompt + generated so far) without re-emitting a token.
`paged=False` restores the contiguous per-slot strips (every slot
reserves its full `max_len` worth of pages up front) for apples-to-apples
studies.

Per-slot sampling params live in persistent device arrays written only on
admission/release/cancel — no host->device uploads or `.at[].set()` loops
inside the hot path.  Weights may be held quantized (int8/int4) at rest
and dequantized on-chip per step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import build
from repro.serving import quantization as q_lib
from repro.serving.kv_cache import (PagedKVPool, cache_bytes, gather_pages,
                                    scatter_pages, scatter_prefill_rows,
                                    split_paged, write_slots)
from repro.serving.kv_hierarchy import (HostPagePool, PrefixCache,
                                        drop_handle, swap_in_slot,
                                        swap_out_slot)
from repro.serving.request import (CODE_ENGINE_FAILED, CODE_INVALID_REQUEST,
                                   Request, RequestState)
from repro.serving.sampler import sample_batched
from repro.serving.scheduler import Scheduler, SchedulerConfig
from repro.serving import spec_decode as spec_lib


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 4
    max_len: int = 128
    quantize: str = ""            # "", "int8", "int4"
    top_k: int = 0                # engine-wide default (per-request wins)
    top_p: float = 1.0
    seed: int = 0
    decode_block: int = 4         # K decode steps fused per dispatch
    prefill_bucket_min: int = 8   # smallest power-of-two prompt bucket
    page_size: int = 16           # KV tokens per physical page
    kv_pages: int = 0             # page budget; 0 => n_slots full strips
    paged: bool = True            # False => contiguous per-slot strips
    # hierarchical KV memory (kv_hierarchy): both tiers default OFF so
    # the baseline engine keeps PR 5's exact allocation behavior
    prefix_cache: bool = False    # cross-request prefix page reuse
    prefix_cache_pages: int = 0   # device pages the cache may pin; 0 => no cap
    host_kv_pages: int = 0        # host-DRAM swap-tier pages; 0 => off
    prefix_share_tenants: bool = False  # share prefix blocks across tenants
    # paged attention + on-device speculative decoding
    paged_attention: bool = False  # attend through the page table (no
    #                                per-dispatch gather/scatter copy)
    speculative: bool = False     # n-gram propose + batched greedy verify
    spec_draft: int = 4           # draft tokens proposed per verify
    spec_table: int = 512         # proposer hash-table buckets (pow2)


class EngineFailure(RuntimeError):
    pass


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def _identity(p):
    return p


class InferenceEngine:
    """One model instance on one node."""

    def __init__(self, cfg: ArchConfig, params, engine_cfg: EngineConfig,
                 scheduler: Optional[Scheduler] = None):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.model = build(cfg)
        self.scheduler = scheduler or Scheduler(SchedulerConfig())
        self._dead = False
        self._key = jax.random.PRNGKey(engine_cfg.seed)
        # recurrent families fold right-pads into their state, so they
        # batch prefills at exact lengths instead of padded buckets
        self._supports_bucket = cfg.block not in ("xlstm", "hymba")
        # meta/vision-prefix tokens occupy cache slots ahead of the prompt
        self._prefix_tokens = (getattr(cfg, "n_meta_tokens", 0)
                               + getattr(cfg, "n_prefix_tokens", 0))
        # state-space caches are constant-size: only KV families run out
        # of cache positions and must stop decoding at max_len
        self._pos_limit = (engine_cfg.max_len if cfg.block != "xlstm"
                           else 2 ** 30)
        # xlstm state has no sequence axis: nothing to page
        self._paged = engine_cfg.paged and cfg.block != "xlstm"
        self.pool = PagedKVPool(engine_cfg.n_slots, engine_cfg.max_len,
                                page_size=engine_cfg.page_size,
                                n_pages=(engine_cfg.kv_pages
                                         if self._paged else 0))
        # page-aware admission: the scheduler charges each queued request
        # its projected page cost against the engine's free page budget
        self.scheduler.pages_for = self._pages_for
        # hierarchical KV memory: prefix reuse needs page-aligned bucketed
        # prefill over a plain causal decoder (recurrent state, enc-dec
        # cross KV, windows and prefix tokens all break block sharing)
        self._prefix_ok = (self._paged and self._supports_bucket
                           and not cfg.is_encdec
                           and self._prefix_tokens == 0
                           and getattr(cfg, "swa_window", 0) == 0)
        # page-table-direct attention: any paged family (recurrent state
        # and enc-dec cross KV stay slot-resident either way)
        self._paged_attn = engine_cfg.paged_attention and self._paged
        # speculative decoding needs the paged-attention verify path and
        # a plain causal decoder (rejected drafts must be erasable by
        # overwrite: recurrent state can't roll back, windows/prefix
        # change visibility) — the same predicate as the prefix cache
        self._spec_ok = (engine_cfg.speculative and self._paged_attn
                         and self._prefix_ok)
        self.host_pool = (HostPagePool(engine_cfg.host_kv_pages)
                          if engine_cfg.host_kv_pages > 0 and self._paged
                          else None)
        self.prefix_cache = (
            PrefixCache(self.pool, host=self.host_pool,
                        max_device_pages=engine_cfg.prefix_cache_pages,
                        share_tenants=engine_cfg.prefix_share_tenants)
            if engine_cfg.prefix_cache and self._prefix_ok else None)
        self._swapped: Dict[int, Any] = {}   # request_id -> SwapHandle

        if engine_cfg.quantize:
            bits = 8 if engine_cfg.quantize == "int8" else 4
            self.params = q_lib.quantize_tree(params, bits=bits)
            self._dequant = q_lib.dequant_tree
        else:
            self.params = params
            self._dequant = _identity

        src_len = engine_cfg.max_len if cfg.is_encdec else 0
        self.cache = self._init_cache(src_len)
        self.slot_req: Dict[int, Request] = {}
        # persistent per-slot device state: touched only by jitted
        # admission / fused-decode calls and the (rare) cancel path
        ns = engine_cfg.n_slots
        self.pos = jnp.zeros((ns,), jnp.int32)
        self.last_tok = jnp.zeros((ns,), jnp.int32)
        self.active = jnp.zeros((ns,), bool)
        self.remaining = jnp.zeros((ns,), jnp.int32)
        self.temps = jnp.zeros((ns,), jnp.float32)
        self.top_ks = jnp.zeros((ns,), jnp.int32)
        self.top_ps = jnp.ones((ns,), jnp.float32)
        self.eos_ids = jnp.full((ns,), -1, jnp.int32)
        # speculative-decoding device state: per-slot bigram proposer
        # table plus the token *preceding* last_tok (the chain seed) —
        # wiped on admission/release so a reused slot never proposes
        # from another request's stream
        self.spec_table, self.spec_prev = spec_lib.init_tables(
            ns, engine_cfg.spec_table)
        # logical KV bytes one fused dispatch moves: the gather path
        # copies every slot's logical view out and back (2x view); the
        # page-table path only writes K new tokens' KV in place
        self._view_bytes = 0
        self._write_token_bytes = 0     # all-slot KV write bytes, 1 step
        if self._paged:
            for leaf in split_paged(self.cache)[0].values():
                per_tok = (leaf.dtype.itemsize * leaf.shape[0]
                           * int(np.prod(leaf.shape[3:])))
                self._view_bytes += (per_tok * ns * self.pool.pages_per_slot
                                     * self.pool.page_size)
                self._write_token_bytes += per_tok * ns
        # decode-boundary page growth must also cover a verify's D+1
        # in-flight writes when speculation is live
        self._growth = max(engine_cfg.decode_block,
                           engine_cfg.spec_draft + 1) if self._spec_ok \
            else engine_cfg.decode_block
        # metrics
        self.total_tokens = 0
        self.total_steps = 0
        self.step_ewma_s = 0.0
        self.dispatches = 0       # jitted calls issued
        self.host_syncs = 0       # blocking device->host transfers
        self.prefill_traces = 0   # compile-cache counter: bucketed prefill
        self.decode_traces = 0    # compiles once per decode_block
        self.suffix_traces = 0    # compile-cache counter: suffix prefill
        self.preemptions = 0      # slots evicted on page exhaustion
        self.prefill_dispatch_tokens = 0   # rows x bucket actually forwarded
        self.suffix_prefills = 0  # rows admitted via cached-prefix suffix
        self.swap_outs = 0        # slots parked to the host tier
        self.swap_ins = 0         # slots restored with zero re-prefill
        # paged-attention / speculative-decoding counters
        self.logical_bytes_moved = 0   # KV bytes copied/written per decode
        self.spec_traces = 0      # compile-cache counter: verify dispatch
        self.spec_dispatches = 0  # verify dispatches issued
        self.spec_emitted = 0     # tokens emitted by verify dispatches
        self.spec_slot_accepted = np.zeros((ns,), np.int64)  # drafts/slot
        self._build_steps()

    # ------------------------------------------------------------- #
    def _init_cache(self, src_len: int):
        """Physical cache: paged leaves live as a flat (layers, n_pages,
        page_size, ...) pool; constant-size leaves (ssm states, encoder
        cross-attention KV, the whole xlstm state) stay slot-resident."""
        ns, ml = self.ecfg.n_slots, self.ecfg.max_len
        if not self._paged:
            return self.model.init_cache(ns, ml, src_len=src_len)
        spec = jax.eval_shape(
            lambda: self.model.init_cache(ns, ml, src_len=src_len))
        paged, resident = split_paged(spec)
        cache = {name: jnp.zeros(s.shape, s.dtype)
                 for name, s in resident.items()}
        for name, s in paged.items():
            cache[name] = jnp.zeros(
                (s.shape[0], self.pool.n_pages, self.pool.page_size)
                + s.shape[3:], s.dtype)
        return cache

    def _pages_for(self, req: Request) -> int:
        """Projected page cost of admitting `req` now: its full effective
        context (prompt + already-generated resume tokens + prefix) plus
        one position of decode headroom — *net* of prefix-cache pages it
        would map for free and, for a swap-parked request, net of the
        shared pages its handle already holds on device."""
        if not self._paged:
            return self.pool.pages_per_slot
        handle = self._swapped.get(req.request_id)
        if handle is not None:
            return max(len(handle.host), 1)
        eff = (len(req.prompt) + len(req.output) + self._prefix_tokens)
        need = self.pool.pages_for_tokens(min(eff + 1, self.ecfg.max_len))
        if self.prefix_cache is not None:
            eff0 = len(req.prompt) + len(req.output)
            cached = self.prefix_cache.peek(
                req.tenant, list(req.prompt) + list(req.output),
                eff0 - 1) // self.pool.page_size
            need = max(need - cached, 1)
        return need

    # ------------------------------------------------------------- #
    def _build_steps(self):
        model, ecfg = self.model, self.ecfg
        paged = self._paged
        paged_attn = self._paged_attn

        def prefill_admit(params, cache, last_tok, pos, active, remaining,
                          temps, top_ks, top_ps, eos_ids, key,
                          spec_table, spec_prev,
                          tokens, lengths, slots, row_pages,
                          r_temps, r_topk, r_topp, r_eos, r_budget,
                          r_prev, extra):
            # Python side effect fires at trace time only: counts compiles
            self.prefill_traces += 1
            p = self._dequant(params)
            kw = dict(extra)
            if self._supports_bucket:
                kw["lengths"] = lengths
            if paged:
                # rows at their true (bucketed) length; pages don't need
                # max_len-wide rows
                logits, rows_cache, pos1 = model.prefill(p, tokens, **kw)
                rows_p, rows_r = split_paged(rows_cache)
                pool_p, pool_r = split_paged(cache)
                pool_p = scatter_prefill_rows(pool_p, rows_p, row_pages)
                pool_r = write_slots(pool_r, rows_r, slots)
                cache = {**pool_p, **pool_r}
            else:
                logits, rows_cache, pos1 = model.prefill(
                    p, tokens, cache_len=ecfg.max_len, **kw)
                cache = write_slots(cache, rows_cache, slots)
            key, sk = jax.random.split(key)
            first = sample_batched(logits, sk, r_temps, r_topk, r_topp)
            done0 = ((r_budget <= 1) | ((r_eos >= 0) & (first == r_eos))
                     # prompt fills the cache: no room to decode further
                     | (pos1 + 1 >= self._pos_limit))
            # scatter admission state; padded rows carry slot == n_slots
            # and are dropped on device
            last_tok = last_tok.at[slots].set(first, mode="drop")
            pos = pos.at[slots].set(pos1 + 1, mode="drop")
            active = active.at[slots].set(~done0, mode="drop")
            remaining = remaining.at[slots].set(r_budget - 1, mode="drop")
            temps = temps.at[slots].set(r_temps, mode="drop")
            top_ks = top_ks.at[slots].set(r_topk, mode="drop")
            top_ps = top_ps.at[slots].set(r_topp, mode="drop")
            eos_ids = eos_ids.at[slots].set(r_eos, mode="drop")
            # fresh proposer state: wipe the slot's table row and seed
            # the bigram chain from the last context token
            spec_table = spec_table.at[slots].set(-1, mode="drop")
            spec_prev = spec_prev.at[slots].set(r_prev, mode="drop")
            return (cache, last_tok, pos, active, remaining, temps,
                    top_ks, top_ps, eos_ids, key, spec_table, spec_prev,
                    first, done0)

        def make_fused_decode(mode: str):
            # "greedy": every slot argmax — no PRNG, no sorts.
            # "temp":   temperature only — one categorical, no sorts.
            # "full":   per-slot top-k/top-p filters too.
            def fused_decode(params, cache, last_tok, pos, active,
                             remaining, temps, top_ks, top_ps, eos_ids,
                             key, page_table, write_table):
                self.decode_traces += 1
                p = self._dequant(params)
                if paged and not paged_attn:
                    pool_p, pool_r = split_paged(cache)
                    # one gather per dispatch materializes every slot's
                    # logical view through its page table
                    view = {**gather_pages(pool_p, page_table), **pool_r}
                else:
                    # page-table-direct attention (or contiguous strips):
                    # the physical cache is the working view — no copy
                    view = cache

                def body(carry, _):
                    view, last_tok, pos, active, remaining, key = carry
                    if paged_attn:
                        logits, view = model.decode_paged(
                            p, view, last_tok, pos, page_table,
                            write_table)
                    else:
                        logits, view = model.decode(p, view, last_tok,
                                                    pos)
                    if mode == "greedy":
                        sampled = jnp.argmax(logits, axis=-1) \
                            .astype(jnp.int32)
                    else:
                        key, sk = jax.random.split(key)
                        sampled = sample_batched(
                            logits, sk, temps, top_ks, top_ps,
                            use_top_k=(mode == "full"),
                            use_top_p=(mode == "full"))
                    tok = jnp.where(active, sampled, last_tok)
                    emit = active
                    remaining = jnp.where(active, remaining - 1,
                                          remaining)
                    pos = pos + active.astype(jnp.int32)
                    done = active & (((eos_ids >= 0) & (tok == eos_ids))
                                     | (remaining <= 0)
                                     # out of cache positions: the next
                                     # write would fall past max_len
                                     | (pos >= self._pos_limit))
                    carry = (view, tok, pos, active & ~done, remaining,
                             key)
                    return carry, (tok, emit, done)

                init = (view, last_tok, pos, active, remaining, key)
                carry, (toks, emits, dones) = jax.lax.scan(
                    body, init, None, length=ecfg.decode_block)
                view, last_tok, pos, active, remaining, key = carry
                if paged and not paged_attn:
                    view_p, view_r = split_paged(view)
                    # one scatter per dispatch lands the block's writes
                    # back in the physical page pool — through the
                    # *write* table, whose cache-shared entries hold the
                    # sentinel so shared prefix pages stay immutable
                    cache = {**scatter_pages(pool_p, view_p, write_table),
                             **view_r}
                else:
                    cache = view
                return (cache, last_tok, pos, active, remaining, key,
                        toks, emits, dones)
            return fused_decode

        def suffix_admit(params, cache, last_tok, pos, active, remaining,
                         temps, top_ks, top_ps, eos_ids, key,
                         spec_table, spec_prev,
                         tokens, offsets, lengths, slots, read_tables,
                         write_tables, r_temps, r_topk, r_topp, r_eos,
                         r_budget, r_prev):
            """Prefix-cache hit admission: gather each row's logical view
            through its *full* page table (shared prefix + private
            pages), run the suffix-only forward, and scatter back through
            the *write* table (shared pages masked to the sentinel, so
            nothing ever lands in a cache-shared page).  One dispatch,
            one host sync — same discipline as `prefill_admit`."""
            self.suffix_traces += 1
            p = self._dequant(params)
            pool_p, pool_r = split_paged(cache)
            view = gather_pages(pool_p, read_tables)
            logits, view, pos1 = model.prefill_suffix(
                p, view, tokens, offsets, lengths)
            view_p, _ = split_paged(view)
            cache = {**scatter_pages(pool_p, view_p, write_tables),
                     **pool_r}
            key, sk = jax.random.split(key)
            first = sample_batched(logits, sk, r_temps, r_topk, r_topp)
            done0 = ((r_budget <= 1) | ((r_eos >= 0) & (first == r_eos))
                     | (pos1 + 1 >= self._pos_limit))
            last_tok = last_tok.at[slots].set(first, mode="drop")
            pos = pos.at[slots].set(pos1 + 1, mode="drop")
            active = active.at[slots].set(~done0, mode="drop")
            remaining = remaining.at[slots].set(r_budget - 1, mode="drop")
            temps = temps.at[slots].set(r_temps, mode="drop")
            top_ks = top_ks.at[slots].set(r_topk, mode="drop")
            top_ps = top_ps.at[slots].set(r_topp, mode="drop")
            eos_ids = eos_ids.at[slots].set(r_eos, mode="drop")
            spec_table = spec_table.at[slots].set(-1, mode="drop")
            spec_prev = spec_prev.at[slots].set(r_prev, mode="drop")
            return (cache, last_tok, pos, active, remaining, temps,
                    top_ks, top_ps, eos_ids, key, spec_table, spec_prev,
                    first, done0)

        def restore_slots(last_tok, pos, active, remaining, temps,
                          top_ks, top_ps, eos_ids, spec_table, spec_prev,
                          slots, r_last, r_pos,
                          r_budget, r_temps, r_topk, r_topp, r_eos,
                          r_prev):
            """Swap-in resume: rebuild per-slot decode state host-known
            at park time — no model forward, zero re-prefill.  Padded
            rows carry slot == n_slots and drop on device."""
            last_tok = last_tok.at[slots].set(r_last, mode="drop")
            pos = pos.at[slots].set(r_pos, mode="drop")
            active = active.at[slots].set(True, mode="drop")
            remaining = remaining.at[slots].set(r_budget, mode="drop")
            temps = temps.at[slots].set(r_temps, mode="drop")
            top_ks = top_ks.at[slots].set(r_topk, mode="drop")
            top_ps = top_ps.at[slots].set(r_topp, mode="drop")
            eos_ids = eos_ids.at[slots].set(r_eos, mode="drop")
            spec_table = spec_table.at[slots].set(-1, mode="drop")
            spec_prev = spec_prev.at[slots].set(r_prev, mode="drop")
            return (last_tok, pos, active, remaining, temps, top_ks,
                    top_ps, eos_ids, spec_table, spec_prev)

        def clear_slots(last_tok, pos, active, remaining, temps,
                        spec_table, spec_prev, slots):
            """Release/cancel/preempt: wipe per-slot device state so a
            freed slot can never be decoded or sampled with stale
            values — including the speculative proposer row and chain
            seed, so un-verified drafts from a cancelled request can
            never be proposed into a reused slot."""
            last_tok = last_tok.at[slots].set(0, mode="drop")
            pos = pos.at[slots].set(0, mode="drop")
            active = active.at[slots].set(False, mode="drop")
            remaining = remaining.at[slots].set(0, mode="drop")
            temps = temps.at[slots].set(0.0, mode="drop")
            spec_table = spec_table.at[slots].set(-1, mode="drop")
            spec_prev = spec_prev.at[slots].set(-1, mode="drop")
            return (last_tok, pos, active, remaining, temps, spec_table,
                    spec_prev)

        def spec_decode(params, cache, last_tok, pos, active, remaining,
                        eos_ids, spec_table, spec_prev, page_table,
                        write_table):
            """One speculative step: propose D drafts from the bigram
            table, verify [last_tok, drafts] in ONE batched paged
            forward, emit the longest greedy-matching prefix plus the
            verifier's own next token.  Greedy-only (the host picks
            this path only for all-greedy batches), so emitted tokens
            are provably identical to sequential greedy decode.  Same
            shape discipline as fused_decode: returns (D+1, n_slots)
            token/emit/done blocks consumed by the same host tail."""
            self.spec_traces += 1
            p = self._dequant(params)
            d = ecfg.spec_draft
            drafts = spec_lib.propose(spec_table, spec_prev, last_tok, d)
            # missing proposals (-1) are fed as token 0 but can never be
            # accepted: a -1 draft never equals a real argmax token
            x = jnp.concatenate(
                [last_tok[:, None], jnp.maximum(drafts, 0)], axis=1)
            logits, cache = model.verify_paged(
                p, cache, x, pos, page_table, write_table)
            greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            n_acc = spec_lib.accept_length(drafts, greedy[:, :d])

            def body(carry, xs):
                g_i, i = xs
                last_c, prev_c, pos_c, act_c, rem_c, tab = carry
                emit = act_c & (i <= n_acc)
                tok = jnp.where(emit, g_i, last_c)
                rem_c = jnp.where(emit, rem_c - 1, rem_c)
                pos_c = pos_c + emit.astype(jnp.int32)
                done = emit & (((eos_ids >= 0) & (tok == eos_ids))
                               | (rem_c <= 0)
                               | (pos_c >= self._pos_limit))
                # the table learns each emitted transition on device
                tab = spec_lib.record(tab, prev_c, last_c, tok, emit)
                prev_c = jnp.where(emit, last_c, prev_c)
                carry = (tok, prev_c, pos_c, act_c & ~done, rem_c, tab)
                return carry, (tok, emit, done)

            init = (last_tok, spec_prev, pos, active, remaining,
                    spec_table)
            carry, (toks, emits, dones) = jax.lax.scan(
                body, init, (greedy.T, jnp.arange(d + 1)))
            last_tok, spec_prev, pos, active, remaining, spec_table = \
                carry
            return (cache, last_tok, pos, active, remaining, spec_table,
                    spec_prev, toks, emits, dones)

        self._prefill_admit = jax.jit(
            prefill_admit, donate_argnums=tuple(range(1, 13)))
        self._suffix_admit = jax.jit(
            suffix_admit, donate_argnums=tuple(range(1, 13)))
        self._restore_slots = jax.jit(
            restore_slots, donate_argnums=tuple(range(10)))
        decode_donate = (1, 2, 3, 4, 5, 10)
        # three variants; jax compiles each lazily on first use only
        self._fused_decode = {
            mode: jax.jit(make_fused_decode(mode),
                          donate_argnums=decode_donate)
            for mode in ("greedy", "temp", "full")}
        self._clear_slots = jax.jit(
            clear_slots, donate_argnums=tuple(range(7)))
        self._spec_decode = jax.jit(
            spec_decode, donate_argnums=(1, 2, 3, 4, 5, 7, 8))

    # ------------------------------------------------------------- #
    def _extra_inputs(self, batch: int):
        extra = {}
        dt = jnp.bfloat16 if self.cfg.dtype == "bf16" else jnp.float32
        if self.cfg.frontend == "vision":
            extra["prefix_embeds"] = jnp.zeros(
                (batch, self.cfg.n_prefix_tokens, self.cfg.d_model), dt)
        if self.cfg.is_encdec:
            extra["src_embeds"] = jnp.zeros(
                (batch, self.ecfg.max_len, self.cfg.d_model), dt)
        return extra

    def _bucket_of(self, prompt_len: int) -> int:
        """Power-of-two padded length bucket (attention families); exact
        length for recurrent families, which can't absorb pads.  Capped so
        bucket + prefix (meta/vision) tokens never outgrow the pool
        cache."""
        if not self._supports_bucket:
            return prompt_len
        b = self.ecfg.prefill_bucket_min
        while b < prompt_len:
            b <<= 1
        return min(b, self.ecfg.max_len - self._prefix_tokens)

    # ------------------------------------------------------------- #
    def submit(self, req: Request) -> bool:
        if self._dead:
            req.finish(error="engine dead", code=CODE_ENGINE_FAILED)
            return False
        if len(req.prompt) + self._prefix_tokens > self.ecfg.max_len:
            # malformed input, not a capacity problem: reject at submit
            # time instead of surfacing OVERLOADED after dequeue
            req.finish(
                error=(f"prompt length {len(req.prompt)} (+ "
                       f"{self._prefix_tokens} prefix tokens) exceeds "
                       f"engine max_len {self.ecfg.max_len}"),
                code=CODE_INVALID_REQUEST)
            return False
        return self.scheduler.submit(req)

    def fail(self):
        """Failure injection: node/instance crash."""
        self._dead = True
        doomed = list(self.slot_req.values())
        self.slot_req.clear()
        # close-and-drain is atomic: a concurrently racing submit either
        # landed in the queue (doomed below) or is rejected by the closed
        # scheduler with ENGINE_FAILED — the frontend fails it over
        doomed += self.scheduler.close()
        for req in doomed:
            req.finish(error="engine crashed", code=CODE_ENGINE_FAILED)

    def cancel(self, request_id: int):
        """Abort a queued or in-flight request, freeing its slot and
        pages.  Takes effect at the next dispatch boundary: the current
        fused block (if any) has already been emitted.  Returns "queued"
        when the request had never been admitted to a slot (the caller
        refunds its tenant token-bucket charge), "active" when it held a
        slot, False when unknown."""
        if self.scheduler.cancel(request_id):
            handle = self._swapped.pop(request_id, None)
            if handle is not None:       # parked in the host swap tier
                drop_handle(self.pool, self.host_pool, handle)
            if self.prefix_cache is not None:
                self.prefix_cache.unbind(request_id)
            return "queued"
        for slot, req in list(self.slot_req.items()):
            if req.request_id == request_id:
                del self.slot_req[slot]
                if self.prefix_cache is not None:
                    self.prefix_cache.unbind(request_id)
                self.pool.release(slot)
                self._release_device_slot(slot)
                return "active"
        return False

    def _release_device_slot(self, slot: int):
        """Zero the slot's persistent device state (done mask, sampling
        temperature, budget) so the next fused dispatch can't decode or
        sample it with stale values."""
        idx = jnp.asarray([slot], jnp.int32)
        (self.last_tok, self.pos, self.active, self.remaining,
         self.temps, self.spec_table, self.spec_prev) = self._clear_slots(
            self.last_tok, self.pos, self.active, self.remaining,
            self.temps, self.spec_table, self.spec_prev, idx)
        self.dispatches += 1

    @property
    def alive(self) -> bool:
        return not self._dead

    @property
    def n_active(self) -> int:
        return self.pool.n_active

    @property
    def load(self) -> float:
        """Active slots + queue pressure, for least-loaded routing."""
        return self.pool.n_active + self.scheduler.depth

    # ------------------------------------------------------------- #
    def step(self) -> int:
        """One engine iteration: admit one prefill bucket, then one fused
        K-step decode dispatch.  Returns number of decode tokens
        emitted."""
        if self._dead:
            raise EngineFailure("engine is dead")
        t0 = time.monotonic()
        self._admit()
        emitted = self._decode_block() if self.slot_req else 0
        self.total_steps += 1
        dt = time.monotonic() - t0
        self.step_ewma_s = 0.9 * self.step_ewma_s + 0.1 * dt \
            if self.total_steps > 1 else dt
        return emitted

    # ---- admissions: one bucketed batch prefill dispatch ---------- #
    def _decode_page_debt(self) -> int:
        """Pages the in-flight slots will need for their next decode
        block — reserved out of the admission budget so a fresh admit
        can't immediately starve running requests into preemption."""
        if not self._paged:
            return 0
        debt = 0
        for slot in self.slot_req:
            target = min(self.pool.lengths[slot] + self._growth,
                         self.ecfg.max_len)
            debt += max(self.pool.pages_for_tokens(target)
                        - len(self.pool.slot_pages[slot]), 0)
        return debt

    def _admit(self):
        budget = len(self.pool.free_pages) - self._decode_page_debt()
        if self.prefix_cache is not None:
            # LRU cache pages are reclaimable on demand: count them into
            # the admission budget so the cache never blocks admission
            budget += self.prefix_cache.evictable_device_pages()
        group = self.scheduler.next_prefill_bucket(
            len(self.pool.free_slots), self._bucket_of,
            free_pages=max(budget, 0))
        if not group:
            return
        # partition: swap-parked resumes restore with zero re-prefill;
        # prefix-cache hits prefill only their suffix; the rest take the
        # classic full bucketed prefill.  Admission may issue up to three
        # dispatches when mixed — all off the fused decode hot path.
        swaps = [r for r in group if r.request_id in self._swapped]
        fresh = [r for r in group if r.request_id not in self._swapped]
        if swaps:
            self._admit_swapped(swaps)
        hits, plain = [], fresh
        if self.prefix_cache is not None and fresh:
            hits, plain = [], []
            paged, _ = split_paged(self.cache)
            for req in fresh:
                toks = list(req.prompt) + list(req.output)
                entries, matched, new_paged = self.prefix_cache.match(
                    req.tenant, toks, len(toks) - 1, paged=paged)
                if new_paged is not None:       # host-tier promotion
                    self.cache.update(new_paged)
                    paged = new_paged
                    self.dispatches += 1
                if entries:
                    # pin immediately: a later reclaim (another row's
                    # shortfall or promotion) must not evict these
                    # before the suffix admission maps their pages
                    self.prefix_cache.bind(req.request_id, entries)
                    hits.append((req, entries, matched))
                else:
                    plain.append(req)
        if hits:
            self._admit_suffix(hits)
        if plain:
            self._admit_prefill(plain)

    def _reclaim_shortfall(self, want: int):
        """Feed the free list from LRU refcount-0 cache pages before an
        allocation would block (demoting to the host tier when one is
        attached)."""
        short = want - len(self.pool.free_pages)
        if short > 0 and self.prefix_cache is not None:
            demote = split_paged(self.cache)[0] if self.host_pool \
                else None
            self.prefix_cache.reclaim(short, demote)

    def _admit_prefill(self, group: List[Request]):
        admitted: List[Tuple[int, Request]] = []
        for req in group:
            eff = len(req.prompt) + len(req.output)
            need = eff + self._prefix_tokens
            self._reclaim_shortfall(
                self.pool.pages_per_slot if not self._paged
                else self.pool.pages_for_tokens(need))
            slot = self.pool.alloc(
                req.request_id, need,
                reserve_tokens=0 if self._paged else self.ecfg.max_len)
            if slot is None:                    # defensive; the admission
                self.scheduler.requeue(req)     # budget above bounds the
                continue                        # group — never drop it
            req.state = RequestState.PREFILLING
            admitted.append((slot, req))
        if not admitted:
            return
        ecfg = self.ecfg
        bucket = self._bucket_of(max(len(r.prompt) + len(r.output)
                                     for _, r in admitted))
        s_tot = bucket + self._prefix_tokens
        n_row_pages = self.pool.pages_for_tokens(s_tot)
        pad_n = _next_pow2(len(admitted))
        toks = np.zeros((pad_n, bucket), np.int32)
        lengths = np.ones((pad_n,), np.int32)
        slots = np.full((pad_n,), ecfg.n_slots, np.int32)  # OOB => drop
        row_pages = np.full((pad_n, n_row_pages), self.pool.n_pages,
                            np.int32)                      # OOB => drop
        r_temps = np.zeros((pad_n,), np.float32)
        r_topk = np.zeros((pad_n,), np.int32)
        r_topp = np.ones((pad_n,), np.float32)
        r_eos = np.full((pad_n,), -1, np.int32)
        r_budget = np.ones((pad_n,), np.int32)
        r_prev = np.full((pad_n,), -1, np.int32)
        for i, (slot, req) in enumerate(admitted):
            prompt = list(req.prompt) + list(req.output)   # resume ctx
            pl = len(prompt)
            toks[i, :pl] = prompt
            lengths[i] = pl
            slots[i] = slot
            row_pages[i] = self.pool.row_pages(slot, n_row_pages)
            s = req.sampling
            r_temps[i] = s.temperature
            r_topk[i] = s.top_k if s.top_k > 0 else ecfg.top_k
            r_topp[i] = s.top_p if s.top_p < 1.0 else ecfg.top_p
            r_eos[i] = s.eos_id
            r_budget[i] = s.max_tokens - len(req.output)
            r_prev[i] = prompt[-1]      # precedes the sampled first token
        extra = self._extra_inputs(pad_n)
        (self.cache, self.last_tok, self.pos, self.active, self.remaining,
         self.temps, self.top_ks, self.top_ps, self.eos_ids, self._key,
         self.spec_table, self.spec_prev,
         first, done0) = self._prefill_admit(
            self.params, self.cache, self.last_tok, self.pos, self.active,
            self.remaining, self.temps, self.top_ks, self.top_ps,
            self.eos_ids, self._key, self.spec_table, self.spec_prev,
            toks, lengths, slots, row_pages,
            r_temps, r_topk, r_topp, r_eos, r_budget, r_prev, extra)
        self.dispatches += 1
        self.prefill_dispatch_tokens += pad_n * bucket
        first_h, done_h = jax.device_get((first, done0))
        self.host_syncs += 1
        self._post_admit(admitted, first_h, done_h)

    def _post_admit(self, admitted: List[Tuple[int, Request]],
                    first_h, done_h):
        """Shared tail of both admission dispatches: emit each row's
        first sampled token, then park it in its slot (or finish it)."""
        for i, (slot, req) in enumerate(admitted):
            req.emit(int(first_h[i]))
            req.state = RequestState.DECODING
            self.total_tokens += 1
            if done_h[i]:
                req.finish()
                self._finish_slot(slot, req)
            else:
                self.slot_req[slot] = req

    def _finish_slot(self, slot: int, req: Request):
        """Free a finishing slot — donating its page-aligned prefix
        blocks to the prefix cache first (the cache `retain`s them, so
        the release below leaves the cache holding the last reference)."""
        if self.prefix_cache is not None:
            if not req.error and not req.cancelled:
                n = self.pool.lengths[slot]
                toks = (list(req.prompt) + list(req.output))[:n]
                self.prefix_cache.insert(req.tenant, toks, n,
                                         self.pool.slot_pages[slot])
            self.prefix_cache.unbind(req.request_id)
        self.pool.release(slot)

    # ---- prefix-cache hits: suffix-only bucketed prefill ---------- #
    def _admit_suffix(self, hits):
        ecfg = self.ecfg
        pps = self.pool.pages_per_slot
        admitted: List[Tuple[int, Request]] = []
        matched_of: Dict[int, int] = {}
        for req, entries, matched in hits:
            eff = len(req.prompt) + len(req.output)
            shared = [e.page for e in entries]
            self._reclaim_shortfall(
                self.pool.pages_for_tokens(eff) - len(shared))
            slot = self.pool.alloc(req.request_id, eff,
                                   shared_pages=shared)
            if slot is None:            # entries were pinned at match
                self.prefix_cache.unbind(req.request_id)
                self.scheduler.requeue(req)
                continue
            req.state = RequestState.PREFILLING
            admitted.append((slot, req))
            matched_of[slot] = matched
        if not admitted:
            return
        bucket = self._bucket_of(max(
            (len(r.prompt) + len(r.output)) - matched_of[s]
            for s, r in admitted))
        pad_n = _next_pow2(len(admitted))
        toks = np.zeros((pad_n, bucket), np.int32)
        offsets = np.zeros((pad_n,), np.int32)
        lengths = np.ones((pad_n,), np.int32)
        slots = np.full((pad_n,), ecfg.n_slots, np.int32)  # OOB => drop
        read_tables = np.full((pad_n, pps), self.pool.n_pages, np.int32)
        write_tables = np.full((pad_n, pps), self.pool.n_pages, np.int32)
        r_temps = np.zeros((pad_n,), np.float32)
        r_topk = np.zeros((pad_n,), np.int32)
        r_topp = np.ones((pad_n,), np.float32)
        r_eos = np.full((pad_n,), -1, np.int32)
        r_budget = np.ones((pad_n,), np.int32)
        r_prev = np.full((pad_n,), -1, np.int32)
        for i, (slot, req) in enumerate(admitted):
            prompt = list(req.prompt) + list(req.output)
            r_prev[i] = prompt[-1]
            matched = matched_of[slot]
            suffix = prompt[matched:]
            toks[i, :len(suffix)] = suffix
            offsets[i] = matched
            lengths[i] = len(suffix)
            slots[i] = slot
            read_tables[i] = self.pool.row_pages(slot, pps)
            write_tables[i] = read_tables[i]
            # shared prefix blocks are read-only: writes there drop
            write_tables[i, :matched // self.pool.page_size] = \
                self.pool.n_pages
            s = req.sampling
            r_temps[i] = s.temperature
            r_topk[i] = s.top_k if s.top_k > 0 else ecfg.top_k
            r_topp[i] = s.top_p if s.top_p < 1.0 else ecfg.top_p
            r_eos[i] = s.eos_id
            r_budget[i] = s.max_tokens - len(req.output)
        (self.cache, self.last_tok, self.pos, self.active, self.remaining,
         self.temps, self.top_ks, self.top_ps, self.eos_ids, self._key,
         self.spec_table, self.spec_prev,
         first, done0) = self._suffix_admit(
            self.params, self.cache, self.last_tok, self.pos, self.active,
            self.remaining, self.temps, self.top_ks, self.top_ps,
            self.eos_ids, self._key, self.spec_table, self.spec_prev,
            toks, offsets, lengths, slots,
            read_tables, write_tables, r_temps, r_topk, r_topp, r_eos,
            r_budget, r_prev)
        self.dispatches += 1
        if self._paged:
            # admission gathers/scatters one logical view per padded row
            self.logical_bytes_moved += \
                2 * (self._view_bytes // self.ecfg.n_slots) * pad_n
        self.prefill_dispatch_tokens += pad_n * bucket
        self.suffix_prefills += len(admitted)
        first_h, done_h = jax.device_get((first, done0))
        self.host_syncs += 1
        self._post_admit(admitted, first_h, done_h)

    # ---- swap-parked resumes: zero re-prefill restore ------------- #
    def _admit_swapped(self, swaps: List[Request]):
        paged, _ = split_paged(self.cache)
        restored: List[Tuple[int, Request]] = []
        for req in swaps:
            handle = self._swapped[req.request_id]
            self._reclaim_shortfall(len(handle.host))
            res = swap_in_slot(self.pool, self.host_pool, paged, handle)
            if res is None:
                # slots/pages short right now: fall back to the classic
                # recompute resume so progress never livelocks on swap
                del self._swapped[req.request_id]
                drop_handle(self.pool, self.host_pool, handle)
                self.scheduler.requeue(req)
                continue
            slot, new_paged = res
            if new_paged is not paged:
                self.cache.update(new_paged)
                paged = new_paged
                self.dispatches += 1        # the swap-in scatter
            del self._swapped[req.request_id]
            self.swap_ins += 1
            restored.append((slot, req))
        if not restored:
            return
        ecfg = self.ecfg
        pad_n = _next_pow2(len(restored))
        slots = np.full((pad_n,), ecfg.n_slots, np.int32)
        r_last = np.zeros((pad_n,), np.int32)
        r_pos = np.zeros((pad_n,), np.int32)
        r_budget = np.zeros((pad_n,), np.int32)
        r_temps = np.zeros((pad_n,), np.float32)
        r_topk = np.zeros((pad_n,), np.int32)
        r_topp = np.ones((pad_n,), np.float32)
        r_eos = np.full((pad_n,), -1, np.int32)
        r_prev = np.full((pad_n,), -1, np.int32)
        for i, (slot, req) in enumerate(restored):
            slots[i] = slot
            r_last[i] = req.output[-1]
            r_prev[i] = req.output[-2] if len(req.output) >= 2 \
                else list(req.prompt)[-1]
            r_pos[i] = self.pool.lengths[slot]
            r_budget[i] = req.sampling.max_tokens - len(req.output)
            s = req.sampling
            r_temps[i] = s.temperature
            r_topk[i] = s.top_k if s.top_k > 0 else ecfg.top_k
            r_topp[i] = s.top_p if s.top_p < 1.0 else ecfg.top_p
            r_eos[i] = s.eos_id
            req.state = RequestState.DECODING
            self.slot_req[slot] = req
        (self.last_tok, self.pos, self.active, self.remaining, self.temps,
         self.top_ks, self.top_ps, self.eos_ids, self.spec_table,
         self.spec_prev) = self._restore_slots(
            self.last_tok, self.pos, self.active, self.remaining,
            self.temps, self.top_ks, self.top_ps, self.eos_ids,
            self.spec_table, self.spec_prev,
            slots, r_last, r_pos, r_budget, r_temps, r_topk, r_topp,
            r_eos, r_prev)
        self.dispatches += 1

    def _decode_mode(self) -> str:
        """Pick the cheapest compiled decode variant the current batch
        permits — the host knows every slot's sampling params, so sorts
        and PRNG stay out of the program unless actually needed."""
        sampling = [r.sampling for r in self.slot_req.values()
                    if r.sampling.temperature > 0]
        if not sampling:
            return "greedy"
        ecfg = self.ecfg
        if any(s.top_k > 0 or s.top_p < 1.0 or ecfg.top_k > 0
               or ecfg.top_p < 1.0 for s in sampling):
            return "full"
        return "temp"

    # ---- preemption: page exhaustion at a decode-block boundary --- #
    def _pick_victim(self) -> Optional[int]:
        """Preemption victim: the slot whose tenant holds the lowest DWRR
        deficit (most recently over-served), breaking ties toward the
        request with the least progress (cheapest resume)."""
        if not self.slot_req:
            return None
        return min(self.slot_req.items(),
                   key=lambda kv: (self.scheduler.deficit(kv[1].tenant),
                                   len(kv[1].output), -kv[0]))[0]

    def _preempt(self, slot: int):
        """Evict `slot`: park its private KV pages in the host swap tier
        when one is attached (O(pages) moved, zero re-prefill on resume),
        else refund the pages and fall back to the classic recompute
        resume (re-prefill prompt + generated-so-far).  Either way the
        request keeps its emitted tokens and re-enters the front of its
        tenant queue with its remaining budget."""
        req = self.slot_req.pop(slot)
        swapped = False
        if self.host_pool is not None:
            paged, _ = split_paged(self.cache)
            handle = swap_out_slot(self.pool, self.host_pool, paged, slot)
            if handle is not None:
                self._swapped[req.request_id] = handle
                self.swap_outs += 1
                self.dispatches += 1    # the page-gather dispatch
                self.host_syncs += 1    # one device_get moves the blocks
                swapped = True
        if not swapped:
            if self.prefix_cache is not None:
                # recompute resume re-matches and re-binds at admission
                self.prefix_cache.unbind(req.request_id)
            self.pool.release(slot)
        self.pool.preemptions += 1
        self.preemptions += 1
        self._release_device_slot(slot)
        self.scheduler.requeue(req)

    def _ensure_decode_pages(self):
        """Grow every active slot's page table to cover the next fused
        block.  On exhaustion, preempt lowest-deficit slots until the
        growth fits (a sole survivor always fits: the pool holds at least
        one full sequence's pages)."""
        if not self._paged:
            return
        k = self._growth
        for slot in sorted(self.slot_req):
            if slot not in self.slot_req:      # evicted by a prior pass
                continue
            target = min(self.pool.lengths[slot] + k, self.ecfg.max_len)
            while slot in self.slot_req \
                    and not self.pool.grow(slot, target):
                victim = self._pick_victim()
                if victim is None:
                    break
                self._preempt(victim)

    # ---- decode: one fused K-step dispatch, one host sync --------- #
    def _decode_block(self) -> int:
        self._ensure_decode_pages()
        if not self.slot_req:
            return 0
        mode = self._decode_mode()
        spec = self._spec_ok and mode == "greedy"
        if spec:
            # one verify dispatch proposes + checks D drafts and emits
            # up to D+1 tokens — same single host sync as the fused path
            (self.cache, self.last_tok, self.pos, self.active,
             self.remaining, self.spec_table, self.spec_prev,
             toks, emits, dones) = self._spec_decode(
                self.params, self.cache, self.last_tok, self.pos,
                self.active, self.remaining, self.eos_ids,
                self.spec_table, self.spec_prev,
                self.pool.page_table(), self.pool.write_table())
            self.spec_dispatches += 1
            self.logical_bytes_moved += \
                (self.ecfg.spec_draft + 1) * self._write_token_bytes
        else:
            fn = self._fused_decode[mode]
            (self.cache, self.last_tok, self.pos, self.active,
             self.remaining, self._key, toks, emits, dones) = fn(
                self.params, self.cache, self.last_tok, self.pos,
                self.active, self.remaining, self.temps, self.top_ks,
                self.top_ps, self.eos_ids, self._key,
                self.pool.page_table(), self.pool.write_table())
            if self._paged_attn:
                # page-table-direct: only the block's new KV is written
                self.logical_bytes_moved += \
                    self.ecfg.decode_block * self._write_token_bytes
            elif self._paged:
                # gather + scatter move every slot's full logical view
                self.logical_bytes_moved += 2 * self._view_bytes
        self.dispatches += 1
        toks_h, emit_h, done_h = jax.device_get((toks, emits, dones))
        self.host_syncs += 1
        emitted = 0
        for slot, req in list(self.slot_req.items()):
            col = emit_h[:, slot]
            if not col.any():
                continue
            block = toks_h[:, slot][col].tolist()
            req.emit_many(block)
            self.pool.advance(slot, len(block))
            emitted += len(block)
            self.total_tokens += len(block)
            if spec:
                self.spec_emitted += len(block)
                # tokens beyond the first came from accepted drafts
                self.spec_slot_accepted[slot] += max(len(block) - 1, 0)
            if done_h[:, slot].any():
                req.finish()
                del self.slot_req[slot]
                self._finish_slot(slot, req)
        return emitted

    def run_until_done(self, max_steps: int = 10_000) -> int:
        steps = 0
        while (self.slot_req or self.scheduler.depth) and \
                steps < max_steps:
            self.step()
            steps += 1
        return steps

    # ---- hierarchical KV memory: admin / autoscaler surface ------- #
    def flush_prefix_cache(self) -> Dict[str, int]:
        """Drop every unpinned prefix-cache entry (both tiers) — the
        `/v1/admin/cache/flush` verb."""
        if self.prefix_cache is None:
            return {"flushed": 0, "remaining": 0}
        return self.prefix_cache.flush()

    def page_pressure(self) -> float:
        """Fraction of the device page budget committed to *live* work.
        Cache pages the engine could reclaim on demand are netted out,
        so a warm-but-evictable prefix cache never reads as memory
        pressure to the autoscaler."""
        if not self._paged or self.pool.n_pages == 0:
            return 0.0
        in_use = self.pool.n_pages - len(self.pool.free_pages)
        if self.prefix_cache is not None:
            in_use -= self.prefix_cache.evictable_device_pages()
        return max(in_use, 0) / self.pool.n_pages

    # ------------------------------------------------------------- #
    def memory_report(self) -> Dict[str, int]:
        return {
            "param_bytes": q_lib.tree_bytes(self.params),
            "cache_bytes": cache_bytes(self.cache),
        }

    def perf_stats(self) -> Dict[str, Any]:
        """Dispatch/sync discipline counters (the paper's 'no CPU
        fallback' claim, made measurable) plus the paged-pool VRAM
        metrics."""
        t = max(self.total_tokens, 1)
        stats = {
            "tokens": self.total_tokens,
            "steps": self.total_steps,
            "dispatches": self.dispatches,
            "host_syncs": self.host_syncs,
            "dispatches_per_token": self.dispatches / t,
            "host_syncs_per_token": self.host_syncs / t,
            "prefill_traces": self.prefill_traces,
            "decode_traces": self.decode_traces,
            "decode_block": self.ecfg.decode_block,
            "paged": self._paged,
            "paged_attention": self._paged_attn,
            "speculative": self._spec_ok,
            # logical KV traffic: gather/scatter views vs in-place writes
            "logical_bytes_moved": self.logical_bytes_moved,
            "logical_bytes_moved_per_token": self.logical_bytes_moved / t,
            # speculative decoding acceptance
            "spec_traces": self.spec_traces,
            "spec_dispatches": self.spec_dispatches,
            "spec_emitted": self.spec_emitted,
            "spec_accepted_per_dispatch": (
                self.spec_emitted / self.spec_dispatches
                if self.spec_dispatches else 0.0),
            "spec_slot_accepted": self.spec_slot_accepted.tolist(),
            "preemptions": self.preemptions,
            "queue_enqueued": self.scheduler.enqueued_total,
            "queue_dequeued": self.scheduler.dequeued_total,
            "queue_requeued": self.scheduler.requeued_total,
            "queue_rejected": self.scheduler.rejected,
            "pending_pages": self.scheduler.pending_pages,
            # hierarchical KV memory (kv_hierarchy)
            "suffix_traces": self.suffix_traces,
            "suffix_prefills": self.suffix_prefills,
            "prefill_dispatch_tokens": self.prefill_dispatch_tokens,
            "swap_outs": self.swap_outs,
            "swap_ins": self.swap_ins,
            "swapped_requests": len(self._swapped),
            "cache_hit_rate": (self.prefix_cache.hit_rate()
                               if self.prefix_cache is not None else 0.0),
            "host_pages": (self.host_pool.n_pages
                           if self.host_pool is not None else 0),
            "host_pages_in_use": (self.host_pool.in_use
                                  if self.host_pool is not None else 0),
        }
        if self.prefix_cache is not None:
            stats["prefix_cache"] = self.prefix_cache.stats()
        stats.update(self.pool.page_stats())
        return stats

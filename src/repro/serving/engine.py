"""Continuous-batching inference engine — the Ollama analogue each backend
node runs, one per deployed model instance.

Fully GPU/TPU-accelerated path (no CPU fallback, per the paper): prefill and
decode are jitted; weights may be held quantized (int8/int4) at rest and
dequantized on-chip per step.  A fixed slot pool gives O(1) admission,
batched decode over all active slots, and exact byte accounting for the SDAI
controller's VRAM-aware placement.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import build
from repro.serving import quantization as q_lib
from repro.serving.kv_cache import SlotPool, write_slot, cache_bytes
from repro.serving.request import (CODE_ENGINE_FAILED, CODE_OVERLOADED,
                                   Request, RequestState)
from repro.serving.sampler import SamplingParams
from repro.serving.scheduler import Scheduler, SchedulerConfig


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 4
    max_len: int = 128
    quantize: str = ""            # "", "int8", "int4"
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0


class EngineFailure(RuntimeError):
    pass


class InferenceEngine:
    """One model instance on one node."""

    def __init__(self, cfg: ArchConfig, params, engine_cfg: EngineConfig,
                 scheduler: Optional[Scheduler] = None):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.model = build(cfg)
        self.scheduler = scheduler or Scheduler(SchedulerConfig())
        self.pool = SlotPool(engine_cfg.n_slots, engine_cfg.max_len)
        self._dead = False
        self._key = jax.random.PRNGKey(engine_cfg.seed)

        if engine_cfg.quantize:
            bits = 8 if engine_cfg.quantize == "int8" else 4
            self.params = q_lib.quantize_tree(params, bits=bits)
            self._dequant = q_lib.dequant_tree
        else:
            self.params = params
            self._dequant = lambda p: p

        src_len = engine_cfg.max_len if cfg.is_encdec else 0
        self.cache = self.model.init_cache(
            engine_cfg.n_slots, engine_cfg.max_len, src_len=src_len)
        self.slot_req: Dict[int, Request] = {}
        self.pos = jnp.zeros((engine_cfg.n_slots,), jnp.int32)
        self.last_tok = jnp.zeros((engine_cfg.n_slots,), jnp.int32)
        # metrics
        self.total_tokens = 0
        self.total_steps = 0
        self.step_ewma_s = 0.0
        self._build_steps()

    # ------------------------------------------------------------- #
    def _build_steps(self):
        model, cfg, ecfg = self.model, self.cfg, self.ecfg

        def prefill_one(params, tokens, extra):
            p = self._dequant(params)
            return model.prefill(p, tokens, cache_len=ecfg.max_len,
                                 **extra)

        def decode_batch(params, cache, token, pos, temps, key):
            p = self._dequant(params)
            logits, new_cache = model.decode(p, cache, token, pos)
            greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            lg = logits.astype(jnp.float32) / jnp.maximum(
                temps[:, None], 1e-6)
            if ecfg.top_k > 0:
                kth = jax.lax.top_k(lg, ecfg.top_k)[0][..., -1:]
                lg = jnp.where(lg < kth, -1e30, lg)
            sampled = jax.random.categorical(key, lg, axis=-1)
            tok = jnp.where(temps > 0, sampled.astype(jnp.int32), greedy)
            return tok, new_cache

        self._prefill_one = jax.jit(prefill_one)
        self._decode_batch = jax.jit(decode_batch, donate_argnums=(1,))

    # ------------------------------------------------------------- #
    def _extra_inputs(self, batch: int):
        extra = {}
        dt = jnp.bfloat16 if self.cfg.dtype == "bf16" else jnp.float32
        if self.cfg.frontend == "vision":
            extra["prefix_embeds"] = jnp.zeros(
                (batch, self.cfg.n_prefix_tokens, self.cfg.d_model), dt)
        if self.cfg.is_encdec:
            extra["src_embeds"] = jnp.zeros(
                (batch, self.ecfg.max_len, self.cfg.d_model), dt)
        return extra

    def submit(self, req: Request) -> bool:
        if self._dead:
            req.finish(error="engine dead", code=CODE_ENGINE_FAILED)
            return False
        return self.scheduler.submit(req)

    def fail(self):
        """Failure injection: node/instance crash."""
        self._dead = True
        doomed = list(self.slot_req.values()) + list(self.scheduler.queue)
        self.slot_req.clear()
        self.scheduler.queue.clear()
        for req in doomed:
            req.finish(error="engine crashed", code=CODE_ENGINE_FAILED)

    def cancel(self, request_id: int) -> bool:
        """Abort a queued or in-flight request, freeing its slot."""
        if self.scheduler.cancel(request_id):
            return True
        for slot, req in list(self.slot_req.items()):
            if req.request_id == request_id:
                del self.slot_req[slot]
                self.pool.release(slot)
                return True
        return False

    @property
    def alive(self) -> bool:
        return not self._dead

    @property
    def n_active(self) -> int:
        return self.pool.n_active

    @property
    def load(self) -> float:
        """Active slots + queue pressure, for least-loaded routing."""
        return self.pool.n_active + self.scheduler.depth

    # ------------------------------------------------------------- #
    def step(self) -> int:
        """One engine iteration: admit prefills, one batched decode.
        Returns number of tokens emitted."""
        if self._dead:
            raise EngineFailure("engine is dead")
        t0 = time.monotonic()
        # ---- admissions
        for req in self.scheduler.next_prefills(len(self.pool.free)):
            slot = self.pool.alloc(req.request_id, len(req.prompt))
            if slot is None:
                req.finish(error="no capacity", code=CODE_OVERLOADED)
                continue
            req.state = RequestState.PREFILLING
            tokens = jnp.asarray([req.prompt], jnp.int32)
            extra = self._extra_inputs(1)
            logits, one_cache, pos1 = self._prefill_one(
                self.params, tokens, extra)
            self.cache = write_slot(self.cache, one_cache, slot)
            first = int(jnp.argmax(logits[0]))
            if req.sampling.temperature > 0:
                self._key, sk = jax.random.split(self._key)
                lg = logits[0].astype(jnp.float32) / \
                    req.sampling.temperature
                first = int(jax.random.categorical(sk, lg))
            req.emit(first)
            req.state = RequestState.DECODING
            self.slot_req[slot] = req
            self.pos = self.pos.at[slot].set(int(pos1[0]) + 1)
            self.last_tok = self.last_tok.at[slot].set(first)
            self.total_tokens += 1
            self._maybe_finish(slot, first)
        # ---- batched decode
        emitted = 0
        if self.slot_req:
            temps = jnp.asarray(
                [self.slot_req[s].sampling.temperature
                 if s in self.slot_req else 0.0
                 for s in range(self.ecfg.n_slots)], jnp.float32)
            self._key, sk = jax.random.split(self._key)
            toks, self.cache = self._decode_batch(
                self.params, self.cache, self.last_tok, self.pos, temps,
                sk)
            toks_host = jax.device_get(toks)
            active = list(self.slot_req.items())
            for slot, req in active:
                tok = int(toks_host[slot])
                req.emit(tok)
                self.pool.advance(slot)
                emitted += 1
                self.total_tokens += 1
                self.last_tok = self.last_tok.at[slot].set(tok)
                self._maybe_finish(slot, tok)
            adv = jnp.zeros((self.ecfg.n_slots,), jnp.int32)
            for slot, _ in active:
                adv = adv.at[slot].set(1)
            self.pos = self.pos + adv
        self.total_steps += 1
        dt = time.monotonic() - t0
        self.step_ewma_s = 0.9 * self.step_ewma_s + 0.1 * dt \
            if self.total_steps > 1 else dt
        return emitted

    def _maybe_finish(self, slot: int, tok: int):
        req = self.slot_req.get(slot)
        if req is None:
            return
        done = (len(req.output) >= req.sampling.max_tokens or
                (req.sampling.eos_id >= 0 and tok == req.sampling.eos_id))
        if done:
            req.finish()
            del self.slot_req[slot]
            self.pool.release(slot)

    def run_until_done(self, max_steps: int = 10_000) -> int:
        steps = 0
        while (self.slot_req or self.scheduler.depth) and \
                steps < max_steps:
            self.step()
            steps += 1
        return steps

    # ------------------------------------------------------------- #
    def memory_report(self) -> Dict[str, int]:
        return {
            "param_bytes": q_lib.tree_bytes(self.params),
            "cache_bytes": cache_bytes(self.cache),
        }

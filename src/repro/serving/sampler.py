"""Token sampling: greedy / temperature / top-k / top-p, batched & jittable."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0          # 0 => greedy
    top_k: int = 0                    # 0 => off
    top_p: float = 1.0                # 1 => off
    max_tokens: int = 64
    eos_id: int = -1                  # -1 => never stops on token


def sample(logits, key, params: SamplingParams):
    """logits: (B, V) -> tokens (B,) int32."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32) / params.temperature
    if params.top_k > 0:
        kth = jax.lax.top_k(lg, params.top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, -1e30, lg)
    if params.top_p < 1.0:
        sorted_lg = jnp.sort(lg, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_lg, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < params.top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_lg, cutoff_idx, axis=-1)
        lg = jnp.where(lg < cutoff, -1e30, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)

"""Token sampling: greedy / temperature / top-k / top-p, batched & jittable."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0          # 0 => greedy
    top_k: int = 0                    # 0 => off
    top_p: float = 1.0                # 1 => off
    max_tokens: int = 64
    eos_id: int = -1                  # -1 => never stops on token


_NEG = -1e30


def sample(logits, key, params: SamplingParams):
    """logits: (B, V) -> tokens (B,) int32."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32) / params.temperature
    if params.top_k > 0:
        kth = jax.lax.top_k(lg, params.top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, _NEG, lg)
    if params.top_p < 1.0:
        sorted_lg = jnp.sort(lg, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_lg, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < params.top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_lg, cutoff_idx, axis=-1)
        lg = jnp.where(lg < cutoff, _NEG, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)


def sample_batched(logits, key, temps, top_ks, top_ps, *,
                   use_top_k: bool = True, use_top_p: bool = True):
    """Per-row sampling with *traced* per-slot params — jittable, so the
    engine's fused decode scan applies each slot's temperature/top-k/top-p
    without a host round-trip.

    logits: (B, V); temps: (B,) f32; top_ks: (B,) int32 (0 => off);
    top_ps: (B,) f32 (1 => off).  Rows with temps <= 0 are greedy.
    Matches `sample` exactly when every row shares one SamplingParams.

    use_top_k / use_top_p are *static* (host-known) switches: when the
    caller can prove no row filters, passing False elides the full-vocab
    sorts from the compiled program — pure temperature sampling then
    costs one categorical, as in the unbatched path.
    """
    v = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32) / jnp.maximum(temps[:, None], 1e-6)
    if use_top_k:
        # per-row traced k: threshold = k-th largest via sort
        sorted_lg = jnp.sort(lg, axis=-1)[..., ::-1]
        kth_idx = jnp.clip(top_ks - 1, 0, v - 1)
        kth = jnp.take_along_axis(sorted_lg, kth_idx[:, None], axis=-1)
        lg = jnp.where((top_ks[:, None] > 0) & (lg < kth), _NEG, lg)
    if use_top_p:
        # top-p on the (top-k-masked) distribution, per-row traced p
        sorted2 = jnp.sort(lg, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted2, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cut_idx = jnp.sum(cum < top_ps[:, None], axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted2, jnp.clip(cut_idx, 0, v - 1),
                                     axis=-1)
        lg = jnp.where((top_ps[:, None] < 1.0) & (lg < cutoff), _NEG, lg)
    sampled = jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0.0, sampled, greedy)

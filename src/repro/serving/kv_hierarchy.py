"""Hierarchical KV memory: refcounted prefix cache + host swap tier.

PR 5's `PagedKVPool` manages *live* KV only — every request pays full
prefill, and page-exhaustion preemption throws the victim's context away
and re-prefills O(context) on resume.  This module adds the two layers
that turn the paged pool into a memory *hierarchy* (AIBrix's KV offload
pool and SLINFER's constrained-memory argument, see PAPERS.md):

* `PrefixCache` — cross-request **prefix reuse**.  Finished requests
  donate their page-aligned leading blocks into a chained-hash index
  (keyed per tenant-visibility salt); at admission the engine matches
  the longest cached prefix, maps the shared physical pages read-only
  into the new slot's page table (refcount bump, zero copies), and
  prefills only the suffix.  Unreferenced entries are LRU-evicted to
  feed the free list before admission blocks — optionally *demoted* to
  the host tier instead of dropped.
* `HostPagePool` — a bounded **host-DRAM page tier**.  Swap-out gathers
  a victim's private pages on device and lands them host-side with one
  `device_get`; swap-in is a `device_put` + jitted scatter.  Preemption
  under page pressure then moves O(pages) instead of recomputing
  O(context), and idle-but-live multi-turn slots can be parked off
  device and restored on the next turn with zero re-prefill.

Tier order on a miss: device pages -> host pool -> recompute.  All data
movement happens at admission/preemption boundaries — the fused decode
hot path never sees a cache lookup or a swap (PR 2's dispatch/host-sync
discipline is preserved, CI-gated).

Safety: only *full* page-aligned blocks are ever shared, and the engine
caps a match below the request's last prompt token, so decode writes
always land in private pages; `PagedKVPool.write_table()` masks shared
pages to the scatter sentinel as a second line of defense, and
`cow_page` + `copy_pages` fork a private copy if a write must land in a
shared page.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.kv_cache import (PagedKVPool, put_pages, take_pages)


# --------------------------------------------------------------------- #
class HostPagePool:
    """Bounded host-DRAM page store (tier 2).  Pages here are plain
    numpy blocks `{leaf: (layers, page_size, ...)}` keyed by host page
    id; the id space is disjoint from the device pool's by construction
    (separate free lists, property-tested)."""

    def __init__(self, n_pages: int):
        self.n_pages = int(n_pages)
        self.free_ids: List[int] = list(range(self.n_pages))[::-1]
        self._store: Dict[int, Dict] = {}
        self.swapped_out = 0          # pages landed host-side
        self.swapped_in = 0           # pages restored to device
        # chaos hook: a swap-tier outage (host OOM, pinned-memory
        # failure).  While set, new swap-outs are refused — the engine
        # falls back to recompute-preemption — but pages already parked
        # stay readable, so swapped requests still resume.
        self.fail_puts = False

    @property
    def in_use(self) -> int:
        return self.n_pages - len(self.free_ids)

    def can_hold(self, n: int) -> bool:
        if self.fail_puts:
            return False
        return n <= len(self.free_ids)

    def put(self, blocks: Dict, n: int,
            force: bool = False) -> Optional[List[int]]:
        """Store `n` pages from stacked host blocks
        `{leaf: (layers, n, page_size, ...)}`.  All-or-nothing.
        `force` bypasses the chaos `fail_puts` hook — used when
        re-parking blocks whose host copies were already released, where
        refusing would lose data instead of degrading service."""
        if (self.fail_puts and not force) or n > len(self.free_ids):
            return None
        ids = [self.free_ids.pop() for _ in range(n)]
        for i, hid in enumerate(ids):
            self._store[hid] = {k: v[:, i] for k, v in blocks.items()}
        self.swapped_out += n
        return ids

    def get(self, ids: List[int]) -> Dict:
        """Stack stored pages back into `{leaf: (layers, n, ...)}` host
        blocks (the `put_pages` upload format)."""
        out: Dict = {}
        for k in (self._store[ids[0]].keys() if ids else ()):
            out[k] = np.stack([self._store[h][k] for h in ids], axis=1)
        return out

    def free(self, ids: List[int]):
        for hid in ids:
            if hid not in self._store:
                raise ValueError(f"free of unallocated host page {hid}")
            del self._store[hid]
            self.free_ids.append(hid)

    def release(self, ids: List[int], restored: bool = False):
        self.free(ids)
        if restored:
            self.swapped_in += len(ids)


# --------------------------------------------------------------------- #
@dataclasses.dataclass
class SwapHandle:
    """Everything needed to rebuild a parked slot's KV without a model
    forward: which table indices keep live device pages (shared prefix
    blocks the handle holds references on) and which moved to the host
    tier.  Engine-visible decode state (last token, sampling budget,
    position) is reconstructed host-side by the engine."""
    request_id: int
    n_tokens: int                       # pool.lengths at detach
    kept: List[Tuple[int, int]]         # (table index, device page id)
    host: List[Tuple[int, int]]         # (table index, host page id)

    @property
    def n_pages(self) -> int:
        return len(self.kept) + len(self.host)


def swap_out_slot(pool: PagedKVPool, host: HostPagePool, paged: Dict,
                  slot: int) -> Optional[SwapHandle]:
    """Park `slot` off-device: detach its page table row, keep device
    references on shared pages (refs > 1 — the prefix-cache blocks,
    which other slots may be reading), and move the private pages to the
    host tier with one jitted gather + one `device_get`.  Returns None —
    leaving the slot untouched — when the host pool cannot hold the
    private pages (caller falls back to recompute-preemption)."""
    pages = pool.slot_pages.get(slot)
    if pages is None:
        return None
    n_tokens = pool.lengths[slot]
    request_id = pool.owners[slot]
    private = [(i, p) for i, p in enumerate(pages)
               if pool.refs.get(p, 1) == 1]
    if not host.can_hold(len(private)):
        return None
    pages = pool.detach(slot)           # handle now owns every reference
    kept = [(i, p) for i, p in enumerate(pages)
            if pool.refs.get(p, 1) > 1]
    priv_ids = [p for i, p in enumerate(pages)
                if pool.refs.get(p, 1) == 1]
    priv_idx = [i for i, p in enumerate(pages)
                if pool.refs.get(p, 1) == 1]
    host_ids: List[int] = []
    if priv_ids:
        blocks = take_pages(paged, priv_ids)    # the one swap-out sync
        host_ids = host.put(blocks, len(priv_ids))
        for p in priv_ids:
            pool.free_page(p)
    return SwapHandle(request_id=request_id, n_tokens=n_tokens, kept=kept,
                      host=list(zip(priv_idx, host_ids)))


def swap_in_slot(pool: PagedKVPool, host: HostPagePool, paged: Dict,
                 handle: SwapHandle) -> Optional[Tuple[int, Dict]]:
    """Restore a parked slot: claim fresh device pages for the host-tier
    blocks, `device_put` + scatter them in (async), and re-attach the
    full page list to a fresh slot.  Returns `(slot, updated_paged)` —
    the caller swaps the updated leaves into its cache — or None
    (handle intact) when slots or pages are short."""
    if not pool.free_slots:
        return None
    fresh = pool.alloc_pages(len(handle.host))
    if fresh is None:
        return None
    table: Dict[int, int] = dict(handle.kept)
    new_paged = paged
    if handle.host:
        hids = [h for _, h in handle.host]
        new_paged = put_pages(paged, fresh, host.get(hids))
        host.release(hids, restored=True)
        for (i, _), p in zip(handle.host, fresh):
            table[i] = p
    pages = [table[i] for i in sorted(table)]
    slot = pool.attach(handle.request_id, pages, handle.n_tokens)
    if slot is None:                    # raced out of slots: undo pages
        for p in fresh:
            pool.free_page(p)
        # host copies are gone; re-park the restored blocks
        if handle.host:
            blocks = take_pages(new_paged, fresh)
            hids = host.put(blocks, len(fresh), force=True)
            handle.host = [(i, h) for (i, _), h
                           in zip(handle.host, hids)]
        return None
    return slot, new_paged


def drop_handle(pool: PagedKVPool, host: HostPagePool,
                handle: SwapHandle):
    """Abandon a parked request (cancel/failure): drop the handle's
    device references and host pages."""
    for _, p in handle.kept:
        pool.free_page(p)
    if handle.host:
        host.free([h for _, h in handle.host])
    handle.kept, handle.host = [], []


# --------------------------------------------------------------------- #
@dataclasses.dataclass
class _Entry:
    key: tuple                          # (salt, parent id, block tokens)
    tokens: tuple                       # the block's token ids
    page: Optional[int]                 # device physical page (tier 1)
    host_id: Optional[int]              # host pool page (tier 2)
    parent: Optional["_Entry"]
    depth: int                          # block index from the root
    eid: int = 0
    users: int = 0                      # live request bindings
    children: int = 0
    tick: int = 0                       # LRU clock

    @property
    def tier(self) -> str:
        return "device" if self.page is not None else "host"


class PrefixCache:
    """Refcounted, copy-on-write prefix index over page-aligned token
    blocks.  Entries form chains (each block keyed by its parent), so a
    lookup walks block-by-block from the root and a match is always a
    *prefix* of full pages.  `users` counts live requests whose slots
    map the entry's page; only `users == 0` leaves are evictable, LRU
    first — demoted to the host tier when one is attached, dropped
    otherwise."""

    def __init__(self, pool: PagedKVPool,
                 host: Optional[HostPagePool] = None,
                 max_device_pages: int = 0,
                 share_tenants: bool = False):
        self.pool = pool
        self.host = host
        self.page_size = pool.page_size
        # 0 => no explicit cap: bounded by the pool + demand reclaim
        self.max_device_pages = int(max_device_pages)
        self.share_tenants = share_tenants
        self._index: Dict[tuple, _Entry] = {}
        self._bound: Dict[int, List[_Entry]] = {}   # request -> entries
        self._ids = 0
        self._clock = 0
        # request-level counters (the admin/bench `cache_hit_rate`)
        self.lookups = 0
        self.hits = 0
        self.matched_tokens = 0
        self.inserted_pages = 0
        self.evictions = 0
        self.demotions = 0
        self.promotions = 0

    # ---- keying --------------------------------------------------- #
    def _salt(self, tenant: str) -> str:
        return "" if self.share_tenants else (tenant or "")

    def _key(self, salt: str, parent: Optional[_Entry],
             block: tuple) -> tuple:
        return (salt, parent.eid if parent else -1, block)

    def _touch(self, e: _Entry):
        self._clock += 1
        e.tick = self._clock

    # ---- metrics -------------------------------------------------- #
    @property
    def device_pages(self) -> int:
        return sum(1 for e in self._index.values() if e.page is not None)

    @property
    def host_pages(self) -> int:
        return sum(1 for e in self._index.values()
                   if e.host_id is not None)

    def evictable_device_pages(self) -> int:
        """Device pages the cache is *guaranteed* to hand back on demand
        — the admission budget and the autoscaler net these out.  Counts
        exactly the entries `reclaim` can evict right now (unpinned
        leaves); interior entries freed by cascade are a bonus, never a
        promise, so the budget can't overcommit against pages a
        host-tier child keeps pinned."""
        return len(self._evictable())

    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def stats(self) -> Dict[str, float]:
        return {
            "entries": len(self._index),
            "device_pages": self.device_pages,
            "host_pages": self.host_pages,
            "evictable_pages": self.evictable_device_pages(),
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_rate": self.hit_rate(),
            "matched_tokens": self.matched_tokens,
            "inserted_pages": self.inserted_pages,
            "evictions": self.evictions,
            "demotions": self.demotions,
            "promotions": self.promotions,
        }

    # ---- lookup / bind -------------------------------------------- #
    def peek(self, tenant: str, tokens, limit_tokens: int) -> int:
        """Non-mutating match length in tokens (device tier only) — the
        scheduler's page-reservation netting; no counters, no LRU
        touches, no promotions."""
        salt = self._salt(tenant)
        parent: Optional[_Entry] = None
        ps = self.page_size
        n = 0
        for b in range(max(limit_tokens, 0) // ps):
            block = tuple(tokens[b * ps:(b + 1) * ps])
            e = self._index.get(self._key(salt, parent, block))
            if e is None or e.tokens != block or e.page is None:
                break
            n += 1
            parent = e
        return n * ps

    def match(self, tenant: str, tokens, limit_tokens: int,
              paged: Optional[Dict] = None):
        """Longest cached prefix of `tokens`, in *full* page blocks,
        never exceeding `limit_tokens`.  Device-tier entries are mapped
        for free; host-tier entries are promoted back to device pages
        when `paged` is given and a page is claimable (one `device_put`
        + scatter, no sync), else the walk stops there.  Returns
        `(entries, matched_tokens, updated_paged_or_None)`."""
        self.lookups += 1
        salt = self._salt(tenant)
        out: List[_Entry] = []
        new_paged = None
        parent: Optional[_Entry] = None
        ps = self.page_size
        max_blocks = max(limit_tokens, 0) // ps
        for b in range(max_blocks):
            block = tuple(tokens[b * ps:(b + 1) * ps])
            e = self._index.get(self._key(salt, parent, block))
            if e is None or e.tokens != block:
                break
            if e.page is None:          # host tier: promote or stop
                if paged is None or self.host is None:
                    break
                src = new_paged if new_paged is not None else paged
                promoted = self._promote(e, src)
                if promoted is None:
                    break
                new_paged = promoted
            self._touch(e)
            out.append(e)
            parent = e
        if out:
            self.hits += 1
            self.matched_tokens += len(out) * ps
        return out, len(out) * ps, new_paged

    def _promote(self, e: _Entry, paged: Dict) -> Optional[Dict]:
        """Host -> device: claim a page (reclaiming LRU cache pages if
        the pool is dry), upload the stored block, rewrite the entry."""
        claimed = self.pool.alloc_pages(1)
        if claimed is None:
            if self.reclaim(1, paged) < 1:
                return None
            claimed = self.pool.alloc_pages(1)
            if claimed is None:
                return None
        page = claimed[0]
        new_paged = put_pages(paged, [page], self.host.get([e.host_id]))
        self.host.release([e.host_id], restored=True)
        e.host_id, e.page = None, page
        self.promotions += 1
        return new_paged

    def bind(self, request_id: int, entries: List[_Entry]):
        """Pin `entries` for a live request (its slot maps their pages);
        pinned entries are not evictable."""
        if not entries:
            return
        for e in entries:
            e.users += 1
        self._bound[request_id] = list(entries)

    def unbind(self, request_id: int):
        for e in self._bound.pop(request_id, ()):
            e.users -= 1

    # ---- insert ---------------------------------------------------- #
    def insert(self, tenant: str, tokens, n_tokens: int,
               slot_pages: List[int]) -> int:
        """Donate a finishing slot's full page-aligned blocks to the
        cache: existing entries are refreshed, new blocks `retain` the
        slot's physical page (so the subsequent `pool.release` leaves
        the cache holding the last reference).  Returns pages newly
        cached."""
        salt = self._salt(tenant)
        ps = self.page_size
        parent: Optional[_Entry] = None
        added = 0
        for b in range(min(n_tokens // ps, len(slot_pages))):
            block = tuple(tokens[b * ps:(b + 1) * ps])
            key = self._key(salt, parent, block)
            e = self._index.get(key)
            if e is None:
                if self.max_device_pages and \
                        self.device_pages >= self.max_device_pages and \
                        self.reclaim(1) < 1:
                    break               # cap reached, nothing evictable
                page = slot_pages[b]
                self.pool.retain(page)
                self._ids += 1
                e = _Entry(key=key, tokens=block, page=page, host_id=None,
                           parent=parent, depth=b, eid=self._ids)
                self._index[key] = e
                if parent is not None:
                    parent.children += 1
                self.inserted_pages += 1
                added += 1
            self._touch(e)
            parent = e
        return added

    # ---- eviction -------------------------------------------------- #
    def _evictable(self) -> List[_Entry]:
        return sorted((e for e in self._index.values()
                       if e.users == 0 and e.children == 0
                       and e.page is not None),
                      key=lambda e: e.tick)

    def _drop(self, e: _Entry, demote_paged: Optional[Dict]):
        """Remove one leaf entry, demoting its block to the host tier
        when possible (so a later match can promote it back) else
        dropping it outright."""
        if e.page is not None:
            if demote_paged is not None and self.host is not None \
                    and self.host.can_hold(1):
                blocks = take_pages(demote_paged, [e.page])
                e.host_id = self.host.put(blocks, 1)[0]
                self.demotions += 1
                self.pool.free_page(e.page)
                e.page = None
                return                  # entry lives on, host tier
            self.pool.free_page(e.page)
            e.page = None
        if e.host_id is not None:
            self.host.free([e.host_id])
            e.host_id = None
        del self._index[e.key]
        if e.parent is not None:
            e.parent.children -= 1
        self.evictions += 1

    def reclaim(self, n_pages: int,
                demote_paged: Optional[Dict] = None) -> int:
        """Free up to `n_pages` device pages by LRU-evicting unpinned
        leaf entries (cascading up chains as leaves clear).  With
        `demote_paged`, evicted blocks demote to the host tier (one
        gather + `device_get` each) instead of vanishing.  Returns pages
        actually freed — the engine calls this before admission blocks
        or preempts."""
        freed = 0
        while freed < n_pages:
            victims = self._evictable()
            if not victims:
                break
            for e in victims:
                if freed >= n_pages:
                    break
                self._drop(e, demote_paged)
                freed += 1
        return freed

    def flush(self) -> Dict[str, int]:
        """Drop every unpinned entry (device and host tiers) — the
        `/v1/admin/cache/flush` verb and the deterministic-test reset.
        Pinned entries (live slots still read their pages) survive."""
        dropped = 0
        while True:
            leaves = [e for e in self._index.values()
                      if e.users == 0 and e.children == 0]
            if not leaves:
                break
            for e in leaves:
                self._drop(e, None)
                dropped += 1
        return {"flushed": dropped, "remaining": len(self._index)}

"""Slot-pool KV cache manager for continuous batching.

The engine owns one model cache sized (layers, n_slots, max_len, ...).  The
pool hands out slots, tracks per-slot lengths, and accounts bytes exactly —
the numbers the SDAI placement controller charges against a node's HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class SlotPool:
    n_slots: int
    max_len: int
    free: List[int] = dataclasses.field(default_factory=list)
    lengths: Dict[int, int] = dataclasses.field(default_factory=dict)
    owners: Dict[int, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self.free = list(range(self.n_slots))[::-1]

    def alloc(self, request_id: int, prompt_len: int) -> Optional[int]:
        if not self.free or prompt_len > self.max_len:
            return None
        slot = self.free.pop()
        self.lengths[slot] = prompt_len
        self.owners[slot] = request_id
        return slot

    def advance(self, slot: int, n: int = 1):
        self.lengths[slot] = min(self.lengths[slot] + n, self.max_len)

    def release(self, slot: int):
        if slot in self.lengths:
            del self.lengths[slot]
            del self.owners[slot]
            self.free.append(slot)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self.free)

    def utilization(self) -> float:
        """Fraction of cache *tokens* in use (the VRAM-efficiency metric)."""
        used = sum(self.lengths.values())
        return used / float(self.n_slots * self.max_len)


def write_slot(cache, slot_cache, slot: int, batch_axis: int = 1):
    """Scatter a single-request cache (batch dim 1) into `slot` of the pool
    cache.  Works for every model family (transformer L-stacked / xlstm)."""
    def upd(pool, one):
        return jax.lax.dynamic_update_slice_in_dim(
            pool, one.astype(pool.dtype), slot, axis=batch_axis)
    return jax.tree.map(upd, cache, slot_cache)


def write_slots(cache, rows_cache, slots, batch_axis: int = 1):
    """Scatter a *batch* of freshly-prefilled rows into the pool cache in
    one op per leaf — jittable, so a whole admission bucket lands with a
    single dispatch.

    rows_cache leaves have the same shape as the pool leaves except the
    batch axis, which is len(slots).  `slots` may be a traced int32 array;
    out-of-range entries (>= n_slots) are dropped, which is how padded
    bucket rows are discarded on device.
    """
    idx = jnp.asarray(slots, jnp.int32)

    def upd(pool, rows):
        moved = jnp.moveaxis(pool, batch_axis, 0)
        rows_m = jnp.moveaxis(rows.astype(pool.dtype), batch_axis, 0)
        out = moved.at[idx].set(rows_m, mode="drop")
        return jnp.moveaxis(out, 0, batch_axis)
    return jax.tree.map(upd, cache, rows_cache)


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))

"""KV-cache managers for continuous batching: contiguous slots and pages.

Two pool flavours back the engine:

* `SlotPool` — the original contiguous layout: one `max_len`-token strip
  per slot.  Simple, but short requests strand the tail of their strip
  (internal fragmentation), so a node's VRAM admits far fewer concurrent
  requests than it could.
* `PagedKVPool` — vLLM-style page-granular allocation: the physical cache
  is a flat pool of fixed-size token pages; each slot owns a *page table*
  (a row of physical page indices, mirrored in one device array) and grows
  page-by-page as it decodes.  Slots can be oversubscribed against the
  page budget — admission is page-aware and the engine preempts on
  exhaustion — which is what turns raw VRAM into admitted requests.

The jitted `gather_pages` / `scatter_pages` / `scatter_prefill_rows`
helpers let the fused decode and bucketed prefill read/write *through*
the page table entirely on device: one gather before the decode scan, one
scatter after, zero extra host syncs.  Unallocated page-table entries hold
the out-of-bounds sentinel (`n_pages`), which `mode="fill"` gathers as
zeros and `mode="drop"` scatters discard — no masking round-trips.

Byte accounting stays exact — the numbers the SDAI placement controller
charges against a node's HBM are now a *page budget*, not worst-case
`n_slots x max_len`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

# cache leaves that scale with sequence length and live in the paged
# physical pool; everything else (ssm states, encoder cross-attention
# KV) is constant-size per slot and stays slot-resident
PAGED_LEAVES = ("k", "v", "k_scale", "v_scale")


@dataclasses.dataclass
class SlotPool:
    n_slots: int
    max_len: int
    free: List[int] = dataclasses.field(default_factory=list)
    lengths: Dict[int, int] = dataclasses.field(default_factory=dict)
    owners: Dict[int, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self.free = list(range(self.n_slots))[::-1]

    def alloc(self, request_id: int, prompt_len: int) -> Optional[int]:
        if not self.free or prompt_len > self.max_len:
            return None
        slot = self.free.pop()
        self.lengths[slot] = prompt_len
        self.owners[slot] = request_id
        return slot

    def advance(self, slot: int, n: int = 1):
        self.lengths[slot] = min(self.lengths[slot] + n, self.max_len)

    def release(self, slot: int):
        if slot in self.lengths:
            del self.lengths[slot]
            del self.owners[slot]
            self.free.append(slot)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self.free)

    def utilization(self) -> float:
        """Fraction of cache *tokens* in use (the VRAM-efficiency metric)."""
        used = sum(self.lengths.values())
        return used / float(self.n_slots * self.max_len)


class PagedKVPool:
    """Page-granular KV allocator with a device-resident page table.

    Host side: a free-list of physical page ids, per-slot page lists, and
    per-slot token lengths.  Device side: one `(n_slots, pages_per_slot)`
    int32 page table, rebuilt lazily after host mutations (an async
    host->device upload, never a blocking sync).  Entries holding the
    sentinel `n_pages` gather as zeros and scatter as no-ops.

    `n_pages` defaults to the contiguous-equivalent budget
    (`n_slots * pages_per_slot`); passing fewer pages oversubscribes the
    slots — more concurrent requests for the same VRAM, relying on
    page-aware admission and engine preemption when decode outgrows the
    pool.

    Pages are **refcounted**: a physical page returns to the free list
    only when its last reference drops.  A slot normally holds the sole
    reference to each of its pages, but the prefix-cache layer
    (`kv_hierarchy.PrefixCache`) can `retain` pages so finished requests
    donate their prefix blocks, and map the same physical page into many
    slots' tables (`alloc(shared_pages=...)`).  Shared pages (refs > 1)
    are read-only through `write_table()` — the decode scatter sees the
    sentinel there, so writes into a shared page drop on device;
    `cow_page` forks a private copy when a write *must* land.
    """

    def __init__(self, n_slots: int, max_len: int, page_size: int = 16,
                 n_pages: int = 0):
        if page_size <= 0:
            raise ValueError("page_size must be positive")
        self.n_slots = n_slots
        self.max_len = max_len
        self.page_size = page_size
        self.pages_per_slot = -(-max_len // page_size)   # ceil
        self.n_pages = n_pages or n_slots * self.pages_per_slot
        if self.n_pages < self.pages_per_slot:
            raise ValueError(
                f"kv pool of {self.n_pages} pages cannot hold even one "
                f"max_len={max_len} sequence ({self.pages_per_slot} pages)")
        self.free_slots: List[int] = list(range(n_slots))[::-1]
        self.free_pages: List[int] = list(range(self.n_pages))[::-1]
        self.slot_pages: Dict[int, List[int]] = {}
        self.lengths: Dict[int, int] = {}     # cache tokens written/held
        self.owners: Dict[int, int] = {}      # slot -> request_id
        self.refs: Dict[int, int] = {}        # page -> reference count
        self.preemptions = 0                  # engine-driven evictions
        self.grow_failures = 0                # page-exhaustion events
        # host mirror of the device page table; sentinel == self.n_pages
        self._table = np.full((n_slots, self.pages_per_slot), self.n_pages,
                              np.int32)
        self._table_dev = None
        self._dirty = True
        self._wtable_dev = None
        self._wdirty = True

    # ---- allocation ---------------------------------------------- #
    def pages_for_tokens(self, n_tokens: int) -> int:
        return max(-(-n_tokens // self.page_size), 1)

    def can_admit(self, n_tokens: int) -> bool:
        return (bool(self.free_slots)
                and self.pages_for_tokens(n_tokens) <= len(self.free_pages))

    def _claim(self, n: int) -> Optional[List[int]]:
        """Pop `n` fresh pages (refcount 1 each); None when short."""
        if n > len(self.free_pages):
            return None
        pages = [self.free_pages.pop() for _ in range(n)]
        for p in pages:
            self.refs[p] = 1
        return pages

    def alloc(self, request_id: int, n_tokens: int,
              reserve_tokens: int = 0, shared_pages=()) -> Optional[int]:
        """Claim a slot plus pages covering `n_tokens` cache positions
        (`reserve_tokens`, when larger, widens the page claim — the
        contiguous/resident mode reserves the full `max_len` strip up
        front).  `shared_pages` (prefix-cache hit) are already-allocated
        pages mapped read-only at the front of the new slot's table; the
        pool bumps their refcount and claims fresh pages only for the
        remainder.  All-or-nothing: returns None (claiming nothing) when
        either the slot or the page budget is exhausted."""
        total = self.pages_for_tokens(max(n_tokens, reserve_tokens))
        fresh = total - len(shared_pages)
        if not self.free_slots or n_tokens > self.max_len or fresh < 0 \
                or fresh > len(self.free_pages):
            return None
        slot = self.free_slots.pop()
        pages = list(shared_pages)
        for p in pages:
            self.refs[p] = self.refs.get(p, 0) + 1
        pages.extend(self._claim(fresh))
        self.slot_pages[slot] = pages
        self.lengths[slot] = n_tokens
        self.owners[slot] = request_id
        self._table[slot, :total] = pages
        self._mark_dirty()
        return slot

    def alloc_pages(self, n: int) -> Optional[List[int]]:
        """Claim `n` orphan pages (no slot) — the COW fork / cache-demote
        path.  Caller owns one reference to each."""
        return self._claim(n)

    def attach(self, request_id: int, pages: List[int],
               n_tokens: int) -> Optional[int]:
        """Map an existing page list into a fresh slot (swap-in restore).
        Ownership of the caller's references *transfers* to the slot — no
        refcount change, no page claim.  Returns the slot, or None when
        every slot is busy (caller keeps ownership)."""
        if not self.free_slots or len(pages) > self.pages_per_slot:
            return None
        slot = self.free_slots.pop()
        self.slot_pages[slot] = list(pages)
        self.lengths[slot] = n_tokens
        self.owners[slot] = request_id
        self._table[slot, :len(pages)] = pages
        self._mark_dirty()
        return slot

    def detach(self, slot: int) -> List[int]:
        """Unmap `slot` *without* dropping its page references (swap-out):
        the caller now owns one reference to each returned page and must
        eventually `free_page` or `attach` them."""
        if slot not in self.lengths:
            return []
        del self.lengths[slot]
        del self.owners[slot]
        pages = self.slot_pages.pop(slot)
        self._table[slot, :] = self.n_pages
        self._mark_dirty()
        self.free_slots.append(slot)
        return pages

    def retain(self, page: int):
        """Add a reference to an allocated page (prefix-cache insert)."""
        if page not in self.refs:
            raise ValueError(f"retain of unallocated page {page}")
        self.refs[page] += 1
        self._wdirty = True

    def free_page(self, page: int):
        """Drop one reference; the page returns to the free list when the
        last reference goes."""
        r = self.refs.get(page)
        if r is None:
            raise ValueError(f"free of unallocated page {page}")
        if r > 1:
            self.refs[page] = r - 1
            self._wdirty = True
        else:
            del self.refs[page]
            self.free_pages.append(page)

    def grow(self, slot: int, upto_tokens: int) -> bool:
        """Extend `slot`'s page table to cover `upto_tokens` positions.
        All-or-nothing; False means the free list ran dry (the engine's
        preemption trigger)."""
        have = self.slot_pages.get(slot)
        if have is None:
            return False
        need = min(self.pages_for_tokens(upto_tokens),
                   self.pages_per_slot) - len(have)
        if need <= 0:
            return True
        new = self._claim(need)
        if new is None:
            self.grow_failures += 1
            return False
        self._table[slot, len(have):len(have) + need] = new
        have.extend(new)
        self._mark_dirty()
        return True

    def cow_page(self, slot: int, i: int) -> Optional[tuple]:
        """Copy-on-write fork: when page `i` of `slot` is shared, replace
        it with a fresh private page and return `(old, new)` so the
        caller copies the device contents (`copy_pages`) — the slot's
        reference moves to the new page.  Returns None when the page is
        already private (nothing to do) or the pool is out of pages."""
        pages = self.slot_pages.get(slot)
        if pages is None or i >= len(pages):
            return None
        old = pages[i]
        if self.refs.get(old, 1) <= 1:
            return None
        claimed = self._claim(1)
        if claimed is None:
            return None
        new = claimed[0]
        self.free_page(old)        # drop the slot's shared reference
        pages[i] = new
        self._table[slot, i] = new
        self._mark_dirty()
        return old, new

    def advance(self, slot: int, n: int = 1):
        self.lengths[slot] = min(self.lengths[slot] + n, self.max_len)

    def release(self, slot: int):
        if slot not in self.lengths:
            return
        del self.lengths[slot]
        del self.owners[slot]
        for p in reversed(self.slot_pages.pop(slot)):
            self.free_page(p)
        self._table[slot, :] = self.n_pages
        self._mark_dirty()
        self.free_slots.append(slot)

    # ---- device view --------------------------------------------- #
    def _mark_dirty(self):
        self._dirty = True
        self._wdirty = True

    def page_table(self):
        """The `(n_slots, pages_per_slot)` int32 device page table.  Only
        re-uploaded after host-side mutations; the upload is asynchronous
        (no device->host sync)."""
        if self._dirty or self._table_dev is None:
            self._table_dev = jnp.asarray(self._table)
            self._dirty = False
        return self._table_dev

    def write_table(self):
        """The page table with **shared** entries (refs > 1) masked to
        the sentinel: reads gather through `page_table()`, writes scatter
        through this one, so a write aimed at a cache-shared page drops
        on device instead of corrupting other readers.  With no sharing
        this is identical to `page_table()` (same device array — no
        second upload on the common path)."""
        if not self._wdirty and self._wtable_dev is not None:
            return self._wtable_dev
        shared = [p for p, r in self.refs.items() if r > 1]
        if not shared:
            self._wtable_dev = self.page_table()
        else:
            wt = self._table.copy()
            wt[np.isin(wt, np.asarray(shared, np.int32))] = self.n_pages
            self._wtable_dev = jnp.asarray(wt)
        self._wdirty = False
        return self._wtable_dev

    def row_pages(self, slot: int, n_pages_row: int) -> np.ndarray:
        """Physical page ids backing `slot`, sentinel-padded to
        `n_pages_row` — the prefill row-scatter index."""
        out = np.full((n_pages_row,), self.n_pages, np.int32)
        pages = self.slot_pages.get(slot, ())
        k = min(len(pages), n_pages_row)
        out[:k] = pages[:k]
        return out

    # ---- metrics -------------------------------------------------- #
    @property
    def free(self) -> List[int]:          # SlotPool-compatible alias
        return self.free_slots

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self.free_slots)

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self.free_pages)

    def utilization(self) -> float:
        """Fraction of pool *tokens* holding live cache entries."""
        used = sum(self.lengths.values())
        return used / float(self.n_pages * self.page_size)

    def page_occupancy(self) -> float:
        """Fraction of physical pages allocated — the admission-pressure
        signal the autoscaler watches."""
        return self.pages_in_use / float(self.n_pages)

    def fragmentation(self) -> float:
        """Internal fragmentation: fraction of *allocated* page tokens not
        holding live entries (bounded by one page per slot)."""
        if not self.pages_in_use:
            return 0.0
        used = sum(self.lengths.values())
        return 1.0 - used / float(self.pages_in_use * self.page_size)

    def page_stats(self) -> Dict[str, float]:
        return {
            "page_size": self.page_size,
            "kv_pages": self.n_pages,
            "pages_in_use": self.pages_in_use,
            "page_occupancy": self.page_occupancy(),
            "kv_page_utilization": self.utilization(),
            "page_fragmentation": self.fragmentation(),
            "preemptions": self.preemptions,
            "grow_failures": self.grow_failures,
        }


# --------------------------------------------------------------------- #
# Jitted paged gather/scatter — the device half of the page table.
# Paged physical leaves are laid out (layers, n_pages, page_size, ...);
# logical views are (layers, n_slots, pages_per_slot * page_size, ...).

def split_paged(cache: Dict) -> (Dict, Dict):
    """Partition a cache dict into (paged, resident) leaf sub-dicts."""
    paged = {k: v for k, v in cache.items() if k in PAGED_LEAVES}
    resident = {k: v for k, v in cache.items() if k not in PAGED_LEAVES}
    return paged, resident


def gather_pages(paged: Dict, page_table):
    """Materialize each slot's logical cache view from the physical page
    pool: one gather per leaf, sentinel entries fill with zeros (masked
    by `pos` in attention, so harmless)."""
    idx = page_table.reshape(-1)
    n_slots, pps = page_table.shape

    def g(leaf):
        rows = jnp.take(leaf, idx, axis=1, mode="fill", fill_value=0)
        return rows.reshape((leaf.shape[0], n_slots, pps * leaf.shape[2])
                            + leaf.shape[3:])
    return {k: g(v) for k, v in paged.items()}


def scatter_pages(paged: Dict, view: Dict, page_table):
    """Write updated logical views back into the physical pool: one
    scatter per leaf; sentinel entries drop on device."""
    idx = page_table.reshape(-1)
    n_slots, pps = page_table.shape

    def s(leaf, vleaf):
        ps = leaf.shape[2]
        rows = vleaf.reshape((leaf.shape[0], n_slots * pps, ps)
                             + leaf.shape[3:])
        return leaf.at[:, idx].set(rows.astype(leaf.dtype), mode="drop")
    return {k: s(v, view[k]) for k, v in paged.items()}


def scatter_prefill_rows(paged: Dict, rows: Dict, row_pages):
    """Land a batch of freshly-prefilled rows in the page pool: each
    row's sequence is zero-padded to a page multiple, cut into pages, and
    scattered through `row_pages` ((n_rows, n_pages_row) physical ids,
    sentinel-padded) — one op per leaf, jittable, padded bucket positions
    and padded batch rows both drop on device."""
    idx = row_pages.reshape(-1)
    n_rows, npr = row_pages.shape

    def s(leaf, rleaf):
        ps = leaf.shape[2]
        pad = npr * ps - rleaf.shape[2]
        widths = [(0, 0), (0, 0), (0, pad)] + [(0, 0)] * (rleaf.ndim - 3)
        padded = jnp.pad(rleaf, widths)
        pages = padded.reshape((leaf.shape[0], n_rows * npr, ps)
                               + leaf.shape[3:])
        return leaf.at[:, idx].set(pages.astype(leaf.dtype), mode="drop")
    return {k: s(v, rows[k]) for k, v in paged.items()}


# --------------------------------------------------------------------- #
# Page movement — COW forks and the host swap tier.  All device work is
# jitted with power-of-two-padded id vectors (sentinel-padded: `fill`
# gathers zeros, `drop` scatters discard), so trace count stays
# logarithmic in swap size instead of one trace per page count.

def _pad_ids(ids, sentinel: int) -> np.ndarray:
    n = max(len(ids), 1)
    m = 1
    while m < n:
        m <<= 1
    out = np.full((m,), sentinel, np.int32)
    out[:len(ids)] = ids
    return out


@jax.jit
def _gather_page_blocks(leaf, idx):
    return jnp.take(leaf, idx, axis=1, mode="fill", fill_value=0)


@jax.jit
def _scatter_page_blocks(leaf, idx, blocks):
    return leaf.at[:, idx].set(blocks.astype(leaf.dtype), mode="drop")


def copy_pages(paged: Dict, src_ids, dst_ids) -> Dict:
    """Device-side page copy (the COW fork data move): physical pages
    `src_ids` are duplicated into `dst_ids`, leaf by leaf.  One jitted
    gather + one jitted scatter; no host sync."""
    sentinel = next(iter(paged.values())).shape[1]
    src = jnp.asarray(_pad_ids(src_ids, sentinel))
    dst = jnp.asarray(_pad_ids(dst_ids, sentinel))
    return {k: _scatter_page_blocks(v, dst, _gather_page_blocks(v, src))
            for k, v in paged.items()}


def take_pages(paged: Dict, page_ids) -> Dict:
    """Swap-out data move: gather physical pages on device (jitted), then
    one `device_get` for the whole block set.  Returns
    `{leaf: np(layers, n, page_size, ...)}` host arrays."""
    sentinel = next(iter(paged.values())).shape[1]
    idx = jnp.asarray(_pad_ids(page_ids, sentinel))
    gathered = {k: _gather_page_blocks(v, idx) for k, v in paged.items()}
    host = jax.device_get(gathered)       # the ONE sync of a swap-out
    n = len(page_ids)
    return {k: v[:, :n] for k, v in host.items()}


def put_pages(paged: Dict, page_ids, host_blocks: Dict) -> Dict:
    """Swap-in data move: `device_put` the host blocks and scatter them
    into physical pages `page_ids` (jitted; async, no host sync)."""
    sentinel = next(iter(paged.values())).shape[1]
    padded = _pad_ids(page_ids, sentinel)
    idx = jnp.asarray(padded)
    out = {}
    for k, leaf in paged.items():
        blk = host_blocks[k]
        pad = len(padded) - blk.shape[1]
        if pad:
            widths = [(0, 0), (0, pad)] + [(0, 0)] * (blk.ndim - 2)
            blk = np.pad(blk, widths)
        out[k] = _scatter_page_blocks(leaf, idx, jax.device_put(blk))
    return out


# --------------------------------------------------------------------- #
def write_slot(cache, slot_cache, slot: int, batch_axis: int = 1):
    """Scatter a single-request cache (batch dim 1) into `slot` of the pool
    cache.  Works for every model family (transformer L-stacked / xlstm)."""
    def upd(pool, one):
        return jax.lax.dynamic_update_slice_in_dim(
            pool, one.astype(pool.dtype), slot, axis=batch_axis)
    return jax.tree.map(upd, cache, slot_cache)


def write_slots(cache, rows_cache, slots, batch_axis: int = 1):
    """Scatter a *batch* of freshly-prefilled rows into the pool cache in
    one op per leaf — jittable, so a whole admission bucket lands with a
    single dispatch.

    rows_cache leaves have the same shape as the pool leaves except the
    batch axis, which is len(slots).  `slots` may be a traced int32 array;
    out-of-range entries (>= n_slots) are dropped, which is how padded
    bucket rows are discarded on device.
    """
    idx = jnp.asarray(slots, jnp.int32)

    def upd(pool, rows):
        moved = jnp.moveaxis(pool, batch_axis, 0)
        rows_m = jnp.moveaxis(rows.astype(pool.dtype), batch_axis, 0)
        out = moved.at[idx].set(rows_m, mode="drop")
        return jnp.moveaxis(out, 0, batch_axis)
    return jax.tree.map(upd, cache, rows_cache)


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))

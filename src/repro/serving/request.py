"""Serving request/response types shared by engine, frontend, and client."""
from __future__ import annotations

import dataclasses
import enum
import itertools
import time
from typing import List, Optional

from repro.serving.sampler import SamplingParams

_ids = itertools.count()


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"
    FAILED = "failed"


@dataclasses.dataclass
class Request:
    model: str
    prompt: List[int]                         # token ids
    sampling: SamplingParams = SamplingParams()
    request_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    state: RequestState = RequestState.QUEUED
    output: List[int] = dataclasses.field(default_factory=list)
    created_at: float = dataclasses.field(default_factory=time.monotonic)
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    error: str = ""
    # routing metadata (filled by frontend)
    node: str = ""
    replica: str = ""
    retries: int = 0

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.created_at

    @property
    def latency(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.created_at

    def finish(self, error: str = ""):
        self.finished_at = time.monotonic()
        self.error = error
        self.state = RequestState.FAILED if error else RequestState.FINISHED

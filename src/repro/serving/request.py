"""Serving request/response types shared by engine, frontend, and client.

`Request` is the *internal, mutable* unit of work that flows through the
frontend, nodes, and engines.  Public callers should use the frozen types
in `repro.api` (`GenerationRequest` / `GenerationResponse` /
`StreamEvent`); the Gateway translates between the two.

Streaming contract: engines (and accounted-mode nodes) deliver every
generated token through `Request.emit`, which invokes the `on_token`
callback, and report completion through `Request.finish`, which invokes
`on_finish` exactly once.  The frontend suppresses `on_finish` while it is
still retrying across replicas so a handle never observes a transient
attempt failure as the final outcome.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import time
from typing import Callable, List, Optional

from repro.serving.sampler import SamplingParams

_ids = itertools.count()


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"
    FAILED = "failed"


# Internal error-code strings; mirrored 1:1 by `repro.api.types.ErrorCode`
# so the gateway never has to parse human-readable error messages.
CODE_NO_BACKEND = "no_backend"
CODE_OVERLOADED = "overloaded"
CODE_ENGINE_FAILED = "engine_failed"
CODE_CANCELLED = "cancelled"
CODE_TIMEOUT = "timeout"
CODE_INVALID_REQUEST = "invalid_request"
CODE_RATE_LIMITED = "rate_limited"


@dataclasses.dataclass
class Request:
    model: str
    prompt: List[int]                         # token ids
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    tenant: str = ""                          # multi-tenant accounting key
    request_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    state: RequestState = RequestState.QUEUED
    output: List[int] = dataclasses.field(default_factory=list)
    created_at: float = dataclasses.field(default_factory=time.monotonic)
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    error: str = ""
    error_code: str = ""
    cancelled: bool = False
    # routing metadata (filled by frontend)
    node: str = ""
    replica: str = ""
    retries: int = 0
    # cumulative WFQ virtual-clock debit this request has paid on its
    # current replica — lets the scheduler charge served tokens exactly
    # once across preempt/resume cycles instead of re-billing the
    # remaining budget at every re-admission
    wfq_charged: float = 0.0
    # streaming hooks (set by the Gateway; None => no-op)
    on_token: Optional[Callable[["Request", int], None]] = \
        dataclasses.field(default=None, repr=False)
    on_finish: Optional[Callable[["Request"], None]] = \
        dataclasses.field(default=None, repr=False)
    # routing-in-progress: the frontend holds finish callbacks until the
    # retry loop settles on a final outcome
    _suppress_finish: bool = dataclasses.field(
        default=False, init=False, repr=False)
    _finish_fired: bool = dataclasses.field(
        default=False, init=False, repr=False)

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.created_at

    @property
    def latency(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.created_at

    # ------------------------------------------------------------- #
    def emit(self, tok: int):
        """Deliver one generated token (engine -> stream callback)."""
        if self.first_token_at is None:
            self.first_token_at = time.monotonic()
        self.output.append(tok)
        if self.on_token is not None:
            self.on_token(self, tok)

    def emit_many(self, toks):
        """Deliver a block of tokens (one fused K-step engine dispatch).
        Drives the per-token `emit` path in order, so the streaming
        contract is byte-identical to K sequential `emit`s."""
        for tok in toks:
            self.emit(tok)

    def finish(self, error: str = "", code: str = ""):
        self.finished_at = time.monotonic()
        self.error = error
        self.error_code = code or (CODE_ENGINE_FAILED if error else "")
        self.state = RequestState.FAILED if error else RequestState.FINISHED
        self._fire_finish()

    def _fire_finish(self):
        if self._suppress_finish or self._finish_fired:
            return
        self._finish_fired = True
        if self.on_finish is not None:
            self.on_finish(self)

    def reset_for_retry(self):
        """Failover/migration reset: clear a failed attempt so the request
        can be resubmitted to the next-best replica.  The emitted-token
        journal (`output`) is authoritative and survives untouched — a
        mid-stream migration resumes from `prompt + output` with the
        remaining budget, never replaying or dropping tokens."""
        self.retries += 1
        self.state = RequestState.QUEUED
        self.error = ""
        self.error_code = ""
        self.finished_at = None
        self._finish_fired = False
        # exactly-once billing across replicas: floor the WFQ debit at
        # the tokens already served, so the next replica's clock bills
        # only the remaining budget (zero served => starts over, the old
        # pre-token failover behaviour)
        self.wfq_charged = float(len(self.output))

"""Weight quantization — the Ollama-GGUF analogue that lets AIvailable pack
models into small/legacy VRAM budgets.

int8 (per-output-channel absmax) and packed int4.  `quantize_tree` converts a
param pytree so *quantized weights are what lives in HBM*; `dequant_tree` is
called inside the jitted step so dequantization happens on-chip per use
(weights stay int8 at rest — this is the memory the placement controller
accounts).  The perf-critical dequant-matmul has a Pallas kernel in
`repro/kernels/int8_matmul`.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

PyTree = Any
_QKEY = "__q__"


def quantize_array(w, bits: int = 8):
    """Per-last-dim-channel absmax quantization.  Returns dict leaf."""
    wf = w.astype(jnp.float32)
    red = tuple(range(w.ndim - 1))
    amax = jnp.max(jnp.abs(wf), axis=red, keepdims=True)
    if bits == 8:
        scale = jnp.maximum(amax, 1e-8) / 127.0
        q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
        return {_QKEY: q, "scale": scale.astype(jnp.float32),
                "dtype": jnp.zeros((), w.dtype), "bits8": jnp.zeros((0,))}
    if bits == 4:
        scale = jnp.maximum(amax, 1e-8) / 7.0
        q = jnp.clip(jnp.round(wf / scale), -7, 7).astype(jnp.int8)
        # pack two int4 per int8 along the leading axis (must be even)
        if q.shape[0] % 2 == 0:
            lo = q[0::2] & 0x0F
            hi = (q[1::2] & 0x0F) << 4
            packed = (lo | hi).astype(jnp.int8)
            return {_QKEY: packed, "scale": scale.astype(jnp.float32),
                    "dtype": jnp.zeros((), w.dtype), "bits4": jnp.zeros((0,))}
        return {_QKEY: q, "scale": scale.astype(jnp.float32),
                "dtype": jnp.zeros((), w.dtype), "bits8": jnp.zeros((0,))}
    raise ValueError(f"bits={bits}")


def dequantize_array(leaf: Dict):
    q, scale = leaf[_QKEY], leaf["scale"]
    dt = leaf["dtype"].dtype
    if "bits4" in leaf:
        lo = (q << 4) >> 4             # sign-extend low nibble
        hi = q >> 4
        full = jnp.stack([lo, hi], axis=1).reshape(
            (q.shape[0] * 2,) + q.shape[1:])
        return (full.astype(jnp.float32) * scale).astype(dt)
    return (q.astype(jnp.float32) * scale).astype(dt)


def is_quantized_leaf(x) -> bool:
    return isinstance(x, dict) and _QKEY in x


def quantize_tree(params: PyTree, bits: int = 8,
                  skip: Optional[Callable[[Any], bool]] = None) -> PyTree:
    """Quantize every >=2D float leaf (norm scales & biases stay as-is)."""
    def q(x):
        if skip is not None and skip(x):
            return x
        if hasattr(x, "ndim") and x.ndim >= 2 and \
                jnp.issubdtype(x.dtype, jnp.floating):
            return quantize_array(x, bits)
        return x
    return jax.tree.map(q, params)


def dequant_tree(params: PyTree) -> PyTree:
    return jax.tree.map(
        lambda x: dequantize_array(x) if is_quantized_leaf(x) else x,
        params, is_leaf=is_quantized_leaf)


def tree_bytes(params: PyTree) -> int:
    """Actual at-rest bytes of a (possibly quantized) param tree."""
    total = 0
    for leaf in jax.tree.leaves(params):
        total += leaf.size * leaf.dtype.itemsize
    return total


def quantized_matmul_ref(x, q, scale):
    """x @ dequant(q): pure-jnp oracle for the Pallas int8 kernel.
    x: (..., K); q: (K, N) int8; scale: (1, N) or (K? broadcast) f32."""
    w = q.astype(jnp.float32) * scale
    return jnp.einsum("...k,kn->...n", x.astype(jnp.float32), w)

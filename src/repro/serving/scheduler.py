"""Two-level request scheduler for the continuous-batching engine.

Level 1 — **tenant fairness**: every tenant gets its own FCFS queue, and
admission order across tenants is weighted fair queuing in the
deficit/virtual-time family (start-time fair queuing): every tenant
carries a *virtual service* clock that advances by
`projected_served_tokens / weight` on each admission, and each round
serves the backlogged tenant with the smallest clock whose head fits the
engine's free *page* budget.  Under contention, served-token shares
converge to the configured `TenantQuota.weight`s even across mixed
prompt lengths and budgets; a tenant joining (or returning from idle)
starts at the current system virtual time, so idling never banks
credit and a newcomer cannot monopolize the engine.  The engine reads
each tenant's `deficit` (the negated clock) to pick preemption victims:
the lowest deficit is the most recently over-served tenant.

Level 2 — **continuous batching admission**: at every decode-block
boundary the engine asks for one prefill bucket; the scheduler hands back
the chosen tenant's head plus later same-bucket requests from that tenant
(one jitted prefill serves the whole batch), bounded by free slots, the
per-step prefill cap, and the free *page* budget.  Preempted requests
re-enter at the front of their tenant queue via `requeue` (they already
waited once).

Page accounting: when the engine wires `pages_for`, every queued request
reserves its projected page need in `pending_pages` (an autoscale
pressure signal); reservations drop on dequeue, cancel, and close.

The queue is guarded by a lock: with the `ServingRuntime` started,
callers submit from arbitrary threads while each node's pump thread
dequeues.  Tracks queue metrics (depth, total enqueued, head wait) the
SDAI controller's load-feedback tick uses for rebalancing decisions.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict, deque
from typing import Callable, Deque, Dict, List, Optional

from repro.serving.request import (CODE_ENGINE_FAILED, CODE_OVERLOADED,
                                   Request, RequestState)


@dataclasses.dataclass
class SchedulerConfig:
    max_prefill_per_step: int = 4
    max_queue: int = 256              # across all tenant queues


class Scheduler:
    def __init__(self, cfg: Optional[SchedulerConfig] = None,
                 weight_of: Optional[Callable[[str], float]] = None):
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        # tenant -> FCFS queue; OrderedDict keeps a stable visit order
        self._queues: "OrderedDict[str, Deque[Request]]" = OrderedDict()
        # weighted virtual-service clocks (tokens / weight); the smallest
        # backlogged clock is served next.  `_vclock` is the monotonic
        # *system* virtual time (start tag of the last admission): the
        # floor a joining tenant starts at, so a newcomer can neither
        # bank credit nor leapfrog an incumbent whose queue happened to
        # be momentarily empty.
        self._vtime: Dict[str, float] = {}
        self._vclock = 0.0
        # installed by the controller at deploy time; defaults to equal
        # weights so standalone engines behave like plain FCFS+DWRR(1)
        self.weight_of: Callable[[str], float] = weight_of or (lambda t: 1.0)
        # installed by the engine: projected page cost of a request; when
        # absent, costs fall back to 1 (request-count fairness)
        self.pages_for: Optional[Callable[[Request], int]] = None
        self.rejected = 0
        self.enqueued_total = 0
        self.dequeued_total = 0
        self.requeued_total = 0
        self._depth = 0            # plain int: read lock-free by pumps
        self.pending_pages = 0
        self._pending: Dict[int, int] = {}    # request_id -> reserved pages
        self.closed = False
        self._lock = threading.Lock()

    # ---------------------------------------------------------------- #
    def _weight(self, tenant: str) -> float:
        try:
            w = float(self.weight_of(tenant))
        except Exception:
            w = 1.0
        return max(w, 1e-3)        # zero/negative weights cannot starve

    def _cost(self, req: Request) -> float:
        """DWRR debit, in *projected served tokens* (the remaining
        generation budget): what a tenant's weight buys is output
        tokens, so served-token shares converge to the weights even
        when tenants mix prompt lengths and budgets."""
        return float(max(req.sampling.max_tokens - len(req.output), 1))

    def _charge(self, req: Request) -> float:
        """Exactly-once admission debit.  The projected lifetime service
        (tokens already generated + remaining budget) is billed net of
        what this request already paid, so a preempted-then-resumed
        request — whose first admission billed its full budget — adds
        ~nothing on re-admission instead of re-billing the remainder
        and drifting its tenant's virtual clock ahead of the tokens
        actually served."""
        projected = float(len(req.output)) + self._cost(req)
        delta = max(projected - req.wfq_charged, 0.0)
        req.wfq_charged += delta
        return delta

    def _pages(self, req: Request) -> float:
        if self.pages_for is None:
            return 0.0
        return float(max(self.pages_for(req), 0))

    def _reserve(self, req: Request):
        pages = int(self.pages_for(req)) if self.pages_for else 0
        self._pending[req.request_id] = pages
        self.pending_pages += pages

    def _unreserve(self, req: Request):
        self.pending_pages -= self._pending.pop(req.request_id, 0)

    def _enqueue(self, req: Request, front: bool = False):
        q = self._queues.get(req.tenant)
        if q is None:
            q = self._queues[req.tenant] = deque()
        if not q:
            # (re)joining the backlog: start no earlier than the system
            # virtual time — idling banks no credit, and a newcomer
            # cannot starve an incumbent whose clock ran ahead
            self._vtime[req.tenant] = max(
                self._vtime.get(req.tenant, 0.0), self._vclock)
        if front:
            q.appendleft(req)
        else:
            q.append(req)
        self._depth += 1
        self._reserve(req)

    # ---------------------------------------------------------------- #
    def submit(self, req: Request) -> bool:
        with self._lock:
            # closed is checked under the same lock close()+drain() hold,
            # so a submit racing an engine failure either lands in the
            # queue before the drain (and is finished by it) or is
            # rejected here — never stranded in a dead engine's queue
            if self.closed:
                error, code = "engine closed", CODE_ENGINE_FAILED
            elif self.depth >= self.cfg.max_queue:
                self.rejected += 1
                error, code = "queue full", CODE_OVERLOADED
            else:
                req.state = RequestState.QUEUED
                self._enqueue(req)
                self.enqueued_total += 1
                error = code = ""
        if error:
            # finish outside the lock: callbacks may re-route the request
            req.finish(error=error, code=code)
            return False
        return True

    def requeue(self, req: Request) -> None:
        """Preemption path: a request evicted from its slot re-enters at
        the *front* of its tenant queue (it already waited its turn) and
        bypasses the queue cap — a preempted request is never dropped."""
        with self._lock:
            if self.closed:
                pass               # drained by close(); finish below
            else:
                req.state = RequestState.QUEUED
                self._enqueue(req, front=True)
                self.requeued_total += 1
                return
        req.finish(error="engine closed", code=CODE_ENGINE_FAILED)

    def cancel(self, request_id: int) -> bool:
        """Drop a still-queued request, releasing its pending-pages
        reservation (the charge the page-aware admission planner holds
        for it)."""
        with self._lock:
            for tenant, q in self._queues.items():
                for req in q:
                    if req.request_id == request_id:
                        q.remove(req)
                        self._depth -= 1
                        self._unreserve(req)
                        return True
        return False

    def close(self) -> List[Request]:
        """Engine failure path: atomically stop accepting submits and
        hand back everything queued so the caller can fail it."""
        with self._lock:
            self.closed = True
            out = [r for q in self._queues.values() for r in q]
            self._queues.clear()
            self._vtime.clear()
            self._vclock = 0.0
            self._pending.clear()
            self.pending_pages = 0
            self._depth = 0
        return out

    # ---------------------------------------------------------------- #
    def next_prefill_bucket(self, free_slots: int,
                            bucket_of: Callable[[int], int],
                            free_pages: Optional[int] = None
                            ) -> List[Request]:
        """One WFQ admission round.  Dequeue the winning tenant's head
        plus up to `max_prefill_per_step - 1` later requests from the
        *same tenant* whose effective prompts fall in the same length
        bucket, so the engine prefills them together in one jitted call.
        The winner is the backlogged tenant with the smallest weighted
        virtual-service clock; every admission advances the clock by
        `projected_tokens / weight`.  `free_pages` (None =>
        unconstrained) bounds admissions by the engine's free page
        budget; when no backlogged tenant's head fits, nothing is
        admitted this round (pages free up at the next decode block, or
        the engine preempts)."""
        with self._lock:
            n = min(free_slots, self.cfg.max_prefill_per_step, self.depth)
            if n <= 0:
                return []
            for tenant in list(self._queues):     # drop drained queues
                if not self._queues[tenant]:
                    del self._queues[tenant]
            # smallest backlogged clock wins; page-blocked tenants sit
            # the round out (their clock stands still, so they win as
            # soon as pages free up)
            best, best_key = None, None
            for tenant, q in self._queues.items():
                if free_pages is not None \
                        and self._pages(q[0]) > free_pages:
                    continue
                key = (self._vtime.get(tenant, 0.0), q[0].created_at)
                if best_key is None or key < best_key:
                    best, best_key = tenant, key
            if best is None:
                return []
            w = self._weight(best)
            # system virtual time advances to the winner's start tag
            self._vclock = max(self._vclock,
                               self._vtime.get(best, 0.0))
            q = self._queues[best]
            head = q.popleft()
            self._depth -= 1
            self._unreserve(head)
            budget = (free_pages - self._pages(head)
                      if free_pages is not None else None)
            self._vtime[best] = self._vtime.get(best, 0.0) \
                + self._charge(head) / w
            out = [head]
            if n > 1:
                hb = bucket_of(self._eff_len(head))
                rest: List[Request] = []
                for req in q:
                    fits = (budget is None
                            or self._pages(req) <= budget)
                    if len(out) < n and fits \
                            and bucket_of(self._eff_len(req)) == hb:
                        out.append(req)
                        self._depth -= 1
                        self._unreserve(req)
                        self._vtime[best] += self._charge(req) / w
                        if budget is not None:
                            budget -= self._pages(req)
                    else:
                        rest.append(req)
                self._queues[best] = deque(rest)
            self.dequeued_total += len(out)
            return out

    @staticmethod
    def _eff_len(req: Request) -> int:
        """Effective prompt length: original prompt plus any tokens
        already generated before a preemption (a resumed request
        re-prefills its full context)."""
        return len(req.prompt) + len(req.output)

    # ---------------------------------------------------------------- #
    def deficit(self, tenant: str) -> float:
        """The tenant's fair-queuing deficit: the negated weighted
        virtual-service clock — the engine's eviction-victim signal
        (lowest deficit == most service consumed per unit weight ==
        most recently over-served)."""
        with self._lock:
            return -self._vtime.get(tenant, 0.0)

    def tenant_backlog(self) -> Dict[str, int]:
        with self._lock:
            return {t: len(q) for t, q in self._queues.items() if q}

    @property
    def depth(self) -> int:
        return self._depth

    def head_wait_s(self, now: Optional[float] = None) -> float:
        """Age of the oldest queued request — the controller's pressure
        signal (a deep-but-draining queue is fine; a stale head is not)."""
        with self._lock:
            heads = [q[0].created_at for q in self._queues.values() if q]
            if not heads:
                return 0.0
            t = time.monotonic() if now is None else now
            return max(0.0, t - min(heads))

"""Request scheduler for the continuous-batching engine.

FCFS admission with prefill/decode interleaving: at each engine step, admit
up to `max_prefill_per_step` queued requests into free slots, then run one
batched decode over all active slots.  Admission is *bucket-aware*: the
engine pads prompts to power-of-two length buckets so one jitted prefill
serves every length in a bucket, and the scheduler hands it a same-bucket
batch (FCFS head plus any later queued requests that share the head's
bucket) so the whole batch lands in a single dispatch.  Tracks queue
metrics the SDAI controller uses for load-based reallocation decisions.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, List, Optional

from repro.serving.request import CODE_OVERLOADED, Request, RequestState


@dataclasses.dataclass
class SchedulerConfig:
    max_prefill_per_step: int = 4
    max_queue: int = 256


class Scheduler:
    def __init__(self, cfg: Optional[SchedulerConfig] = None):
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        self.queue: Deque[Request] = deque()
        self.rejected = 0

    def submit(self, req: Request) -> bool:
        if len(self.queue) >= self.cfg.max_queue:
            self.rejected += 1
            req.finish(error="queue full", code=CODE_OVERLOADED)
            return False
        req.state = RequestState.QUEUED
        self.queue.append(req)
        return True

    def cancel(self, request_id: int) -> bool:
        for req in self.queue:
            if req.request_id == request_id:
                self.queue.remove(req)
                return True
        return False

    def next_prefill_bucket(self, free_slots: int,
                            bucket_of: Callable[[int], int]
                            ) -> List[Request]:
        """Dequeue the FCFS head plus up to `max_prefill_per_step - 1`
        later requests whose prompts fall in the *same* length bucket, so
        the engine prefills them together in one jitted call.  The head is
        always admitted (no starvation); requests from other buckets keep
        their relative order for the next step."""
        n = min(free_slots, self.cfg.max_prefill_per_step, len(self.queue))
        if n <= 0:
            return []
        head = self.queue.popleft()
        out = [head]
        if n > 1:
            hb = bucket_of(len(head.prompt))
            rest: List[Request] = []
            for req in self.queue:
                if len(out) < n and bucket_of(len(req.prompt)) == hb:
                    out.append(req)
                else:
                    rest.append(req)
            self.queue = deque(rest)
        return out

    @property
    def depth(self) -> int:
        return len(self.queue)

"""Request scheduler for the continuous-batching engine.

FCFS admission with prefill/decode interleaving: at each engine step, admit
up to `max_prefill_per_step` queued requests into free slots, then run one
batched decode over all active slots.  Admission is *bucket-aware*: the
engine pads prompts to power-of-two length buckets so one jitted prefill
serves every length in a bucket, and the scheduler hands it a same-bucket
batch (FCFS head plus any later queued requests that share the head's
bucket) so the whole batch lands in a single dispatch.

The queue is guarded by a lock: with the `ServingRuntime` started, callers
submit from arbitrary threads while each node's pump thread dequeues.
Tracks queue metrics (depth, total enqueued, head wait) the SDAI
controller's load-feedback tick uses for rebalancing decisions.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Deque, List, Optional

from repro.serving.request import (CODE_ENGINE_FAILED, CODE_OVERLOADED,
                                   Request, RequestState)


@dataclasses.dataclass
class SchedulerConfig:
    max_prefill_per_step: int = 4
    max_queue: int = 256


class Scheduler:
    def __init__(self, cfg: Optional[SchedulerConfig] = None):
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        self.queue: Deque[Request] = deque()
        self.rejected = 0
        self.enqueued_total = 0
        self.dequeued_total = 0
        self.closed = False
        self._lock = threading.Lock()

    def submit(self, req: Request) -> bool:
        with self._lock:
            # closed is checked under the same lock close()+drain() hold,
            # so a submit racing an engine failure either lands in the
            # queue before the drain (and is finished by it) or is
            # rejected here — never stranded in a dead engine's queue
            if self.closed:
                error, code = "engine closed", CODE_ENGINE_FAILED
            elif len(self.queue) >= self.cfg.max_queue:
                self.rejected += 1
                error, code = "queue full", CODE_OVERLOADED
            else:
                req.state = RequestState.QUEUED
                self.queue.append(req)
                self.enqueued_total += 1
                error = code = ""
        if error:
            # finish outside the lock: callbacks may re-route the request
            req.finish(error=error, code=code)
            return False
        return True

    def cancel(self, request_id: int) -> bool:
        with self._lock:
            for req in self.queue:
                if req.request_id == request_id:
                    self.queue.remove(req)
                    return True
        return False

    def close(self) -> List[Request]:
        """Engine failure path: atomically stop accepting submits and
        hand back everything queued so the caller can fail it."""
        with self._lock:
            self.closed = True
            out = list(self.queue)
            self.queue.clear()
        return out

    def next_prefill_bucket(self, free_slots: int,
                            bucket_of: Callable[[int], int]
                            ) -> List[Request]:
        """Dequeue the FCFS head plus up to `max_prefill_per_step - 1`
        later requests whose prompts fall in the *same* length bucket, so
        the engine prefills them together in one jitted call.  The head is
        always admitted (no starvation); requests from other buckets keep
        their relative order for the next step."""
        with self._lock:
            n = min(free_slots, self.cfg.max_prefill_per_step,
                    len(self.queue))
            if n <= 0:
                return []
            head = self.queue.popleft()
            out = [head]
            if n > 1:
                hb = bucket_of(len(head.prompt))
                rest: List[Request] = []
                for req in self.queue:
                    if len(out) < n and bucket_of(len(req.prompt)) == hb:
                        out.append(req)
                    else:
                        rest.append(req)
                self.queue = deque(rest)
            self.dequeued_total += len(out)
            return out

    @property
    def depth(self) -> int:
        return len(self.queue)

    def head_wait_s(self, now: Optional[float] = None) -> float:
        """Age of the oldest queued request — the controller's pressure
        signal (a deep-but-draining queue is fine; a stale head is not)."""
        with self._lock:
            if not self.queue:
                return 0.0
            t = time.monotonic() if now is None else now
            return max(0.0, t - self.queue[0].created_at)

"""Request scheduler for the continuous-batching engine.

FCFS admission with prefill/decode interleaving: at each engine step, admit
up to `max_prefill_per_step` queued requests into free slots, then run one
batched decode over all active slots.  Tracks queue metrics the SDAI
controller uses for load-based reallocation decisions.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

from repro.serving.request import CODE_OVERLOADED, Request, RequestState


@dataclasses.dataclass
class SchedulerConfig:
    max_prefill_per_step: int = 1
    max_queue: int = 256


class Scheduler:
    def __init__(self, cfg: Optional[SchedulerConfig] = None):
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        self.queue: Deque[Request] = deque()
        self.rejected = 0

    def submit(self, req: Request) -> bool:
        if len(self.queue) >= self.cfg.max_queue:
            self.rejected += 1
            req.finish(error="queue full", code=CODE_OVERLOADED)
            return False
        req.state = RequestState.QUEUED
        self.queue.append(req)
        return True

    def cancel(self, request_id: int) -> bool:
        for req in self.queue:
            if req.request_id == request_id:
                self.queue.remove(req)
                return True
        return False

    def next_prefills(self, free_slots: int) -> List[Request]:
        out = []
        n = min(free_slots, self.cfg.max_prefill_per_step, len(self.queue))
        for _ in range(n):
            out.append(self.queue.popleft())
        return out

    @property
    def depth(self) -> int:
        return len(self.queue)

from repro.serving.engine import EngineConfig, EngineFailure, InferenceEngine
from repro.serving.kv_cache import PagedKVPool, SlotPool
from repro.serving.request import Request, RequestState
from repro.serving.sampler import SamplingParams, sample_batched
from repro.serving.scheduler import Scheduler, SchedulerConfig

__all__ = ["InferenceEngine", "EngineConfig", "EngineFailure", "Request",
           "RequestState", "SamplingParams", "sample_batched", "Scheduler",
           "SchedulerConfig", "PagedKVPool", "SlotPool"]

from repro.serving.engine import InferenceEngine, EngineConfig, EngineFailure
from repro.serving.request import Request, RequestState
from repro.serving.sampler import SamplingParams
from repro.serving.scheduler import Scheduler, SchedulerConfig

__all__ = ["InferenceEngine", "EngineConfig", "EngineFailure", "Request",
           "RequestState", "SamplingParams", "Scheduler", "SchedulerConfig"]

"""On-device n-gram speculative decoding: proposer tables + accept logic.

The proposer is a per-slot *bigram suffix-hash table*: a flat
``(n_slots, table_size)`` int32 array mapping ``hash(prev, last)`` to the
token that followed that pair most recently in the slot's own emitted
stream.  Everything is device-resident and O(1) per token:

* ``propose`` chains D lookups from the slot's last two emitted tokens
  to build a draft sequence (a missing entry yields -1, which can never
  match a real greedy token — the chain degrades to "no proposal" and
  verify costs exactly one dispatch, same as a fused K=1 step).
* ``record`` learns one (prev, last) -> next transition per emitted
  token.  Writes go through ``mode="drop"`` with the index masked to the
  sentinel for invalid rows, so padded/inactive slots never dirty the
  table.

Greedy verify accepts the longest prefix of drafts matching the batched
forward's own argmax — by induction the emitted stream is *provably
identical* to non-speculative greedy decoding: token i+1 is only
emitted when draft i equals exactly what greedy would have sampled at
that position, so every accepted position reproduces the sequential
trajectory, and the first mismatch position emits the verifier's own
argmax (what sequential decoding would have produced) and stops.

Collisions are harmless for correctness (a wrong table entry is just a
bad draft — rejected by verify) and rare at the default 512-entry
table; the multiplicative hash is Knuth's 2654435761 with an odd-salt
mix of the second key.
"""
from __future__ import annotations

import jax.numpy as jnp

_MUL_A = 2654435761      # Knuth multiplicative hash constants
_MUL_B = 40503
_SALT = 2654435769


def init_tables(n_slots: int, table_size: int):
    """Fresh proposer state: (table (n_slots, T) int32 = -1,
    prev (n_slots,) int32 = -1).  T must be a power of two."""
    assert table_size & (table_size - 1) == 0, "table size: power of two"
    return (jnp.full((n_slots, table_size), -1, jnp.int32),
            jnp.full((n_slots,), -1, jnp.int32))


def ngram_hash(a, b, table_size: int):
    """Bigram bucket: hash(a, b) & (T - 1).  a/b: int32 arrays."""
    ua = a.astype(jnp.uint32) * jnp.uint32(_MUL_A)
    ub = b.astype(jnp.uint32) * jnp.uint32(_MUL_B) + jnp.uint32(_SALT)
    return ((ua ^ ub) & jnp.uint32(table_size - 1)).astype(jnp.int32)


def propose(table, prev, last, n_draft: int):
    """Chain D bigram lookups into a draft sequence.

    table: (B, T); prev/last: (B,) — the two most recent emitted tokens
    (-1 when unknown).  Returns drafts (B, n_draft) int32 with -1 for
    "no proposal" (guaranteed to be rejected by greedy verify).
    """
    b, t = table.shape
    rows = jnp.arange(b)
    drafts = jnp.full((b, n_draft), -1, jnp.int32)
    a, c = prev, last
    for i in range(n_draft):
        h = ngram_hash(a, c, t)
        nxt = table[rows, h]
        nxt = jnp.where((a < 0) | (c < 0), -1, nxt)
        drafts = drafts.at[:, i].set(nxt)
        a, c = c, nxt
    return drafts


def record(table, prev, last, nxt, valid):
    """Learn one transition per row: table[hash(prev, last)] = nxt where
    `valid` (and all three tokens are real).  Invalid rows scatter to
    the sentinel column and drop."""
    b, t = table.shape
    h = ngram_hash(prev, last, t)
    ok = valid & (prev >= 0) & (last >= 0) & (nxt >= 0)
    idx = jnp.where(ok, h, t)
    return table.at[jnp.arange(b), idx].set(nxt, mode="drop")


def accept_length(drafts, greedy):
    """Longest matching prefix length: drafts (B, D) vs the verifier's
    greedy tokens at the same positions (B, D).  Returns (B,) int32 in
    [0, D] — position i is accepted iff drafts[:, :i+1] all matched."""
    match = (drafts == greedy).astype(jnp.int32)
    return jnp.sum(jnp.cumprod(match, axis=1), axis=1)

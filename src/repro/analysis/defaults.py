"""Shared-mutable-default checker.

PR 1's very first bugfix class: a mutable default (``def f(x=[])`` or a
dataclass field ``x: Foo = Foo()`` with mutable ``Foo``) is one shared
object across every call/instance.  Flags:

* mutable literal / constructor defaults on function parameters
  (``[]``, ``{}``, ``set()``, ``list()``, ``deque()``, ...);
* call defaults constructing a class defined in the analyzed sources
  that is a *non-frozen* dataclass (``sampling=SamplingParams()`` is
  fine precisely because `SamplingParams` is ``frozen=True``);
* dataclass field defaults that are calls to non-frozen dataclasses
  (``field(default_factory=...)`` is the correct spelling and passes).
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.core import (Checker, ProjectIndex, Violation,
                                 call_name)

_MUTABLE_CONSTRUCTORS = {"list", "dict", "set", "bytearray", "deque",
                         "defaultdict", "OrderedDict", "Counter"}


def _mutable_default_reason(expr: ast.expr,
                            index: ProjectIndex) -> Optional[str]:
    if isinstance(expr, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return "mutable literal"
    if isinstance(expr, ast.Call):
        name = call_name(expr)
        if name in _MUTABLE_CONSTRUCTORS:
            return f"mutable {name}()"
        if name in index.dataclasses \
                and name not in index.frozen_dataclasses:
            return f"instance of non-frozen dataclass {name}"
    return None


class MutableDefaultChecker(Checker):
    rule = "mutable-default"

    def check(self, index: ProjectIndex) -> List[Violation]:
        out: List[Violation] = []
        for fi in index.functions:
            args = fi.node.args
            defaults = list(args.defaults) + [d for d in args.kw_defaults
                                              if d is not None]
            for d in defaults:
                reason = _mutable_default_reason(d, index)
                if reason is not None:
                    out.append(Violation(
                        self.rule, fi.module.rel, d.lineno, fi.qualname,
                        f"parameter default is a {reason} — one shared "
                        f"object across every call "
                        f"({ast.unparse(d)[:40]})",
                        detail=f"arg:{ast.unparse(d)[:24]}"))
        # dataclass field defaults
        for cls_name in sorted(index.dataclasses):
            cls = index.classes[cls_name]
            mod = index.class_module[cls_name]
            for stmt in cls.body:
                if not isinstance(stmt, ast.AnnAssign) \
                        or stmt.value is None:
                    continue
                if isinstance(stmt.value, ast.Call) \
                        and call_name(stmt.value) == "field":
                    continue            # dataclasses.field(...) is fine
                reason = _mutable_default_reason(stmt.value, index)
                if reason is not None:
                    target = ast.unparse(stmt.target)
                    out.append(Violation(
                        self.rule, mod.rel, stmt.lineno,
                        f"{cls_name}.{target}",
                        f"dataclass field default is a {reason} — one "
                        f"shared object across every instance; use "
                        f"field(default_factory=...)"))
        return out

"""Host-sync-in-hot-path checker.

The decode/prefill hot path's perf contract is "at most two jitted
dispatches and one host sync per step" (CI-gated by bench counters
since PR 5).  The bench can only count syncs it executes; this checker
pins the *sites*: every expression that forces a device->host transfer
(``jax.device_get``, ``.item()``, ``.tolist()``, ``np.asarray``/
``np.array`` on device values, ``float()``/``int()``/``bool()`` of a
name or attribute, ``.block_until_ready()``) reachable from the engine
step entry point over the name-based call graph.

Each sanctioned sync is waived individually in `analysis_baseline.json`
(keyed by function + pattern + occurrence), so adding a *second*
``device_get`` to `_decode_block` surfaces as a new unwaived violation
even if the bench workload happens not to hit it.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import (Checker, FunctionInfo, ProjectIndex,
                                 Violation, call_name, call_receiver)

# (class, method) roots of the fused decode/prefill paths
DEFAULT_ENTRIES: Tuple[Tuple[str, str], ...] = (
    ("InferenceEngine", "step"),
)

_NP_MODULES = {"np", "numpy", "onp"}


def _sync_pattern(call: ast.Call) -> Optional[str]:
    """Pattern slug when `call` forces a host sync, else None."""
    name = call_name(call)
    if name is None:
        return None
    recv = call_receiver(call)
    if name == "device_get":
        return "device_get"
    if name in ("item", "tolist", "block_until_ready") and not call.args:
        return name
    if name in ("asarray", "array") and recv is not None \
            and recv[-1] in _NP_MODULES:
        # literals are host-side already; anything else may be a tracer
        if call.args and not isinstance(call.args[0],
                                        (ast.Constant, ast.List,
                                         ast.Tuple, ast.ListComp)):
            return f"np.{name}"
        return None
    if name in ("float", "int", "bool") and recv is None and call.args:
        # float(self.x) / int(done) force concretization when the value
        # is device-resident; float(len(..)) and literals don't
        if isinstance(call.args[0], (ast.Name, ast.Attribute)):
            return name
    return None


class HotPathSyncChecker(Checker):
    rule = "hot-path-sync"

    def __init__(self,
                 entries: Sequence[Tuple[str, str]] = DEFAULT_ENTRIES):
        self.entries = tuple(entries)

    def check(self, index: ProjectIndex) -> List[Violation]:
        # reachability over the name-based call graph from the entries
        roots: List[FunctionInfo] = []
        for cls, meth in self.entries:
            fi = index.by_class.get(cls, {}).get(meth)
            if fi is not None:
                roots.append(fi)
        reached: Dict[str, FunctionInfo] = {}
        work = list(roots)
        while work:
            fi = work.pop()
            if fi.uid in reached:
                continue
            reached[fi.uid] = fi
            for node in ast.walk(fi.node):
                if isinstance(node, ast.Call):
                    for target in index.resolve_call(node, fi.cls):
                        if target.uid not in reached:
                            work.append(target)

        out: List[Violation] = []
        for uid in sorted(reached):
            fi = reached[uid]
            counts: Dict[str, int] = {}
            for node in ast.walk(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                pattern = _sync_pattern(node)
                if pattern is None:
                    continue
                n = counts.get(pattern, 0)
                counts[pattern] = n + 1
                out.append(Violation(
                    self.rule, fi.module.rel, node.lineno, fi.qualname,
                    f"{pattern} site reachable from the engine step hot "
                    f"path ({ast.unparse(node)[:60]}) — forces a "
                    f"device->host sync",
                    detail=f"{pattern}#{n}"))
        return out


def reachable_functions(index: ProjectIndex,
                        entries: Sequence[Tuple[str, str]] = DEFAULT_ENTRIES
                        ) -> Set[str]:
    """Qualnames reachable from the hot-path entries (for tests)."""
    checker = HotPathSyncChecker(entries)
    roots = [index.by_class.get(c, {}).get(m) for c, m in checker.entries]
    reached: Set[str] = set()
    work = [fi for fi in roots if fi is not None]
    seen: Set[str] = set()
    while work:
        fi = work.pop()
        if fi.uid in seen:
            continue
        seen.add(fi.uid)
        reached.add(fi.qualname)
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Call):
                for target in index.resolve_call(node, fi.cls):
                    if target.uid not in seen:
                        work.append(target)
    return reached

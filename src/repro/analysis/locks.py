"""Lock-order analyzer — static half of the deadlock defense.

The runtime's canonical hierarchy is ``node -> instance -> scheduler``
(documented at `BackendNode.__init__` since PR 3 and load-bearing since
the PR 5 sharded pump): a thread holding a later lock must never
acquire an earlier one.  This checker extracts every acquisition site
(`with <lock>` plus explicit ``.acquire()``/``.release()`` pairs),
classifies it onto the hierarchy by owner class / receiver name, and
propagates "eventually acquires" summaries over the name-based call
graph so an inversion hiding two calls deep is still an edge.

Unranked locks (``work_cv``, handle ``_cv``, gateway stats/inflight
locks, HTTP server locks) are deliberately outside the hierarchy: they
are leaf locks by convention and never wrap a ranked acquisition; the
runtime `LockOrderTracker` (tracker.py) cross-checks the same ranks
against actual acquisition orders during the tier-1 suite.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import (Checker, FunctionInfo, ProjectIndex,
                                 Violation, dotted_parts)

CANONICAL_ORDER: Tuple[str, ...] = ("node", "instance", "scheduler")
LOCK_RANKS: Dict[str, int] = {n: i for i, n in enumerate(CANONICAL_ORDER)}

# `self.<attr>` acquisitions classified by owner class
_SELF_LOCKS: Dict[Tuple[str, str], str] = {
    ("BackendNode", "lock"): "node",
    ("Instance", "lock"): "instance",
    ("Scheduler", "_lock"): "scheduler",
}
# `<owner>.lock` / `<owner>._lock` acquisitions classified by the
# receiver's conventional local name
_OWNER_HINTS: Dict[str, str] = {
    "inst": "instance", "instance": "instance", "victim": "instance",
    "node": "node",
    "scheduler": "scheduler", "sched": "scheduler",
}


def classify_lock(expr: ast.expr, cls: Optional[str]) -> Optional[str]:
    """Hierarchy level for a lock expression, or None if unranked."""
    parts = dotted_parts(expr)
    if parts is None or len(parts) < 2:
        return None
    attr = parts[-1]
    if attr not in ("lock", "_lock"):
        return None
    owner = parts[-2]
    if owner == "self" and len(parts) == 2:
        return _SELF_LOCKS.get((cls or "", attr))
    return _OWNER_HINTS.get(owner)


def allowed_edges() -> Set[Tuple[str, str]]:
    """Every (outer, inner) pair the hierarchy permits — used by the
    runtime tracker's cross-validation."""
    out: Set[Tuple[str, str]] = set()
    for a, ra in LOCK_RANKS.items():
        for b, rb in LOCK_RANKS.items():
            if rb > ra:
                out.add((a, b))
    return out


@dataclasses.dataclass(frozen=True)
class _Acq:
    line: int
    level: str
    text: str                       # lock expression, for same-rank check
    held: Tuple[Tuple[str, str], ...]   # ((level, text), ...) outer-first


@dataclasses.dataclass(frozen=True)
class _CallSite:
    line: int
    call: ast.Call
    held: Tuple[Tuple[str, str], ...]


class _FuncScanner(ast.NodeVisitor):
    """One function: acquisition events and call sites with the ranked
    locks lexically held at each."""

    def __init__(self, cls: Optional[str]):
        self.cls = cls
        self.held: List[Tuple[str, str]] = []
        self.manual: List[Tuple[str, str]] = []   # .acquire()'d, unreleased
        self.acquisitions: List[_Acq] = []
        self.calls: List[_CallSite] = []

    def _snapshot(self) -> Tuple[Tuple[str, str], ...]:
        return tuple(self.held + self.manual)

    def _record_acquire(self, lvl: str, text: str, line: int) -> None:
        self.acquisitions.append(
            _Acq(line=line, level=lvl, text=text, held=self._snapshot()))

    def visit_With(self, node: ast.With) -> None:
        self._with(node)

    def visit_AsyncWith(self, node: ast.AsyncWith) -> None:
        self._with(node)

    def _with(self, node) -> None:
        pushed = 0
        for item in node.items:
            # the context expr may itself contain calls
            self.visit(item.context_expr)
            lvl = classify_lock(item.context_expr, self.cls)
            if lvl is not None:
                text = ast.unparse(item.context_expr)
                self._record_acquire(lvl, text, node.lineno)
                self.held.append((lvl, text))
                pushed += 1
        for stmt in node.body:
            self.visit(stmt)
        for _ in range(pushed):
            self.held.pop()

    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        if isinstance(fn, ast.Attribute) and fn.attr in ("acquire",
                                                         "release"):
            lvl = classify_lock(fn.value, self.cls)
            if lvl is not None:
                text = ast.unparse(fn.value)
                if fn.attr == "acquire":
                    self._record_acquire(lvl, text, node.lineno)
                    self.manual.append((lvl, text))
                else:
                    for i in range(len(self.manual) - 1, -1, -1):
                        if self.manual[i][1] == text:
                            del self.manual[i]
                            break
                self.generic_visit(node)
                return
        self.calls.append(_CallSite(line=node.lineno, call=node,
                                    held=self._snapshot()))
        self.generic_visit(node)

    # nested defs run in other contexts (threads, callbacks): their
    # bodies do not inherit the lexically-held locks
    def visit_FunctionDef(self, node) -> None:
        pass

    def visit_AsyncFunctionDef(self, node) -> None:
        pass

    def visit_Lambda(self, node) -> None:
        pass


def _scan(fi: FunctionInfo) -> _FuncScanner:
    sc = _FuncScanner(fi.cls)
    for stmt in fi.node.body:
        sc.visit(stmt)
    return sc


class LockOrderChecker(Checker):
    rule = "lock-order"

    def check(self, index: ProjectIndex) -> List[Violation]:
        scans: Dict[str, _FuncScanner] = {}
        for fi in index.functions:
            scans[fi.uid] = _scan(fi)

        # fixpoint: levels each function eventually acquires (itself or
        # via any resolvable callee)
        eventually: Dict[str, Set[str]] = {
            fi.uid: {a.level for a in scans[fi.uid].acquisitions}
            for fi in index.functions}
        changed = True
        while changed:
            changed = False
            for fi in index.functions:
                acc = eventually[fi.uid]
                for site in scans[fi.uid].calls:
                    for target in index.resolve_call(site.call, fi.cls):
                        extra = eventually[target.uid] - acc
                        if extra:
                            acc |= extra
                            changed = True

        out: List[Violation] = []
        edge_graph: Set[Tuple[str, str]] = set()
        seen_keys: Set[str] = set()

        def emit(v: Violation) -> None:
            if v.key not in seen_keys:
                seen_keys.add(v.key)
                out.append(v)

        for fi in index.functions:
            sc = scans[fi.uid]
            rel = fi.module.rel
            # lexical nesting: every acquisition under held locks
            for acq in sc.acquisitions:
                for h_lvl, h_text in acq.held:
                    edge_graph.add((h_lvl, acq.level))
                    if LOCK_RANKS[acq.level] < LOCK_RANKS[h_lvl]:
                        emit(Violation(
                            self.rule, rel, acq.line, fi.qualname,
                            f"acquires {acq.level!r} lock ({acq.text}) "
                            f"while holding {h_lvl!r} — inverts the "
                            f"canonical {' -> '.join(CANONICAL_ORDER)} "
                            f"order",
                            detail=f"{h_lvl}->{acq.level}"))
                    elif (acq.level == h_lvl and acq.text != h_text):
                        emit(Violation(
                            self.rule, rel, acq.line, fi.qualname,
                            f"nests two distinct {acq.level!r}-rank locks "
                            f"({h_text} then {acq.text}) — same-rank "
                            f"nesting can deadlock against the opposite "
                            f"interleaving",
                            detail=f"{h_lvl}={acq.level}"))
            # interprocedural: call sites under held locks reaching
            # functions that eventually acquire a lower rank
            for site in sc.calls:
                if not site.held:
                    continue
                for target in index.resolve_call(site.call, fi.cls):
                    for lvl in eventually[target.uid]:
                        for h_lvl, _h_text in site.held:
                            edge_graph.add((h_lvl, lvl))
                            if LOCK_RANKS[lvl] < LOCK_RANKS[h_lvl]:
                                emit(Violation(
                                    self.rule, rel, site.line, fi.qualname,
                                    f"holds {h_lvl!r} lock across a call "
                                    f"into {target.qualname} which "
                                    f"(transitively) acquires {lvl!r} — "
                                    f"inverts the canonical order",
                                    detail=(f"{h_lvl}->{lvl}"
                                            f"@{target.qualname}")))

        # cycle check over the observed edge graph (covers pairs the
        # rank test can't see if ranks are ever extended)
        for a, b in sorted(edge_graph):
            if a != b and (b, a) in edge_graph and a < b:
                emit(Violation(
                    self.rule, "<graph>", 0, f"{a}<->{b}",
                    f"acquisition-order cycle between {a!r} and {b!r} "
                    f"locks", detail="cycle"))
        return out


def static_edges(paths: Sequence[str]) -> Set[Tuple[str, str]]:
    """The (outer, inner) level edges the given sources exhibit —
    exported for tests that cross-validate the runtime tracker."""
    from repro.analysis.core import load_modules
    index = ProjectIndex(load_modules(paths))
    edges: Set[Tuple[str, str]] = set()
    for fi in index.functions:
        sc = _scan(fi)
        for acq in sc.acquisitions:
            for h_lvl, _ in acq.held:
                edges.add((h_lvl, acq.level))
    return edges

"""Waiver baseline — the analyzer's accepted-sites ledger.

`analysis_baseline.json` pins every violation the project has examined
and accepted (sanctioned host syncs, documented lock-free patterns the
code-level allowlist doesn't cover, known call-graph imprecision).
Each waiver is `{key, reason}`; a waiver with no reason is invalid by
construction — `--check` refuses it, so the baseline can never silently
accumulate unexplained debt.  New violations (keys not in the file)
fail `--check`; stale waivers (keys matching nothing) are reported so
fixed sites get their waivers removed.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List, Sequence, Tuple, Union

from repro.analysis.core import Violation

BASELINE_VERSION = 1
DEFAULT_BASELINE = "analysis_baseline.json"


@dataclasses.dataclass
class Baseline:
    waivers: Dict[str, str]          # key -> reason

    @classmethod
    def load(cls, path: Union[str, pathlib.Path]) -> "Baseline":
        p = pathlib.Path(path)
        if not p.exists():
            return cls(waivers={})
        data = json.loads(p.read_text())
        if data.get("version") != BASELINE_VERSION:
            raise ValueError(
                f"{p}: unsupported baseline version {data.get('version')!r}")
        waivers: Dict[str, str] = {}
        for w in data.get("waivers", []):
            waivers[w["key"]] = w.get("reason", "")
        return cls(waivers=waivers)

    def save(self, path: Union[str, pathlib.Path]) -> None:
        body = {
            "version": BASELINE_VERSION,
            "waivers": [{"key": k, "reason": self.waivers[k]}
                        for k in sorted(self.waivers)],
        }
        pathlib.Path(path).write_text(json.dumps(body, indent=2) + "\n")

    # -------------------------------------------------------------- #
    def unexplained(self) -> List[str]:
        """Waiver keys whose reason is empty/placeholder — never valid."""
        return sorted(k for k, r in self.waivers.items()
                      if not r.strip() or r.strip().upper().startswith("TODO"))

    def split(self, violations: Sequence[Violation]
              ) -> Tuple[List[Violation], List[Violation], List[str]]:
        """(new, waived, stale_waiver_keys)."""
        new: List[Violation] = []
        waived: List[Violation] = []
        seen = set()
        for v in violations:
            seen.add(v.key)
            if v.key in self.waivers:
                waived.append(v)
            else:
                new.append(v)
        stale = sorted(k for k in self.waivers if k not in seen)
        return new, waived, stale

    def absorb(self, violations: Sequence[Violation],
               placeholder: str = "TODO: justify or fix") -> None:
        """--write-baseline: add waivers for every current violation,
        keeping existing reasons; fixed sites drop out."""
        fresh: Dict[str, str] = {}
        for v in violations:
            fresh[v.key] = self.waivers.get(v.key, placeholder)
        self.waivers = fresh

"""Refcount-pairing checker for the paged KV pool.

`PagedKVPool.retain(page)` takes shared ownership of a page; every code
path that retains must either release it (`free_page`/`detach`) or
*store* it somewhere that owns it (page table, prefix-cache entry, swap
handle) before the function can exit.  A `retain` followed by an early
``return``/``raise`` with neither is a leaked page — the pool's free
list shrinks until admission wedges.

The check is a line-ordered scan per function (flow-insensitive): for
each ``retain(X)`` call, any later exit statement with no intervening
release call or store mentioning ``X`` flags.  Coarse, but the settled
patterns in kv_cache/kv_hierarchy (retain-then-store-in-entry,
detach-then-free) all pass, and the classic leak shape (validate after
retain, raise on failure) is exactly what it catches.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import List, Optional

from repro.analysis.core import (Checker, ProjectIndex, Violation,
                                 call_name)

_RELEASES = {"free_page", "detach", "attach", "release_page", "free"}
_SKIP_FUNCS = {"retain", "free_page", "detach", "attach"}


@dataclasses.dataclass
class _Event:
    line: int
    kind: str          # "retain" | "settle" | "exit"
    text: str          # arg text for retain; full text for settle


class _Collector(ast.NodeVisitor):
    def __init__(self):
        self.events: List[_Event] = []

    def _arg_text(self, call: ast.Call) -> str:
        return ast.unparse(call.args[0]) if call.args else ""

    def visit_Call(self, node: ast.Call) -> None:
        name = call_name(node)
        if name == "retain":
            self.events.append(_Event(node.lineno, "retain",
                                      self._arg_text(node)))
        else:
            # any call/store mentioning the retained name is an
            # ownership handoff (release, table/entry insert, helper)
            self.events.append(_Event(node.lineno, "settle",
                                      ast.unparse(node)))
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        self.events.append(_Event(node.lineno, "settle",
                                  ast.unparse(node)))
        self.generic_visit(node)

    def visit_Return(self, node: ast.Return) -> None:
        # `return page` transfers ownership to the caller; a bare or
        # unrelated return after a retain is an exit without settling
        self.events.append(_Event(node.lineno, "exit",
                                  ast.unparse(node)))

    def visit_Raise(self, node: ast.Raise) -> None:
        # the exception text mentioning the page does not settle it
        self.events.append(_Event(node.lineno, "exit", "raise"))

    def visit_FunctionDef(self, node) -> None:
        pass

    def visit_AsyncFunctionDef(self, node) -> None:
        pass


def _root_name(arg_text: str) -> str:
    """'page' for 'page', 'pages' for 'pages[i]'; the loop-variable stem
    used for the mention test."""
    for sep in (".", "[", "("):
        if sep in arg_text:
            arg_text = arg_text.split(sep, 1)[0]
    return arg_text.strip()


class RefcountChecker(Checker):
    rule = "refcount-pairing"

    def check(self, index: ProjectIndex) -> List[Violation]:
        out: List[Violation] = []
        for fi in index.functions:
            if fi.name in _SKIP_FUNCS:
                continue
            col = _Collector()
            for stmt in fi.node.body:
                col.visit(stmt)
            retains = [e for e in col.events if e.kind == "retain"]
            if not retains:
                continue
            events = sorted(col.events, key=lambda e: e.line)
            for r in retains:
                stem = _root_name(r.text)
                if not stem:
                    continue
                settled: Optional[int] = None
                leak_at: Optional[int] = None
                for e in events:
                    if e.line <= r.line:
                        continue
                    if e.kind == "settle" and stem in e.text:
                        settled = e.line
                        break
                    if e.kind == "exit":
                        if e.text.startswith("return") \
                                and stem in e.text:
                            settled = e.line      # ownership to caller
                        else:
                            leak_at = e.line
                        break
                if settled is None:
                    how = (f"exits at line {leak_at}"
                           if leak_at is not None
                           else "reaches end of function")
                    out.append(Violation(
                        self.rule, fi.module.rel, r.line, fi.qualname,
                        f"retain({r.text}) at line {r.line} {how} "
                        f"without a matching free_page/detach or an "
                        f"ownership-transferring store — leaked page "
                        f"refcount",
                        detail=f"retain:{r.text[:24]}"))
        return out

"""repro.analysis — concurrency & hot-path static analyzer.

AST-based checkers for the runtime's machine-checked invariants
(canonical lock order, guarded shared state, hot-path host-sync
discipline, mutable defaults, page-refcount pairing), a waiver
baseline, and a runtime `LockOrderTracker` that cross-validates actual
acquisition orders during the tier-1 suite.

Run `python -m repro.analysis --check` (CI's static-analysis gate).
Pure stdlib — importable without jax.
"""
from repro.analysis.baseline import Baseline
from repro.analysis.core import (Checker, ProjectIndex, Violation,
                                 load_modules, run_checkers)
from repro.analysis.defaults import MutableDefaultChecker
from repro.analysis.hotpath import HotPathSyncChecker
from repro.analysis.locks import (CANONICAL_ORDER, LOCK_RANKS,
                                  LockOrderChecker, allowed_edges)
from repro.analysis.refcount import RefcountChecker
from repro.analysis.shared_state import (ALLOWED_LOCKFREE,
                                         SharedStateChecker)
from repro.analysis.tracker import (LockOrderTracker, TrackedLock,
                                    install, uninstall)

__all__ = [
    "ALLOWED_LOCKFREE", "Baseline", "CANONICAL_ORDER", "Checker",
    "HotPathSyncChecker", "LOCK_RANKS", "LockOrderChecker",
    "LockOrderTracker", "MutableDefaultChecker", "ProjectIndex",
    "RefcountChecker", "SharedStateChecker", "TrackedLock", "Violation",
    "allowed_edges", "install", "load_modules", "run_checkers",
    "uninstall",
]

"""Unguarded-shared-state checker.

For every class that guards at least one attribute write with a lock,
flag attributes that are *also* written (or read) lock-free in another
method of the same class: the classic "counter bumped under the stats
lock in one thread, incremented bare in another" race
(`ServingRuntime.stats` before this PR).

Grouping is by attribute *root*: `self.stats.ticks += 1` and
`self.stats.watchdog_fired += 1` both touch root ``stats``, so guarding
one path and not the other is reported once per (class, root, kind).
Writes cover assignments, augmented assignments, subscript stores, and
the common container mutators (append/add/update/...).

Documented lock-free patterns are allowlisted in code (they are part of
the design, not accepted debt): `Scheduler._depth` ("plain int: read
lock-free by pumps"), `GenerationHandle._done`/`_response` ("`_done`
goes last"), and `BackendNode._alive`/`instances` reads (deliberately
lock-free submit/heartbeat paths).  Anything else needs a baseline
waiver with a reason.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import (Checker, ProjectIndex, Violation,
                                 dotted_parts)

# documented lock-free access patterns: (class, attribute root, kind)
ALLOWED_LOCKFREE: Set[Tuple[str, str, str]] = {
    ("Scheduler", "_depth", "read"),
    ("GenerationHandle", "_done", "read"),
    ("GenerationHandle", "_response", "read"),
    ("BackendNode", "_alive", "read"),
    ("BackendNode", "instances", "read"),
}

_GUARD_RE = re.compile(r"lock|_cv\b|cv$|cond|mutex")
_MUTATORS = {"append", "extend", "add", "insert", "update", "pop",
             "popleft", "appendleft", "remove", "discard", "clear",
             "setdefault"}


def _is_guard_attr(name: str) -> bool:
    return bool(_GUARD_RE.search(name))


@dataclasses.dataclass
class _Access:
    root: str
    kind: str          # "write" | "read"
    method: str
    line: int
    guarded: bool


@dataclasses.dataclass
class _SelfCall:
    callee: str
    guarded: bool


class _MethodScanner(ast.NodeVisitor):
    def __init__(self, method: str):
        self.method = method
        self.depth = 0                  # nesting level of guard withs
        self.accesses: List[_Access] = []
        self.guards_used: Set[str] = set()
        self.self_calls: List[_SelfCall] = []

    # ---- guard tracking ---- #
    def visit_With(self, node: ast.With) -> None:
        self._with(node)

    def visit_AsyncWith(self, node) -> None:
        self._with(node)

    def _with(self, node) -> None:
        pushed = 0
        for item in node.items:
            self.visit(item.context_expr)
            parts = dotted_parts(item.context_expr)
            if parts and parts[0] == "self" and len(parts) == 2 \
                    and _is_guard_attr(parts[1]):
                self.guards_used.add(parts[1])
                self.depth += 1
                pushed += 1
        for stmt in node.body:
            self.visit(stmt)
        self.depth -= pushed

    # ---- access collection ---- #
    def _self_root(self, expr: ast.expr) -> Optional[str]:
        parts = dotted_parts(expr)
        if parts and parts[0] == "self" and len(parts) >= 2:
            return parts[1]
        return None

    def _record(self, root: Optional[str], kind: str, line: int) -> None:
        if root is None or _is_guard_attr(root):
            return
        self.accesses.append(_Access(root=root, kind=kind,
                                     method=self.method, line=line,
                                     guarded=self.depth > 0))

    def _record_target(self, target: ast.expr) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._record_target(elt)
        elif isinstance(target, ast.Subscript):
            self._record(self._self_root(target.value), "write",
                         target.lineno)
            self.visit(target.slice)
        elif isinstance(target, ast.Attribute):
            self._record(self._self_root(target), "write", target.lineno)

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._record_target(t)
        self.visit(node.value)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._record_target(node.target)
        self.visit(node.value)

    def visit_Delete(self, node: ast.Delete) -> None:
        for t in node.targets:
            self._record_target(t)

    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        if isinstance(fn, ast.Attribute):
            parts = dotted_parts(fn)
            if parts is not None and parts[0] == "self" \
                    and len(parts) == 2:
                self.self_calls.append(_SelfCall(callee=parts[1],
                                                 guarded=self.depth > 0))
            if fn.attr in _MUTATORS:
                root = self._self_root(fn.value)
                if root is not None:
                    self._record(root, "write", node.lineno)
                    for a in node.args:
                        self.visit(a)
                    for k in node.keywords:
                        self.visit(k.value)
                    return
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if isinstance(node.ctx, ast.Load):
            self._record(self._self_root(node), "read", node.lineno)
        self.generic_visit(node)

    # nested defs / lambdas: separate execution context
    def visit_FunctionDef(self, node) -> None:
        pass

    def visit_AsyncFunctionDef(self, node) -> None:
        pass

    def visit_Lambda(self, node) -> None:
        pass


class SharedStateChecker(Checker):
    rule = "shared-state"

    def check(self, index: ProjectIndex) -> List[Violation]:
        out: List[Violation] = []
        for cls_name, methods in sorted(index.by_class.items()):
            callables = set(methods)        # method/property names: not
            scans: Dict[str, _MethodScanner] = {}   # shared *state* roots
            any_guards = False
            mod = None
            for mname, fi in sorted(methods.items()):
                mod = fi.module
                sc = _MethodScanner(mname)
                for stmt in fi.node.body:
                    sc.visit(stmt)
                any_guards = any_guards or bool(sc.guards_used)
                scans[mname] = sc
            if not any_guards or mod is None:
                continue
            # interprocedural guard propagation: a helper whose every
            # in-class call site runs with a guard held (lexically, or
            # from an already-guarded helper) is itself guarded —
            # `Scheduler._reserve` ("callers hold _lock") needs no
            # waiver, while a helper reachable from any bare call site
            # stays unguarded
            sites: Dict[str, List[Tuple[str, bool]]] = {}
            for mname, sc in scans.items():
                for call in sc.self_calls:
                    if call.callee in scans:
                        sites.setdefault(call.callee, []).append(
                            (mname, call.guarded))
            guarded_methods: Set[str] = set()
            changed = True
            while changed:
                changed = False
                for mname, callers in sites.items():
                    if mname in guarded_methods:
                        continue
                    if all(g or c in guarded_methods
                           for c, g in callers):
                        guarded_methods.add(mname)
                        changed = True
            accesses: List[_Access] = []
            for mname, sc in scans.items():
                effective = mname in guarded_methods
                for a in sc.accesses:
                    if a.root in callables:
                        continue
                    if effective and not a.guarded:
                        a = dataclasses.replace(a, guarded=True)
                    accesses.append(a)
            guarded_roots = {a.root for a in accesses
                             if a.kind == "write" and a.guarded
                             and a.method != "__init__"}
            for root in sorted(guarded_roots):
                for kind in ("write", "read"):
                    if (cls_name, root, kind) in ALLOWED_LOCKFREE:
                        continue
                    bare = [a for a in accesses
                            if a.root == root and a.kind == kind
                            and not a.guarded and a.method != "__init__"]
                    if not bare:
                        continue
                    where = sorted({f"{a.method}:{a.line}" for a in bare})
                    out.append(Violation(
                        self.rule, mod.rel, bare[0].line,
                        f"{cls_name}.{root}",
                        f"attribute {root!r} is written under a lock "
                        f"elsewhere in {cls_name} but {kind} lock-free "
                        f"at {', '.join(where)}",
                        detail=kind))
        return out

"""Runtime lock-order validator — the dynamic half of the deadlock
defense (a lightweight TSan for the pump/cancel/migration races).

`install()` wraps the three ranked locks (`BackendNode.lock`,
`Instance.lock`, `Scheduler._lock`) in `TrackedLock` proxies at
construction time; every acquisition pushes onto a thread-local held
stack and checks its rank against the stack top.  The tier-1 conftest
installs a session tracker, so every test that pumps, cancels, fails
over, or migrates is simultaneously validating the canonical
``node -> instance -> scheduler`` order the static analyzer enforces —
and the observed edge set cross-validates against
`repro.analysis.locks.allowed_edges()`.

Pure stdlib, import-light: installing touches repro.cluster/serving
lazily so `repro.analysis` itself stays importable without jax.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.locks import LOCK_RANKS, allowed_edges


@dataclasses.dataclass(frozen=True)
class OrderViolation:
    thread: str
    held_level: str
    acquired_level: str

    def render(self) -> str:
        return (f"[{self.thread}] acquired {self.acquired_level!r} lock "
                f"while holding {self.held_level!r} — violates "
                f"node -> instance -> scheduler")


class LockOrderTracker:
    """Thread-safe recorder of actual lock-acquisition orders."""

    def __init__(self, ranks: Optional[Dict[str, int]] = None):
        self.ranks = dict(LOCK_RANKS) if ranks is None else dict(ranks)
        self._local = threading.local()
        self._mu = threading.Lock()
        self.violations: List[OrderViolation] = []
        self.edges: Set[Tuple[str, str]] = set()
        self.acquisitions = 0

    def _stack(self) -> List[Tuple[str, int]]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = []
            self._local.stack = st
        return st

    # -------------------------------------------------------------- #
    def on_acquire(self, level: str, lock_id: int) -> None:
        st = self._stack()
        reentrant = any(lid == lock_id for _, lid in st)
        if st and not reentrant:
            held_levels = {lvl for lvl, _ in st}
            top_level = st[-1][0]
            with self._mu:
                self.acquisitions += 1
                for h in held_levels:
                    self.edges.add((h, level))
                bad = (self.ranks[level] <= self.ranks[top_level])
                if bad:
                    self.violations.append(OrderViolation(
                        thread=threading.current_thread().name,
                        held_level=top_level, acquired_level=level))
        else:
            with self._mu:
                self.acquisitions += 1
        st.append((level, lock_id))

    def on_release(self, lock_id: int) -> None:
        st = self._stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i][1] == lock_id:
                del st[i]
                return

    # -------------------------------------------------------------- #
    def disallowed_edges(self) -> Set[Tuple[str, str]]:
        """Observed edges outside the static hierarchy (empty == the
        runtime agreed with the analyzer)."""
        return self.edges - allowed_edges()

    def report(self) -> str:
        lines = [f"lock acquisitions observed: {self.acquisitions}",
                 f"nesting edges: {sorted(self.edges)}"]
        lines += [v.render() for v in self.violations]
        return "\n".join(lines)


class TrackedLock:
    """Context-manager/acquire/release proxy reporting to a tracker.
    Reentrant acquisitions of the same underlying lock are recorded but
    never flagged (the ranked locks are RLocks or never re-entered)."""

    def __init__(self, inner, level: str, tracker: LockOrderTracker):
        self._inner = inner
        self._level = level
        self._tracker = tracker

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            self._tracker.on_acquire(self._level, id(self._inner))
        return ok

    def release(self) -> None:
        self._inner.release()
        self._tracker.on_release(id(self._inner))

    def __enter__(self) -> "TrackedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


# ------------------------------------------------------------------ #
@dataclasses.dataclass
class _InstallHandle:
    node_init: object
    inst_init: object
    sched_init: object


_active: Optional[_InstallHandle] = None


def install(tracker: LockOrderTracker) -> _InstallHandle:
    """Wrap the ranked locks of every BackendNode/Instance/Scheduler
    constructed from now on.  Returns the handle `uninstall` needs."""
    global _active
    if _active is not None:
        raise RuntimeError("LockOrderTracker already installed")
    from repro.cluster import node as node_mod
    from repro.serving import scheduler as sched_mod

    orig_node = node_mod.BackendNode.__init__
    orig_inst = node_mod.Instance.__init__
    orig_sched = sched_mod.Scheduler.__init__

    def node_init(self, *a, **k):
        orig_node(self, *a, **k)
        self.lock = TrackedLock(self.lock, "node", tracker)

    def inst_init(self, *a, **k):
        orig_inst(self, *a, **k)
        self.lock = TrackedLock(self.lock, "instance", tracker)

    def sched_init(self, *a, **k):
        orig_sched(self, *a, **k)
        self._lock = TrackedLock(self._lock, "scheduler", tracker)

    node_mod.BackendNode.__init__ = node_init
    node_mod.Instance.__init__ = inst_init
    sched_mod.Scheduler.__init__ = sched_init
    _active = _InstallHandle(orig_node, orig_inst, orig_sched)
    return _active


def uninstall(handle: Optional[_InstallHandle] = None) -> None:
    global _active
    h = handle if handle is not None else _active
    if h is None:
        return
    from repro.cluster import node as node_mod
    from repro.serving import scheduler as sched_mod
    node_mod.BackendNode.__init__ = h.node_init
    node_mod.Instance.__init__ = h.inst_init
    sched_mod.Scheduler.__init__ = h.sched_init
    _active = None

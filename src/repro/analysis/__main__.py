"""CLI: `python -m repro.analysis [--check] [paths...]`.

Default paths cover `src/repro`; the default baseline is the checked-in
`analysis_baseline.json` at the repo root.  Exit codes: 0 clean (or
report-only mode), 2 new unwaived violations, 3 invalid baseline
(waiver without a reason).
"""
from __future__ import annotations

import argparse
import pathlib
import sys
from typing import List

from repro.analysis.baseline import DEFAULT_BASELINE, Baseline
from repro.analysis.core import Violation, run_checkers
from repro.analysis.defaults import MutableDefaultChecker
from repro.analysis.hotpath import HotPathSyncChecker
from repro.analysis.locks import LockOrderChecker
from repro.analysis.refcount import RefcountChecker
from repro.analysis.shared_state import SharedStateChecker

ALL_CHECKERS = {
    "lock-order": LockOrderChecker,
    "shared-state": SharedStateChecker,
    "hot-path-sync": HotPathSyncChecker,
    "mutable-default": MutableDefaultChecker,
    "refcount-pairing": RefcountChecker,
}


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Concurrency & hot-path static analyzer "
                    "(lock order, shared state, host syncs, mutable "
                    "defaults, refcount pairing)")
    p.add_argument("paths", nargs="*", default=None,
                   help="files/dirs to analyze (default: src/repro)")
    p.add_argument("--baseline", default=DEFAULT_BASELINE,
                   help=f"waiver baseline file (default: "
                        f"{DEFAULT_BASELINE})")
    p.add_argument("--no-baseline", action="store_true",
                   help="report every violation, ignoring waivers")
    p.add_argument("--check", action="store_true",
                   help="exit 2 on unwaived violations, 3 on waivers "
                        "without reasons")
    p.add_argument("--write-baseline", action="store_true",
                   help="absorb current violations into the baseline "
                        "(preserving existing reasons)")
    p.add_argument("--rules", default="",
                   help="comma-separated subset of rules to run "
                        f"(default all: {','.join(ALL_CHECKERS)})")
    return p


def main(argv: List[str] = None) -> int:
    args = build_parser().parse_args(argv)
    paths = args.paths or ["src/repro"]
    rules = [r for r in args.rules.split(",") if r] or list(ALL_CHECKERS)
    unknown = [r for r in rules if r not in ALL_CHECKERS]
    if unknown:
        print(f"unknown rules: {unknown}", file=sys.stderr)
        return 2
    checkers = [ALL_CHECKERS[r]() for r in rules]
    root = pathlib.Path.cwd()
    violations: List[Violation] = run_checkers(paths, checkers, root=root)

    if args.no_baseline:
        for v in violations:
            print(v.render())
        print(f"{len(violations)} violation(s), baseline ignored")
        return 2 if (args.check and violations) else 0

    baseline = Baseline.load(args.baseline)
    if args.write_baseline:
        baseline.absorb(violations)
        baseline.save(args.baseline)
        print(f"wrote {len(baseline.waivers)} waiver(s) to "
              f"{args.baseline}; fill in every TODO reason")
        return 0

    new, waived, stale = baseline.split(violations)
    unexplained = baseline.unexplained()
    for v in new:
        print(v.render())
    if stale:
        print(f"stale waivers (fixed sites — remove from "
              f"{args.baseline}):")
        for k in stale:
            print(f"  {k}")
    print(f"{len(violations)} violation(s): {len(new)} new, "
          f"{len(waived)} waived, {len(stale)} stale waiver(s)")
    if unexplained:
        print("waivers without a reason:", file=sys.stderr)
        for k in unexplained:
            print(f"  {k}", file=sys.stderr)
        if args.check:
            return 3
    if args.check and new:
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""repro.analysis framework core — source loading, indexing, call graph.

The analyzer is pure-stdlib (``ast`` only) so the CI job can run it
without installing jax.  Checkers consume a `ProjectIndex` — every
function/method in the analyzed files plus a *name-based* call graph
with receiver hints (``self.scheduler.submit()`` resolves to
`Scheduler.submit`, not every ``submit`` in the tree).  That is coarse
by design: the runtime's locking and hot-path disciplines are enforced
on well-known class names, and the `analysis_baseline.json` waiver
layer absorbs the residual imprecision explicitly instead of silently.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
from typing import Dict, List, Optional, Sequence, Tuple, Union

FunctionNode = Union[ast.FunctionDef, ast.AsyncFunctionDef]


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding.  `key` deliberately excludes the line number so a
    refactor that moves code does not churn the waiver baseline; the
    `detail` slug disambiguates repeated findings inside one symbol
    (e.g. the 2nd `device_get` in a function gets its own key)."""
    rule: str        # checker id, e.g. "lock-order"
    file: str        # repo-relative posix path
    line: int
    symbol: str      # dotted symbol, e.g. "BackendNode.fail"
    message: str
    detail: str = ""

    @property
    def key(self) -> str:
        base = f"{self.rule}::{self.file}::{self.symbol}"
        return f"{base}::{self.detail}" if self.detail else base

    def render(self) -> str:
        return (f"{self.file}:{self.line}: [{self.rule}] "
                f"{self.symbol}: {self.message}")


@dataclasses.dataclass
class SourceModule:
    path: pathlib.Path
    rel: str                     # posix path relative to the scan root
    tree: ast.Module


@dataclasses.dataclass
class FunctionInfo:
    module: SourceModule
    cls: Optional[str]           # enclosing class name, None at top level
    name: str
    node: FunctionNode

    @property
    def qualname(self) -> str:
        return f"{self.cls}.{self.name}" if self.cls else self.name

    @property
    def uid(self) -> str:
        """Globally unique id (two files may define same-named classes)."""
        return f"{self.module.rel}::{self.qualname}"


def load_modules(paths: Sequence[Union[str, pathlib.Path]],
                 root: Optional[pathlib.Path] = None) -> List[SourceModule]:
    """Parse every .py under `paths` (files or directories)."""
    files: List[pathlib.Path] = []
    for p in paths:
        p = pathlib.Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    out: List[SourceModule] = []
    for f in files:
        rel = f.as_posix()
        if root is not None:
            try:
                rel = f.resolve().relative_to(root.resolve()).as_posix()
            except ValueError:
                pass
        tree = ast.parse(f.read_text(), filename=str(f))
        out.append(SourceModule(path=f, rel=rel, tree=tree))
    return out


# ------------------------------------------------------------------ #
def dotted_parts(expr: ast.expr) -> Optional[Tuple[str, ...]]:
    """('self', 'scheduler', '_lock') for self.scheduler._lock; None for
    anything that isn't a plain Name/Attribute chain."""
    parts: List[str] = []
    node = expr
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return tuple(reversed(parts))


def call_name(call: ast.Call) -> Optional[str]:
    """Bare callee name: `self._admit()` -> '_admit', `foo()` -> 'foo'."""
    fn = call.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return None


def call_receiver(call: ast.Call) -> Optional[Tuple[str, ...]]:
    """Receiver chain of a method call: `inst.engine.cancel()` ->
    ('inst', 'engine'); None for bare-name calls."""
    fn = call.func
    if not isinstance(fn, ast.Attribute):
        return None
    return dotted_parts(fn.value)


# Receiver-name -> class-name hints.  The runtime uses these local names
# consistently (enforced by review idiom, exploited here): they make the
# name-based call graph resolve `inst.engine.cancel()` to
# `InferenceEngine.cancel` instead of every `cancel` in the tree.
RECEIVER_CLASS_HINTS: Dict[str, str] = {
    "engine": "InferenceEngine", "eng": "InferenceEngine",
    "scheduler": "Scheduler", "sched": "Scheduler",
    "node": "BackendNode",
    "inst": "Instance", "instance": "Instance",
    "pool": "PagedKVPool",
    "req": "Request", "request": "Request", "retry": "Request",
    "frontend": "ServiceFrontend",
    "host": "HostPagePool",
    "gw": "Gateway", "gateway": "Gateway", "_gw": "Gateway",
    "handle": "GenerationHandle",
    "rt": "ServingRuntime", "runtime": "ServingRuntime",
    "tenants": "TenantLimiter",
}


def _is_frozen_dataclass_decorator(dec: ast.expr) -> Optional[bool]:
    """True/False for a @dataclass decorator (frozen or not); None when
    the decorator isn't a dataclass decorator at all."""
    target = dec.func if isinstance(dec, ast.Call) else dec
    parts = dotted_parts(target)
    if parts is None or parts[-1] != "dataclass":
        return None
    if isinstance(dec, ast.Call):
        for kw in dec.keywords:
            if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
                return bool(kw.value.value)
    return False


class ProjectIndex:
    """Every class and function in the analyzed files, plus resolution
    helpers shared by the lock-order and hot-path checkers."""

    def __init__(self, modules: Sequence[SourceModule]):
        self.modules = list(modules)
        self.functions: List[FunctionInfo] = []
        self.by_name: Dict[str, List[FunctionInfo]] = {}
        self.by_class: Dict[str, Dict[str, FunctionInfo]] = {}
        self.classes: Dict[str, ast.ClassDef] = {}
        self.class_module: Dict[str, SourceModule] = {}
        self.frozen_dataclasses: set = set()
        self.dataclasses: set = set()
        for mod in self.modules:
            for node in mod.tree.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self._add(FunctionInfo(mod, None, node.name, node))
                elif isinstance(node, ast.ClassDef):
                    self.classes[node.name] = node
                    self.class_module[node.name] = mod
                    for dec in node.decorator_list:
                        frozen = _is_frozen_dataclass_decorator(dec)
                        if frozen is not None:
                            self.dataclasses.add(node.name)
                            if frozen:
                                self.frozen_dataclasses.add(node.name)
                    for sub in node.body:
                        if isinstance(sub, (ast.FunctionDef,
                                            ast.AsyncFunctionDef)):
                            self._add(FunctionInfo(mod, node.name,
                                                   sub.name, sub))

    def _add(self, fi: FunctionInfo) -> None:
        self.functions.append(fi)
        self.by_name.setdefault(fi.name, []).append(fi)
        if fi.cls:
            self.by_class.setdefault(fi.cls, {})[fi.name] = fi

    # -------------------------------------------------------------- #
    def resolve_call(self, call: ast.Call,
                     caller_cls: Optional[str]) -> List[FunctionInfo]:
        """Candidate targets for a call site.  `self.f()` binds to the
        caller's own class when it defines `f`; a hinted receiver binds
        to that class only (empty when the class lacks the method —
        a confident receiver with an unknown method is external code);
        anything else falls back to every function with that bare name."""
        name = call_name(call)
        if name is None:
            return []
        recv = call_receiver(call)
        if recv is not None:
            key = recv[-1]
            if key == "self" and caller_cls is not None:
                own = self.by_class.get(caller_cls, {})
                if name in own:
                    return [own[name]]
                return self.by_name.get(name, [])
            hinted = RECEIVER_CLASS_HINTS.get(key)
            if hinted is not None:
                meth = self.by_class.get(hinted, {}).get(name)
                return [meth] if meth is not None else []
        return self.by_name.get(name, [])


class Checker:
    """Base interface: one rule id, one pass over the index."""
    rule: str = ""

    def check(self, index: ProjectIndex) -> List[Violation]:
        raise NotImplementedError


def run_checkers(paths: Sequence[Union[str, pathlib.Path]],
                 checkers: Sequence[Checker],
                 root: Optional[pathlib.Path] = None) -> List[Violation]:
    index = ProjectIndex(load_modules(paths, root=root))
    out: List[Violation] = []
    for ch in checkers:
        out.extend(ch.check(index))
    out.sort(key=lambda v: (v.file, v.line, v.rule, v.symbol))
    return out

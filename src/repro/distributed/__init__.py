from repro.distributed.sharding import (Strategy, make_sharder,
                                        tree_shardings, pick_strategy,
                                        train_strategy, train_strategy_fsdp,
                                        serve_strategy, STRATEGIES)

__all__ = ["Strategy", "make_sharder", "tree_shardings", "pick_strategy",
           "train_strategy", "train_strategy_fsdp", "serve_strategy",
           "STRATEGIES"]

from repro.distributed.sharding import (STRATEGIES, Strategy, make_sharder,
                                        pick_strategy, serve_strategy,
                                        train_strategy, train_strategy_fsdp,
                                        tree_shardings)

__all__ = ["Strategy", "make_sharder", "tree_shardings", "pick_strategy",
           "train_strategy", "train_strategy_fsdp", "serve_strategy",
           "STRATEGIES"]

"""Logical-axis sharding: MaxText-style rules with divisibility fallbacks.

Every model tensor (param, activation, cache) is annotated with a tuple of
*logical* axis names.  A `Strategy` maps logical axes to prioritized lists of
mesh-axis tuples; `resolve()` picks, per tensor, the first candidate that
divides the dim and whose mesh axes are still unused in that tensor.  This is
what lets one model definition serve *every* (arch x shape x mesh) cell —
including awkward cases like kv_heads=5 or d_ff=5504 that don't divide a
16-way axis.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Tuple[str, ...]
Candidate = Tuple[str, ...]          # tuple of mesh axis names


@dataclasses.dataclass(frozen=True)
class Strategy:
    """Priority-ordered rules: logical axis -> candidate mesh-axis tuples.

    `priority` orders *which logical axes get first pick* of mesh axes when
    several dims of one tensor compete (e.g. kv_heads before seq_kv so head
    sharding wins when divisible).
    """
    rules: Dict[str, List[Candidate]]
    priority: List[str]
    name: str = ""

    def spec_for(self, axes: Axes, shape: Sequence[int],
                 mesh: Mesh) -> P:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        assign: Dict[int, Candidate] = {}
        used: set = set()
        order = [a for a in self.priority if a in axes] + \
                [a for a in axes if a not in self.priority]
        for logical in order:
            if logical not in self.rules:
                continue
            # find the dim index (first unassigned occurrence)
            dim = None
            for i, a in enumerate(axes):
                if a == logical and i not in assign:
                    dim = i
                    break
            if dim is None:
                continue
            for cand in self.rules[logical]:
                if any(c in used for c in cand):
                    continue
                total = int(np.prod([sizes[c] for c in cand]))
                if shape[dim] % total == 0 and total > 1:
                    assign[dim] = cand
                    used.update(cand)
                    break
        parts = []
        for i in range(len(axes)):
            if i in assign:
                cand = assign[i]
                parts.append(cand[0] if len(cand) == 1 else cand)
            else:
                parts.append(None)
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def sharding_for(self, axes: Axes, shape: Sequence[int],
                     mesh: Mesh) -> NamedSharding:
        return NamedSharding(mesh, self.spec_for(axes, shape, mesh))


def tree_shardings(axes_tree, specs_tree, mesh: Mesh, strategy: Strategy):
    """Map a tree of logical-axes tuples + ShapeDtypeStructs to shardings."""
    return jax.tree.map(
        lambda ax, spec: strategy.sharding_for(ax, spec.shape, mesh),
        axes_tree, specs_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))


def make_sharder(mesh: Optional[Mesh], strategy: Optional[Strategy]):
    """Returns sh(x, logical_axes) applying a sharding constraint."""
    if mesh is None or strategy is None:
        return lambda x, axes: x

    def sh(x, axes):
        spec = strategy.spec_for(tuple(axes), x.shape, mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))
    return sh


def make_weight_sharder(mesh: Optional[Mesh],
                        strategy: Optional[Strategy]):
    """Returns shw(param_tree, axes_tree) constraining weights to their
    *compute* sharding inside the step.

    This is the explicit-FSDP-gather trick: weights are STORED sharded over
    the DP axis (in_shardings) but CONSTRAINED to a DP-replicated, TP-sharded
    layout at use — so XLA inserts a cheap per-layer weight all-gather
    instead of involuntarily rematerializing (replicating!) the much larger
    activations to match the weight sharding.  Without this, SPMD
    partitioning emits 'involuntary full rematerialization' and the memory/
    collective terms explode by ~2 orders of magnitude (see EXPERIMENTS.md
    §Perf iteration 1).
    """
    if mesh is None or strategy is None:
        return None

    def shw(tree, axes_tree):
        def f(x, ax):
            spec = strategy.spec_for(tuple(ax), x.shape, mesh)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        return jax.tree.map(
            f, tree, axes_tree,
            is_leaf=lambda t: isinstance(t, tuple) and all(
                isinstance(a, (str, type(None))) for a in t))
    return shw


def make_tp_projector(mesh: Optional[Mesh], act_strategy: Optional[Strategy],
                      w_strategy: Optional[Strategy]):
    """Explicit row-parallel (Megatron) out-projection.

    XLA's SPMD partitioner emits a full ALL-REDUCE for
    `einsum(x, w_contracted_over_tp)` even when the output is constrained
    to a seq-sharded layout (verified by micro-benchmark — no AR->RS
    strength reduction).  This helper wraps the einsum in shard_map with an
    explicit `psum_scatter`, halving the wire bytes.  Falls back to a plain
    einsum whenever the preconditions don't hold (contraction not sharded
    over exactly the TP axis, scatter dim not divisible, decode S=1, ...).

    Returns project(x, w, eq, x_axes, w_axes, out_axes, scatter_axis).
    """
    if mesh is None or act_strategy is None or w_strategy is None:
        return None
    from jax.experimental.shard_map import shard_map
    tp = _tp(mesh)[0]
    tp_size = dict(zip(mesh.axis_names, mesh.devices.shape))[tp]

    def project(x, w, eq, x_axes, w_axes, out_axes, scatter_axis):
        out_shape = jax.eval_shape(
            lambda a, b: jnp.einsum(eq, a, b), x, w).shape
        x_spec = act_strategy.spec_for(tuple(x_axes), x.shape, mesh)
        w_spec = w_strategy.spec_for(tuple(w_axes), w.shape, mesh)
        # precondition: w's first (contracted) dim sharded over tp alone,
        # x's matching dim likewise, scatter dim divisible
        x_parts = tuple(x_spec) + (None,) * (len(x.shape) - len(x_spec))
        w_parts = tuple(w_spec) + (None,) * (len(w.shape) - len(w_spec))
        ok = (tp in w_parts and
              out_shape[scatter_axis] % tp_size == 0 and
              x_parts.count(tp) == 1 and w_parts.count(tp) == 1)
        if not ok:
            out = jnp.einsum(eq, x, w)
            return jax.lax.with_sharding_constraint(
                out, NamedSharding(mesh, act_strategy.spec_for(
                    tuple(out_axes), out_shape, mesh)))
        out_parts = [None] * len(out_shape)
        out_parts[scatter_axis] = tp
        # keep x's non-tp sharding (e.g. batch over dp) in the out spec
        for i, p in enumerate(x_parts[:len(out_parts)]):
            if p is not None and p != tp and i != scatter_axis:
                out_parts[i] = p

        def body(x_, w_):
            o = jnp.einsum(eq, x_, w_)
            return jax.lax.psum_scatter(o, tp,
                                        scatter_dimension=scatter_axis,
                                        tiled=True)

        return shard_map(body, mesh=mesh,
                         in_specs=(x_spec, w_spec),
                         out_specs=P(*out_parts),
                         check_rep=False)(x, w)

    return project


def make_tp_col_projector(mesh: Optional[Mesh],
                          act_strategy: Optional[Strategy],
                          w_strategy: Optional[Strategy]):
    """Column-parallel (Megatron f-operator) projection with the einsum
    INSIDE the shard_map: fwd = all_gather(x_seq) -> local einsum; bwd =
    one fused psum_scatter.  Composing a standalone gather with an outside
    einsum leaves XLA resolving the partial cotangent with a full
    all-reduce first (measured: 2x wire, §Perf iteration 10).

    Only used when the OUTPUT carries the tp axis (q heads / mlp F) so
    shard_map grads stay exact; falls back to plain einsum + constraint.
    """
    if mesh is None or act_strategy is None or w_strategy is None:
        return None
    from jax.experimental.shard_map import shard_map
    tp = _tp(mesh)[0]

    def project(x, w, eq, x_axes, w_axes, out_axes, gather_axis=1):
        out_shape = jax.eval_shape(
            lambda a, b: jnp.einsum(eq, a, b), x, w).shape
        x_spec = act_strategy.spec_for(tuple(x_axes), x.shape, mesh)
        w_spec = w_strategy.spec_for(tuple(w_axes), w.shape, mesh)
        out_spec = act_strategy.spec_for(tuple(out_axes), out_shape, mesh)
        x_parts = tuple(x_spec) + (None,) * (len(x.shape) - len(x_spec))
        w_parts = tuple(w_spec) + (None,) * (len(w.shape) - len(w_spec))
        out_parts = tuple(out_spec) + (None,) * (len(out_shape)
                                                 - len(out_spec))
        ok = (len(x_parts) > gather_axis and
              x_parts[gather_axis] == tp and
              x_parts.count(tp) == 1 and
              tp in out_parts and tp in w_parts)
        if not ok:
            out = jnp.einsum(eq, x, w)
            return jax.lax.with_sharding_constraint(
                out, NamedSharding(mesh, out_spec))

        def body(x_, w_):
            x_full = jax.lax.all_gather(x_, tp, axis=gather_axis,
                                        tiled=True)
            return jnp.einsum(eq, x_full, w_)

        return shard_map(body, mesh=mesh, in_specs=(x_spec, w_spec),
                         out_specs=out_spec, check_rep=False)(x, w)

    return project


def make_tp_gather(mesh: Optional[Mesh],
                   act_strategy: Optional[Strategy]):
    """Megatron-SP f-operator: gather the TP(seq)-sharded residual once per
    block, as a shard_map all_gather whose TRANSPOSE is a reduce-scatter.
    (A plain sharding-constraint gather gets a full 2x-wire all-reduce in
    the backward from XLA's partitioner — measured, §Perf iteration 9.)

    Returns gather(x, x_axes, gather_axis=1) -> x with that dim whole.
    """
    if mesh is None or act_strategy is None:
        return None
    from jax.experimental.shard_map import shard_map
    tp = _tp(mesh)[0]

    def gather(x, x_axes, gather_axis: int = 1):
        x_spec = act_strategy.spec_for(tuple(x_axes), x.shape, mesh)
        x_parts = tuple(x_spec) + (None,) * (len(x.shape) - len(x_spec))
        if len(x_parts) <= gather_axis or x_parts[gather_axis] != tp:
            return x        # already whole on this dim
        out_parts = list(x_parts)
        out_parts[gather_axis] = None
        while out_parts and out_parts[-1] is None:
            out_parts.pop()

        def body(x_):
            return jax.lax.all_gather(x_, tp, axis=gather_axis,
                                      tiled=True)

        return shard_map(body, mesh=mesh, in_specs=(x_spec,),
                         out_specs=P(*out_parts), check_rep=False)(x)

    return gather


def train_compute_strategy(mesh: Mesh) -> Strategy:
    """Weight layout at *use* time during training: TP dims sharded, the
    FSDP (embed) dim gathered."""
    tp = _tp(mesh)
    rules = {
        "mlp": [tp], "heads": [tp], "kv_heads": [tp], "inner": [tp],
        "vocab": [tp], "experts": [tp],
    }
    return Strategy(rules=rules,
                    priority=["mlp", "heads", "kv_heads", "inner",
                              "vocab", "experts"],
                    name="train_compute")


# --------------------------------------------------------------------- #
# Strategy presets.  DP = data(-parallel) meta axis; TP = model axis.

def _dp(mesh: Mesh) -> Candidate:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _tp(mesh: Mesh) -> Candidate:
    return ("model",)


def train_strategy(mesh: Mesh, name: str = "fsdp_tp") -> Strategy:
    """FSDP over DP + tensor-parallel over TP + sequence-parallel residual.

    Params: embed dim FSDP-sharded over DP; mlp/heads/vocab over TP.
    Activations: batch over DP, seq over TP (Megatron-SP style residual).
    """
    dp, tp = _dp(mesh), _tp(mesh)
    rules = {
        # params
        "embed": [dp],
        "mlp": [tp],
        "heads": [tp],
        "kv_heads": [tp],
        "inner": [tp],
        "vocab": [tp],
        "experts": [tp],        # EP when divisible, else falls through
        # activations
        "batch": [dp],
        "seq": [tp],
        "embed_rs": [tp],       # MoE down-proj reduce-scatter target
    }
    return Strategy(rules=rules,
                    priority=["batch", "embed", "mlp", "heads", "kv_heads",
                              "inner", "vocab", "experts", "embed_rs",
                              "seq"],
                    name=name)


def train_strategy_fsdp(mesh: Mesh) -> Strategy:
    """Pure FSDP: batch over DP+TP flattened; params fully sharded over the
    flattened mesh on their largest logical dim.  Best for small models
    where TP would be latency-bound."""
    dp, tp = _dp(mesh), _tp(mesh)
    all_ = dp + tp
    rules = {
        "embed": [all_, dp, tp],
        "mlp": [all_, tp, dp],
        "vocab": [all_, tp, dp],
        "heads": [tp],
        "kv_heads": [tp],
        "inner": [all_, tp, dp],
        "experts": [tp],
        "batch": [all_, dp],
        "seq": [tp],
        "embed_rs": [tp, dp],   # MoE down-proj reduce-scatter target
    }
    return Strategy(rules=rules,
                    priority=["batch", "mlp", "vocab", "embed", "inner",
                              "heads", "kv_heads", "experts", "embed_rs",
                              "seq"],
                    name="fsdp")


def serve_strategy(mesh: Mesh, name: str = "serve") -> Strategy:
    """Serving: params TP-only (no per-step gathers); batch over DP;
    KV heads over TP when divisible, else KV sequence; long-context batch=1
    spreads KV sequence over every axis."""
    dp, tp = _dp(mesh), _tp(mesh)
    all_ = dp + tp
    rules = {
        # weights TP-only: no per-step gathers on the serving path (the
        # embed/contraction dim stays replicated across DP)
        "mlp": [tp],
        "heads": [tp],
        "kv_heads": [tp],
        "inner": [tp],
        "vocab": [tp],
        "experts": [tp],
        "batch": [dp],
        "seq": [tp],
        "seq_kv": [tp, dp, all_],
    }
    return Strategy(rules=rules,
                    priority=["batch", "kv_heads", "seq_kv", "heads", "mlp",
                              "inner", "vocab", "experts", "seq"],
                    name=name)


STRATEGIES = {
    "fsdp_tp": train_strategy,
    "fsdp": train_strategy_fsdp,
    "serve": serve_strategy,
}


def pick_strategy(kind: str, mesh: Mesh, arch_params: int,
                  override: str = "") -> Strategy:
    """Default policy: big models train with fsdp_tp (SP residual keeps
    activations bounded); small models (<8B) train pure-FSDP; serving is
    always TP-centric."""
    if override:
        return STRATEGIES[override](mesh)
    if kind == "train":
        if arch_params >= 8e9:
            return train_strategy(mesh)
        return train_strategy_fsdp(mesh)
    return serve_strategy(mesh)

"""Service Frontend (HAProxy analogue) + HealthMonitor: routing, load
balancing fairness, failover, straggler demotion, heartbeat lifecycle."""
from repro.cluster import BackendNode, Fleet
from repro.configs import ZOO
from repro.core.frontend import FrontendConfig, ServiceFrontend
from repro.core.health import HealthConfig, HealthMonitor, NodeHealth
from repro.core.registry import ReplicaInfo, ReplicaKey, ReplicaRegistry
from repro.serving.request import Request
from repro.serving.sampler import SamplingParams


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _stack(n_nodes=3, model="deepseek-r1-7b"):
    fleet = Fleet([BackendNode(f"n{i}", "v5e-1") for i in range(n_nodes)])
    clock = FakeClock()
    monitor = HealthMonitor(HealthConfig(suspect_after=2, dead_after=5),
                            clock=clock)
    replicas = ReplicaRegistry()
    cfg = ZOO[model]
    for i, node in enumerate(fleet.nodes.values()):
        inst = node.deploy(cfg, quantize="int8", n_slots=4, max_len=1024,
                           real=False)
        replicas.add(ReplicaInfo(ReplicaKey(node.node_id,
                                            inst.instance_id),
                                 model, "int8", 4, 1024, inst.bytes))
        monitor.observe_heartbeat(node.node_id)
    fe = ServiceFrontend(fleet, replicas, monitor, FrontendConfig())
    return fleet, monitor, replicas, fe, clock


def test_routing_table_lists_healthy():
    fleet, mon, reps, fe, clock = _stack(3)
    table = fe.routing_table()
    assert len(table["deepseek-r1-7b"]) == 3


def test_load_balancing_distributes():
    fleet, mon, reps, fe, clock = _stack(3)
    for _ in range(30):
        req = Request(model="deepseek-r1-7b", prompt=[1, 2, 3],
                      sampling=SamplingParams(max_tokens=2))
        assert fe.submit(req)
    counts = fe.stats.per_replica
    assert len(counts) == 3
    # accounted-mode requests finish instantly -> near-even spread
    assert max(counts.values()) - min(counts.values()) <= 12


def test_failover_on_node_death():
    fleet, mon, reps, fe, clock = _stack(2)
    victim = list(fleet.nodes)[0]
    fleet.fail_node(victim)
    for _ in range(5):
        req = Request(model="deepseek-r1-7b", prompt=[1],
                      sampling=SamplingParams(max_tokens=2))
        ok = fe.submit(req)
        assert ok and req.node != victim
    assert fe.stats.failed == 0


def test_no_backend_rejection():
    fleet, mon, reps, fe, clock = _stack(1)
    fleet.fail_node(list(fleet.nodes)[0])
    req = Request(model="deepseek-r1-7b", prompt=[1])
    assert not fe.submit(req)
    assert req.error == "no healthy backend"


def test_mark_dead_excludes_from_routing():
    fleet, mon, reps, fe, clock = _stack(3)
    victim = list(fleet.nodes)[1]
    mon.mark_dead(victim)
    assert all(victim not in k
               for k in fe.routing_table()["deepseek-r1-7b"])
    mon.clear_mark(victim)
    assert any(victim in k
               for k in fe.routing_table()["deepseek-r1-7b"])


def test_heartbeat_lifecycle():
    clock = FakeClock()
    mon = HealthMonitor(HealthConfig(suspect_after=2, dead_after=5),
                        clock=clock)
    mon.observe_heartbeat("a")
    assert mon.status("a") == NodeHealth.HEALTHY
    clock.advance(3)
    assert mon.status("a") == NodeHealth.SUSPECT
    assert not mon.heartbeat_expired("a")
    clock.advance(3)
    assert mon.heartbeat_expired("a")
    mon.observe_heartbeat("a")
    assert mon.status("a") == NodeHealth.HEALTHY


def test_straggler_detection():
    mon = HealthMonitor()
    for i in range(5):
        mon.observe_latency(f"r{i}", 0.01)
    for _ in range(20):
        mon.observe_latency("slow", 1.0)
    assert mon.is_straggler("slow")
    assert not mon.is_straggler("r0")


def test_straggler_demoted_in_pick():
    fleet, mon, reps, fe, clock = _stack(4)
    keys = [str(r.key) for r in reps.for_model("deepseek-r1-7b")]
    # make replica 0 a straggler (others healthy)
    for _ in range(20):
        mon.observe_latency(keys[0], 2.0)
        for k in keys[1:]:
            mon.observe_latency(k, 0.01)
    picks = [str(fe.pick("deepseek-r1-7b")) for _ in range(9)]
    assert keys[0] not in picks
    assert set(picks) == set(keys[1:])      # round-robin over healthy

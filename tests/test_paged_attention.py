"""Engine-level paged-attention parity: the page-table-direct decode
kernel must be a pure memory optimization — greedy outputs token-identical
to the gather/scatter path at every fused-block size, across prefix-cache
COW sharing, host-swap resume, sentinel-padded tables, and preemption —
while moving >= 2x fewer logical KV bytes per token at identical
dispatch/sync counts."""
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           SamplingParams)


@pytest.fixture(scope="module")
def cfg():
    return ARCHS["olmo-1b"].reduced()


@pytest.fixture(scope="module")
def params(cfg, param_store):
    return param_store(cfg)


def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    return InferenceEngine(cfg, params, EngineConfig(**kw))


def _run(eng, reqs, max_steps=10_000):
    for r in reqs:
        assert eng.submit(r)
    eng.run_until_done(max_steps)
    return [tuple(r.output) for r in reqs]


def _serial(eng, prompts, max_tokens=8):
    outs = []
    for p in prompts:
        r = Request(model="m", prompt=list(p),
                    sampling=SamplingParams(max_tokens=max_tokens))
        assert eng.submit(r)
        eng.run_until_done()
        outs.append(tuple(r.output))
    return outs


def _work(n=5, max_tokens=10):
    return [Request(model="m", prompt=list(range(1, 2 + i)),
                    sampling=SamplingParams(max_tokens=max_tokens + i))
            for i in range(n)]


SHARED = list(range(1, 25))            # 24 tokens = 3 pages at size 8


# ------------------- greedy parity --------------------------------- #
@pytest.mark.parametrize("k", [1, 4, 8])
def test_paged_attention_greedy_parity(cfg, params, k):
    """Token-for-token identical outputs with the kernel on and off at
    every fused-block size — short prompts leave most of each slot's
    page table at the OOB sentinel, so padded tables are exercised on
    every dispatch."""
    ref = _run(_engine(cfg, params, decode_block=k), _work())
    eng = _engine(cfg, params, decode_block=k, paged_attention=True)
    assert _run(eng, _work()) == ref
    assert eng.perf_stats()["paged_attention"]


def test_dispatch_and_sync_counts_unchanged(cfg, params):
    """The kernel changes what a dispatch reads, never how many
    dispatches or host syncs a token costs."""
    a = _engine(cfg, params, decode_block=4)
    _run(a, _work())
    b = _engine(cfg, params, decode_block=4, paged_attention=True)
    _run(b, _work())
    sa, sb = a.perf_stats(), b.perf_stats()
    assert sa["dispatches"] == sb["dispatches"]
    assert sa["host_syncs"] == sb["host_syncs"]
    assert sa["tokens"] == sb["tokens"]


def test_logical_bytes_reduced_2x(cfg, params):
    """The point of the kernel: >= 2x fewer logical KV bytes per token
    than gather/scatter on a decode-heavy workload."""
    a = _engine(cfg, params, decode_block=4)
    _run(a, _work(max_tokens=20))
    b = _engine(cfg, params, decode_block=4, paged_attention=True)
    _run(b, _work(max_tokens=20))
    sa, sb = a.perf_stats(), b.perf_stats()
    assert sa["logical_bytes_moved"] > 0
    assert sb["logical_bytes_moved"] > 0
    ratio = (sa["logical_bytes_moved_per_token"]
             / sb["logical_bytes_moved_per_token"])
    assert ratio >= 2.0, ratio


# ------------------- prefix cache / COW sharing -------------------- #
@pytest.mark.parametrize("k", [1, 4])
def test_parity_with_cow_shared_pages(cfg, params, k):
    """Slots whose tables map refcounted cache-shared prefix pages read
    them through the kernel exactly as the gathered view did — and the
    write-table sentinel keeps those pages immutable."""
    prompts = [SHARED + [30, 31],          # cold: populates the cache
               SHARED + [40, 41, 42],      # full 3-page hit
               SHARED[:12] + [7]]          # partial 1-page hit
    ref = _serial(_engine(cfg, params, decode_block=k,
                          prefix_cache=True), prompts)
    eng = _engine(cfg, params, decode_block=k, prefix_cache=True,
                  paged_attention=True)
    assert _serial(eng, prompts) == ref
    assert eng.suffix_prefills >= 2        # hits actually shared pages
    # shared pages were never dirtied: the same hits replay identically
    assert _serial(eng, prompts[1:]) == ref[1:]


# ------------------- swap resume / preemption ---------------------- #
@pytest.mark.parametrize("k", [1, 4])
def test_parity_across_swap_resume(cfg, params, k):
    """Preempted slots park on host DRAM and resume into *different*
    physical pages; the kernel must follow the rebuilt page table."""
    def contended():
        return [Request(model="m", prompt=list(range(1, 3 + i)),
                        sampling=SamplingParams(max_tokens=20))
                for i in range(6)]
    base = _engine(cfg, params, n_slots=6, kv_pages=18, decode_block=k)
    ref = _run(base, contended())
    assert base.preemptions >= 1           # contention actually happened
    eng = _engine(cfg, params, n_slots=6, kv_pages=18, decode_block=k,
                  host_kv_pages=64, paged_attention=True)
    assert _run(eng, contended()) == ref
    assert eng.swap_ins >= 1               # kernel ran over swapped-in KV
    assert eng.pool.pages_in_use == 0


def test_cancel_midflight_then_reuse_slot(cfg, params):
    """Cancelling an active request under the kernel releases its pages
    and the reused slot decodes a fresh request identically to an
    uncontended engine."""
    eng = _engine(cfg, params, n_slots=2, decode_block=2,
                  paged_attention=True)
    victim = Request(model="m", prompt=[1, 2, 3],
                     sampling=SamplingParams(max_tokens=30))
    assert eng.submit(victim)
    eng.step()
    assert eng.slot_req                    # admitted and decoding
    assert eng.cancel(victim.request_id) == "active"
    fresh = Request(model="m", prompt=[4, 5],
                    sampling=SamplingParams(max_tokens=6))
    assert eng.submit(fresh)
    eng.run_until_done()
    ref = _run(_engine(cfg, params, n_slots=2, decode_block=2),
               [Request(model="m", prompt=[4, 5],
                        sampling=SamplingParams(max_tokens=6))])
    assert [tuple(fresh.output)] == ref


# ------------------- admin surface --------------------------------- #
def test_perf_stats_surface(cfg, params):
    eng = _engine(cfg, params, decode_block=4, paged_attention=True)
    _run(eng, _work(n=2))
    st = eng.perf_stats()
    assert st["paged_attention"] is True
    assert st["speculative"] is False
    assert st["logical_bytes_moved_per_token"] > 0
    assert st["spec_dispatches"] == 0
    assert np.asarray(st["spec_slot_accepted"]).sum() == 0

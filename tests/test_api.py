"""Gateway API v1: incremental streaming, async handles + cancellation,
admission control, structured failure surfacing, accounted-mode parity,
and the typed admin surface."""
import dataclasses

import pytest

from repro.api import (AdminAPI, ErrorCode, FleetSnapshot, Gateway,
                       GatewayConfig, GenerationRequest, StreamEventType)
from repro.cluster import BackendNode, Fleet
from repro.configs import ARCHS, ZOO
from repro.core import (Client, ModelCatalog, ModelDemand, ReplicaInfo,
                        ReplicaKey, SDAIController)
from repro.serving import SamplingParams

MODEL = "olmo-1b-reduced"


def _live_stack(param_store, n_nodes=2, n_slots=2, max_len=48,
                min_replicas=2):
    """Small fleet running REAL tiny engines behind a controller."""
    fleet = Fleet([BackendNode(f"n{i}", "v5e-1", param_store=param_store)
                   for i in range(n_nodes)])
    cfg = ARCHS["olmo-1b"].reduced()
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.discover()
    plan = ctrl.deploy([ModelDemand(cfg, min_replicas=min_replicas,
                                    n_slots=n_slots, max_len=max_len)])
    assert not plan.unplaced
    return fleet, ctrl


def _pinned_stack(param_store, n_nodes=2):
    """One REAL engine per node, registered manually so replicas are
    guaranteed to span nodes (failover tests need that determinism)."""
    cfg = ARCHS["olmo-1b"].reduced()
    fleet = Fleet([BackendNode(f"n{i}", "v5e-1", param_store=param_store)
                   for i in range(n_nodes)])
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.discover()
    for node in fleet.nodes.values():
        inst = node.deploy(cfg, n_slots=2, max_len=48)
        ctrl.replicas.add(ReplicaInfo(
            ReplicaKey(node.node_id, inst.instance_id),
            cfg.name, "", 2, 48, inst.bytes))
    return fleet, ctrl


def _accounted_stack(n_nodes=2, min_replicas=2):
    """Accounted-mode (analytic) replicas of a big model, deployed
    through the controller's placement path."""
    fleet = Fleet([BackendNode(f"n{i}", "v5e-4") for i in range(n_nodes)])
    cfg = ZOO["deepseek-r1-7b"]
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.discover()
    plan = ctrl.deploy([ModelDemand(cfg, min_replicas=min_replicas,
                                    max_replicas=min_replicas)])
    assert not plan.unplaced
    return fleet, ctrl


@pytest.fixture(scope="module")
def live(param_store):
    return _live_stack(param_store)


# ------------------------- streaming ------------------------------- #
def test_stream_yields_tokens_incrementally(live):
    fleet, ctrl = live
    gw = Gateway(ctrl)
    handle = gw.submit(MODEL, [1, 2, 3], SamplingParams(max_tokens=6))
    assert not handle.done
    events = []
    tokens_before_done = 0
    for ev in handle.stream():
        if ev.type is StreamEventType.TOKEN and not handle.done:
            tokens_before_done += 1
        events.append(ev)
    # true incremental streaming: deltas arrive before the request ends
    assert tokens_before_done >= 1
    assert [e.type for e in events].count(StreamEventType.FINISH) == 1
    assert events[-1].type is StreamEventType.FINISH
    resp = events[-1].response
    assert resp.ok and resp.finish_reason == "length"
    assert list(resp.tokens) == [e.token for e in events[:-1]]
    assert [e.index for e in events[:-1]] == list(range(6))


def test_sync_generate_matches_internal_contract(live):
    fleet, ctrl = live
    gw = Gateway(ctrl)
    resp = gw.generate(MODEL, [4, 5], SamplingParams(max_tokens=4))
    assert resp.ok and len(resp.tokens) == 4
    assert resp.ttft is not None and resp.latency is not None
    assert resp.node in fleet.nodes
    # responses are frozen
    with pytest.raises(dataclasses.FrozenInstanceError):
        resp.tokens = ()


def test_generate_batch_completes_all(live):
    fleet, ctrl = live
    gw = Gateway(ctrl)
    reqs = [GenerationRequest(model=MODEL, prompt=(1, 2, i),
                              sampling=SamplingParams(max_tokens=3))
            for i in range(5)]
    resps = gw.generate_batch(reqs)
    assert len(resps) == 5
    assert all(r.ok and len(r.tokens) == 3 for r in resps)


# ------------------------- cancellation ---------------------------- #
def test_cancel_frees_engine_slot(live):
    fleet, ctrl = live
    gw = Gateway(ctrl)
    handle = gw.submit(MODEL, [7, 8], SamplingParams(max_tokens=10_000))
    it = handle.stream()
    first = next(it)                       # at least one token streamed
    assert first.type is StreamEventType.TOKEN
    assert handle.cancel()
    resp = handle.response
    assert resp.finish_reason == "cancelled"
    assert resp.error.code is ErrorCode.CANCELLED
    # the engine slot the request occupied is released
    for node in fleet.nodes.values():
        for inst in node.instances.values():
            if inst.engine is not None:
                assert all(r.request_id != handle.internal.request_id
                           for r in inst.engine.slot_req.values())
    assert not handle.cancel()             # idempotent once finished
    # terminal event still surfaces on the stream
    rest = list(it)
    assert rest and rest[-1].type is StreamEventType.ERROR


# ------------------------- admission control ----------------------- #
def test_admission_rejects_overloaded_then_recovers(live):
    fleet, ctrl = live
    gw = Gateway(ctrl, GatewayConfig(max_inflight_per_model=2))
    h1 = gw.submit(MODEL, [1], SamplingParams(max_tokens=2000))
    h2 = gw.submit(MODEL, [2], SamplingParams(max_tokens=2000))
    h3 = gw.submit(MODEL, [3], SamplingParams(max_tokens=2))
    assert h3.done                         # structured 429, no queuing
    assert h3.response.error.code is ErrorCode.OVERLOADED
    assert h3.response.error.retryable
    assert gw.stats.rejected_overloaded == 1
    h1.cancel()
    h2.cancel()
    h4 = gw.submit(MODEL, [4], SamplingParams(max_tokens=2))
    assert h4.result().ok                  # slot freed -> admitted again


def test_admission_queue_depth_limit(live):
    fleet, ctrl = live
    gw = Gateway(ctrl, GatewayConfig(max_queue_depth_per_model=1))
    h1 = gw.submit(MODEL, [1], SamplingParams(max_tokens=2))
    h2 = gw.submit(MODEL, [2], SamplingParams(max_tokens=2))
    # h1 still sits in a backend scheduler queue (nothing pumped yet)
    assert h2.done
    assert h2.response.error.code is ErrorCode.OVERLOADED
    assert h1.result().ok                  # backlog drains
    assert gw.generate(MODEL, [3], SamplingParams(max_tokens=2)).ok


# ------------------------- failure surfacing ----------------------- #
def test_midstream_failure_surfaces_structured_error(param_store):
    fleet, ctrl = _pinned_stack(param_store, n_nodes=1)
    gw = Gateway(ctrl)
    handle = gw.submit(MODEL, [9, 9], SamplingParams(max_tokens=10_000))
    it = handle.stream()
    assert next(it).type is StreamEventType.TOKEN
    fleet.fail_node(handle.internal.node)  # crash mid-stream
    events = list(it)                      # must terminate, not hang
    assert events[-1].type is StreamEventType.ERROR
    assert events[-1].error.code is ErrorCode.ENGINE_FAILED
    assert handle.response.finish_reason == "error"


def test_pretoken_failure_retries_transparently(param_store):
    fleet, ctrl = _pinned_stack(param_store, n_nodes=2)
    gw = Gateway(ctrl)
    handle = gw.submit(MODEL, [1, 2], SamplingParams(max_tokens=3))
    victim = handle.internal.node
    fleet.fail_node(victim)                # dies before any token
    resp = handle.result()
    assert resp.ok, resp.error             # re-routed to the survivor
    assert resp.node != victim
    assert resp.retries >= 1
    assert gw.stats.stream_retries >= 1


def test_no_backend_is_structured(live):
    fleet, ctrl = live
    gw = Gateway(ctrl)
    resp = gw.generate("no-such-model", [1])
    assert not resp.ok
    assert resp.error.code is ErrorCode.NO_BACKEND


def test_invalid_request_rejected_before_routing(live):
    fleet, ctrl = live
    gw = Gateway(ctrl)
    resp = gw.generate(MODEL, [])              # empty prompt
    assert resp.error.code is ErrorCode.INVALID_REQUEST
    resp = gw.generate(MODEL, [1], SamplingParams(max_tokens=0))
    assert resp.error.code is ErrorCode.INVALID_REQUEST
    assert not resp.error.retryable
    # the fleet keeps serving afterwards (no engine saw the bad input)
    assert gw.generate(MODEL, [1], SamplingParams(max_tokens=2)).ok


# ------------------------- accounted mode -------------------------- #
def test_accounted_mode_honors_max_tokens_and_streams():
    fleet, ctrl = _accounted_stack(n_nodes=2, min_replicas=2)
    gw = Gateway(ctrl)
    handle = gw.submit("deepseek-r1-7b", [1, 2, 3],
                       SamplingParams(max_tokens=20))
    events = list(handle.stream())
    toks = [e for e in events if e.type is StreamEventType.TOKEN]
    assert len(toks) == 20                 # not capped at 8 any more
    assert events[-1].type is StreamEventType.FINISH
    assert len(handle.response.tokens) == 20
    assert handle.response.ttft is not None


# ------------------------- admin surface --------------------------- #
def test_admin_snapshot_typed_and_legacy_dict(live):
    fleet, ctrl = live
    gw = Gateway(ctrl)
    snap = gw.admin.snapshot()
    assert isinstance(snap, FleetSnapshot)
    assert snap.connected == snap.total == len(fleet.nodes)
    assert any(m.name == MODEL and m.healthy_replicas >= 2
               for m in snap.models)
    node = snap.node("n0")
    assert node is not None and node.hbm_budget > 0
    with pytest.raises(dataclasses.FrozenInstanceError):
        node.alive = False
    # legacy dashboard() renders the same typed snapshot
    dash = ctrl.dashboard()
    assert dash["connected"] == snap.connected
    assert set(dash["agents"]) == {n.node_id for n in snap.nodes}
    assert dash["models"] == {m.name: m.replicas for m in snap.models}


def test_admin_scale_and_undeploy():
    model = "deepseek-r1-7b"
    fleet, ctrl = _accounted_stack(n_nodes=3, min_replicas=1)
    gw = Gateway(ctrl)
    assert len(ctrl.frontend.healthy_replicas(model)) == 1
    res = gw.admin.scale_model(model, 3)
    assert res.ok
    assert len(ctrl.frontend.healthy_replicas(model)) == 3
    res = gw.admin.scale_model(model, 1)
    assert len(ctrl.frontend.healthy_replicas(model)) == 1
    removed = gw.admin.undeploy_model(model)
    assert removed == 1
    assert model not in gw.models()
    resp = gw.generate(model, [1])
    assert resp.error.code is ErrorCode.NO_BACKEND


def test_undeploy_with_inflight_settles_structured(param_store):
    fleet, ctrl = _pinned_stack(param_store, n_nodes=1)
    gw = Gateway(ctrl)
    h = gw.submit(MODEL, [1, 2], SamplingParams(max_tokens=1000))
    assert not h.done
    gw.admin.undeploy_model(MODEL)
    # retired engine fails its queue -> handle settles immediately with a
    # structured error instead of stranding until the pump budget runs out
    assert h.done
    assert h.response.error.code in (ErrorCode.NO_BACKEND,
                                     ErrorCode.ENGINE_FAILED)
    assert gw.inflight(MODEL) == 0


def test_admin_drain_rejects_new_traffic(param_store):
    fleet, ctrl = _pinned_stack(param_store, n_nodes=1)
    gw = Gateway(ctrl)
    h = gw.submit(MODEL, [1], SamplingParams(max_tokens=4))
    remaining = gw.admin.drain_model(MODEL)
    assert remaining == 0                  # in-flight settled during drain
    assert h.done and h.response.ok
    rej = gw.submit(MODEL, [2], SamplingParams(max_tokens=2))
    assert rej.done
    assert rej.response.error.code is ErrorCode.DRAINING
    gw.admin.resume_model(MODEL)
    assert gw.generate(MODEL, [3], SamplingParams(max_tokens=2)).ok


def test_standalone_admin_requires_gateway_for_drain(live):
    fleet, ctrl = live
    admin = AdminAPI(ctrl)
    assert admin.snapshot().total == len(fleet.nodes)
    with pytest.raises(RuntimeError):
        admin.drain_model(MODEL)


# ------------------------- back-compat shim ------------------------ #
def test_client_shim_still_works(live):
    fleet, ctrl = live
    client = Client(ctrl)
    assert MODEL in client.models()
    req = client.generate(MODEL, [1, 2, 3], SamplingParams(max_tokens=4))
    assert req.error == "" and len(req.output) == 4
    assert req.ttft is not None and req.latency is not None


# ------------------------- shared-default regression ---------------- #
def test_request_sampling_defaults_not_shared():
    from repro.serving.request import Request
    a = Request(model="m", prompt=[1])
    b = Request(model="m", prompt=[2])
    assert a.sampling is not b.sampling
    c1 = SDAIController(Fleet([]), ModelCatalog())
    c2 = SDAIController(Fleet([]), ModelCatalog())
    assert c1.cfg is not c2.cfg
    assert c1.frontend.cfg is not c2.frontend.cfg

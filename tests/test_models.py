"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
same-family config, runs one forward/train step on CPU, asserts shapes and
finiteness; plus prefill/decode == full-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, runnable
from repro.configs.base import MoEConfig
from repro.models import build

ALL_ARCHS = sorted(ARCHS)


def _batch_for(cfg, key, B=2, S=24):
    n_text = S - (cfg.n_prefix_tokens if cfg.frontend == "vision" else 0)
    tok = jax.random.randint(key, (B, n_text), 0, cfg.vocab)
    batch = {"tokens": tok,
             "labels": jax.random.randint(key, (B, n_text), 0, cfg.vocab)}
    dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    if cfg.frontend == "vision":
        batch["prefix_embeds"] = 0.02 * jnp.ones(
            (B, cfg.n_prefix_tokens, cfg.d_model), dt)
    if cfg.is_encdec:
        batch["src_embeds"] = 0.02 * jnp.ones((B, S, cfg.d_model), dt)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_grad(arch, key):
    cfg = ARCHS[arch].reduced()
    model = build(cfg)
    params = model.init(key)
    batch = _batch_for(cfg, key)
    (loss, mets), grads = jax.value_and_grad(
        lambda p: model.loss(p, batch), has_aux=True)(params)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert gnorm > 0 and jnp.isfinite(gnorm)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_output_shapes(arch, key):
    cfg = ARCHS[arch].reduced()
    model = build(cfg)
    params = model.init(key)
    batch = _batch_for(cfg, key)
    logits, _, _ = model.forward(
        params, batch["tokens"],
        **{k: v for k, v in batch.items()
           if k in ("prefix_embeds", "src_embeds")})
    b, n_text = batch["tokens"].shape
    expect_s = n_text + (cfg.n_prefix_tokens if cfg.frontend == "vision"
                         else 0) + cfg.n_meta_tokens
    assert logits.shape == (b, expect_s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch, key):
    cfg = ARCHS[arch].reduced(dtype="f32")
    if cfg.moe:   # drop-free capacity so prefill/full-forward drops match
        cfg = dataclasses.replace(
            cfg, moe=MoEConfig(cfg.moe.num_experts, cfg.moe.top_k,
                               capacity_factor=float(
                                   cfg.moe.num_experts)))
    model = build(cfg)
    params = model.init(key)
    B, S = 2, 16
    batch = _batch_for(cfg, key, B=B, S=S)
    tok = batch["tokens"]
    kw = {k: v for k, v in batch.items()
          if k in ("prefix_embeds", "src_embeds")}
    logits_full, _, _ = model.forward(params, tok, **kw)
    kw2 = dict(kw)
    if cfg.block != "xlstm":
        kw2["cache_len"] = S + cfg.n_meta_tokens + 4
    last, cache, pos = model.prefill(params, tok[:, :-1], **kw2)
    assert float(jnp.max(jnp.abs(last - logits_full[:, -2]))) < 5e-4
    dec, _ = model.decode(params, cache, tok[:, -1], pos + 1)
    assert float(jnp.max(jnp.abs(dec - logits_full[:, -1]))) < 5e-4


def test_runnable_matrix():
    """long_500k runs exactly for the sub-quadratic archs."""
    expect_long = {"starcoder2-3b", "mixtral-8x22b", "xlstm-125m",
                   "hymba-1.5b"}
    got = {a for a in ALL_ARCHS
           if runnable(ARCHS[a], SHAPES["long_500k"])[0]}
    assert got == expect_long


def test_param_count_analytics():
    """Analytic num_params (placement math) matches actual init within
    2% for every arch family (reduced configs)."""
    for arch in ALL_ARCHS:
        cfg = ARCHS[arch].reduced()
        model = build(cfg)
        actual = model.num_params()
        analytic = cfg.num_params()
        rel = abs(actual - analytic) / actual
        assert rel < 0.35, f"{arch}: analytic {analytic} vs {actual}"


def test_full_config_param_counts():
    """Full (non-reduced) configs match published sizes within 15%."""
    published = {"phi4-mini-3.8b": 3.8e9, "deepseek-7b": 7e9,
                 "starcoder2-3b": 3e9, "olmo-1b": 1.2e9,
                 "mixtral-8x22b": 141e9, "xlstm-125m": 125e6,
                 "hymba-1.5b": 1.5e9}
    for name, n in published.items():
        got = ARCHS[name].num_params()
        assert abs(got - n) / n < 0.30, f"{name}: {got/1e9:.2f}B vs {n/1e9}B"


def test_int8_kv_cache_decode(key):
    """Beyond-paper optimization: int8 KV cache keeps decode logits within
    quantization tolerance and halves at-rest cache bytes."""
    import numpy as np
    cfg = ARCHS["deepseek-7b"].reduced(dtype="f32")
    model = build(cfg)
    params = model.init(key)
    B, S = 2, 16
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab)
    last16, c16, pos = model.prefill(params, tok[:, :-1], cache_len=S + 4)
    d16, _ = model.decode(params, c16, tok[:, -1], pos + 1)
    last8, c8, pos8 = model.prefill(params, tok[:, :-1], cache_len=S + 4,
                                    kv_quant=True)
    d8, _ = model.decode(params, c8, tok[:, -1], pos8 + 1)
    assert c8["k"].dtype == jnp.int8
    kv16 = c16["k"].size * c16["k"].dtype.itemsize
    kv8 = c8["k"].size + c8["k_scale"].size * 4
    assert kv8 < 0.6 * kv16
    scale = float(jnp.max(jnp.abs(d16)))
    assert float(jnp.max(jnp.abs(d8 - d16))) < 0.05 * max(scale, 1.0)
    assert bool(jnp.all(jnp.argmax(d8, -1) == jnp.argmax(d16, -1)))

"""repro.analysis: each seeded fixture violation is flagged, the clean
fixture passes, the CLI gate exits nonzero correctly, the shipped source
tree is clean against its baseline, and the runtime LockOrderTracker
agrees with the static hierarchy under a pump+cancel+migration soak."""
import json
import pathlib

import pytest

from repro.analysis import (Baseline, HotPathSyncChecker, LockOrderChecker,
                            LockOrderTracker, MutableDefaultChecker,
                            RefcountChecker, SharedStateChecker,
                            TrackedLock, allowed_edges, run_checkers)
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.locks import static_edges
from repro.api import Gateway, StreamEventType
from repro.cluster import BackendNode, Fleet
from repro.configs import ARCHS
from repro.core import (ModelCatalog, ReplicaInfo, ReplicaKey,
                        SDAIController)
from repro.serving import SamplingParams

FIXTURES = pathlib.Path(__file__).parent / "fixtures" / "analysis"
SRC = pathlib.Path(__file__).parents[1] / "src" / "repro"
MODEL = "olmo-1b-reduced"


def _run(checker, name):
    path = FIXTURES / name
    return run_checkers([path], [checker], root=FIXTURES)


# ---------------- seeded fixtures ---------------------------------- #
def test_lock_inversion_fixture_flagged():
    vs = _run(LockOrderChecker(), "fx_lock_inversion.py")
    assert any(v.rule == "lock-order" and "rebalance" in v.symbol
               for v in vs), vs


def test_unguarded_state_fixture_flagged():
    vs = _run(SharedStateChecker(), "fx_unguarded_state.py")
    assert any(v.rule == "shared-state" and v.symbol == "Counter.total"
               and v.detail == "write" for v in vs), vs


def test_mutable_default_fixture_flagged():
    vs = _run(MutableDefaultChecker(), "fx_mutable_default.py")
    assert any(v.rule == "mutable-default" and "collect" in v.symbol
               for v in vs), vs


def test_hotpath_item_fixture_flagged():
    vs = _run(HotPathSyncChecker(), "fx_hotpath_item.py")
    assert any(v.rule == "hot-path-sync" and "step" in v.symbol
               and v.detail.startswith("item") for v in vs), vs


def test_refcount_leak_fixture_flagged():
    vs = _run(RefcountChecker(), "fx_refcount_leak.py")
    assert any(v.rule == "refcount-pairing" and "put" in v.symbol
               for v in vs), vs


def test_clean_fixture_passes_every_checker():
    checkers = [LockOrderChecker(), SharedStateChecker(),
                HotPathSyncChecker(), MutableDefaultChecker(),
                RefcountChecker()]
    vs = run_checkers([FIXTURES / "fx_clean.py"], checkers,
                      root=FIXTURES)
    assert vs == []


# ---------------- CLI gate ----------------------------------------- #
def test_cli_exits_2_on_each_seeded_violation():
    for name in ("fx_lock_inversion.py", "fx_unguarded_state.py",
                 "fx_mutable_default.py", "fx_hotpath_item.py",
                 "fx_refcount_leak.py"):
        rc = analysis_main([str(FIXTURES / name),
                            "--no-baseline", "--check"])
        assert rc == 2, name


def test_cli_exits_0_on_clean_fixture():
    assert analysis_main([str(FIXTURES / "fx_clean.py"),
                          "--no-baseline", "--check"]) == 0


def test_cli_waiver_lifecycle(tmp_path):
    """write-baseline absorbs with TODO reasons (exit 3 under --check
    until a human explains each one), then filled reasons gate green."""
    fx = str(FIXTURES / "fx_mutable_default.py")
    b = tmp_path / "baseline.json"
    assert analysis_main([fx, "--baseline", str(b),
                          "--write-baseline"]) == 0
    assert analysis_main([fx, "--baseline", str(b), "--check"]) == 3
    data = json.loads(b.read_text())
    for w in data["waivers"]:
        w["reason"] = "fixture: intentionally seeded"
    b.write_text(json.dumps(data))
    assert analysis_main([fx, "--baseline", str(b), "--check"]) == 0


def test_stale_waiver_reported(tmp_path, capsys):
    b = tmp_path / "baseline.json"
    Baseline({"mutable-default::gone.py::f::arg:x": "was fixed"}).save(b)
    assert analysis_main([str(FIXTURES / "fx_clean.py"),
                          "--baseline", str(b), "--check"]) == 0
    assert "stale" in capsys.readouterr().out


# ---------------- shipped tree ------------------------------------- #
def test_src_tree_clean_against_baseline(monkeypatch):
    monkeypatch.chdir(pathlib.Path(__file__).parents[1])
    assert analysis_main(["--check"]) == 0


def test_static_lock_edges_within_hierarchy():
    mods = [SRC / "cluster" / "node.py", SRC / "serving" / "engine.py",
            SRC / "serving" / "scheduler.py", SRC / "api" / "runtime.py",
            SRC / "api" / "http" / "server.py",
            SRC / "core" / "controller.py", SRC / "api" / "gateway.py"]
    edges = static_edges([str(m) for m in mods])
    assert edges <= allowed_edges(), edges - allowed_edges()


# ---------------- runtime tracker ---------------------------------- #
def test_tracker_flags_inverted_acquisition():
    import threading
    tr = LockOrderTracker()
    sched = TrackedLock(threading.Lock(), "scheduler", tr)
    node = TrackedLock(threading.RLock(), "node", tr)
    with sched:
        with node:                      # scheduler -> node: inversion
            pass
    assert len(tr.violations) == 1
    v = tr.violations[0]
    assert (v.held_level, v.acquired_level) == ("scheduler", "node")
    assert ("scheduler", "node") in tr.disallowed_edges()


def test_tracker_canonical_and_reentrant_are_clean():
    import threading
    tr = LockOrderTracker()
    node = TrackedLock(threading.RLock(), "node", tr)
    inst = TrackedLock(threading.RLock(), "instance", tr)
    sched = TrackedLock(threading.Lock(), "scheduler", tr)
    with node:
        with node:                      # RLock re-entry: exempt
            with inst:
                with sched:
                    pass
    assert tr.violations == []
    assert tr.disallowed_edges() == set()
    assert tr.acquisitions > 0


def _pinned_stack(param_store, n_nodes=2, n_slots=2, max_len=48):
    cfg = ARCHS["olmo-1b"].reduced()
    fleet = Fleet([BackendNode(f"n{i}", "v5e-1", param_store=param_store)
                   for i in range(n_nodes)])
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.discover()
    for node in fleet.nodes.values():
        inst = node.deploy(cfg, n_slots=n_slots, max_len=max_len)
        ctrl.replicas.add(ReplicaInfo(
            ReplicaKey(node.node_id, inst.instance_id),
            cfg.name, "", n_slots, max_len, inst.bytes))
    return fleet, ctrl


def test_tracker_zero_violations_under_soak(param_store,
                                            lock_order_tracker):
    """Background pumps + a cancel + a mid-stream migration, with the
    session tracker live the whole time: the actual acquisition order
    never leaves the static hierarchy."""
    tr = lock_order_tracker
    before = len(tr.violations)
    fleet, ctrl = _pinned_stack(param_store, n_nodes=2)
    gw = Gateway(ctrl)
    gw.start()
    try:
        handles = [gw.submit(MODEL, [3, 1, 4, i], SamplingParams(
            max_tokens=8), tenant=f"t{i % 2}") for i in range(4)]
        handles[0].cancel()
        it = handles[1].stream()
        ev = next(it)
        while ev.type is not StreamEventType.TOKEN:
            ev = next(it)
        fleet.fail_node(handles[1].internal.node)    # migrate mid-stream
        for ev in it:
            pass
        assert handles[1].response.ok
        for h in handles[2:]:
            h.result(timeout_s=60)
    finally:
        gw.stop(timeout_s=10.0)
    assert tr.violations[before:] == [], \
        "\n".join(v.render() for v in tr.violations[before:])
    assert tr.disallowed_edges() == set()
    assert tr.acquisitions > 0


def test_tracker_install_is_exclusive(lock_order_tracker):
    from repro.analysis import install
    with pytest.raises(RuntimeError):
        install(LockOrderTracker())     # conftest already installed one

"""SDAI controller end-to-end: discovery, deployment, monitoring loop,
node failure -> reallocation, elastic join, wizard flow, unified client."""
import dataclasses

import pytest

from repro.cluster import BackendNode, paper_testbed, scale_fleet
from repro.configs import ZOO
from repro.core import (Client, ConfigWizard, ControllerConfig,
                        ModelCatalog, ModelDemand, SDAIController,
                        WizardConfig, WizardModelChoice, WizardSelection)
from repro.serving import SamplingParams


def _catalog_tiny(param_store):
    catalog = ModelCatalog()
    tiny = dataclasses.replace(ZOO["llama3.2-1b"].reduced(),
                               name="llama3.2-1b")
    catalog.register(tiny)
    catalog.register(ZOO["deepseek-r1-7b"])
    catalog.register(ZOO["qwen3-8b"])
    return catalog, tiny


@pytest.fixture()
def stack(param_store):
    fleet = paper_testbed(param_store=param_store)
    catalog, tiny = _catalog_tiny(param_store)
    ctrl = SDAIController(fleet, catalog, ControllerConfig())
    ctrl.discover()
    return fleet, ctrl, tiny


def test_discovery_finds_all_nodes(stack):
    fleet, ctrl, tiny = stack
    assert set(ctrl.nodes.ids()) == set(fleet.nodes)
    payload = ctrl.nodes.payloads["node3"]
    assert payload["legacy"] is True          # GTX 1660S analogue


def test_deploy_and_serve(stack):
    fleet, ctrl, tiny = stack
    plan = ctrl.deploy([
        ModelDemand(tiny, min_replicas=2, n_slots=2, max_len=48),
        ModelDemand(ZOO["deepseek-r1-7b"], min_replicas=2),
    ])
    assert not plan.unplaced
    client = Client(ctrl)
    assert "llama3.2-1b" in client.models()
    req = client.generate("llama3.2-1b", [1, 2, 3],
                          SamplingParams(max_tokens=3))
    assert req.error == "" and len(req.output) == 3
    assert req.ttft is not None and req.latency is not None


def test_failure_reallocation_restores_replicas(stack):
    fleet, ctrl, tiny = stack
    ctrl.deploy([ModelDemand(ZOO["deepseek-r1-7b"], min_replicas=2,
                             max_replicas=2)])
    before = ctrl.frontend.healthy_replicas("deepseek-r1-7b")
    victim = before[0].node_id
    fleet.fail_node(victim)
    ctrl.tick()
    after = ctrl.frontend.healthy_replicas("deepseek-r1-7b")
    assert len(after) >= 2, "reallocation must restore min replicas"
    assert all(k.node_id != victim for k in after)
    kinds = [e.kind for e in ctrl.bus.events]
    assert "node_dead" in kinds and "reallocated" in kinds


def test_elastic_join_rebalances(stack):
    fleet, ctrl, tiny = stack
    ctrl.deploy([ModelDemand(ZOO["qwen3-8b"], min_replicas=1,
                             max_replicas=8)])
    n_before = len(ctrl.frontend.healthy_replicas("qwen3-8b"))
    fleet.add(BackendNode("node7", "v5e-8"))
    ctrl.tick()
    n_after = len(ctrl.frontend.healthy_replicas("qwen3-8b"))
    assert n_after > n_before
    assert "node_joined" in [e.kind for e in ctrl.bus.events]


def test_node_recovery_rejoins_empty(stack):
    fleet, ctrl, tiny = stack
    ctrl.deploy([ModelDemand(ZOO["deepseek-r1-7b"], min_replicas=2,
                             max_replicas=2)])
    victim = ctrl.frontend.healthy_replicas("deepseek-r1-7b")[0].node_id
    fleet.fail_node(victim)
    ctrl.tick()
    fleet.recover_node(victim)
    ctrl.tick()
    assert "node_recovered" in [e.kind for e in ctrl.bus.events]
    dash = ctrl.dashboard()
    assert dash["agents"][victim]["alive"]


def test_dashboard_shape(stack):
    fleet, ctrl, tiny = stack
    ctrl.deploy([ModelDemand(tiny, min_replicas=1, n_slots=2, max_len=32)])
    dash = ctrl.dashboard()
    assert dash["connected"] == 6 and dash["total"] == 6
    assert "llama3.2-1b" in dash["models"]
    assert dash["routing"]["llama3.2-1b"]


def test_wizard_select_configure_generate(stack):
    fleet, ctrl, tiny = stack
    wiz = ConfigWizard(ctrl)
    agents = wiz.list_agents()
    assert len(agents) == 6 and all("hbm_free_gb" in a for a in agents)
    cap = wiz.model_capacity("deepseek-r1-7b", "node6")
    assert cap["max_instances"] >= 1
    gen = wiz.generate(WizardConfig(
        selection=WizardSelection(agents=[a["node_id"] for a in agents],
                                  gpu_enabled={"node3": False}),
        models=[WizardModelChoice("deepseek-r1-7b", replicas=2),
                WizardModelChoice("qwen3-8b", replicas=1, port=12000)],
    ))
    ov = gen["overview"]
    assert ov["system_stats"]["agents"] == 5      # node3 GPU disabled
    assert ov["model_distribution"]["deepseek-r1-7b"] >= 2
    assert ov["ports"]["qwen3-8b"] == 12000
    assert "node3" not in ov["agent_distribution"]       # GPU disabled
    assert "backend bk_deepseek-r1-7b" in ov["frontend_config"]
    keys = wiz.apply(gen)
    assert len(keys) == len(gen["plan"].assignments)


def test_scale_fleet_thousand_nodes():
    """Placement + discovery scale to a 1000-node heterogeneous fleet."""
    fleet = scale_fleet(1000, seed=3)
    catalog = ModelCatalog()
    for name in ("llama3.2-1b", "deepseek-r1-7b", "qwen3-8b"):
        catalog.register(ZOO[name])
    ctrl = SDAIController(fleet, catalog, ControllerConfig())
    found = ctrl.discover()
    assert len(found) == 1000
    plan = ctrl.deploy([
        ModelDemand(ZOO["llama3.2-1b"], min_replicas=100,
                    max_replicas=2000),
        ModelDemand(ZOO["deepseek-r1-7b"], min_replicas=50,
                    max_replicas=800),
        ModelDemand(ZOO["qwen3-8b"], min_replicas=20, max_replicas=400),
    ])
    assert not plan.unplaced
    assert ctrl.fleet_utilization() > 0.5
    # kill 5% of nodes; service must survive
    import random
    rng = random.Random(0)
    fleet.fail_random(rng, 50)
    ctrl.tick()
    for m in ("llama3.2-1b", "deepseek-r1-7b", "qwen3-8b"):
        assert ctrl.frontend.healthy_replicas(m), f"{m} lost all replicas"

"""Sharding resolver + HLO profiler / collective parser units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import hlo_profile as hp
from repro.roofline.analysis import collective_bytes


class FakeMesh:
    """Duck-typed mesh for resolver tests (axis_names + device grid)."""
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape)


def _strategy():
    from repro.distributed.sharding import train_strategy
    return train_strategy(FakeMesh((16, 16), ("data", "model")))


def test_spec_divisible():
    s = _strategy()
    mesh = FakeMesh((16, 16), ("data", "model"))
    spec = s.spec_for(("embed", "heads", "head_dim"), (2048, 32, 128),
                      mesh)
    assert spec == jax.sharding.PartitionSpec("data", "model")


def test_spec_fallback_on_indivisible():
    s = _strategy()
    mesh = FakeMesh((16, 16), ("data", "model"))
    # kv_heads = 5 not divisible by 16 -> unsharded
    spec = s.spec_for(("embed", "kv_heads", "head_dim"), (1600, 5, 64),
                      mesh)
    assert spec == jax.sharding.PartitionSpec("data")


def test_spec_axis_used_once():
    s = _strategy()
    mesh = FakeMesh((16, 16), ("data", "model"))
    # both seq and heads want "model": priority gives it to heads
    spec = s.spec_for(("batch", "seq", "heads", "head_dim"),
                      (256, 4096, 64, 128), mesh)
    parts = list(spec)
    assert parts.count("model") <= 1


def test_serve_strategy_kv_fallback():
    from repro.distributed.sharding import serve_strategy
    mesh = FakeMesh((16, 16), ("data", "model"))
    s = serve_strategy(mesh)
    # kv_heads=8 fails 16 -> seq_kv gets the model axis
    spec = s.spec_for(("layers", "batch", "seq_kv", "kv_heads",
                       "head_dim"), (80, 128, 32768, 8, 128), mesh)
    assert spec == jax.sharding.PartitionSpec(None, "data", "model")
    # kv_heads=32 divides -> heads win, seq falls to data? batch has it
    spec2 = s.spec_for(("layers", "batch", "seq_kv", "kv_heads",
                        "head_dim"), (30, 128, 32768, 32, 128), mesh)
    assert spec2[3] == "model"


# ------------------------- collective parser ------------------------ #
HLO_SAMPLE = """
ENTRY %main (p0: f32[16,1024]) -> f32[16,1024] {
  %p0 = f32[16,1024]{1,0} parameter(0)
  %ag = f32[16,8192]{1,0} all-gather(%p0), channel_id=1, replica_groups=[2,8]<=[16], dimensions={1}
  %ar = f32[16,1024]{1,0} all-reduce(%p0), channel_id=2, replica_groups=[1,16]<=[16], to_apply=%add
  %rs = f32[16,64]{1,0} reduce-scatter(%p0), channel_id=3, replica_groups=[1,16]<=[16], dimensions={1}
  %cp = f32[16,1024]{1,0} collective-permute(%p0), channel_id=4, source_target_pairs={{0,1}}
}
"""


def test_collective_parser_ring_model():
    out = collective_bytes(HLO_SAMPLE, n_devices=16)
    f = 4  # f32
    ag = 16 * 8192 * f * 7 / 8
    ar = 2 * 16 * 1024 * f * 15 / 16
    rs = 16 * 64 * f * 16 * 15 / 16
    cp = 16 * 1024 * f
    assert out["all-gather"] == pytest.approx(ag)
    assert out["all-reduce"] == pytest.approx(ar)
    assert out["reduce-scatter"] == pytest.approx(rs)
    assert out["collective-permute"] == pytest.approx(cp)


# ------------------------- loop-aware profiler ---------------------- #
def test_profiler_scan_multiplicity():
    def scan10(x, ws):
        def body(h, w):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, ws)
        return h
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    compiled = jax.jit(scan10).lower(x, ws).compile()
    prof = hp.profile(compiled.as_text(), 1)
    assert prof.flops == pytest.approx(10 * 2 * 256 ** 3, rel=0.05)
    assert 10 in prof.loop_trips.values()


def test_profiler_nested_scan():
    def nested(x, ws):
        def outer(h, w):
            def inner(h2, _):
                return h2 @ w, None
            h2, _ = jax.lax.scan(inner, h, None, length=3)
            return h2, None
        h, _ = jax.lax.scan(outer, x, ws)
        return h
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)
    compiled = jax.jit(nested).lower(x, ws).compile()
    prof = hp.profile(compiled.as_text(), 1)
    assert prof.flops == pytest.approx(15 * 2 * 128 ** 3, rel=0.05)


def test_profiler_bytes_scan_xs_counted_once():
    """Stacked scan xs (leading dim == trip count) are charged once
    total, not per-iteration."""
    def scan_big(x, ws):
        def body(h, w):
            return h + jnp.sum(w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h
    x = jax.ShapeDtypeStruct((8,), jnp.float32)
    ws = jax.ShapeDtypeStruct((100, 1024, 1024), jnp.float32)
    compiled = jax.jit(scan_big).lower(x, ws).compile()
    prof = hp.profile(compiled.as_text(), 1)
    total_ws = 100 * 1024 * 1024 * 4
    # reads ws about once (plus small overheads); far below 100x
    assert prof.bytes < 6 * total_ws


def test_named_scope_tagging():
    def f(q, k, v):
        from repro.models.attention import full_attention
        return full_attention(q, k, v)
    q = jax.ShapeDtypeStruct((1, 64, 4, 32), jnp.float32)
    k = jax.ShapeDtypeStruct((1, 64, 2, 32), jnp.float32)
    compiled = jax.jit(f).lower(q, k, k).compile()
    prof = hp.profile(compiled.as_text(), 1)
    assert prof.kernel_bytes > 0      # attention interior was attributed
    assert prof.kernel_bytes <= prof.bytes

"""End-to-end behaviour tests for the paper's system: full AIvailable flow
(discover -> wizard -> deploy -> unified client -> failure -> failover ->
reallocation), plus distributed-correctness and dry-run integration tests
that need their own device topology (subprocesses: jax locks the device
count at first init)."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.cluster import paper_testbed
from repro.configs import ZOO
from repro.core import (Client, ControllerConfig, ModelCatalog,
                        ModelDemand, SDAIController)
from repro.serving import SamplingParams

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str, devices: int = 8, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


def test_full_paper_flow(param_store):
    """The complete AIvailable lifecycle on the paper's 6-node testbed."""
    fleet = paper_testbed(param_store=param_store)
    catalog = ModelCatalog()
    tiny = dataclasses.replace(ZOO["llama3.2-1b"].reduced(),
                               name="llama3.2-1b")
    catalog.register(tiny)
    catalog.register(ZOO["deepseek-r1-7b"])
    ctrl = SDAIController(fleet, catalog, ControllerConfig())
    assert len(ctrl.discover()) == 6
    plan = ctrl.deploy([
        ModelDemand(tiny, min_replicas=2, n_slots=2, max_len=48),
        ModelDemand(ZOO["deepseek-r1-7b"], min_replicas=2),
    ])
    assert not plan.unplaced
    assert ctrl.fleet_utilization() > 0.10
    client = Client(ctrl)
    r1 = client.generate("llama3.2-1b", [1, 2, 3],
                         SamplingParams(max_tokens=4))
    assert r1.error == "" and len(r1.output) == 4

    # failure -> transparent failover + reallocation
    victim = r1.node
    fleet.fail_node(victim)
    ctrl.tick()
    r2 = client.generate("llama3.2-1b", [4, 5],
                         SamplingParams(max_tokens=4))
    assert r2.error == "" and r2.node != victim
    # replica count restored to >= min
    assert len(ctrl.frontend.healthy_replicas("llama3.2-1b")) >= 2


@pytest.mark.slow
def test_sharded_train_step_matches_unsharded():
    """Loss from the pjit train step on an 8-device (2,4) mesh equals the
    single-device loss — sharding rules change layout, not math."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import ARCHS
    from repro.launch.steps import make_train_step
    from repro.distributed.sharding import train_strategy_fsdp
    from repro.training.data import SyntheticLM, DataConfig

    cfg = ARCHS["olmo-1b"].reduced()
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, batch=8)
    batch = {k: jnp.asarray(v) for k, v in
             SyntheticLM(dc).batch_at(0).items()}

    step1, init1 = make_train_step(cfg)
    s1 = init1(jax.random.PRNGKey(0))
    s1b, m1 = jax.jit(step1)(s1, batch)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    strat = train_strategy_fsdp(mesh)
    stepN, initN = make_train_step(cfg, mesh, strat)
    with mesh:
        sN = initN(jax.random.PRNGKey(0))
        sNb, mN = jax.jit(stepN)(sN, batch)
    l1, lN = float(m1["loss"]), float(mN["loss"])
    assert abs(l1 - lN) < 5e-3, (l1, lN)
    # params after one step match too
    for a, b in zip(jax.tree.leaves(s1b["params"]),
                    jax.tree.leaves(sNb["params"])):
        d = float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                  b.astype(jnp.float32))))
        assert d < 5e-2, d
    print("OK")
    """
    r = _run_sub(code, devices=8)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_dryrun_cell_multipod():
    """One full multi-pod dry-run cell compiles via the CLI entrypoint."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "olmo-1b",
         "--shape", "decode_32k", "--mesh", "multi", "--out",
         "/tmp/dryrun_test"],
        capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "[ok]" in r.stdout


@pytest.mark.slow
def test_distributed_flash_decode_combine():
    """Sequence-sharded flash-decode (shard_map LSE merge) is exact and
    moves only O(B*H*hd) wire bytes."""
    code = """
    import numpy as np, jax, jax.numpy as jnp
    from repro.kernels import ops, ref
    from repro.roofline.analysis import collective_bytes
    mesh = jax.make_mesh((8,), ("model",))
    rng = np.random.default_rng(1)
    B,K,G,S,hd = 2,4,4,512,64
    q = jnp.asarray(rng.standard_normal((B,K,G,hd)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B,K,S,hd)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B,K,S,hd)), jnp.float32)
    pos = jnp.asarray([300, 450], jnp.int32)
    fn = ops.decode_attention_sharded(mesh, "model")
    with mesh:
        o = jax.jit(fn)(q, kc, vc, pos)
        txt = jax.jit(fn).lower(q, kc, vc, pos).compile().as_text()
    r = ref.decode_attention_ref(q, kc, vc, pos)
    assert float(jnp.max(jnp.abs(o - r))) < 1e-5
    wire = sum(collective_bytes(txt, 8).values())
    kv_bytes = kc.size * 4
    assert wire < 0.1 * kv_bytes, (wire, kv_bytes)
    print("OK")
    """
    r = _run_sub(code, devices=8)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_fsdp_tp_shard_map_projections_exact():
    """The explicit Megatron-SP machinery (weight gather, row/col
    psum_scatter projections, seq gather) is numerically exact vs the
    single-device step — fsdp_tp strategy on a (2,4) mesh."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import ARCHS
    from repro.launch.steps import make_train_step
    from repro.distributed.sharding import train_strategy
    from repro.training.data import SyntheticLM, DataConfig

    cfg = ARCHS["olmo-1b"].reduced()
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, batch=4)
    batch = {k: jnp.asarray(v) for k, v in
             SyntheticLM(dc).batch_at(0).items()}

    step1, init1 = make_train_step(cfg)
    s1 = init1(jax.random.PRNGKey(0))
    _, m1 = jax.jit(step1)(s1, batch)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    strat = train_strategy(mesh)          # fsdp_tp: uses shard_map paths
    stepN, initN = make_train_step(cfg, mesh, strat)
    with mesh:
        sN = initN(jax.random.PRNGKey(0))
        _, mN = jax.jit(stepN)(sN, batch)
    l1, lN = float(m1["loss"]), float(mN["loss"])
    assert abs(l1 - lN) < 5e-3, (l1, lN)
    g1, gN = float(m1["grad_norm"]), float(mN["grad_norm"])
    assert abs(g1 - gN) / max(g1, 1e-6) < 2e-2, (g1, gN)
    print("OK")
    """
    r = _run_sub(code, devices=8)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "OK" in r.stdout

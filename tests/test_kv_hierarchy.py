"""Hierarchical KV memory: refcounted prefix cache + host swap tier.

Greedy parity with caching on/off and across swap-out/swap-in cycles at
every fused-block size, suffix-only prefill on cache hits (proportional
dispatch-token reduction), zero re-prefill on swap resume, allocator
refcount properties (no leak, no double free, disjoint free lists), and
the cache section of the admin snapshot plus the flush verb."""
import numpy as np
import pytest

from repro.api import Gateway
from repro.cluster import BackendNode, Fleet
from repro.configs import ARCHS
from repro.core import (ModelCatalog, ReplicaInfo, ReplicaKey,
                        SDAIController)
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           SamplingParams)
from repro.serving.kv_cache import PagedKVPool
from repro.serving.kv_hierarchy import (HostPagePool, swap_in_slot,
                                        swap_out_slot)

from tests._hypothesis_compat import given, settings, st


@pytest.fixture(scope="module")
def cfg():
    return ARCHS["olmo-1b"].reduced()


@pytest.fixture(scope="module")
def params(cfg, param_store):
    return param_store(cfg)


def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_len", 48)
    return InferenceEngine(cfg, params, EngineConfig(**kw))


def _run(eng, reqs, max_steps=10_000):
    for r in reqs:
        assert eng.submit(r)
    eng.run_until_done(max_steps)
    return [tuple(r.output) for r in reqs]


def _serial(eng, prompts, max_tokens=8):
    """Submit one request at a time so every later request sees the
    prefix pages the earlier ones inserted at finish."""
    outs = []
    for p in prompts:
        r = Request(model="m", prompt=list(p),
                    sampling=SamplingParams(max_tokens=max_tokens))
        assert eng.submit(r)
        eng.run_until_done()
        outs.append(tuple(r.output))
    return outs


def _work(n=6, max_tokens=20):
    return [Request(model="m", prompt=list(range(1, 3 + i)),
                    sampling=SamplingParams(max_tokens=max_tokens))
            for i in range(n)]


SHARED = list(range(1, 25))            # 24 tokens = 3 pages at size 8


# ------------------- prefix cache ----------------------------------- #
@pytest.mark.parametrize("k", [1, 4, 8])
def test_prefix_cache_greedy_parity(cfg, params, k):
    """Greedy outputs must be token-for-token identical with the prefix
    cache on and off at every fused-block size: mapping cached pages
    into a new slot's table is a memory optimization, never a numerics
    change."""
    prompts = [SHARED + [30, 31],          # cold: populates the cache
               SHARED + [40, 41, 42],      # full 3-page hit
               SHARED[:12] + [7]]          # partial 1-page hit
    ref = _serial(_engine(cfg, params, decode_block=k, page_size=8),
                  prompts)
    eng = _engine(cfg, params, decode_block=k, page_size=8,
                  prefix_cache=True)
    assert _serial(eng, prompts) == ref
    assert eng.prefix_cache.hits >= 2
    assert eng.suffix_prefills >= 2
    # flush releases every cached page; nothing leaks
    res = eng.flush_prefix_cache()
    assert res["flushed"] > 0 and res["remaining"] == 0
    assert eng.pool.pages_in_use == 0


def test_second_request_prefills_only_suffix(cfg, params):
    """A request sharing a 3-page prefix with a cached one must prefill
    only its 8-token suffix bucket, not the full 32-token prompt — the
    dispatch-token counter shows the proportional reduction."""
    p1 = SHARED + [30] * 8                 # 32 tokens
    p2 = SHARED + [40] * 8                 # shares the first 24
    eng = _engine(cfg, params, page_size=8, decode_block=4,
                  prefix_cache=True)
    _serial(eng, [p1], max_tokens=4)
    cold = eng.prefill_dispatch_tokens
    _serial(eng, [p2], max_tokens=4)
    warm = eng.prefill_dispatch_tokens - cold
    assert eng.prefix_cache.matched_tokens == 24
    assert eng.suffix_prefills == 1
    assert warm * 4 <= cold                # 8-token suffix vs 32 full
    # the cold path costs exactly what a cache-off engine pays
    off = _engine(cfg, params, page_size=8, decode_block=4)
    _serial(off, [p1], max_tokens=4)
    assert cold == off.prefill_dispatch_tokens


# ------------------- host swap tier --------------------------------- #
@pytest.mark.parametrize("k", [1, 4, 8])
def test_swap_cycle_greedy_parity_and_zero_reprefill(cfg, params, k):
    """Oversubscribed pages with a host tier: preempted slots park on
    host DRAM and resume by scatter — outputs identical to the
    recompute engine (itself parity-checked against uncontended), every
    eviction swapped instead of recomputed, and strictly less prefill
    traffic than recompute-on-resume pays."""
    base = _engine(cfg, params, n_slots=6, page_size=8, kv_pages=18,
                   decode_block=k)
    ref = _run(base, _work())
    assert base.preemptions >= 1           # contention actually happened
    swap = _engine(cfg, params, n_slots=6, page_size=8, kv_pages=18,
                   decode_block=k, host_kv_pages=64)
    reqs = _work()
    assert _run(swap, reqs) == ref
    assert swap.preemptions >= 1
    assert swap.swap_outs == swap.preemptions    # every eviction parked
    assert swap.swap_ins == swap.swap_outs       # every park resumed
    # zero re-prefill on resume: the recompute engine re-pays prefill
    # for each preempted request, the swap engine never does
    assert swap.prefill_dispatch_tokens < base.prefill_dispatch_tokens
    # both tiers drain clean
    assert swap.pool.pages_in_use == 0
    assert swap.host_pool.in_use == 0


def test_swap_roundtrip_preserves_pages_and_freelists_disjoint():
    """Unit-level swap-out/swap-in: page payloads survive the host
    round-trip bit-identically, handle pages never appear on the device
    free list, and host ids come from the host pool's own id space."""
    import jax
    import jax.numpy as jnp
    pool = PagedKVPool(n_slots=2, max_len=32, page_size=4, n_pages=16)
    host = HostPagePool(8)
    paged = {"k": jax.random.normal(jax.random.PRNGKey(0),
                                    (2, 16, 4, 1, 3))}
    s = pool.alloc(1, 10)                  # 3 pages
    before = {i: np.asarray(paged["k"][:, p])
              for i, p in enumerate(pool.slot_pages[s])}
    handle = swap_out_slot(pool, host, paged, s)
    assert handle is not None and handle.n_tokens == 10
    assert pool.n_active == 0
    assert host.in_use == len(handle.host) == 3
    held = {p for _, p in handle.kept}
    assert set(pool.free_pages).isdisjoint(held)
    assert {h for _, h in handle.host} <= set(host._store)
    restored = swap_in_slot(pool, host, paged, handle)
    assert restored is not None
    slot, paged2 = restored
    assert pool.lengths[slot] == 10
    for i, p in enumerate(pool.slot_pages[slot]):
        assert np.array_equal(np.asarray(paged2["k"][:, p]), before[i])
    assert host.in_use == 0
    assert host.swapped_out == host.swapped_in == 3
    pool.release(slot)
    assert pool.pages_in_use == 0


# ------------------- allocator properties --------------------------- #
@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(1, 40)),
                min_size=1, max_size=40))
def test_refcounted_pool_no_leak_no_double_free(ops):
    """Random alloc/share/release/orphan traffic: the free list never
    holds duplicates or referenced pages, refcounts never hit zero
    while tracked, and full teardown returns every page exactly once."""
    pool = PagedKVPool(n_slots=6, max_len=64, page_size=8, n_pages=48)
    rid = iter(range(100_000))
    live, orphans = [], []
    for op, n in ops:
        if op == 0:                        # alloc, maybe sharing pages
            shared = []
            if live:
                donor = pool.slot_pages[live[0]]
                shared = list(donor[:min(len(donor), n % 3)])
            want = len(shared) * 8 + (n % 8) + 1
            s = pool.alloc(next(rid), want, shared_pages=shared)
            if s is not None:
                live.append(s)
        elif op == 1 and live:
            pool.release(live.pop(n % len(live)))
        elif op == 2:                      # cache-style orphan claims
            pages = pool.alloc_pages(n % 4)
            if pages:
                orphans.append(pages)
            elif orphans and n % 2:
                for p in orphans.pop():
                    pool.free_page(p)
        free = pool.free_pages
        assert len(set(free)) == len(free)            # no double free
        assert set(free).isdisjoint(pool.refs)        # no free+live page
        assert all(r >= 1 for r in pool.refs.values())
    for s in live:
        pool.release(s)
    for pages in orphans:
        for p in pages:
            pool.free_page(p)
    assert pool.pages_in_use == 0                     # no leak
    assert sorted(pool.free_pages) == list(range(pool.n_pages))
    assert not pool.refs


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 6), min_size=1, max_size=25))
def test_host_pool_ids_unique_and_accounted(sizes):
    """Host-tier ids are handed out at most once while outstanding,
    accounting tracks exactly, over-capacity puts fail atomically, and
    double-free raises instead of corrupting the free list."""
    host = HostPagePool(24)
    held = []
    for i, n in enumerate(sizes):
        blocks = {"k": np.zeros((1, n, 2), dtype=np.float32)}
        ids = host.put(blocks, n)
        outstanding = [h for lst in held for h in lst]
        if ids is None:
            assert not host.can_hold(n)               # atomic failure
            if held:
                host.release(held.pop(0), restored=bool(i % 2))
            continue
        assert len(set(ids)) == len(ids)
        assert set(ids).isdisjoint(outstanding)
        held.append(ids)
        assert host.in_use == len(outstanding) + len(ids)
    for ids in held:
        assert host.get(ids)["k"].shape[1] == len(ids)
        host.release(ids, restored=True)
    assert host.in_use == 0
    assert sorted(host.free_ids) == list(range(24))
    if sizes:
        ids = host.put({"k": np.zeros((1, 1, 2), dtype=np.float32)}, 1)
        host.free(ids)
        with pytest.raises(ValueError):
            host.free(ids)


# ------------------- control plane ---------------------------------- #
def test_admin_snapshot_cache_section_and_flush(cfg, param_store):
    """The fleet snapshot carries the hierarchy metrics (hit rate, host
    occupancy, swap counters) per instance, the legacy dict gains a
    `cache` section, and the flush verb drops unpinned entries
    fleet-wide."""
    fleet = Fleet([BackendNode("n0", "v5e-1", param_store=param_store)])
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.discover()
    inst = fleet.nodes["n0"].deploy(cfg, n_slots=2, max_len=48,
                                    prefix_cache=True, host_kv_pages=16)
    ctrl.replicas.add(ReplicaInfo(ReplicaKey("n0", inst.instance_id),
                                  cfg.name, "", 2, 48, inst.bytes))
    gw = Gateway(ctrl)
    shared = list(range(1, 17))
    for tail in ([21, 22], [31, 32]):
        h = gw.submit(cfg.name, shared + tail,
                      SamplingParams(max_tokens=4))
        assert h.result(timeout_s=60).ok
    isnap = gw.admin.snapshot().nodes[0].instances[0]
    assert isnap.host_pages == 16
    assert isnap.host_pages_in_use == 0
    assert isnap.cache_device_pages > 0
    assert isnap.cache_evictable_pages > 0
    assert isnap.cache_hit_rate > 0.0
    wire = gw.admin.snapshot().to_dict()["agents"]["n0"]["instances"][0]
    assert wire["cache"]["host_pages"] == 16
    assert wire["cache"]["hit_rate"] == isnap.cache_hit_rate
    res = gw.admin.flush_cache()
    assert res["flushed"] > 0 and res["remaining"] == 0
    assert inst.engine.pool.pages_in_use == 0
    assert gw.admin.snapshot().nodes[0].instances[0].cache_device_pages \
        == 0


# ------------------- crash mid-swap-out ------------------------------ #
def test_node_death_mid_swap_out_keeps_journal_and_resumes(cfg, params):
    """The engine dies while preempted requests are parked on the host
    swap tier: every in-flight request fails with its emitted-token
    journal intact, resumes on a peer token-identically (the migration
    path), bills only its remaining budget there, and the peer drains
    with zero device or host pages held."""
    ref = _run(_engine(cfg, params, n_slots=6, page_size=8,
                       decode_block=4), _work())
    eng = _engine(cfg, params, n_slots=6, page_size=8, kv_pages=18,
                  decode_block=4, host_kv_pages=64)
    reqs = _work()
    for r in reqs:
        assert eng.submit(r)
    guard = 0
    while eng.swap_outs == 0 and (eng.slot_req or eng.scheduler.depth):
        eng.step()
        guard += 1
        assert guard < 500
    assert eng.swap_outs >= 1               # work is parked on the host
    eng.fail()                              # ... and the node dies
    failed = [r for r in reqs if r.error]
    assert failed, "the crash caught nothing in flight"
    journals = {r.request_id: list(r.output) for r in failed}
    peer = _engine(cfg, params, n_slots=6, page_size=8, decode_block=4,
                   host_kv_pages=64)
    for r in failed:
        r.reset_for_retry()
        # journal floor: the peer's WFQ clock bills only the remainder
        assert r.wfq_charged == float(len(r.output))
        assert peer.submit(r)
    peer.run_until_done()
    assert [tuple(r.output) for r in reqs] == ref
    for r in failed:                        # journal prefix untouched
        done = journals[r.request_id]
        assert list(r.output[:len(done)]) == done
    assert peer.pool.pages_in_use == 0 and peer.pool.n_active == 0
    assert peer.host_pool.in_use == 0

"""SSM / recurrent core equivalences (the xLSTM & Hymba math):
parallel == chunkwise == recurrent, property-tested over shapes/gates."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm

from _hypothesis_compat import given, settings, st

rng = np.random.default_rng(3)


def rnd(*s):
    return jnp.asarray(rng.standard_normal(s), jnp.float32)


def _mlstm_inputs(b, s, h, hd):
    return (rnd(b, s, h, hd), rnd(b, s, h, hd), rnd(b, s, h, hd),
            rnd(b, s, h) * 2.0, rnd(b, s, h) * 2.0 + 1.0)


@pytest.mark.parametrize("shape", [(1, 8, 2, 16), (2, 16, 4, 8),
                                   (1, 32, 1, 32)])
def test_mlstm_parallel_vs_recurrent(shape):
    b, s, h, hd = shape
    q, k, v, i_raw, f_raw = _mlstm_inputs(b, s, h, hd)
    par = ssm.mlstm_parallel(q, k, v, i_raw, f_raw)
    st_ = ssm.mlstm_init_state(b, h, hd)
    outs = []
    for t in range(s):
        o, st_ = ssm.mlstm_recurrent(q[:, t], k[:, t], v[:, t],
                                     i_raw[:, t], f_raw[:, t], st_)
        outs.append(o)
    rec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(par, rec, atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_mlstm_chunkwise_vs_parallel(chunk):
    b, s, h, hd = 2, 32, 2, 16
    q, k, v, i_raw, f_raw = _mlstm_inputs(b, s, h, hd)
    par = ssm.mlstm_parallel(q, k, v, i_raw, f_raw)
    chw, fin = ssm.mlstm_chunkwise(q, k, v, i_raw, f_raw,
                                   ssm.mlstm_init_state(b, h, hd),
                                   chunk=chunk)
    np.testing.assert_allclose(par, chw, atol=2e-4, rtol=2e-3)


def test_mlstm_chunkwise_state_continues():
    """Chunkwise final state == recurrent final state; and continuing from
    it matches a longer parallel run."""
    b, s, h, hd = 1, 16, 2, 8
    q, k, v, i_raw, f_raw = _mlstm_inputs(b, 2 * s, h, hd)
    # full parallel over 2s
    full = ssm.mlstm_parallel(q, k, v, i_raw, f_raw)
    # chunkwise first half -> state -> chunkwise second half
    st0 = ssm.mlstm_init_state(b, h, hd)
    out1, st1 = ssm.mlstm_chunkwise(q[:, :s], k[:, :s], v[:, :s],
                                    i_raw[:, :s], f_raw[:, :s], st0,
                                    chunk=8)
    out2, _ = ssm.mlstm_chunkwise(q[:, s:], k[:, s:], v[:, s:],
                                  i_raw[:, s:], f_raw[:, s:], st1,
                                  chunk=8)
    glued = jnp.concatenate([out1, out2], axis=1)
    np.testing.assert_allclose(full, glued, atol=2e-4, rtol=2e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(2, 24), st.integers(1, 3),
       st.integers(2, 5))
def test_mlstm_property_equivalence(b, s, h, hd_pow):
    hd = 2 ** hd_pow
    q, k, v, i_raw, f_raw = _mlstm_inputs(b, s, h, hd)
    par = ssm.mlstm_parallel(q, k, v, i_raw, f_raw)
    chw, _ = ssm.mlstm_chunkwise(q, k, v, i_raw, f_raw,
                                 ssm.mlstm_init_state(b, h, hd),
                                 chunk=max(1, s // 2) if s % 2 == 0 else s)
    np.testing.assert_allclose(par, chw, atol=5e-4, rtol=5e-3)


# --------------------------- selective scan ------------------------- #
def _naive_selective(u, dt, A, B_t, C_t, h0):
    b, s, i = u.shape
    h = np.asarray(h0).copy()
    ys = []
    for t in range(s):
        dA = np.exp(np.asarray(dt[:, t])[..., None] * np.asarray(A))
        dBu = (np.asarray(dt[:, t]) * np.asarray(u[:, t]))[..., None] * \
            np.asarray(B_t[:, t])[:, None, :]
        h = dA * h + dBu
        ys.append(np.einsum("bin,bn->bi", h, np.asarray(C_t[:, t])))
    return np.stack(ys, axis=1), h


@pytest.mark.parametrize("chunk", [2, 4, 8])
def test_selective_scan_vs_naive(chunk):
    b, s, i, n = 2, 8, 6, 4
    u, dt = rnd(b, s, i), jnp.abs(rnd(b, s, i)) * 0.1
    A = -jnp.abs(rnd(i, n))
    B_t, C_t = rnd(b, s, n), rnd(b, s, n)
    h0 = jnp.zeros((b, i, n))
    y, hf = ssm.selective_scan(u, dt, A, B_t, C_t, h0, chunk=chunk)
    y_ref, h_ref = _naive_selective(u, dt, A, B_t, C_t, h0)
    np.testing.assert_allclose(y, y_ref, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(hf, h_ref, atol=1e-4, rtol=1e-3)


def test_selective_step_matches_scan():
    b, s, i, n = 1, 5, 4, 3
    u, dt = rnd(b, s, i), jnp.abs(rnd(b, s, i)) * 0.1
    A = -jnp.abs(rnd(i, n))
    B_t, C_t = rnd(b, s, n), rnd(b, s, n)
    h = jnp.zeros((b, i, n))
    ys = []
    for t in range(s):
        y, h = ssm.selective_step(u[:, t], dt[:, t], A, B_t[:, t],
                                  C_t[:, t], h)
        ys.append(y)
    stepped = jnp.stack(ys, axis=1)
    scanned, hf = ssm.selective_scan(u, dt, A, B_t, C_t,
                                     jnp.zeros((b, i, n)), chunk=s)
    np.testing.assert_allclose(stepped, scanned, atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(h, hf, atol=1e-5, rtol=1e-4)


# ------------------------------ sLSTM -------------------------------- #
def test_slstm_scan_matches_steps():
    b, s, h, hd = 2, 6, 2, 4
    xw = rnd(b, s, 4, h, hd)
    r = rnd(4, h, hd, hd) * 0.1
    st0 = ssm.slstm_init_state(b, h, hd)
    hs, fin = ssm.slstm_scan(xw, r, st0)
    st_ = st0
    for t in range(s):
        st_ = ssm.slstm_step(xw[:, t], r, st_)
        np.testing.assert_allclose(hs[:, t], st_.h, atol=1e-5)
    np.testing.assert_allclose(fin.c, st_.c, atol=1e-6)


def test_slstm_stability_long_sequence():
    """Stabilized gates: no overflow over 500 steps of extreme inputs."""
    b, s, h, hd = 1, 500, 1, 4
    xw = rnd(b, s, 4, h, hd) * 5.0
    r = rnd(4, h, hd, hd) * 0.5
    hs, fin = ssm.slstm_scan(xw, r, ssm.slstm_init_state(b, h, hd))
    assert bool(jnp.all(jnp.isfinite(hs)))

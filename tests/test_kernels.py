"""Pallas kernel validation: shape/dtype sweeps, assert_allclose against the
pure-jnp ref.py oracles (interpret=True executes kernel bodies on CPU).

Known-red on CPU CI: the installed jax's Pallas TPU module lacks the
`CompilerParams` API every kernel here passes at call time, so no case in
this module can execute past kernel construction.  The xfail is
*conditional on that exact missing attribute* — while it holds, nothing
else is maskable (every test dies on the same line); on a toolchain where
the API exists the marks disarm automatically and any kernel regression
fails CI for real.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.pallas import tpu as pltpu

_PALLAS_API_MISSING = not hasattr(pltpu, "CompilerParams")

pytestmark = pytest.mark.xfail(
    condition=_PALLAS_API_MISSING,
    strict=False,
    reason="installed jax's pallas.tpu lacks CompilerParams — kernels "
           "cannot run on this CPU toolchain (pre-existing, quarantined)")

from repro.kernels import ops, ref  # noqa: E402
from repro.kernels.decode_attention import decode_attention  # noqa: E402
from repro.kernels.flash_attention import flash_attention  # noqa: E402
from repro.kernels.int8_matmul import int8_matmul  # noqa: E402
from repro.serving.quantization import quantize_array  # noqa: E402

rng = np.random.default_rng(7)


def rnd(*s, dt=jnp.float32):
    return jnp.asarray(rng.standard_normal(s), dt)


FLASH_CASES = [
    # B, H, K, Sq, Skv, hd, win, prefix, dtype
    (2, 4, 2, 128, 128, 64, 0, 0, jnp.float32),
    (1, 8, 4, 256, 256, 128, 0, 0, jnp.float32),
    (2, 4, 1, 128, 256, 64, 64, 0, jnp.float32),
    (1, 4, 2, 128, 128, 64, 48, 16, jnp.float32),
    (1, 2, 2, 64, 64, 32, 0, 0, jnp.bfloat16),
    (1, 6, 2, 192, 192, 64, 0, 0, jnp.float32),   # non-pow2 heads
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_vs_oracle(case):
    B, H, K, Sq, Skv, hd, win, pre, dt = case
    q, k, v = rnd(B, H, Sq, hd, dt=dt), rnd(B, K, Skv, hd, dt=dt), \
        rnd(B, K, Skv, hd, dt=dt)
    out = flash_attention(q, k, v, causal=True, window=win, prefix=pre,
                          block_q=64, block_k=64, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=win,
                                     prefix=pre)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(np.float32),
                               expect.astype(np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_noncausal():
    q, k, v = rnd(1, 4, 128, 64), rnd(1, 2, 128, 64), rnd(1, 2, 128, 64)
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


DECODE_CASES = [
    # B, K, G, S, hd, win, block_k
    (2, 2, 4, 512, 64, 0, 128),
    (4, 8, 8, 256, 128, 0, 128),
    (2, 1, 4, 512, 64, 128, 128),
    (1, 4, 2, 1024, 64, 0, 256),
    (3, 2, 8, 256, 32, 0, 64),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_vs_oracle(case):
    B, K, G, S, hd, win, bk = case
    q = rnd(B, K, G, hd)
    kc, vc = rnd(B, K, S, hd), rnd(B, K, S, hd)
    pos = jnp.asarray(rng.integers(max(win, 1), S, B), jnp.int32)
    out = decode_attention(q, kc, vc, pos, window=win, block_k=bk,
                           interpret=True)
    expect = ref.decode_attention_ref(q, kc, vc, pos, window=win)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_decode_ragged_positions():
    """Per-sequence lengths mask correctly (continuous batching)."""
    B, K, G, S, hd = 4, 2, 2, 512, 64
    q = rnd(B, K, G, hd)
    kc, vc = rnd(B, K, S, hd), rnd(B, K, S, hd)
    pos = jnp.asarray([0, 63, 200, 511], jnp.int32)
    out = decode_attention(q, kc, vc, pos, block_k=64, interpret=True)
    expect = ref.decode_attention_ref(q, kc, vc, pos)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


INT8_CASES = [
    (128, 256, 128, jnp.float32),
    (256, 512, 256, jnp.bfloat16),
    (128, 128, 384, jnp.float32),
]


@pytest.mark.parametrize("case", INT8_CASES)
def test_int8_matmul_vs_oracle(case):
    M, K, N, dt = case
    x = rnd(M, K, dt=dt)
    w = rnd(K, N) * 0.1
    qd = quantize_array(w, 8)
    out = int8_matmul(x, qd["__q__"], qd["scale"], interpret=True)
    expect = ref.int8_matmul_ref(x, qd["__q__"], qd["scale"])
    np.testing.assert_allclose(out.astype(np.float32),
                               expect.astype(np.float32),
                               atol=5e-2, rtol=5e-2)


def test_int8_matmul_quantization_error_bound():
    """End-to-end quant error stays within the analytic absmax bound."""
    x = rnd(64, 128)
    w = rnd(128, 64)
    qd = quantize_array(w, 8)
    out = int8_matmul(x, qd["__q__"], qd["scale"], block_m=64, block_n=64,
                      block_k=64, interpret=True)
    exact = x @ w
    # per-element error <= sum_k |x_k| * scale/2
    bound = jnp.sum(jnp.abs(x), axis=1, keepdims=True) * \
        jnp.max(qd["scale"]) * 0.5 + 1e-4
    assert bool(jnp.all(jnp.abs(out - exact) <= bound))


def test_ops_wrappers_jit():
    """Public ops are jit-compiled and match the raw kernels."""
    q, k, v = rnd(1, 4, 128, 64), rnd(1, 2, 128, 64), rnd(1, 2, 128, 64)
    o1 = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    o2 = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(o1, o2, atol=1e-6)

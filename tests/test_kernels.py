"""Pallas kernel validation: shape/dtype sweeps, assert_allclose against the
pure-jnp ref.py oracles (interpret=True executes kernel bodies on CPU).

Every kernel resolves the compiler-params constructor through a compat
alias (``CompilerParams`` on current toolchains, ``TPUCompilerParams``
on older ones), so this module runs green on CPU CI.  The lone skip
below guards the one toolchain shape where *neither* attribute exists —
there the kernels cannot even be constructed, and only that exact
condition may quarantine anything here.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.pallas import tpu as pltpu

if not (hasattr(pltpu, "CompilerParams")
        or hasattr(pltpu, "TPUCompilerParams")):   # pragma: no cover
    pytest.skip("installed jax's pallas.tpu exposes no compiler-params "
                "API at all — kernels cannot be constructed",
                allow_module_level=True)

from repro.kernels import ops, ref  # noqa: E402
from repro.kernels import paged_attention as pa  # noqa: E402
from repro.kernels.decode_attention import decode_attention  # noqa: E402
from repro.kernels.flash_attention import flash_attention  # noqa: E402
from repro.kernels.int8_matmul import int8_matmul  # noqa: E402
from repro.serving.quantization import quantize_array  # noqa: E402

rng = np.random.default_rng(7)


def rnd(*s, dt=jnp.float32):
    return jnp.asarray(rng.standard_normal(s), dt)


FLASH_CASES = [
    # B, H, K, Sq, Skv, hd, win, prefix, dtype
    (2, 4, 2, 128, 128, 64, 0, 0, jnp.float32),
    (1, 8, 4, 256, 256, 128, 0, 0, jnp.float32),
    (2, 4, 1, 128, 256, 64, 64, 0, jnp.float32),
    (1, 4, 2, 128, 128, 64, 48, 16, jnp.float32),
    (1, 2, 2, 64, 64, 32, 0, 0, jnp.bfloat16),
    (1, 6, 2, 192, 192, 64, 0, 0, jnp.float32),   # non-pow2 heads
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_vs_oracle(case):
    B, H, K, Sq, Skv, hd, win, pre, dt = case
    q, k, v = rnd(B, H, Sq, hd, dt=dt), rnd(B, K, Skv, hd, dt=dt), \
        rnd(B, K, Skv, hd, dt=dt)
    out = flash_attention(q, k, v, causal=True, window=win, prefix=pre,
                          block_q=64, block_k=64, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=win,
                                     prefix=pre)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(np.float32),
                               expect.astype(np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_noncausal():
    q, k, v = rnd(1, 4, 128, 64), rnd(1, 2, 128, 64), rnd(1, 2, 128, 64)
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


DECODE_CASES = [
    # B, K, G, S, hd, win, block_k
    (2, 2, 4, 512, 64, 0, 128),
    (4, 8, 8, 256, 128, 0, 128),
    (2, 1, 4, 512, 64, 128, 128),
    (1, 4, 2, 1024, 64, 0, 256),
    (3, 2, 8, 256, 32, 0, 64),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_vs_oracle(case):
    B, K, G, S, hd, win, bk = case
    q = rnd(B, K, G, hd)
    kc, vc = rnd(B, K, S, hd), rnd(B, K, S, hd)
    pos = jnp.asarray(rng.integers(max(win, 1), S, B), jnp.int32)
    out = decode_attention(q, kc, vc, pos, window=win, block_k=bk,
                           interpret=True)
    expect = ref.decode_attention_ref(q, kc, vc, pos, window=win)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_decode_ragged_positions():
    """Per-sequence lengths mask correctly (continuous batching)."""
    B, K, G, S, hd = 4, 2, 2, 512, 64
    q = rnd(B, K, G, hd)
    kc, vc = rnd(B, K, S, hd), rnd(B, K, S, hd)
    pos = jnp.asarray([0, 63, 200, 511], jnp.int32)
    out = decode_attention(q, kc, vc, pos, block_k=64, interpret=True)
    expect = ref.decode_attention_ref(q, kc, vc, pos)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


INT8_CASES = [
    (128, 256, 128, jnp.float32),
    (256, 512, 256, jnp.bfloat16),
    (128, 128, 384, jnp.float32),
]


@pytest.mark.parametrize("case", INT8_CASES)
def test_int8_matmul_vs_oracle(case):
    M, K, N, dt = case
    x = rnd(M, K, dt=dt)
    w = rnd(K, N) * 0.1
    qd = quantize_array(w, 8)
    out = int8_matmul(x, qd["__q__"], qd["scale"], interpret=True)
    expect = ref.int8_matmul_ref(x, qd["__q__"], qd["scale"])
    np.testing.assert_allclose(out.astype(np.float32),
                               expect.astype(np.float32),
                               atol=5e-2, rtol=5e-2)


def test_int8_matmul_quantization_error_bound():
    """End-to-end quant error stays within the analytic absmax bound."""
    x = rnd(64, 128)
    w = rnd(128, 64)
    qd = quantize_array(w, 8)
    out = int8_matmul(x, qd["__q__"], qd["scale"], block_m=64, block_n=64,
                      block_k=64, interpret=True)
    exact = x @ w
    # per-element error <= sum_k |x_k| * scale/2
    bound = jnp.sum(jnp.abs(x), axis=1, keepdims=True) * \
        jnp.max(qd["scale"]) * 0.5 + 1e-4
    assert bool(jnp.all(jnp.abs(out - exact) <= bound))


def test_ops_wrappers_jit():
    """Public ops are jit-compiled and match the raw kernels."""
    q, k, v = rnd(1, 4, 128, 64), rnd(1, 2, 128, 64), rnd(1, 2, 128, 64)
    o1 = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    o2 = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(o1, o2, atol=1e-6)


# ------------------- paged decode attention ------------------------ #
def _gather_view(pool, table):
    """Oracle helper: materialize (B, K, pps*ps, hd) logical views from
    the (P, ps, K, hd) page pool; sentinel entries read as zeros (masked
    by pos in the dense oracle)."""
    P = pool.shape[0]
    valid = table < P
    g = jnp.take(pool, jnp.where(valid, table, 0), axis=0)
    g = jnp.where(valid[:, :, None, None, None], g, 0)
    b, pps, ps, k, hd = g.shape
    return g.reshape(b, pps * ps, k, hd).transpose(0, 2, 1, 3)


def _paged_case(B, K, G, n_pages, pps, ps, hd, pos_list):
    kp, vp = rnd(n_pages, ps, K, hd), rnd(n_pages, ps, K, hd)
    pos = jnp.asarray(pos_list, jnp.int32)
    # each slot maps just enough pages to cover pos, sentinel after that
    table = np.full((B, pps), n_pages, np.int32)
    free = iter(rng.permutation(n_pages))
    for i, p in enumerate(pos_list):
        for j in range(p // ps + 1):
            table[i, j] = next(free)
    q = rnd(B, K, G, hd)
    return q, kp, vp, jnp.asarray(table), pos


PAGED_CASES = [
    # B, K, G, n_pages, pps, ps, hd, window
    (3, 2, 4, 24, 6, 8, 64, 0),
    (2, 4, 2, 32, 8, 4, 32, 0),
    (4, 1, 8, 24, 4, 8, 128, 0),
    (3, 2, 4, 24, 6, 8, 64, 16),     # sliding window
]


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_decode_vs_gather_oracle(case):
    """Kernel and fori_loop reference both match dense attention over
    the gathered logical view — including sentinel-padded tables and a
    static sliding window."""
    B, K, G, n_pages, pps, ps, hd, win = case
    q, kp, vp, table, pos = _paged_case(
        B, K, G, n_pages, pps, ps, hd,
        [ps - 1, ps * 2 + 3, ps * (pps - 1)][:B] + [5] * max(B - 3, 0))
    expect = ref.decode_attention_ref(q, _gather_view(kp, table),
                                      _gather_view(vp, table), pos,
                                      window=win)
    out_ref = pa.paged_decode_attention_ref(q, kp, vp, table, pos,
                                            window=win)
    np.testing.assert_allclose(out_ref, expect, atol=2e-5, rtol=2e-5)
    out_k = pa.paged_decode_attention(q, kp, vp, table, pos, window=win,
                                      interpret=True)
    np.testing.assert_allclose(out_k, expect, atol=2e-5, rtol=2e-5)


def test_paged_decode_traced_window_ref():
    """The reference also supports per-slot *traced* windows (hymba's
    global/local mix routes through it): window 0 rows stay full-causal
    in the same call as windowed rows."""
    B, K, G, n_pages, pps, ps, hd = 3, 2, 4, 24, 6, 8, 64
    q, kp, vp, table, pos = _paged_case(B, K, G, n_pages, pps, ps, hd,
                                        [ps * 3, ps * 2 + 3, ps * 5 - 1])
    win = jnp.asarray([0, 8, 16], jnp.int32)
    kc, vc = _gather_view(kp, table), _gather_view(vp, table)
    for i in range(B):
        expect = ref.decode_attention_ref(q[i:i + 1], kc[i:i + 1],
                                          vc[i:i + 1], pos[i:i + 1],
                                          window=int(win[i]))
        got = pa.paged_decode_attention_ref(q, kp, vp, table, pos,
                                            window=win)[i:i + 1]
        np.testing.assert_allclose(got, expect, atol=2e-5, rtol=2e-5)


def test_paged_decode_shared_pages():
    """Two slots mapping the *same* physical prefix page (prefix-cache
    COW sharing) read identical keys through their own tables."""
    B, K, G, n_pages, pps, ps, hd = 2, 2, 2, 16, 4, 8, 32
    kp, vp = rnd(n_pages, ps, K, hd), rnd(n_pages, ps, K, hd)
    table = jnp.asarray([[3, 5, 16, 16], [3, 7, 16, 16]], jnp.int32)
    pos = jnp.asarray([ps * 2 - 1, ps * 2 - 1], jnp.int32)
    q = jnp.tile(rnd(1, K, G, hd), (B, 1, 1, 1))
    out = pa.paged_decode_attention_ref(q, kp, vp, table, pos)
    expect = ref.decode_attention_ref(q, _gather_view(kp, table),
                                      _gather_view(vp, table), pos)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)
    outk = pa.paged_decode_attention(q, kp, vp, table, pos,
                                     interpret=True)
    np.testing.assert_allclose(outk, expect, atol=2e-5, rtol=2e-5)


def test_paged_suffix_vs_dense_oracle():
    """Multi-query verify attention (Q draft positions per slot) matches
    dense causal attention over the gathered view at every position."""
    B, Q, K, G, n_pages, pps, ps, hd = 3, 5, 2, 3, 24, 6, 8, 32
    H = K * G
    kp, vp = rnd(n_pages, ps, K, hd), rnd(n_pages, ps, K, hd)
    pos0 = [5, 20, 33]
    table = np.full((B, pps), n_pages, np.int32)
    free = iter(rng.permutation(n_pages))
    for i, p in enumerate(pos0):
        for j in range((p + Q - 1) // ps + 1):
            table[i, j] = next(free)
    table = jnp.asarray(table)
    q = rnd(B, Q, H, hd)
    q_pos = jnp.asarray(pos0, jnp.int32)[:, None] + jnp.arange(Q)[None, :]
    out = pa.paged_suffix_attention_ref(q, kp, vp, table, q_pos)
    kc, vc = _gather_view(kp, table), _gather_view(vp, table)
    # dense oracle: fold H -> (K, G) K-major, mask kv_pos <= q_pos
    qf = q.reshape(B, Q, K, G, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bqkgd,bksd->bqkgs", qf, kc.astype(jnp.float32))
    kv = jnp.arange(kc.shape[2])
    mask = kv[None, None, :] <= q_pos[:, :, None]
    s = jnp.where(mask[:, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    expect = jnp.einsum("bqkgs,bksd->bqkgd", p,
                        vc.astype(jnp.float32)).reshape(B, Q, H, hd)
    np.testing.assert_allclose(out, expect.astype(out.dtype),
                               atol=2e-5, rtol=2e-5)

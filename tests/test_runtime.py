"""Continuous serving runtime: background pumps, event-blocking handles,
per-tenant token buckets, wall-clock timeouts, load-driven autoscale (up
AND down), and the threaded soak (concurrent tenants + mid-run node
kill)."""
import dataclasses
import threading
import time

import pytest

from repro.api import (ErrorCode, Gateway, RuntimeConfig,
                       StreamEventType, TenantQuota)
from repro.cluster import BackendNode, Fleet
from repro.configs import ARCHS
from repro.core import (ModelCatalog, ModelDemand, ModelLoad,
                        SDAIController)
from repro.serving import SamplingParams

MODEL = "olmo-1b-reduced"


def _stack(param_store, n_nodes=2, n_slots=2, max_len=48, min_replicas=2,
           max_replicas=0, fill=True):
    fleet = Fleet([BackendNode(f"n{i}", "v5e-1", param_store=param_store)
                   for i in range(n_nodes)])
    cfg = ARCHS["olmo-1b"].reduced()
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.cfg.fill_vram = fill
    ctrl.discover()
    plan = ctrl.deploy([ModelDemand(cfg, min_replicas=min_replicas,
                                    max_replicas=max_replicas,
                                    n_slots=n_slots, max_len=max_len)])
    assert not plan.unplaced
    return fleet, ctrl


@pytest.fixture(scope="module")
def shared(param_store):
    """Module-shared healthy stack (tests that kill nodes build their
    own)."""
    return _stack(param_store)


@pytest.fixture()
def gw(shared):
    fleet, ctrl = shared
    gateway = Gateway(ctrl)
    yield gateway
    gateway.stop(timeout_s=10.0)


# -------------------- lifecycle ------------------------------------ #
def test_runtime_drives_fleet_without_caller_pumps(gw):
    rt = gw.start()
    assert rt.running and gw.runtime_active
    handles = [gw.submit(MODEL, [1, 2, i + 1],
                         SamplingParams(max_tokens=4))
               for i in range(4)]
    for h in handles:
        resp = h.result(timeout_s=60)
        assert resp.ok and len(resp.tokens) == 4
    # pump threads did all the work: the callers never advanced the fleet
    assert gw.stats.caller_pumps == 0
    assert rt.stats.tokens_pumped > 0


def test_stop_joins_all_pump_threads(gw):
    rt = gw.start()
    threads = rt.threads()
    assert len(threads) == len(gw.c.fleet.nodes) + 1   # pumps + ticker
    assert all(t.is_alive() for t in threads)
    assert gw.stop() is True
    assert all(not t.is_alive() for t in threads)
    # restartable: a fresh start serves again
    gw.start()
    assert gw.generate(MODEL, [5], SamplingParams(max_tokens=2),
                       timeout_s=60).ok
    assert gw.stop() is True


def test_stop_drains_inflight_work(gw):
    gw.start()
    handles = [gw.submit(MODEL, [3, i + 1], SamplingParams(max_tokens=6))
               for i in range(4)]
    assert gw.stop(drain=True, timeout_s=60) is True
    assert all(h.done for h in handles)
    assert all(h.response.ok for h in handles)


def test_streaming_through_runtime(gw):
    gw.start()
    events = list(gw.submit(MODEL, [9, 9],
                            SamplingParams(max_tokens=5)).stream(
                                timeout_s=60))
    toks = [e for e in events if e.type is StreamEventType.TOKEN]
    assert len(toks) == 5
    assert [e.index for e in toks] == list(range(5))
    assert events[-1].type is StreamEventType.FINISH
    assert gw.stats.caller_pumps == 0


# -------------------- tenant rate limits --------------------------- #
def test_rate_limited_tenant_gets_structured_429(gw):
    gw.admin.set_tenant_quota("burst1", requests_per_s=1)
    h1 = gw.submit(MODEL, [1], SamplingParams(max_tokens=2),
                   tenant="burst1")
    h2 = gw.submit(MODEL, [2], SamplingParams(max_tokens=2),
                   tenant="burst1")
    assert h2.done                          # rejected at admission
    assert h2.response.error.code is ErrorCode.RATE_LIMITED
    assert h2.response.error.retryable
    assert gw.stats.rejected_rate_limited == 1
    # an unlimited tenant is unaffected
    h3 = gw.submit(MODEL, [3], SamplingParams(max_tokens=2),
                   tenant="other")
    assert not h3.done
    assert h1.result(timeout_s=60).ok and h3.result(timeout_s=60).ok
    # buckets refill over wall clock: tenant admits again
    time.sleep(1.1)
    assert gw.generate(MODEL, [4], SamplingParams(max_tokens=2),
                       tenant="burst1", timeout_s=60).ok
    gw.admin.remove_tenant_quota("burst1")


def test_token_rate_quota_charges_max_tokens(gw):
    gw.admin.set_tenant_quota("tokcap", TenantQuota(tokens_per_s=4,
                                                    burst_tokens=4))
    ok = gw.submit(MODEL, [1], SamplingParams(max_tokens=4),
                   tenant="tokcap")
    hot = gw.submit(MODEL, [2], SamplingParams(max_tokens=4),
                    tenant="tokcap")
    assert hot.done
    assert hot.response.error.code is ErrorCode.RATE_LIMITED
    assert "tok/s" in hot.response.error.message
    assert ok.result(timeout_s=60).ok
    gw.admin.remove_tenant_quota("tokcap")


def test_tenant_quotas_inspectable_via_admin(gw):
    gw.admin.set_tenant_quota("acme", requests_per_s=100)
    gw.submit(MODEL, [1], SamplingParams(max_tokens=2),
              tenant="acme").result(timeout_s=60)
    snap = gw.admin.snapshot()
    acme = {t.tenant: t for t in snap.tenants}["acme"]
    assert acme.requests_per_s == 100
    assert acme.admitted >= 1
    assert acme.tokens_charged >= 2
    assert "acme" in snap.to_dict()["tenants"]
    assert "acme" in gw.admin.tenant_quotas()
    gw.admin.remove_tenant_quota("acme")
    assert "acme" not in gw.admin.tenant_quotas()


# -------------------- wall-clock timeout (bugfix) ------------------ #
def test_blocking_calls_time_out_on_wall_clock(gw):
    # hand-pump mode: an already-expired deadline surfaces TIMEOUT
    # deterministically — no pump-step counting involved
    h = gw.submit(MODEL, [7], SamplingParams(max_tokens=1000))
    resp = h.result(timeout_s=0.0)
    assert resp.error.code is ErrorCode.TIMEOUT
    assert resp.error.retryable
    assert gw.stats.timeouts == 1


def test_long_generation_not_spuriously_capped(gw):
    # the old pump-count cap could fire on long generations; wall-clock
    # budgets don't (40 tokens through 2-slot engines, many pump rounds)
    resp = gw.generate(MODEL, [1, 2], SamplingParams(max_tokens=40),
                       timeout_s=120)
    assert resp.ok and len(resp.tokens) == 40


def test_timeout_in_runtime_mode(gw):
    gw.start()
    h = gw.submit(MODEL, [8], SamplingParams(max_tokens=1000))
    resp = h.result(timeout_s=0.001)
    assert resp.error.code is ErrorCode.TIMEOUT
    # the slot freed: a fresh request completes
    assert gw.generate(MODEL, [9], SamplingParams(max_tokens=2),
                       timeout_s=60).ok


# -------------------- load-driven autoscale ------------------------ #
def test_sustained_pressure_triggers_scale_up(param_store):
    fleet, ctrl = _stack(param_store, n_nodes=3, min_replicas=1,
                         max_replicas=3, fill=False)
    assert len(ctrl.replicas.for_model(MODEL)) == 1
    acfg = ctrl.cfg.autoscale
    for _ in range(acfg.sustain_ticks + 1):
        ctrl.tick(load={MODEL: ModelLoad(
            queue_depth=8, inflight=8,
            replicas=len(ctrl.frontend.healthy_replicas(MODEL)))})
    assert ctrl.scale_ups == 1
    assert len(ctrl.replicas.for_model(MODEL)) == 2
    assert ctrl.bus.of_kind("autoscaled_up")
    # cooldown: immediate further pressure does not thrash
    ctrl.tick(load={MODEL: ModelLoad(queue_depth=8, inflight=8,
                                     replicas=2)})
    assert ctrl.scale_ups == 1


def test_scale_up_respects_replica_cap_and_vram(param_store):
    fleet, ctrl = _stack(param_store, n_nodes=2, min_replicas=2,
                         max_replicas=2, fill=False)
    assert ctrl.scale_up(MODEL) is False          # at replica cap
    assert len(ctrl.replicas.for_model(MODEL)) == 2


def test_idle_models_never_scale(param_store):
    fleet, ctrl = _stack(param_store, n_nodes=3, min_replicas=1,
                         max_replicas=3, fill=False)
    for _ in range(10):
        ctrl.tick(load={MODEL: ModelLoad(queue_depth=0, inflight=0,
                                         replicas=1)})
    assert ctrl.scale_ups == 0
    assert len(ctrl.replicas.for_model(MODEL)) == 1


# -------------------- load-driven scale-down ----------------------- #
def test_idle_streak_scales_down_to_min_with_cooldown(param_store):
    fleet, ctrl = _stack(param_store, n_nodes=3, min_replicas=1,
                         max_replicas=3, fill=False)
    acfg = ctrl.cfg.autoscale
    acfg.idle_sustain_ticks, acfg.down_cooldown_ticks = 3, 4
    assert ctrl.scale_up(MODEL) and ctrl.scale_up(MODEL)
    assert len(ctrl.replicas.for_model(MODEL)) == 3
    hbm_before = fleet.used_hbm()

    def idle_tick():
        ctrl.tick(load={MODEL: ModelLoad(
            queue_depth=0, inflight=0,
            replicas=len(ctrl.frontend.healthy_replicas(MODEL)))})

    for _ in range(acfg.idle_sustain_ticks):
        idle_tick()
    assert ctrl.scale_downs == 1            # one retirement per streak
    assert len(ctrl.replicas.for_model(MODEL)) == 2
    assert ctrl.bus.of_kind("autoscaled_down")
    assert fleet.used_hbm() < hbm_before    # VRAM returned to the pool
    # cooldown: the next idle ticks don't immediately retire another
    for _ in range(2):
        idle_tick()
    assert ctrl.scale_downs == 1
    # ... but a full streak after cooldown does, down to min_replicas
    for _ in range(40):
        idle_tick()
    assert ctrl.scale_downs == 2
    assert len(ctrl.replicas.for_model(MODEL)) == 1
    # the floor holds no matter how long the model idles
    for _ in range(40):
        idle_tick()
    assert len(ctrl.replicas.for_model(MODEL)) == 1


def test_scale_down_never_retires_busy_replicas(param_store):
    fleet, ctrl = _stack(param_store, n_nodes=2, min_replicas=2,
                         max_replicas=2, fill=False)
    ctrl.demands[MODEL] = dataclasses.replace(ctrl.demands[MODEL],
                                              min_replicas=1)
    gw = Gateway(ctrl)
    handles = [gw.submit(MODEL, [1, 2, i + 1],
                         SamplingParams(max_tokens=4)) for i in range(2)]
    # both replicas hold work -> nothing is eligible to retire
    assert ctrl.scale_down(MODEL) is False
    assert len(ctrl.replicas.for_model(MODEL)) == 2
    for h in handles:
        assert h.result(timeout_s=60).ok
    # drained: the surplus replica retires cleanly
    assert ctrl.scale_down(MODEL) is True
    assert len(ctrl.replicas.for_model(MODEL)) == 1
    assert gw.generate(MODEL, [3], SamplingParams(max_tokens=2),
                       timeout_s=60).ok


def test_runtime_closes_the_elasticity_loop(param_store):
    """Through the live runtime: sustained pressure grows the model,
    sustained idleness shrinks it back to min_replicas."""
    fleet, ctrl = _stack(param_store, n_nodes=3, min_replicas=1,
                         max_replicas=3, fill=False)
    acfg = ctrl.cfg.autoscale
    acfg.sustain_ticks, acfg.cooldown_ticks = 2, 2
    acfg.idle_sustain_ticks, acfg.down_cooldown_ticks = 5, 2
    gw = Gateway(ctrl)
    gw.start(RuntimeConfig(tick_interval_s=0.01))
    try:
        handles = [gw.submit(MODEL, [1, 2, (i % 5) + 1],
                             SamplingParams(max_tokens=10))
                   for i in range(16)]
        for h in handles:
            assert h.result(timeout_s=120) is not None
        deadline = time.monotonic() + 60
        while ctrl.scale_ups < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert ctrl.scale_ups >= 1          # grew under pressure
        while (len(ctrl.replicas.for_model(MODEL)) > 1
               and time.monotonic() < deadline):
            time.sleep(0.01)                # idle: shrink back
        assert len(ctrl.replicas.for_model(MODEL)) == 1
        assert ctrl.scale_downs >= 1
        assert ctrl.bus.of_kind("autoscaled_down")
        # the survivor still serves
        assert gw.generate(MODEL, [7], SamplingParams(max_tokens=2),
                           timeout_s=60).ok
    finally:
        assert gw.stop(timeout_s=60) is True


# -------------------- threaded soak -------------------------------- #
def test_soak_concurrent_tenants_node_kill_and_clean_stop(param_store):
    """N tenants submit concurrently through the runtime; one node dies
    mid-run.  Every request settles (ok or structured error), streams
    lose/duplicate no tokens, the rate-limited tenant sees RATE_LIMITED
    (never OVERLOADED), and stop() joins every pump thread."""
    fleet, ctrl = _stack(param_store, n_nodes=3, min_replicas=3,
                         max_replicas=3, fill=False)
    gw = Gateway(ctrl)
    # burst of 2, then effectively no refill during the run: the capped
    # tenant deterministically sees RATE_LIMITED on later submits
    gw.admin.set_tenant_quota("capped", TenantQuota(requests_per_s=0.01,
                                                    burst_requests=2))
    rt = gw.start(RuntimeConfig(tick_interval_s=0.02))
    results = []            # (tenant, response, stream_tokens)
    lock = threading.Lock()

    def worker(tenant, n_requests):
        for i in range(n_requests):
            h = gw.submit(MODEL, [1, 2, (i % 5) + 1],
                          SamplingParams(max_tokens=6), tenant=tenant)
            toks = []
            for ev in h.stream(timeout_s=120):
                if ev.type is StreamEventType.TOKEN:
                    toks.append((ev.index, ev.token))
            with lock:
                results.append((tenant, h.response, toks))

    tenants = ["alpha", "beta", "gamma", "capped"]
    threads = [threading.Thread(target=worker, args=(t, 5))
               for t in tenants]
    for t in threads:
        t.start()
    time.sleep(0.3)
    victim = "n2"
    fleet.fail_node(victim)                 # mid-run outage
    for t in threads:
        t.join(timeout=180)
        assert not t.is_alive()

    assert len(results) == len(tenants) * 5
    ok = 0
    for tenant, resp, toks in results:
        assert resp is not None             # every request settled
        if resp.ok:
            ok += 1
            # stream integrity: indexes contiguous, tokens match the
            # final response exactly — nothing lost, nothing duplicated
            assert [i for i, _ in toks] == list(range(len(toks)))
            assert [tok for _, tok in toks] == list(resp.tokens)
        else:
            assert resp.error.code in (ErrorCode.ENGINE_FAILED,
                                       ErrorCode.RATE_LIMITED,
                                       ErrorCode.TIMEOUT,
                                       ErrorCode.NO_BACKEND)
            if tenant != "capped":
                assert resp.error.code is not ErrorCode.RATE_LIMITED
    assert ok >= 10                         # the fleet kept serving
    capped_codes = [r.error.code for t, r, _ in results
                    if t == "capped" and not r.ok]
    assert ErrorCode.OVERLOADED not in capped_codes
    assert any(c is ErrorCode.RATE_LIMITED for c in capped_codes)

    threads = rt.threads()
    assert gw.stop(timeout_s=60) is True
    assert all(not t.is_alive() for t in threads)

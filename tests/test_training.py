"""Training substrate: optimizer, checkpoint roundtrip + crash-resume
equality, deterministic data, gradient-compression error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.training import checkpoint as ckpt_lib
from repro.training import compression as comp_lib
from repro.training import optimizer as opt_lib
from repro.training.data import DataConfig, SyntheticLM, host_shard
from repro.training.train_loop import TrainConfig, Trainer

from _hypothesis_compat import given, settings, st


# --------------------------- optimizer ------------------------------ #
def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = opt_lib.adamw_init(params)
    cfg = opt_lib.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=1000,
                              weight_decay=0.0, grad_clip=0,
                              min_lr_ratio=1.0)
    step = jnp.zeros((), jnp.int32)
    for i in range(80):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = opt_lib.adamw_update(params, g, opt, step, cfg)
        step = step + 1
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.5


def test_lr_schedule_shape():
    cfg = opt_lib.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              min_lr_ratio=0.1)
    lr0 = float(opt_lib.lr_schedule(cfg, jnp.asarray(0.0)))
    lr_w = float(opt_lib.lr_schedule(cfg, jnp.asarray(10.0)))
    lr_end = float(opt_lib.lr_schedule(cfg, jnp.asarray(100.0)))
    assert lr0 < 0.05 and abs(lr_w - 1.0) < 1e-5
    assert abs(lr_end - 0.1) < 1e-5


def test_grad_clip_caps_norm():
    params = {"w": jnp.ones((4,))}
    opt = opt_lib.adamw_init(params)
    cfg = opt_lib.AdamWConfig(lr=0.0, grad_clip=1.0)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = opt_lib.adamw_update(params, g, opt, jnp.zeros((),
                                                             jnp.int32),
                                   cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-3)


def test_adafactor_memory_factored():
    params = {"w": jnp.ones((16, 8)), "b": jnp.ones((8,))}
    st_ = opt_lib.adafactor_init(params)
    n_state = sum(x.size for x in jax.tree.leaves(st_))
    assert n_state == 16 + 8 + 8      # vr + vc + vector v


# --------------------------- checkpoint ----------------------------- #
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}
    ckpt_lib.save(tree, tmp_path / "x.msgpack")
    like = jax.tree.map(jnp.zeros_like, tree)
    back = ckpt_lib.restore(tmp_path / "x.msgpack", like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_manager_gc(tmp_path):
    mgr = ckpt_lib.CheckpointManager(tmp_path, keep=2)
    tree = {"w": jnp.ones((2,))}
    for s in (10, 20, 30, 40):
        mgr.save(s, tree)
    assert mgr.latest_step() == 40
    found = sorted(p.name for p in tmp_path.glob("ckpt_*.msgpack"))
    assert len(found) == 2


def test_restore_shape_mismatch_raises(tmp_path):
    ckpt_lib.save({"w": jnp.ones((4,))}, tmp_path / "x.msgpack")
    with pytest.raises(ValueError):
        ckpt_lib.restore(tmp_path / "x.msgpack", {"w": jnp.ones((5,))})


def test_crash_resume_equality(tmp_path):
    """train(2N) == train(N) + crash + resume(N) — bit-exact."""
    cfg = ARCHS["xlstm-125m"].reduced()
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, batch=2)
    kw = dict(log_every=100)
    full = Trainer(cfg, dc, TrainConfig(steps=8, ckpt_every=4,
                                        ckpt_dir=str(tmp_path / "a"),
                                        **kw))
    r_full = full.run()
    part = Trainer(cfg, dc, TrainConfig(steps=4, ckpt_every=4,
                                        ckpt_dir=str(tmp_path / "b"),
                                        **kw))
    part.run()
    resumed = Trainer(cfg, dc, TrainConfig(steps=8, ckpt_every=4,
                                           ckpt_dir=str(tmp_path / "b"),
                                           **kw))
    r_res = resumed.run()
    assert r_res["resumed_from"] == 4
    for a, b in zip(jax.tree.leaves(r_full["state"]["params"]),
                    jax.tree.leaves(r_res["state"]["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ------------------------------ data -------------------------------- #
def test_data_deterministic_per_step():
    dc = DataConfig(vocab=64, seq_len=16, batch=2, seed=5)
    d1, d2 = SyntheticLM(dc), SyntheticLM(dc)
    b1, b2 = d1.batch_at(7), d2.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch_at(8)["tokens"], b1["tokens"])


def test_data_labels_shifted():
    dc = DataConfig(vocab=64, seq_len=16, batch=2)
    b = SyntheticLM(dc).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_is_learnable_structure():
    """The n-gram table makes next tokens predictable > chance."""
    dc = DataConfig(vocab=128, seq_len=256, batch=4, seed=1)
    data = SyntheticLM(dc)
    b = data.batch_at(0)
    ctx = np.stack([b["tokens"][:, i:i + 3].reshape(-1, 3)
                    for i in range(0, 200, 7)]).reshape(-1, 3)
    preds = data._table[data._ctx_hash(ctx)]
    # compare against actual next tokens
    nxt = np.stack([b["tokens"][:, i + 3].reshape(-1)
                    for i in range(0, 200, 7)]).reshape(-1)
    acc = float((preds == nxt).mean())
    assert acc > 0.3      # 65% table-follow rate, >> 1/128 chance


def test_host_shard_partitions():
    dc = DataConfig(vocab=16, seq_len=4, batch=8)
    b = SyntheticLM(dc).batch_at(0)
    shards = [host_shard(b, i, 4) for i in range(4)]
    glued = np.concatenate([s["tokens"] for s in shards])
    np.testing.assert_array_equal(glued, b["tokens"])


# --------------------------- compression ---------------------------- #
def test_compression_error_feedback_unbiased():
    """With EF, the *accumulated* applied updates converge to the true
    gradient sum (bias is pushed into the bounded error term)."""
    rng = np.random.default_rng(0)
    g_seq = [jnp.asarray(rng.standard_normal((64,)), jnp.float32) * 0.1
             for _ in range(50)]
    e = jnp.zeros((64,))
    applied = jnp.zeros((64,))
    for g in g_seq:
        q, scale, e = comp_lib.compress(g, e)
        applied += comp_lib.decompress(q, scale)
    true = sum(g_seq)
    # applied + residual error == true sum exactly
    np.testing.assert_allclose(applied + e, true, atol=1e-4)
    # and the residual is bounded by one quantization step
    assert float(jnp.linalg.norm(e)) < 0.1 * float(jnp.linalg.norm(true)) \
        + 1.0


def test_compression_wire_bytes():
    tree = {"w": jnp.ones((1000,)), "b": jnp.ones((10,))}
    full = comp_lib.wire_bytes(tree, compressed=False)
    comp = comp_lib.wire_bytes(tree, compressed=True)
    assert comp < 0.27 * full


@settings(max_examples=20, deadline=None)
@given(st.integers(4, 256))
def test_compress_roundtrip_bound(n):
    rng = np.random.default_rng(n)
    g = jnp.asarray(rng.standard_normal((n,)), jnp.float32)
    q, scale, err = comp_lib.compress(g, jnp.zeros((n,)))
    # reconstruction error bounded by half a quantization step
    assert float(jnp.max(jnp.abs(err))) <= float(scale) * 0.51

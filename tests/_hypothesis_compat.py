"""Degrade gracefully when `hypothesis` is not installed.

Property-based tests import `given`/`settings`/`st` from here instead of
from `hypothesis` directly.  With hypothesis present this is a pure
re-export; without it, `@given(...)` marks the test as skipped (so the
rest of the module's tests still collect and run, instead of the whole
module erroring at import time).
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - env dependent
    import pytest

    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Absorbs any strategy construction at module-import time."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _StrategyStub()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

"""MoE sort-based dispatch vs dense-masked oracle; capacity semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models import moe as moe_lib

from _hypothesis_compat import given, settings, st

rng = np.random.default_rng(11)


def _setup(b, s, d, f, e, k, cf):
    x = jnp.asarray(rng.standard_normal((b, s, d)) * 0.3, jnp.float32)
    router = jnp.asarray(rng.standard_normal((d, e)), jnp.float32)
    wi = jnp.asarray(rng.standard_normal((e, 2, d, f)) * 0.1, jnp.float32)
    wo = jnp.asarray(rng.standard_normal((e, f, d)) * 0.1, jnp.float32)
    return x, router, wi, wo, MoEConfig(e, k, capacity_factor=cf)


def test_dispatch_matches_oracle_dropfree():
    x, router, wi, wo, cfg = _setup(2, 16, 8, 16, 4, 2, cf=4.0)
    y1, a1 = moe_lib.moe_ffn(x, router, wi, wo, cfg, "swiglu")
    y2, a2 = moe_lib.moe_ffn_ref(x, router, wi, wo, cfg, "swiglu")
    np.testing.assert_allclose(y1, y2, atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(a1, a2, atol=1e-6)


def test_gelu_variant():
    b, s, d, f, e, k = 1, 8, 8, 16, 4, 2
    x = jnp.asarray(rng.standard_normal((b, s, d)) * 0.3, jnp.float32)
    router = jnp.asarray(rng.standard_normal((d, e)), jnp.float32)
    wi = jnp.asarray(rng.standard_normal((e, d, f)) * 0.1, jnp.float32)
    wo = jnp.asarray(rng.standard_normal((e, f, d)) * 0.1, jnp.float32)
    cfg = MoEConfig(e, k, capacity_factor=4.0)
    y1, _ = moe_lib.moe_ffn(x, router, wi, wo, cfg, "gelu")
    y2, _ = moe_lib.moe_ffn_ref(x, router, wi, wo, cfg, "gelu")
    np.testing.assert_allclose(y1, y2, atol=1e-5, rtol=1e-4)


def test_capacity_drops_reduce_output():
    """With tiny capacity some tokens get dropped (zero contribution) —
    output must differ from drop-free but stay finite."""
    x, router, wi, wo, _ = _setup(1, 32, 8, 16, 4, 2, cf=1.0)
    tight = MoEConfig(4, 2, capacity_factor=0.25)
    loose = MoEConfig(4, 2, capacity_factor=8.0)
    y_tight, _ = moe_lib.moe_ffn(x, router, wi, wo, tight, "swiglu")
    y_loose, _ = moe_lib.moe_ffn(x, router, wi, wo, loose, "swiglu")
    assert bool(jnp.all(jnp.isfinite(y_tight)))
    assert float(jnp.max(jnp.abs(y_tight - y_loose))) > 1e-6


def test_grad_flows_through_dispatch():
    x, router, wi, wo, cfg = _setup(1, 8, 8, 16, 4, 2, cf=4.0)

    def loss(wi_):
        y, aux = moe_lib.moe_ffn(x, router, wi_, wo, cfg, "swiglu")
        return jnp.sum(y ** 2) + 0.01 * aux
    g = jax.grad(loss)(wi)
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.sum(jnp.abs(g))) > 0


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 2), st.integers(4, 24), st.integers(2, 6),
       st.integers(1, 3))
def test_property_dispatch_equals_oracle(b, s, e, k):
    k = min(k, e)
    x, router, wi, wo, _ = _setup(b, s, 8, 8, e, k, cf=1.0)
    cfg = MoEConfig(e, k, capacity_factor=float(e))   # drop-free
    y1, _ = moe_lib.moe_ffn(x, router, wi, wo, cfg, "swiglu")
    y2, _ = moe_lib.moe_ffn_ref(x, router, wi, wo, cfg, "swiglu")
    np.testing.assert_allclose(y1, y2, atol=2e-5, rtol=2e-4)


def test_aux_loss_balanced_router_is_low():
    """Uniform router => aux loss ~= 1.0 (its minimum for top-1 term)."""
    b, s, d, e = 2, 64, 8, 4
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    router = jnp.zeros((d, e), jnp.float32)    # uniform probs
    _, _, aux = moe_lib.router_topk(x, router, MoEConfig(e, 2))
    assert 0.9 < float(aux) < 1.1

"""VRAM-aware placement: unit + hypothesis property tests of the paper's
core invariants."""
from repro.configs import ZOO
from repro.core.placement import (ModelDemand, place, place_naive,
                                  plan_utilization, reallocation_plan)

from _hypothesis_compat import given, settings, st

GB = 1024 ** 3


def _demand(name="llama3.2-1b", replicas=1, **kw):
    return ModelDemand(ZOO[name], min_replicas=replicas, **kw)


def _nodes(*sizes_gb, legacy=False):
    return {f"n{i}": (int(s * GB), legacy)
            for i, s in enumerate(sizes_gb)}


def test_respects_capacity():
    nodes = _nodes(8, 8)
    plan = place(nodes, [_demand("deepseek-r1-7b", 2)])
    used = {}
    for a in plan.assignments:
        used[a.node_id] = used.get(a.node_id, 0) + a.bytes
    for nid, b in used.items():
        assert b <= nodes[nid][0]


def test_quantization_fallback_on_small_nodes():
    # 7B bf16 ~ 15.5GB doesn't fit an 8GB node; int8 (~7.7GB) does
    plan = place(_nodes(8), [_demand("deepseek-r1-7b", 1)], fill=False)
    assert len(plan.assignments) == 1
    assert plan.assignments[0].quantize in ("int8", "int4")


def test_no_quant_when_disallowed():
    plan = place(_nodes(8), [ModelDemand(ZOO["deepseek-r1-7b"],
                                         min_replicas=1,
                                         allow_quant=False)], fill=False)
    assert plan.assignments == []
    assert plan.unplaced == ["deepseek-r1-7b"]


def test_replica_anti_affinity():
    plan = place(_nodes(16, 16, 16), [_demand("llama3.2-1b", 3)],
                 fill=False)
    nodes_used = {a.node_id for a in plan.assignments}
    assert len(nodes_used) == 3


def test_fill_respects_cap():
    d = ModelDemand(ZOO["llama3.2-1b"], min_replicas=1, max_replicas=2)
    plan = place(_nodes(64, 64), [d], fill=True)
    assert len(plan.replicas("llama3.2-1b")) == 2


def test_beats_naive_utilization():
    """The paper's claim: VRAM-aware placement uses the fleet better than
    naive first-fit (which can't quantize or reorder)."""
    nodes = _nodes(6, 8, 8, 16)
    demands = [_demand("llama3.2-1b", 2), _demand("deepseek-r1-7b", 2),
               _demand("qwen3-8b", 1), _demand("gemma3-1b", 2)]
    smart = place(nodes, demands)
    naive = place_naive(nodes, demands)
    assert len(smart.unplaced) <= len(naive.unplaced)
    assert plan_utilization(smart, nodes) >= plan_utilization(naive, nodes)


def test_reallocation_after_failure():
    nodes = _nodes(16, 16, 16)
    demands = [_demand("llama3.2-1b", 2)]
    plan = place(nodes, demands, fill=False)
    dead = plan.assignments[0].node_id
    survivors = {k: v for k, v in nodes.items() if k != dead}
    re = reallocation_plan(survivors, [_demand("llama3.2-1b", 1)])
    assert len(re.assignments) == 1
    assert re.assignments[0].node_id != dead


# ---------------------------- properties --------------------------- #
MODELS = ["llama3.2-1b", "gemma3-1b", "qwen3-1.7b", "deepseek-r1-7b",
          "nomic-embed-text"]


@settings(max_examples=40, deadline=None)
@given(
    sizes=st.lists(st.floats(4, 48), min_size=1, max_size=8),
    picks=st.lists(st.sampled_from(MODELS), min_size=1, max_size=4,
                   unique=True),
    replicas=st.integers(1, 3),
)
def test_placement_never_overcommits(sizes, picks, replicas):
    nodes = _nodes(*sizes)
    demands = [_demand(m, replicas) for m in picks]
    plan = place(nodes, demands)
    used = {}
    for a in plan.assignments:
        used[a.node_id] = used.get(a.node_id, 0) + a.bytes
    for nid, b in used.items():
        assert b <= nodes[nid][0], "placement exceeded node VRAM"
    # every model either fully placed (>= min replicas) or in unplaced
    for d in demands:
        got = len(plan.replicas(d.cfg.name))
        assert got >= d.min_replicas or d.cfg.name in plan.unplaced


@settings(max_examples=30, deadline=None)
@given(
    sizes=st.lists(st.floats(6, 32), min_size=2, max_size=6),
    kill=st.integers(0, 5),
)
def test_reallocation_never_targets_dead_node(sizes, kill):
    nodes = _nodes(*sizes)
    dead = f"n{kill % len(sizes)}"
    survivors = {k: v for k, v in nodes.items() if k != dead}
    re = reallocation_plan(survivors, [_demand("gemma3-1b", 1)])
    for a in re.assignments:
        assert a.node_id != dead

import jax
import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device;
# only launch/dryrun.py forces the 512-device placeholder fleet.

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session", autouse=True)
def lock_order_tracker():
    """Runtime cross-check of the static lock hierarchy: every
    BackendNode/Instance/Scheduler built during the suite gets tracked
    locks, and teardown asserts no acquisition ever violated the
    canonical node -> instance -> scheduler order (see repro.analysis)."""
    from repro.analysis import LockOrderTracker, install, uninstall
    tracker = LockOrderTracker()
    handle = install(tracker)
    yield tracker
    uninstall(handle)
    assert tracker.violations == [], \
        "lock-order violations observed at runtime:\n" + \
        "\n".join(v.render() for v in tracker.violations)
    assert tracker.disallowed_edges() == set(), \
        f"acquisition edges outside the static hierarchy: " \
        f"{sorted(tracker.disallowed_edges())}"


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def tiny_params_store():
    """Session-cached real params for reduced zoo models."""
    from repro.models import build
    cache = {}

    def store(cfg):
        if cfg.name not in cache:
            cache[cfg.name] = build(cfg).init(jax.random.PRNGKey(0))
        return cache[cfg.name]
    return store


@pytest.fixture(scope="session")
def param_store():
    return tiny_params_store()

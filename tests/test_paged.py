"""Paged KV cache + two-level tenant-fair scheduling: paged-vs-contiguous
greedy parity, page-exhaustion preemption/requeue round-trips, weighted
fair shares under contention, queued-cancel refunds, page accounting in
the admin snapshot, and the sharded node executor."""
import jax
import pytest

from repro.api import Gateway, TenantQuota
from repro.cluster import BackendNode, Fleet
from repro.configs import ARCHS
from repro.core import (ModelCatalog, ModelDemand, ReplicaInfo, ReplicaKey,
                        SDAIController)
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           RequestState, SamplingParams, Scheduler,
                           SchedulerConfig)
from repro.serving.kv_cache import PagedKVPool, gather_pages, scatter_pages


@pytest.fixture(scope="module")
def cfg():
    return ARCHS["olmo-1b"].reduced()


@pytest.fixture(scope="module")
def params(cfg, param_store):
    return param_store(cfg)


def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_len", 48)
    return InferenceEngine(cfg, params, EngineConfig(**kw))


def _run(eng, reqs, max_steps=10_000):
    for r in reqs:
        assert eng.submit(r)
    eng.run_until_done(max_steps)
    return [tuple(r.output) for r in reqs]


def _work(n=5, max_tokens=10):
    return [Request(model="m", prompt=list(range(1, 3 + i)),
                    sampling=SamplingParams(max_tokens=max_tokens))
            for i in range(n)]


# ------------------- allocator unit behaviour ---------------------- #
def test_paged_pool_alloc_grow_release_accounting():
    pool = PagedKVPool(n_slots=4, max_len=32, page_size=8, n_pages=10)
    assert pool.pages_per_slot == 4
    s0 = pool.alloc(100, 5)              # 1 page
    s1 = pool.alloc(101, 17)             # 3 pages
    assert s0 is not None and s1 is not None and s0 != s1
    assert pool.pages_in_use == 4
    assert pool.page_occupancy() == pytest.approx(0.4)
    # 22 live tokens over 4 allocated pages of 8
    assert pool.fragmentation() == pytest.approx(1 - 22 / 32)
    assert pool.grow(s0, 9)              # 5 -> 2 pages
    assert pool.pages_in_use == 5
    # exhaustion: growing s1 to need 5 more pages than exist must fail
    # atomically (free list unchanged)
    free_before = len(pool.free_pages)
    s2 = pool.alloc(102, 32)             # 4 pages -> 9 in use, 1 free
    assert s2 is not None
    assert not pool.grow(s0, 32)         # needs 2, only 1 free
    assert len(pool.free_pages) == 1 == free_before - 4
    pool.release(s1)
    assert pool.grow(s0, 32)
    # page table rows of released slots are all-sentinel
    table = pool.page_table()
    row = table[s1].tolist() if hasattr(table[s1], "tolist") else []
    assert all(p == pool.n_pages for p in row)


def test_paged_pool_rejects_undersized_budget():
    with pytest.raises(ValueError):
        PagedKVPool(n_slots=2, max_len=64, page_size=8, n_pages=7)


def test_gather_scatter_roundtrip():
    """A logical view gathered through the page table and scattered back
    leaves the physical pool byte-identical (and sentinel pages drop)."""
    import jax.numpy as jnp
    pool = PagedKVPool(n_slots=2, max_len=16, page_size=4)
    s = pool.alloc(1, 9)                  # 3 pages out of 8
    paged = {"k": jax.random.normal(jax.random.PRNGKey(0),
                                    (2, pool.n_pages, 4, 1, 3))}
    table = pool.page_table()
    view = gather_pages(paged, table)
    assert view["k"].shape == (2, 2, 16, 1, 3)
    back = scatter_pages(paged, view, table)
    assert jnp.array_equal(back["k"], paged["k"])
    # mutate the slot's view; only its allocated pages change
    view2 = {"k": view["k"].at[:, s, :9].add(1.0)}
    out = scatter_pages(paged, view2, table)["k"]
    touched = sorted(pool.slot_pages[s])
    for p in range(pool.n_pages):
        if p in touched:
            continue
        assert jnp.array_equal(out[:, p], paged["k"][:, p])


# ------------------- paged vs contiguous parity -------------------- #
@pytest.mark.parametrize("k", [1, 4, 8])
def test_paged_matches_contiguous_greedy_parity(cfg, params, k):
    """Greedy decode through the page table must be token-for-token
    identical to the contiguous per-slot layout at every fused-block
    size: paging is a memory-layout choice, never a numerics choice."""
    contiguous = _run(_engine(cfg, params, decode_block=k, paged=False),
                      _work())
    paged = _run(_engine(cfg, params, decode_block=k, page_size=8),
                 _work())
    assert paged == contiguous
    # and a deliberately page-misaligned pool (view longer than max_len)
    odd = _run(_engine(cfg, params, decode_block=k, page_size=16,
                       max_len=40), _work())
    ref = _run(_engine(cfg, params, decode_block=k, paged=False,
                       max_len=40), _work())
    assert odd == ref


def test_paged_dispatch_discipline_unchanged(cfg, params):
    """Page-table gather/scatter lives inside the two jitted calls: the
    paged engine issues exactly as many dispatches and host syncs as the
    contiguous one on the same workload."""
    stats = {}
    for paged in (False, True):
        eng = _engine(cfg, params, decode_block=4, paged=paged)
        _run(eng, _work(6, max_tokens=12))
        stats[paged] = eng.perf_stats()
    for metric in ("tokens", "dispatches", "host_syncs"):
        assert stats[True][metric] == stats[False][metric], metric


# ------------------- preemption / requeue round-trip ---------------- #
def test_page_exhaustion_preempts_requeues_and_resumes(cfg, params):
    """Oversubscribed slots: 6 slots against a ~3-sequence page budget.
    The engine must preempt on page exhaustion (evict, refund pages,
    requeue) and the evicted requests must *resume* — every output
    token-for-token identical to an uncontended run, nothing dropped,
    no token emitted twice."""
    ref = _run(_engine(cfg, params, n_slots=6, page_size=8,
                       decode_block=4), _work(6, max_tokens=20))
    eng = _engine(cfg, params, n_slots=6, page_size=8, kv_pages=18,
                  decode_block=4)
    reqs = _work(6, max_tokens=20)
    out = _run(eng, reqs)
    assert out == ref
    assert all(len(o) == 20 for o in out)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert eng.preemptions >= 1
    assert eng.scheduler.requeued_total == eng.preemptions
    assert eng.pool.grow_failures >= 1
    # pool fully drained afterwards: no leaked pages or slots
    assert eng.pool.pages_in_use == 0 and eng.pool.n_active == 0


def test_preemption_victim_is_lowest_deficit_tenant(cfg, params):
    """With one over-served and one under-served tenant in slots, page
    exhaustion evicts the over-served (lowest-deficit) tenant's slot."""
    eng = _engine(cfg, params, n_slots=2, max_len=48, page_size=8,
                  kv_pages=7, decode_block=4)
    rich = Request(model="m", prompt=[1, 2], tenant="rich",
                   sampling=SamplingParams(max_tokens=30))
    poor = Request(model="m", prompt=[3, 4], tenant="poor",
                   sampling=SamplingParams(max_tokens=30))
    assert eng.submit(rich) and eng.submit(poor)
    # skew the fairness clocks: "rich" has consumed far more service
    eng.scheduler._vtime["rich"] = 100.0
    eng.scheduler._vtime["poor"] = 1.0
    while not eng.preemptions and (eng.slot_req or eng.scheduler.depth):
        eng.step()
    assert eng.preemptions >= 1
    # the over-served tenant was evicted and requeued, not the other
    assert eng.scheduler.tenant_backlog().get("rich", 0) >= 1 \
        or rich.state == RequestState.QUEUED
    eng.run_until_done()
    assert len(rich.output) == 30 and len(poor.output) == 30


# ------------------- weighted fair shares --------------------------- #
def test_three_tenant_weighted_shares_within_20pct(cfg, params):
    """Mixed-length 3-tenant soak under sustained contention: while every
    tenant stays backlogged, served-token shares track the configured
    DWRR weights within 20%."""
    weights = {"a": 1.0, "b": 2.0, "c": 3.0}
    eng = _engine(cfg, params, n_slots=3, page_size=8, decode_block=4)
    eng.scheduler.weight_of = lambda t: weights.get(t, 1.0)
    plens = {"a": 3, "b": 9, "c": 5}          # mixed prompt lengths
    budgets = {"a": 8, "b": 6, "c": 10}       # mixed generation budgets
    reqs = []
    for t in weights:
        for _ in range(40):
            r = Request(model="m", prompt=list(range(1, 1 + plens[t])),
                        tenant=t,
                        sampling=SamplingParams(max_tokens=budgets[t]))
            reqs.append(r)
            assert eng.submit(r)
    for _ in range(45):
        eng.step()
    backlog = eng.scheduler.tenant_backlog()
    assert all(backlog.get(t, 0) > 0 for t in weights), \
        "window outlived the contention the test needs"
    served = {t: 0 for t in weights}
    for r in reqs:
        served[r.tenant] += len(r.output)
    total = sum(served.values())
    wtotal = sum(weights.values())
    for t, w in weights.items():
        share, target = served[t] / total, w / wtotal
        assert abs(share - target) / target <= 0.20, (t, served)


def test_single_tenant_keeps_fcfs_and_bucket_grouping():
    """With one tenant the two-level scheduler degenerates to the old
    behaviour: FCFS head plus same-bucket lookahead, order preserved."""
    sched = Scheduler(SchedulerConfig(max_prefill_per_step=3))
    lens = [3, 20, 5, 6, 18]               # buckets: 8, 32, 8, 8, 32
    reqs = [Request(model="m", prompt=list(range(n))) for n in lens]
    for r in reqs:
        sched.submit(r)

    def bucket_of(n):
        b = 8
        while b < n:
            b <<= 1
        return b
    group = sched.next_prefill_bucket(4, bucket_of)
    assert [len(r.prompt) for r in group] == [3, 5, 6]
    group = sched.next_prefill_bucket(4, bucket_of)
    assert [len(r.prompt) for r in group] == [20, 18]
    assert sched.depth == 0


def test_late_joiner_cannot_starve_incumbent():
    """A tenant joining after an incumbent has accrued a large virtual
    clock starts at the *system* virtual time, not zero — admissions
    interleave immediately instead of the newcomer monopolizing the
    engine until its clock catches up."""
    sched = Scheduler(SchedulerConfig(max_prefill_per_step=1))

    def submit(tenant, n=1):
        for _ in range(n):
            sched.submit(Request(model="m", prompt=[1], tenant=tenant,
                                 sampling=SamplingParams(max_tokens=8)))
    # incumbent b serves alone for a while: clock runs far ahead
    submit("b", 10)
    for _ in range(10):
        assert sched.next_prefill_bucket(1, lambda n: 8)
    # newcomer a joins while b momentarily has an empty queue
    submit("a", 6)
    submit("b", 6)
    order = [sched.next_prefill_bucket(1, lambda n: 8)[0].tenant
             for _ in range(12)]
    # equal weights => near-alternation; the newcomer must not win more
    # than one extra round in any prefix
    for i in range(1, 13):
        a_wins = order[:i].count("a")
        assert a_wins <= i // 2 + 1, order


def test_preempted_resume_charges_wfq_exactly_once():
    """A preempted-then-resumed request must advance its tenant's
    virtual clock by its served tokens exactly once: the first
    admission bills the full projected budget, so re-admission after
    partial service bills ~nothing — the clock tracks tokens actually
    served instead of drifting ahead by the remaining budget at every
    preemption cycle."""
    sched = Scheduler(SchedulerConfig(max_prefill_per_step=1))
    req = Request(model="m", prompt=[1, 2], tenant="t",
                  sampling=SamplingParams(max_tokens=10))
    sched.submit(req)
    assert sched.next_prefill_bucket(1, lambda n: 8) == [req]
    v1 = sched._vtime["t"]
    assert v1 == pytest.approx(10.0)       # full budget billed up front
    req.output.extend([5] * 4)             # 4 tokens served, preempted
    sched.requeue(req)
    assert sched.next_prefill_bucket(1, lambda n: 8) == [req]
    assert sched._vtime["t"] == pytest.approx(v1)    # no re-billing
    # a second cycle after more service still adds nothing
    req.output.extend([5] * 3)
    sched.requeue(req)
    sched.next_prefill_bucket(1, lambda n: 8)
    assert sched._vtime["t"] == pytest.approx(v1)
    # failover/migration moves the request to a fresh replica whose WFQ
    # clock never saw it: the charge floors at the tokens already served,
    # so the new replica bills only the remaining budget — exactly-once
    # across the cluster, and zero served still starts the charge over
    req.reset_for_retry()
    assert req.wfq_charged == float(len(req.output)) == 7.0
    fresh = Request(model="m", prompt=[1], tenant="t",
                    sampling=SamplingParams(max_tokens=10))
    fresh.reset_for_retry()
    assert fresh.wfq_charged == 0.0


def test_page_budget_gates_admission():
    """The scheduler admits nothing when no backlogged head fits the
    free-page budget, and respects the budget across a lookahead."""
    sched = Scheduler(SchedulerConfig(max_prefill_per_step=4))
    sched.pages_for = lambda r: len(r.prompt)      # 1 page per token
    reqs = [Request(model="m", prompt=[1] * 4) for _ in range(3)]
    for r in reqs:
        sched.submit(r)
    assert sched.next_prefill_bucket(4, lambda n: 8, free_pages=3) == []
    group = sched.next_prefill_bucket(4, lambda n: 8, free_pages=9)
    assert len(group) == 2                         # 4 + 4 <= 9, not 12
    assert sched.depth == 1


# ------------------- cancel refunds --------------------------------- #
def test_scheduler_cancel_drops_pending_pages():
    sched = Scheduler()
    sched.pages_for = lambda r: 5
    a, b = Request(model="m", prompt=[1]), Request(model="m", prompt=[2])
    sched.submit(a), sched.submit(b)
    assert sched.pending_pages == 10
    assert sched.cancel(a.request_id)
    assert sched.pending_pages == 5
    assert not sched.cancel(a.request_id)          # idempotent
    assert sched.pending_pages == 5


def _gateway_stack(param_store, cfg, n_slots=1, max_len=48):
    fleet = Fleet([BackendNode("n0", "v5e-1", param_store=param_store)])
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.discover()
    inst = fleet.nodes["n0"].deploy(cfg, n_slots=n_slots, max_len=max_len)
    ctrl.replicas.add(ReplicaInfo(ReplicaKey("n0", inst.instance_id),
                                  cfg.name, "", n_slots, max_len,
                                  inst.bytes))
    return ctrl, inst, Gateway(ctrl)


def test_cancel_queued_request_refunds_token_bucket(cfg, param_store):
    """Buckets are charged max_tokens at submit; cancelling a request
    that never left the engine queue must give the charge back — a
    third request the un-refunded bucket could not afford is admitted."""
    ctrl, inst, gw = _gateway_stack(param_store, cfg, n_slots=1)
    # two requests' worth of tokens, effectively no refill
    gw.admin.set_tenant_quota("t", TenantQuota(
        tokens_per_s=0.001, burst_tokens=20.0))
    sp = SamplingParams(max_tokens=10)
    h1 = gw.submit(cfg.name, [1, 2], sp, tenant="t")   # occupies the slot
    gw._pump()                                         # admitted: decoding
    h2 = gw.submit(cfg.name, [3, 4], sp, tenant="t")   # queued behind it
    assert not h2.done
    assert h2.internal.state == RequestState.QUEUED
    assert h2.cancel()
    usage = ctrl.frontend.tenants.usage["t"]
    assert usage.refunds == 1
    assert usage.tokens_charged == 10                  # h1's charge only
    # the refunded tokens admit a third request; without the refund this
    # would be RATE_LIMITED
    h3 = gw.submit(cfg.name, [5, 6], sp, tenant="t")
    assert h3.response is None or h3.response.error is None
    assert h1.result(timeout_s=60).ok and h3.result(timeout_s=60).ok


def test_cancel_decoding_request_does_not_refund(cfg, param_store):
    """Only never-admitted requests refund: an in-flight request already
    consumed slot time, so its charge stands."""
    ctrl, inst, gw = _gateway_stack(param_store, cfg, n_slots=1)
    gw.admin.set_tenant_quota("t", TenantQuota(
        tokens_per_s=0.001, burst_tokens=20.0))
    h1 = gw.submit(cfg.name, [1, 2], SamplingParams(max_tokens=10),
                   tenant="t")
    gw._pump()
    assert h1.cancel()
    usage = ctrl.frontend.tenants.usage["t"]
    assert usage.refunds == 0
    assert usage.tokens_charged == 10


# ------------------- page accounting upward ------------------------- #
def test_admin_snapshot_exposes_page_occupancy(cfg, param_store):
    ctrl, inst, gw = _gateway_stack(param_store, cfg, n_slots=2)
    h = gw.submit(cfg.name, [1, 2, 3], SamplingParams(max_tokens=30))
    gw._pump()                      # admitted: pages held mid-flight
    snap = gw.admin.snapshot()
    isnap = snap.nodes[0].instances[0]
    assert isnap.kv_pages == inst.engine.pool.n_pages > 0
    assert isnap.pages_in_use > 0
    assert 0.0 < isnap.page_occupancy <= 1.0
    assert 0.0 <= isnap.page_fragmentation < 1.0
    d = snap.to_dict()
    wire = d["agents"]["n0"]["instances"][0]
    assert wire["pages_in_use"] == isnap.pages_in_use
    assert wire["page_occupancy"] == isnap.page_occupancy
    assert h.result(timeout_s=60).ok
    # drained: occupancy returns to zero in a fresh snapshot
    assert gw.admin.snapshot().nodes[0].instances[0].pages_in_use == 0


def test_placement_charges_page_budget_not_worst_case(cfg):
    """A kv_page_frac < 1 demand is strictly cheaper per replica than
    the contiguous-equivalent, and the page budget floors at one full
    sequence."""
    full = ModelDemand(cfg, n_slots=8, max_len=64, page_size=8)
    packed = ModelDemand(cfg, n_slots=8, max_len=64, page_size=8,
                         kv_page_frac=0.5)
    assert packed.kv_pages == full.kv_pages // 2
    assert packed.bytes_at("") < full.bytes_at("")
    tiny = ModelDemand(cfg, n_slots=4, max_len=64, page_size=8,
                       kv_page_frac=0.01)
    assert tiny.kv_pages == -(-64 // 8)          # one full sequence


def test_engine_weights_flow_from_tenant_quotas(cfg, param_store):
    """set_tenant_quota(weight=...) reaches every deployed engine's
    scheduler without a broadcast."""
    fleet = Fleet([BackendNode("n0", "v5e-1", param_store=param_store)])
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.discover()
    plan = ctrl.deploy([ModelDemand(cfg, min_replicas=1, max_replicas=1,
                                    n_slots=2, max_len=48)])
    assert not plan.unplaced
    gw = Gateway(ctrl)
    gw.admin.set_tenant_quota("vip", TenantQuota(weight=4.0))
    inst = next(iter(fleet.nodes["n0"].instances.values()))
    assert inst.engine.scheduler.weight_of("vip") == 4.0
    assert inst.engine.scheduler.weight_of("anon") == 1.0
    snap = gw.admin.snapshot()
    vip = next(t for t in snap.tenants if t.tenant == "vip")
    assert vip.weight == 4.0


# ------------------- sharded node executor -------------------------- #
def test_multi_instance_node_pumps_through_executor(cfg, param_store):
    """A node hosting two engines steps them via its per-node thread
    pool; both make progress and the pool is created lazily."""
    node = BackendNode("n0", "v5e-1", param_store=param_store)
    i1 = node.deploy(cfg, n_slots=2, max_len=48)
    i2 = node.deploy(cfg, n_slots=2, max_len=48)
    assert node._executor is None
    reqs = []
    for inst in (i1, i2):
        for j in range(2):
            r = Request(model=cfg.name, prompt=[1, 2 + j],
                        sampling=SamplingParams(max_tokens=6))
            reqs.append(r)
            assert node.submit(inst.instance_id, r)
    for _ in range(40):
        if not node.has_work():
            break
        node.pump()
    assert node._executor is not None          # sharded path exercised
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert all(len(r.output) == 6 for r in reqs)
    # single-instance nodes never pay for a pool
    solo = BackendNode("n1", "v5e-1", param_store=param_store)
    s1 = solo.deploy(cfg, n_slots=2, max_len=48)
    r = Request(model=cfg.name, prompt=[1, 2],
                sampling=SamplingParams(max_tokens=4))
    assert solo.submit(s1.instance_id, r)
    while solo.has_work():
        solo.pump()
    assert solo._executor is None
    assert len(r.output) == 4


# ------------------- crash-timing matrix ---------------------------- #
def _two_node_stack(param_store, cfg, n_slots=2, max_len=48):
    """Cross-node replicas so a victim always has a survivor."""
    fleet = Fleet([BackendNode(f"n{i}", "v5e-1", param_store=param_store)
                   for i in range(2)])
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.discover()
    for node in fleet.nodes.values():
        inst = node.deploy(cfg, n_slots=n_slots, max_len=max_len)
        ctrl.replicas.add(ReplicaInfo(
            ReplicaKey(node.node_id, inst.instance_id),
            cfg.name, "", n_slots, max_len, inst.bytes))
    return fleet, ctrl, Gateway(ctrl)


def _survivor_engines(fleet):
    return [inst.engine for node in fleet.nodes.values() if node.alive
            for inst in node.instances.values()
            if inst.engine is not None]


def test_node_death_during_prefill_reroutes_without_leak(cfg,
                                                         param_store):
    """The victim dies while the request is still queued for prefill:
    the pre-token re-route lands it on the survivor, which bills the
    full budget exactly once and drains to zero pages."""
    fleet, ctrl, gw = _two_node_stack(param_store, cfg)
    n = 8
    h = gw.submit(cfg.name, [1, 2, 3], SamplingParams(max_tokens=n),
                  tenant="matrix")
    victim = h.internal.node
    assert not h.internal.output            # no token out yet
    fleet.fail_node(victim)                 # dies before first token
    resp = h.result(timeout_s=120)
    assert resp.ok, resp.error
    assert resp.node != victim and resp.retries >= 1
    assert len(resp.tokens) == n
    assert gw.stats.stream_retries >= 1 and gw.stats.migrations == 0
    # exactly-once billing: the full budget, charged by the survivor
    assert h.internal.wfq_charged == float(n)
    for eng in _survivor_engines(fleet):
        assert eng.pool.pages_in_use == 0 and eng.pool.n_active == 0


def test_node_death_mid_decode_block_migrates_cleanly(cfg, param_store):
    """The victim dies with tokens already emitted: the journal resumes
    on the survivor token-identically, the survivor's WFQ clock advances
    only by the remaining budget (no double billing), and no pages
    leak."""
    fleet, ctrl, gw = _two_node_stack(param_store, cfg)
    n = 12
    ref = gw.generate(cfg.name, [5, 3, 1], SamplingParams(max_tokens=n),
                      timeout_s=120)
    assert ref.ok
    h = gw.submit(cfg.name, [5, 3, 1], SamplingParams(max_tokens=n),
                  tenant="matrix")
    victim = h.internal.node
    guard = 0
    while not h.internal.output:            # run into mid-decode
        gw._pump()
        guard += 1
        assert guard < 200
    fleet.fail_node(victim)
    resp = h.result(timeout_s=120)
    assert resp.ok, resp.error
    assert resp.node != victim
    assert list(resp.tokens) == list(ref.tokens)
    assert gw.stats.migrations >= 1
    assert h.internal.wfq_charged == float(n)
    # the survivor billed only the remaining budget: its tenant clock
    # sits at budget - journal, not at the full budget again
    resumed = ctrl.bus.of_kind("request_migrated")[-1]
    survivor = fleet.nodes[resp.node]
    vtimes = [inst.engine.scheduler._vtime.get("matrix", 0.0)
              for inst in survivor.instances.values()
              if inst.engine is not None]
    assert max(vtimes) == pytest.approx(
        n - resumed.data["tokens_resumed"])
    for eng in _survivor_engines(fleet):
        assert eng.pool.pages_in_use == 0 and eng.pool.n_active == 0

"""Serving stack: engine continuous batching, slot pool invariants
(hypothesis), scheduler, sampler, quantization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           RequestState, SamplingParams, Scheduler,
                           SchedulerConfig)
from repro.serving import quantization as q_lib
from repro.serving.kv_cache import SlotPool
from repro.serving.sampler import sample

from _hypothesis_compat import given, settings, st


@pytest.fixture(scope="module")
def tiny_engine():
    cfg = ARCHS["olmo-1b"].reduced()
    params = build(cfg).init(jax.random.PRNGKey(0))
    return InferenceEngine(cfg, params, EngineConfig(n_slots=3,
                                                     max_len=48))


def test_continuous_batching_completes(tiny_engine):
    reqs = [Request(model="m", prompt=[1, 2, 3 + i],
                    sampling=SamplingParams(max_tokens=5))
            for i in range(7)]
    for r in reqs:
        assert tiny_engine.submit(r)
    tiny_engine.run_until_done()
    for r in reqs:
        assert r.state == RequestState.FINISHED
        assert len(r.output) == 5
        assert r.ttft is not None


def test_greedy_deterministic(tiny_engine):
    outs = []
    for _ in range(2):
        r = Request(model="m", prompt=[9, 8, 7],
                    sampling=SamplingParams(max_tokens=6))
        tiny_engine.submit(r)
        tiny_engine.run_until_done()
        outs.append(tuple(r.output))
    assert outs[0] == outs[1]


def test_engine_failure_fails_requests(param_store):
    cfg = ARCHS["olmo-1b"].reduced()
    eng = InferenceEngine(cfg, param_store(cfg),
                          EngineConfig(n_slots=2, max_len=32))
    r = Request(model="m", prompt=[1, 2],
                sampling=SamplingParams(max_tokens=50))
    eng.submit(r)
    eng.step()
    eng.fail()
    assert r.state == RequestState.FAILED
    assert not eng.alive
    r2 = Request(model="m", prompt=[1])
    assert not eng.submit(r2)


def test_quantized_engine_matches_memory_claim(param_store):
    cfg = ARCHS["olmo-1b"].reduced()
    e16 = InferenceEngine(cfg, param_store(cfg),
                          EngineConfig(n_slots=2, max_len=32))
    e8 = InferenceEngine(cfg, param_store(cfg),
                         EngineConfig(n_slots=2, max_len=32,
                                      quantize="int8"))
    b16 = e16.memory_report()["param_bytes"]
    b8 = e8.memory_report()["param_bytes"]
    assert b8 < 0.65 * b16
    r = Request(model="m", prompt=[3, 1, 4],
                sampling=SamplingParams(max_tokens=4))
    e8.submit(r)
    e8.run_until_done()
    assert r.state == RequestState.FINISHED


def test_scheduler_queue_bound():
    s = Scheduler(SchedulerConfig(max_queue=2))
    reqs = [Request(model="m", prompt=[1]) for _ in range(4)]
    oks = [s.submit(r) for r in reqs]
    assert oks == [True, True, False, False]
    assert s.rejected == 2
    assert reqs[2].state == RequestState.FAILED


# ------------------------- slot pool properties -------------------- #
@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "release"]),
                          st.integers(0, 7)), max_size=40))
def test_slot_pool_invariants(ops):
    pool = SlotPool(n_slots=4, max_len=64)
    live = {}
    for op, arg in ops:
        if op == "alloc":
            slot = pool.alloc(request_id=arg, prompt_len=8)
            if slot is not None:
                assert slot not in live
                live[slot] = arg
            else:
                assert len(live) == 4
        else:
            if live:
                slot = sorted(live)[arg % len(live)]
                pool.release(slot)
                del live[slot]
    assert pool.n_active == len(live)
    assert 0.0 <= pool.utilization() <= 1.0


# ------------------------- quantization ---------------------------- #
@settings(max_examples=30, deadline=None)
@given(st.integers(2, 64), st.integers(2, 32),
       st.sampled_from([8, 4]))
def test_quantize_roundtrip_bounded(m, n, bits):
    rng = np.random.default_rng(m * 100 + n)
    w = jnp.asarray(rng.standard_normal((2 * m, n)), jnp.float32)
    q = q_lib.quantize_array(w, bits)
    w2 = q_lib.dequantize_array(q)
    amax = float(jnp.max(jnp.abs(w), axis=0).max())
    tol = amax / (127 if bits == 8 else 7) * 0.51
    assert float(jnp.max(jnp.abs(w - w2))) <= tol + 1e-6


def test_quantize_tree_skips_small_leaves():
    tree = {"w": jnp.ones((8, 8)), "scale": jnp.ones((8,)),
            "step": jnp.zeros((), jnp.int32)}
    qt = q_lib.quantize_tree(tree)
    assert q_lib.is_quantized_leaf(qt["w"])
    assert not q_lib.is_quantized_leaf(qt["scale"])
    back = q_lib.dequant_tree(qt)
    assert back["w"].shape == (8, 8)
    assert float(jnp.max(jnp.abs(back["w"] - 1.0))) < 0.02


def test_int4_pack_roundtrip():
    w = jnp.asarray(np.random.default_rng(0).standard_normal((16, 8)),
                    jnp.float32)
    q = q_lib.quantize_array(w, 4)
    assert q["__q__"].shape == (8, 8)           # packed
    w2 = q_lib.dequantize_array(q)
    assert w2.shape == (16, 8)


# ------------------------- sampler --------------------------------- #
def test_sampler_greedy_argmax():
    logits = jnp.asarray([[0.1, 5.0, 0.2], [3.0, 0.0, -1.0]])
    toks = sample(logits, jax.random.PRNGKey(0),
                  SamplingParams(temperature=0.0))
    assert toks.tolist() == [1, 0]


def test_sampler_topk_restricts():
    logits = jnp.asarray([[0.0, 1.0, 2.0, 3.0]])
    for seed in range(20):
        t = sample(logits, jax.random.PRNGKey(seed),
                   SamplingParams(temperature=1.0, top_k=2))
        assert int(t[0]) in (2, 3)
